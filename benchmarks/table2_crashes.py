"""Table II: percentage of crashed jobs under the memory-unsafe CG scheduler,
by worker count and mix ratio, on both systems.

Paper claim: erratic and increasing with workers — 0-22% on P100s and
0-50% on V100s; the 3/6-worker row is near zero, the 6/12 row is the worst.
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import workloads as W

MIXES = {"1:1": (1, 1), "2:1": (2, 1), "3:1": (3, 1), "5:1": (5, 1)}
# paper's worker rows: {P100 workers}/{V100 workers}
WORKER_ROWS = [(3, 6), (4, 8), (5, 10), (6, 12)]
N_JOBS = 32


def run() -> dict:
    out = {}
    for system, n_dev in C.SYSTEMS.items():
        col = 0 if system == "2xP100" else 1
        rows = {}
        for wp, wv in WORKER_ROWS:
            workers = (wp, wv)[col]
            row = {}
            for mix_name, ratio in MIXES.items():
                jobs = W.make_mix(123, N_JOBS, ratio)
                r = C.run_cg(jobs, n_dev, workers)
                row[mix_name] = 100.0 * r.crashed / N_JOBS
            rows[f"{workers}w"] = row
        out[system] = rows
        print(f"Table2 [{system}] CG crash % (rows=workers, cols=mix):")
        for wname, row in rows.items():
            print(f"  {wname:4s} " + "  ".join(
                f"{m}:{v:5.1f}%" for m, v in row.items()))
    # the paper's qualitative claims: monotone-ish growth with workers,
    # non-trivial crash rates at high worker counts
    for system in C.SYSTEMS:
        rows = list(out[system].values())
        first = sum(rows[0].values()) / 4
        last = sum(rows[-1].values()) / 4
        print(C.check(f"{system} crash% (min workers)", first, 0.0, 20.0))
        print(C.check(f"{system} crash% (max workers)", last, 10.0, 60.0))
    C.save_json("table2.json", out)
    return out


if __name__ == "__main__":
    run()
