"""Table III: MGB average turnaround-time speedup over SA, per mix and size.

Paper claim: 2.0x-4.9x speedups; averages 3.7x (2xP100) and 2.8x (4xV100).
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import workloads as W

MIXES = {"1:1": (1, 1), "2:1": (2, 1), "3:1": (3, 1), "5:1": (5, 1)}


def run() -> dict:
    out = {}
    for system, n_dev in C.SYSTEMS.items():
        workers = C.MGB_WORKERS[system]
        rows = {}
        for n_jobs in (16, 32):
            for mix_name, ratio in MIXES.items():
                jobs = W.make_mix(7, n_jobs, ratio)
                sa = C.run_sa(jobs, n_dev)
                mgb = C.run_mgb(jobs, n_dev, workers, alg=3)
                rows[f"{n_jobs}j_{mix_name}"] = \
                    sa.mean_turnaround / mgb.mean_turnaround
        avg = sum(rows.values()) / len(rows)
        out[system] = {"rows": rows, "avg_speedup": avg}
        print(f"Table3 [{system}] turnaround speedup: " + "  ".join(
            f"{k}:{v:.1f}x" for k, v in rows.items()))
        lo, hi = (1.8, 5.2), (1.6, 4.2)
        band = lo if system == "2xP100" else hi
        print(C.check(f"{system} avg turnaround speedup", avg,
                      band[0], band[1]))
    out["paper_claim"] = {"2xP100_avg": 3.7, "4xV100_avg": 2.8,
                          "max": 4.9}
    C.save_json("table3.json", out)
    return out


if __name__ == "__main__":
    run()
