"""Fig. 5: SA / CG / MGB throughput on both systems, normalized to SA.

Paper claims: MGB/SA 1.8-2.5x (avg 2.2x) on 2xP100, 1.4-2.5x (avg 2.0x) on
4xV100; MGB/CG +64% (P100) and +41% (V100) on average, with CG sometimes at
or below SA because of crashes.
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import workloads as W


def run() -> dict:
    out = {}
    for system, n_dev in C.SYSTEMS.items():
        workers = C.MGB_WORKERS[system]
        sweep = [n_dev * k for k in (1, 2, 3, 4, 5, 6)]
        rows = {}
        for wname in sorted(W.WORKLOADS):
            jobs = W.workload(wname)
            sa = C.run_sa(jobs, n_dev)
            mgb = C.run_mgb(jobs, n_dev, workers, alg=3)
            cg, cg_w = C.best_cg(jobs, n_dev, sweep)
            rows[wname] = {
                "sa": sa.throughput, "mgb": mgb.throughput,
                "cg": cg.throughput if cg else 0.0,
                "cg_workers": cg_w,
                "cg_crashed": cg.crashed if cg else -1,
                "mgb_over_sa": mgb.throughput / sa.throughput,
                "mgb_over_cg": (mgb.throughput / cg.throughput
                                if cg and cg.throughput else float("inf")),
            }
        avg_sa = sum(r["mgb_over_sa"] for r in rows.values()) / len(rows)
        avg_cg = sum(r["mgb_over_cg"] for r in rows.values()) / len(rows)
        out[system] = {"rows": rows, "avg_mgb_over_sa": avg_sa,
                       "avg_mgb_over_cg": avg_cg}
        print(f"Fig5 [{system}] MGB/SA per workload: " + "  ".join(
            f"{w}:{r['mgb_over_sa']:.2f}x" for w, r in rows.items()))
        lo, hi = (1.6, 2.7) if system == "2xP100" else (1.3, 2.7)
        print(C.check(f"{system} avg MGB/SA", avg_sa, lo, hi))
        print(C.check(f"{system} avg MGB/CG", avg_cg, 1.0, 2.2))
    out["paper_claim"] = {
        "2xP100_avg_mgb_over_sa": 2.2, "4xV100_avg_mgb_over_sa": 2.0,
        "2xP100_mgb_over_cg_pct": 64, "4xV100_mgb_over_cg_pct": 41}
    C.save_json("fig5.json", out)
    return out


if __name__ == "__main__":
    run()
