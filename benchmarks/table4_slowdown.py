"""Table IV: per-kernel slowdown vs single-assignment for Alg. 2 and Alg. 3
on the 8 workloads, 4xV100.

Paper claim: Alg. 2 averages 1.8%, Alg. 3 2.5% — both negligible, <1% apart.
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import workloads as W


def run() -> dict:
    n_dev = C.SYSTEMS["4xV100"]
    workers = C.MGB_WORKERS["4xV100"]
    rows = {}
    for wname in sorted(W.WORKLOADS):
        jobs = W.workload(wname)
        r2 = C.run_mgb(jobs, n_dev, workers, alg=2)
        r3 = C.run_mgb(jobs, n_dev, workers, alg=3)
        rows[wname] = {"alg2_pct": r2.mean_slowdown_pct,
                       "alg3_pct": r3.mean_slowdown_pct}
    avg2 = sum(r["alg2_pct"] for r in rows.values()) / len(rows)
    avg3 = sum(r["alg3_pct"] for r in rows.values()) / len(rows)
    out = {"rows": rows, "avg_alg2_pct": avg2, "avg_alg3_pct": avg3,
           "paper_claim": {"avg_alg2_pct": 1.8, "avg_alg3_pct": 2.5}}
    print("Table4 kernel slowdown % (Alg2 / Alg3):")
    for wname, r in rows.items():
        print(f"  {wname}: {r['alg2_pct']:5.2f}% / {r['alg3_pct']:5.2f}%")
    print(C.check("avg Alg2 slowdown %", avg2, 0.0, 3.0))
    print(C.check("avg Alg3 slowdown %", avg3, 0.0, 3.5))
    C.save_json("table4.json", out)
    return out


if __name__ == "__main__":
    run()
