"""Benchmark runner: one experiment per paper table/figure, printed summary,
JSON artifacts under benchmarks/results/.

    PYTHONPATH=src python -m benchmarks.run [--only fig5]
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (
    bench_executor, bench_gang, fig4_alg2_vs_alg3, fig5_throughput,
    fig6_nn_schedgpu, kernels_bench, table2_crashes, table3_turnaround,
    table4_slowdown,
)

EXPERIMENTS = {
    "fig4": fig4_alg2_vs_alg3.run,
    "fig5": fig5_throughput.run,
    "table2": table2_crashes.run,
    "table3": table3_turnaround.run,
    "table4": table4_slowdown.run,
    "fig6": fig6_nn_schedgpu.run,
    "kernels": kernels_bench.run,
    "executor": bench_executor.run,
    "gang": bench_gang.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=sorted(EXPERIMENTS))
    args = ap.parse_args()
    names = [args.only] if args.only else list(EXPERIMENTS)
    t0 = time.time()
    for name in names:
        print(f"\n=== {name} " + "=" * (70 - len(name)))
        EXPERIMENTS[name]()
    print(f"\nall benchmarks done in {time.time() - t0:.0f}s; "
          f"artifacts in benchmarks/results/")


if __name__ == "__main__":
    main()
