"""Benchmark runner: one experiment per paper table/figure, printed summary,
JSON artifacts under benchmarks/results/.

    PYTHONPATH=src python -m benchmarks.run [--only fig5]
    PYTHONPATH=src python -m benchmarks.run --only executor,gang,preempt --smoke
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (
    bench_executor, bench_gang, bench_obs, bench_preempt,
    bench_sched_scale, bench_serve, bench_whatif, fig4_alg2_vs_alg3,
    fig5_throughput, fig6_nn_schedgpu, kernels_bench, table2_crashes,
    table3_turnaround, table4_slowdown,
)

EXPERIMENTS = {
    "fig4": fig4_alg2_vs_alg3.run,
    "fig5": fig5_throughput.run,
    "table2": table2_crashes.run,
    "table3": table3_turnaround.run,
    "table4": table4_slowdown.run,
    "fig6": fig6_nn_schedgpu.run,
    "kernels": kernels_bench.run,
    "executor": bench_executor.run,
    "gang": bench_gang.run,
    "preempt": bench_preempt.run,
    "sched_scale": bench_sched_scale.run,
    "serve": bench_serve.run,
    "obs": bench_obs.run,
    "whatif": bench_whatif.run,
}

# experiments whose run() takes smoke= (tiny inputs, assert-only, no JSON);
# --smoke forwards to these and leaves the rest at full size
SMOKE_CAPABLE = frozenset({"executor", "gang", "obs", "preempt",
                           "sched_scale", "serve", "whatif"})


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated experiment list, e.g. "
                         f"'fig5' or 'executor,gang,preempt' "
                         f"(available: {', '.join(sorted(EXPERIMENTS))})")
    ap.add_argument("--smoke", action="store_true",
                    help="forward smoke mode to the experiments that "
                         f"support it ({', '.join(sorted(SMOKE_CAPABLE))})")
    args = ap.parse_args()
    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in names if n not in EXPERIMENTS]
        if unknown:
            ap.error(f"unknown experiment(s) {', '.join(unknown)} "
                     f"(available: {', '.join(sorted(EXPERIMENTS))})")
    else:
        names = list(EXPERIMENTS)
    t0 = time.time()
    for name in names:
        print(f"\n=== {name} " + "=" * (70 - len(name)))
        if args.smoke and name in SMOKE_CAPABLE:
            EXPERIMENTS[name](smoke=True)
        else:
            EXPERIMENTS[name]()
    where = ("(smoke runs are assert-only: no new artifacts)" if args.smoke
             else "artifacts in benchmarks/results/")
    print(f"\nall benchmarks done in {time.time() - t0:.0f}s; {where}")


if __name__ == "__main__":
    main()
