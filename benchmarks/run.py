"""Benchmark runner: one experiment per paper table/figure, printed summary,
JSON artifacts under benchmarks/results/, plus a consolidated
``BENCH_10.json`` of per-bench headline numbers so the perf trajectory is
tracked across PRs.

    PYTHONPATH=src python -m benchmarks.run [--only fig5]
    PYTHONPATH=src python -m benchmarks.run --only executor,gang,preempt --smoke
"""
from __future__ import annotations

import argparse
import numbers
import time
from typing import Any, Dict

from benchmarks import (
    bench_executor, bench_gang, bench_obs, bench_preempt, bench_profile,
    bench_sched_scale, bench_serve, bench_whatif, fig4_alg2_vs_alg3,
    fig5_throughput, fig6_nn_schedgpu, kernels_bench, table2_crashes,
    table3_turnaround, table4_slowdown,
)
from benchmarks.common import save_json

EXPERIMENTS = {
    "fig4": fig4_alg2_vs_alg3.run,
    "fig5": fig5_throughput.run,
    "table2": table2_crashes.run,
    "table3": table3_turnaround.run,
    "table4": table4_slowdown.run,
    "fig6": fig6_nn_schedgpu.run,
    "kernels": kernels_bench.run,
    "executor": bench_executor.run,
    "gang": bench_gang.run,
    "preempt": bench_preempt.run,
    "sched_scale": bench_sched_scale.run,
    "serve": bench_serve.run,
    "obs": bench_obs.run,
    "profile": bench_profile.run,
    "whatif": bench_whatif.run,
}

# experiments whose run() takes smoke= (tiny inputs, assert-only, no JSON);
# --smoke forwards to these and leaves the rest at full size
SMOKE_CAPABLE = frozenset({"executor", "gang", "obs", "preempt", "profile",
                           "sched_scale", "serve", "whatif"})


def _headline(result: Any, depth: int = 0) -> Any:
    """Distill an experiment's return value to its numeric scalars: dicts
    keep number-valued entries (one level of nesting), lists of row-dicts
    are keyed by their 'bench'/'config'/'name' labels. Anything else is
    dropped — the trajectory file wants comparable numbers, not blobs."""
    if isinstance(result, bool):
        return None
    if isinstance(result, numbers.Number):
        return result
    if isinstance(result, dict):
        out = {}
        for k, v in result.items():
            h = _headline(v, depth + 1) if depth < 2 else (
                v if isinstance(v, numbers.Number)
                and not isinstance(v, bool) else None)
            if h is not None and h != {}:
                out[str(k)] = h
        return out
    if isinstance(result, (list, tuple)) and depth < 2:
        out = {}
        for i, row in enumerate(result):
            if not isinstance(row, dict):
                continue
            label = "/".join(str(row[k]) for k in ("bench", "config", "name",
                                                   "engine", "depth")
                             if k in row) or str(i)
            h = _headline(row, depth + 1)
            if h:
                out[label] = h
        return out
    return None


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated experiment list, e.g. "
                         f"'fig5' or 'executor,gang,preempt' "
                         f"(available: {', '.join(sorted(EXPERIMENTS))})")
    ap.add_argument("--smoke", action="store_true",
                    help="forward smoke mode to the experiments that "
                         f"support it ({', '.join(sorted(SMOKE_CAPABLE))})")
    args = ap.parse_args()
    if args.only:
        names = [n.strip() for n in args.only.split(",") if n.strip()]
        unknown = [n for n in names if n not in EXPERIMENTS]
        if unknown:
            ap.error(f"unknown experiment(s) {', '.join(unknown)} "
                     f"(available: {', '.join(sorted(EXPERIMENTS))})")
    else:
        names = list(EXPERIMENTS)
    t0 = time.time()
    summary: Dict[str, Any] = {"smoke": args.smoke,
                               "experiments": {}}
    for name in names:
        print(f"\n=== {name} " + "=" * (70 - len(name)))
        if args.smoke and name in SMOKE_CAPABLE:
            result = EXPERIMENTS[name](smoke=True)
        else:
            result = EXPERIMENTS[name]()
        head = _headline(result)
        if head:
            summary["experiments"][name] = head
    summary["elapsed_s"] = round(time.time() - t0, 1)
    path = save_json("BENCH_10.json", summary)
    where = ("(smoke runs are assert-only: no new per-bench artifacts)"
             if args.smoke else "artifacts in benchmarks/results/")
    print(f"\nall benchmarks done in {summary['elapsed_s']:.0f}s; {where}")
    print(f"consolidated headline numbers -> {path}")


if __name__ == "__main__":
    main()
