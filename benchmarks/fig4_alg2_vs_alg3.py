"""Fig. 4: MGB Alg. 2 vs Alg. 3 throughput on the 8 workloads, 4xV100.

Paper claim: Alg. 3 averages ~1.21x the throughput of Alg. 2 (optimistic
packing exploits fast completions; Alg. 2 holds jobs back ~30% longer).
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import workloads as W


def run() -> dict:
    n_dev = C.SYSTEMS["4xV100"]
    workers = C.MGB_WORKERS["4xV100"]
    rows = {}
    for wname in sorted(W.WORKLOADS):
        jobs = W.workload(wname)
        r2 = C.run_mgb(jobs, n_dev, workers, alg=2)
        r3 = C.run_mgb(jobs, n_dev, workers, alg=3)
        rows[wname] = {
            "alg2_throughput": r2.throughput, "alg3_throughput": r3.throughput,
            "alg3_over_alg2": r3.throughput / r2.throughput,
            "alg2_makespan_s": r2.makespan, "alg3_makespan_s": r3.makespan,
        }
    avg = sum(r["alg3_over_alg2"] for r in rows.values()) / len(rows)
    out = {"rows": rows, "avg_alg3_over_alg2": avg,
           "paper_claim": {"avg_alg3_over_alg2": 1.21}}
    print("Fig4  Alg3/Alg2 throughput per workload:")
    for wname, r in rows.items():
        print(f"  {wname}: {r['alg3_over_alg2']:.2f}x")
    print(C.check("avg Alg3/Alg2", avg, 1.0, 1.45))
    C.save_json("fig4.json", out)
    return out


if __name__ == "__main__":
    run()
