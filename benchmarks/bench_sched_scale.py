"""Scheduler control-plane scale benchmark: indexed admission vs the
pre-refactor engines, at fleet depth.

Three measurements, one per layer of the fleet-scale refactor:

  1. **flat admission churn** — MGB Alg. 3 over the indexed waiter queue
     (``_WaiterIndex``) vs the verbatim pre-refactor sorted-list engine
     (``ReferenceAlg3Scheduler``), at queue depths 1e2 -> 1e5. Protocol:
     fill every device with a resident, park ``depth`` waiters, then drive
     ``task_end`` churn — each completion frees exactly one waiter's worth
     of capacity, so admissions/sec isolates the drain cost. The reference
     engine re-scans the whole queue per wakeup (O(depth) per admission);
     deep runs are TIME-CAPPED and report the rate over the measured
     window (the queue shrinks negligibly within the cap, so the partial
     rate is the rate at that depth);
  2. **gang placement probe** — ``GangScheduler._find_group`` against the
     topology's incremental tile index vs a bench-local copy of the
     historical full enumeration (per-candidate member walks + resident
     demand sums), on fleets of 1k -> 10k chips, all tiles resident (the
     alg3 scoring worst case). Both probes must pick the SAME group;
  3. **sharded control plane** — single-chip admission churn on one global
     ``GangScheduler`` vs ``ShardedScheduler`` (one engine per pod): the
     global drain re-scans a fleet-sized shape index per admission, the
     sharded drain touches only the owner pod's 256 positions, and idle
     pods pull backlog over the stealing path.

    PYTHONPATH=src python -m benchmarks.bench_sched_scale            # full
    PYTHONPATH=src python -m benchmarks.bench_sched_scale --smoke    # CI

``--smoke`` additionally enforces the REGRESSION GUARD: flat indexed
admissions/sec at depth 1e4 must stay within ``guard_factor`` (2x) of the
committed baseline in ``benchmarks/baselines/sched_scale.json`` — a queue
or drain regression fails CI instead of landing silently.
"""
from __future__ import annotations

import argparse
import json
import os
import time
from collections import deque
from statistics import median
from typing import Any, Dict, List, Optional, Tuple

from benchmarks.common import save_json
from repro.core.scheduler import (
    GangScheduler, MGBAlg3Scheduler, ReferenceAlg3Scheduler,
    ShardedScheduler,
)
from repro.core.scheduler.base import slots_needed
from repro.core.task import ResourceVector, Task, UnitTask

GB = 1024**3
BASELINE_PATH = os.path.join(os.path.dirname(__file__), "baselines",
                             "sched_scale.json")

# flat sweep scenario: 64 devices, one 16 GB resident each, homogeneous
# 16 GB waiters — every task_end admits exactly one waiter
FLAT_DEVICES = 64
FLAT_DEPTHS = (100, 1_000, 10_000, 100_000)
# gang fleet sweep: pods x 16x16 chips (256/pod), 16-chip (4x4) gangs
FLEET_PODS = (4, 16, 40)          # 1_024 / 4_096 / 10_240 chips


def mk_task(name: str, mem_gb: float = 16.0, chips: int = 1,
            prio: int = 0, deadline: Optional[float] = None,
            demand: float = 0.5) -> Task:
    vec = ResourceVector(hbm_bytes=int(mem_gb * GB), flops=1e12,
                         bytes_accessed=1e9, est_seconds=10.0,
                         core_demand=demand, bw_demand=demand, chips=chips)
    t = Task(units=[UnitTask(fn=None, memobjs=frozenset({name}),
                             resources=vec, name=name)], name=name)
    t.priority = prio
    t.deadline_t = deadline
    return t


# ---------------------------------------------------------------------------
# 1) flat admission churn: indexed queue vs sorted-list reference
# ---------------------------------------------------------------------------

def flat_churn(engine: str, depth: int, *, budget_s: float,
               mixed: bool = False, n_dev: int = FLAT_DEVICES,
               order_log: Optional[List[str]] = None) -> Dict[str, Any]:
    """One churn run; returns the metrics row. ``mixed`` stamps 4 priority
    classes and EDF deadlines on a third of the waiters (exercises the
    class/deadline index paths); ``order_log`` collects the admission
    sequence for cross-engine parity checks."""
    cls = {"indexed": MGBAlg3Scheduler,
           "reference": ReferenceAlg3Scheduler}[engine]
    sched = cls(n_dev)
    hogs = [mk_task(f"hog{i}") for i in range(n_dev)]
    for h in hogs:
        assert sched.task_begin(h) is not None
    admitted: deque = deque()

    def cb(t: Task, placement, epoch: int) -> None:
        admitted.append(t)

    base_t = time.monotonic() + 1e6   # far-future deadlines: EDF order only
    t0 = time.perf_counter()
    for i in range(depth):
        prio = (i % 4) if mixed else 0
        dl = (base_t + i) if (mixed and i % 3 == 0) else None
        sched.admit_or_enqueue(mk_task(f"w{i}", prio=prio, deadline=dl), cb)
    enqueue_s = time.perf_counter() - t0
    assert sched.waiting_count() == depth

    current: deque = deque(hogs)
    lats: List[float] = []
    n_adm = 0
    t0 = time.perf_counter()
    while current and n_adm < depth:
        if time.perf_counter() - t0 > budget_s:
            break
        vic = current.popleft()
        t1 = time.perf_counter()
        sched.task_end(vic)
        lats.append(time.perf_counter() - t1)
        while admitted:
            w = admitted.popleft()
            if order_log is not None:
                order_log.append(w.name)
            current.append(w)
            n_adm += 1
    elapsed = max(time.perf_counter() - t0, 1e-9)
    return {
        "bench": "flat", "engine": engine, "depth": depth,
        "mixed": mixed,
        "enqueue_per_s": depth / max(enqueue_s, 1e-9),
        "admissions_per_s": n_adm / elapsed,
        "drain_p50_us": 1e6 * median(lats) if lats else 0.0,
        "admitted": n_adm,
        "capped": n_adm < depth,
    }


# ---------------------------------------------------------------------------
# 2) gang placement probe: tile index vs historical enumeration
# ---------------------------------------------------------------------------

def legacy_find_group(sched: GangScheduler, task: Task):
    """The pre-refactor ``_find_group``, verbatim: full candidate
    enumeration with per-member feasibility walks and per-candidate
    resident demand sums (the benchmark foil — O(tiles x tile size) per
    probe, against the index's O(tiles))."""
    r = task.resources
    k = max(r.chips, 1)
    per_chip = r.hbm_bytes // k
    need = slots_needed(task)
    best = None
    best_key: Tuple[float, float] = (float("inf"), float("inf"))
    for group in sched.topo.candidate_groups(k):
        if not all(sched._member_ok(c, per_chip, need)
                   for c in group.cells()):
            continue
        if sched.policy == "alg2" \
                and not sched.topo.link_headroom_ok(group, r):
            continue
        key = (sum(sched.topo.cells[c].in_use_demand
                   for c in group.cells()),
               sched.topo.max_link_load(group))
        if key < best_key:
            best, best_key = group, key
        if key == (0.0, 0.0):
            return group
    return best


def _fill_tiles(sched: GangScheduler, *, sr: int, sc: int,
                mem_gb_per_chip: float, demand: float) -> List[Task]:
    """Reserve every aligned (sr x sc) tile directly (the public admission
    path would pay a position scan per fill — quadratic setup the benchmark
    is not measuring). The reserve path keeps the tile index exact."""
    topo = sched.topo
    chips = sr * sc
    out: List[Task] = []
    for p in range(topo.pods):
        for r0 in range(0, topo.rows - sr + 1, sr):
            for c0 in range(0, topo.cols - sc + 1, sc):
                t = mk_task(f"res{p}.{r0}.{c0}",
                            mem_gb=mem_gb_per_chip * chips, chips=chips,
                            demand=demand)
                group = topo.tile_group(sr, sc, (p, r0, c0))
                with sched._lock:
                    sched._reserve_group_locked(t, group)
                out.append(t)
    return out


def _probe_rate(fn, *, budget_s: float) -> Tuple[float, Any]:
    """(probes/sec, last result) over a time-boxed repeat loop."""
    n = 0
    last = None
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < budget_s or n == 0:
        last = fn()
        n += 1
    return n / (time.perf_counter() - t0), last


def gang_probe(pods: int, *, budget_s: float, rows: int = 16,
               cols: int = 16, sr: int = 4, sc: int = 4) -> Dict[str, Any]:
    """Placement probe latency on an all-resident fleet (every tile
    feasible, so the alg3 scoring path walks/aggregates ALL of them — the
    worst case for both probes). Asserts both pick the identical group."""
    sched = GangScheduler(pods=pods, rows=rows, cols=cols)
    chips = sr * sc
    # 4 GB/chip residents: a 4 GB/chip probe fits everywhere, nothing free
    _fill_tiles(sched, sr=sr, sc=sc, mem_gb_per_chip=4.0, demand=0.3)
    probe = mk_task("probe", mem_gb=4.0 * chips, chips=chips, demand=0.3)
    sched._find_group(probe)  # warm: builds the shape indexes once
    idx_rate, g_idx = _probe_rate(lambda: sched._find_group(probe),
                                  budget_s=budget_s)
    leg_rate, g_leg = _probe_rate(lambda: legacy_find_group(sched, probe),
                                  budget_s=budget_s)
    assert g_idx is not None and g_leg is not None
    assert g_idx.lead == g_leg.lead, (g_idx, g_leg)  # identical pick
    return {
        "bench": "gang_probe", "chips": pods * rows * cols,
        "gang_chips": chips,
        "indexed_probes_per_s": idx_rate,
        "legacy_probes_per_s": leg_rate,
        "speedup": idx_rate / max(leg_rate, 1e-9),
    }


class LegacyProbeGangScheduler(GangScheduler):
    """GangScheduler whose placement probe is the historical enumeration —
    the end-to-end churn foil (everything else identical)."""

    def _find_group(self, task: Task):
        return legacy_find_group(self, task)


def gang_churn(pods: int, *, engine: str, budget_s: float,
               waiters: int = 256, rows: int = 16, cols: int = 16,
               sr: int = 4, sc: int = 4) -> Dict[str, Any]:
    """End-to-end gang admission churn on an exactly-full fleet: each
    ``task_end`` frees one tile and admits exactly one parked gang."""
    cls = {"indexed": GangScheduler,
           "legacy": LegacyProbeGangScheduler}[engine]
    sched = cls(pods=pods, rows=rows, cols=cols)
    chips = sr * sc
    hogs = _fill_tiles(sched, sr=sr, sc=sc, mem_gb_per_chip=16.0,
                       demand=0.5)
    admitted: deque = deque()

    def cb(t: Task, placement, epoch: int) -> None:
        admitted.append(t)

    for i in range(waiters):
        sched.admit_or_enqueue(
            mk_task(f"g{i}", mem_gb=16.0 * chips, chips=chips), cb)
    current: deque = deque(hogs)
    n_adm = 0
    lats: List[float] = []
    t0 = time.perf_counter()
    while current and n_adm < waiters:
        if time.perf_counter() - t0 > budget_s:
            break
        vic = current.popleft()
        t1 = time.perf_counter()
        sched.task_end(vic)
        lats.append(time.perf_counter() - t1)
        while admitted:
            current.append(admitted.popleft())
            n_adm += 1
    elapsed = max(time.perf_counter() - t0, 1e-9)
    return {
        "bench": "gang_churn", "engine": engine,
        "chips": pods * rows * cols, "gang_chips": chips,
        "admissions_per_s": n_adm / elapsed,
        "drain_p50_us": 1e6 * median(lats) if lats else 0.0,
        "admitted": n_adm, "capped": n_adm < waiters,
    }


# ---------------------------------------------------------------------------
# 3) sharded control plane vs one global engine
# ---------------------------------------------------------------------------

def _fill_cells(sched: GangScheduler, *, per_cell: int = 2,
                mem_gb: float = 8.0) -> List[Task]:
    """``per_cell`` co-resident tasks on every chip, reserved directly
    (same rationale as _fill_tiles). Two 8 GB residents per 16 GB chip
    means ending ONE leaves the cell busy-but-feasible — the drain cannot
    shortcut through the free-tile heap and pays the real position scan,
    which is the fleet-size-dependent cost this section measures."""
    topo = sched.topo
    out: List[Task] = []
    for p in range(topo.pods):
        for r0 in range(topo.rows):
            for c0 in range(topo.cols):
                for j in range(per_cell):
                    t = mk_task(f"res{p}.{r0}.{c0}.{j}", mem_gb=mem_gb,
                                demand=0.25)
                    group = topo.tile_group(1, 1, (p, r0, c0))
                    with sched._lock:
                        sched._reserve_group_locked(t, group)
                    out.append(t)
    return out


def _fill_cells_sharded(sched: ShardedScheduler) -> List[Task]:
    # direct per-shard fill (+ owner registration, normally done by the
    # admission path) — same rationale as _fill_tiles: the setup's position
    # scans are not what this benchmark measures
    out: List[Task] = []
    for si, sh in enumerate(sched.shards):
        ts = _fill_cells(sh)
        for t in ts:
            sched._owner[t.uid] = si
        out.extend(ts)
    return out


def _interleave_by_pod(tasks: List[Task], pods: int) -> List[Task]:
    """Round-robin the completion order across pods — the open-arrival
    steady state (completions land fleet-wide, not pod-by-pod), which keeps
    the sharded drain on the owner pod instead of forcing a steal per
    admission."""
    per_pod: List[List[Task]] = [[] for _ in range(pods)]
    for i, t in enumerate(tasks):
        per_pod[(i * pods) // len(tasks)].append(t)
    out: List[Task] = []
    for j in range(max(len(g) for g in per_pod)):
        for g in per_pod:
            if j < len(g):
                out.append(g[j])
    return out


def sharded_churn(pods: int, *, engine: str, budget_s: float,
                  waiters: int = 512, rows: int = 16,
                  cols: int = 16) -> Dict[str, Any]:
    """Single-chip admission churn at fleet size: global engine (one lock,
    fleet-sized position scan per drain — every cell is busy-but-feasible,
    so no free-tile shortcut) vs per-pod shards (the owner pod's 256
    positions per drain, work stealing for imbalance). Completions arrive
    interleaved across pods, the open-arrival steady state."""
    if engine == "global":
        sched: Any = GangScheduler(pods=pods, rows=rows, cols=cols)
        hogs = _fill_cells(sched)
    else:
        sched = ShardedScheduler(pods=pods, rows=rows, cols=cols)
        hogs = _fill_cells_sharded(sched)
    hogs = _interleave_by_pod(hogs, pods)
    admitted: deque = deque()

    def cb(t: Task, placement, epoch: int) -> None:
        admitted.append(t)

    t0 = time.perf_counter()
    for i in range(waiters):
        sched.admit_or_enqueue(mk_task(f"w{i}", mem_gb=8.0, demand=0.25),
                               cb)
    enqueue_s = time.perf_counter() - t0
    current: deque = deque(hogs)
    n_adm = 0
    t0 = time.perf_counter()
    while current and n_adm < waiters:
        if time.perf_counter() - t0 > budget_s:
            break
        vic = current.popleft()
        sched.task_end(vic)
        while admitted:
            current.append(admitted.popleft())
            n_adm += 1
    elapsed = max(time.perf_counter() - t0, 1e-9)
    row = {
        "bench": "sharded_churn", "engine": engine,
        "chips": pods * rows * cols,
        "enqueue_per_s": waiters / max(enqueue_s, 1e-9),
        "admissions_per_s": n_adm / elapsed,
        "admitted": n_adm, "capped": n_adm < waiters,
    }
    if engine == "sharded":
        row["steals"] = sched.steals
    return row


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _fmt(row: Dict[str, Any]) -> str:
    if row["bench"] == "flat":
        cap = " (capped)" if row["capped"] else ""
        mix = " mixed" if row["mixed"] else ""
        return (f"flat{mix} {row['engine']:>9} depth={row['depth']:>6}: "
                f"{row['admissions_per_s']:>10.0f} adm/s  "
                f"drain p50={row['drain_p50_us']:8.1f}us  "
                f"enq={row['enqueue_per_s']:.0f}/s{cap}")
    if row["bench"] == "gang_probe":
        return (f"gang probe  {row['chips']:>6} chips: indexed "
                f"{row['indexed_probes_per_s']:>8.0f}/s vs legacy "
                f"{row['legacy_probes_per_s']:>7.0f}/s "
                f"({row['speedup']:.1f}x)")
    if row["bench"] == "gang_churn":
        cap = " (capped)" if row["capped"] else ""
        return (f"gang churn {row['engine']:>8} {row['chips']:>6} chips: "
                f"{row['admissions_per_s']:>8.0f} adm/s  "
                f"p50={row['drain_p50_us']:8.1f}us{cap}")
    cap = " (capped)" if row["capped"] else ""
    extra = f" steals={row['steals']}" if "steals" in row else ""
    return (f"sharded churn {row['engine']:>7} {row['chips']:>6} chips: "
            f"{row['admissions_per_s']:>8.0f} adm/s  "
            f"enq={row['enqueue_per_s']:.0f}/s{extra}{cap}")


def _parity_check(depth: int = 300) -> None:
    """Both engines must replay an identical mixed-class admission
    sequence (the full battery lives in tests/test_sched_scale.py; this is
    the benchmark's own sanity gate)."""
    seq_i: List[str] = []
    seq_r: List[str] = []
    flat_churn("indexed", depth, budget_s=30.0, mixed=True,
               order_log=seq_i)
    flat_churn("reference", depth, budget_s=30.0, mixed=True,
               order_log=seq_r)
    assert seq_i == seq_r, (
        f"admission order diverged at "
        f"{next(i for i, (a, b) in enumerate(zip(seq_i, seq_r)) if a != b)}")


def _load_baseline() -> Optional[Dict[str, Any]]:
    if not os.path.exists(BASELINE_PATH):
        return None
    with open(BASELINE_PATH) as f:
        return json.load(f)


def _regression_guard() -> Dict[str, Any]:
    """The CI guard: flat indexed admissions/sec at the baseline depth must
    stay within guard_factor of the committed number."""
    base = _load_baseline()
    if base is None:
        raise AssertionError(f"missing committed baseline {BASELINE_PATH}")
    depth = int(base["depth"])
    row = flat_churn("indexed", depth, budget_s=60.0)
    assert not row["capped"], row
    floor = base["admissions_per_s"] / base["guard_factor"]
    print(f"guard: depth={depth} measured "
          f"{row['admissions_per_s']:.0f} adm/s vs committed "
          f"{base['admissions_per_s']:.0f} (floor {floor:.0f})")
    assert row["admissions_per_s"] >= floor, (
        f"admission-rate regression: {row['admissions_per_s']:.0f}/s is "
        f">{base['guard_factor']}x below the committed baseline "
        f"{base['admissions_per_s']:.0f}/s at depth {depth} — "
        f"see {BASELINE_PATH}")
    return row


def run(seed: int = 0, smoke: bool = False,
        budget_s: float = 8.0) -> List[Dict[str, Any]]:
    t_start = time.time()
    rows: List[Dict[str, Any]] = []
    if smoke:
        _parity_check(depth=300)
        for engine in ("indexed", "reference"):
            rows.append(flat_churn(engine, 2_000, budget_s=budget_s))
            print(_fmt(rows[-1]))
        idx, ref = rows[-2], rows[-1]
        assert idx["admissions_per_s"] > 3 * ref["admissions_per_s"], rows
        rows.append(gang_probe(2, budget_s=0.3, rows=4, cols=4,
                               sr=2, sc=2))
        print(_fmt(rows[-1]))
        for engine in ("global", "sharded"):
            rows.append(sharded_churn(2, engine=engine, budget_s=budget_s,
                                      waiters=64, rows=4, cols=4))
            print(_fmt(rows[-1]))
        rows.append(_regression_guard())
        print("bench_sched_scale --smoke OK "
              f"({time.time() - t_start:.1f}s)")
        return rows

    _parity_check(depth=500)
    by_depth: Dict[int, Dict[str, Dict[str, Any]]] = {}
    for depth in FLAT_DEPTHS:
        for engine in ("indexed", "reference"):
            row = flat_churn(engine, depth, budget_s=budget_s)
            by_depth.setdefault(depth, {})[engine] = row
            rows.append(row)
            print(_fmt(row))
    # acceptance: >=10x admissions/sec at depth 1e5 on the flat trace
    deepest = max(FLAT_DEPTHS)
    speedup = (by_depth[deepest]["indexed"]["admissions_per_s"]
               / by_depth[deepest]["reference"]["admissions_per_s"])
    print(f"flat depth={deepest}: indexed is {speedup:.0f}x the "
          f"pre-refactor engine")
    assert speedup >= 10.0, by_depth[deepest]
    # acceptance: sub-linear drain-latency growth 1e2 -> 1e5 (a linear
    # drain would grow ~1000x; the indexed drain is ~flat + log factors)
    shallow_p50 = max(by_depth[min(FLAT_DEPTHS)]["indexed"]["drain_p50_us"],
                      1e-3)
    deep_p50 = by_depth[deepest]["indexed"]["drain_p50_us"]
    growth = deep_p50 / shallow_p50
    print(f"flat indexed drain p50 growth 1e2->1e5: {growth:.1f}x "
          f"(linear would be ~1000x)")
    assert growth < 100.0, by_depth

    rows.append(flat_churn("indexed", 10_000, budget_s=budget_s,
                           mixed=True))
    print(_fmt(rows[-1]))

    for pods in FLEET_PODS:
        row = gang_probe(pods, budget_s=min(budget_s / 4, 2.0))
        rows.append(row)
        print(_fmt(row))
        assert row["speedup"] > 1.0, row
    for pods in (FLEET_PODS[0], FLEET_PODS[-1]):
        for engine in ("indexed", "legacy"):
            row = gang_churn(pods, engine=engine, budget_s=budget_s)
            rows.append(row)
            print(_fmt(row))
    for pods in (FLEET_PODS[0], FLEET_PODS[-1]):
        pair: Dict[str, Dict[str, Any]] = {}
        for engine in ("global", "sharded"):
            row = sharded_churn(pods, engine=engine, budget_s=budget_s)
            pair[engine] = row
            rows.append(row)
            print(_fmt(row))
        print(f"  sharded/global at {pair['global']['chips']} chips: "
              f"{pair['sharded']['admissions_per_s'] / max(pair['global']['admissions_per_s'], 1e-9):.1f}x")
        if pods == FLEET_PODS[-1]:
            # the per-pod control plane must not degrade with fleet size
            assert (pair["sharded"]["admissions_per_s"]
                    > pair["global"]["admissions_per_s"]), pair

    save_json("bench_sched_scale.json", rows)
    print(f"bench_sched_scale done ({time.time() - t_start:.0f}s)")
    return rows


def write_baseline(depth: int = 10_000, guard_factor: float = 2.0) -> None:
    """Re-measure and commit the smoke guard's baseline (run on the
    reference machine after intentional scheduler-core changes)."""
    row = flat_churn("indexed", depth, budget_s=60.0)
    assert not row["capped"], row
    os.makedirs(os.path.dirname(BASELINE_PATH), exist_ok=True)
    payload = {
        "depth": depth,
        "admissions_per_s": round(row["admissions_per_s"], 1),
        "guard_factor": guard_factor,
        "note": "flat-trace indexed admissions/sec; smoke fails below "
                "admissions_per_s / guard_factor",
    }
    with open(BASELINE_PATH, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"baseline written: {payload}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny depths + admission-order parity + the "
                         "committed-baseline regression guard (CI)")
    ap.add_argument("--budget", type=float, default=8.0,
                    help="per-measurement time cap, seconds")
    ap.add_argument("--write-baseline", action="store_true",
                    help="re-measure and overwrite the smoke guard's "
                         "committed baseline")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.write_baseline:
        write_baseline()
        return
    run(args.seed, smoke=args.smoke, budget_s=args.budget)


if __name__ == "__main__":
    main()
