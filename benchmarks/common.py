"""Shared benchmark harness: scheduler comparisons over the paper's
workloads, with the paper's own protocol (worker-pool sizing, CG sweeps).
"""
from __future__ import annotations

import copy
import json
import os
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import workloads as W
from repro.core.scheduler import (
    CGScheduler, MemOnlyScheduler, MGBAlg2Scheduler, MGBAlg3Scheduler,
    SAScheduler,
)
from repro.core.simulator import SimResult, Simulator

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")

# the paper's two systems: 2xP100 and 4xV100 (16 GB each). Worker-pool sizes
# per §V-A: SA = n_gpus; MGB = 10 (2-GPU) / 16 (4-GPU).
SYSTEMS = {"2xP100": 2, "4xV100": 4}
MGB_WORKERS = {"2xP100": 10, "4xV100": 16}


def fresh(jobs: Sequence) -> List:
    return [copy.deepcopy(j) for j in jobs]


def run_sa(jobs, n_dev: int) -> SimResult:
    return Simulator(SAScheduler(n_dev), workers=n_dev).run(fresh(jobs))


def run_mgb(jobs, n_dev: int, workers: int, alg: int = 3) -> SimResult:
    cls = MGBAlg3Scheduler if alg == 3 else MGBAlg2Scheduler
    return Simulator(cls(n_dev), workers=workers).run(fresh(jobs))


def run_memonly(jobs, n_dev: int, workers: int) -> SimResult:
    return Simulator(MemOnlyScheduler(n_dev), workers=workers).run(fresh(jobs))


def run_cg(jobs, n_dev: int, workers: int) -> SimResult:
    """CG with ratio = workers / n_dev (paper: 1 worker per core feeding)."""
    ratio = max(1, workers // n_dev)
    return Simulator(CGScheduler(n_dev, ratio=ratio),
                     workers=workers).run(fresh(jobs))


def best_cg(jobs, n_dev: int,
            worker_sweep: Sequence[int]) -> Tuple[Optional[SimResult], int]:
    """Paper protocol: sweep CG worker pools, take the best run that did NOT
    crash; if every setting crashes, the best-throughput crashing run."""
    best, best_w = None, 0
    best_crashing, best_crashing_w = None, 0
    for w in worker_sweep:
        r = run_cg(jobs, n_dev, w)
        if r.crashed == 0:
            if best is None or r.throughput > best.throughput:
                best, best_w = r, w
        else:
            if best_crashing is None or r.throughput > best_crashing.throughput:
                best_crashing, best_crashing_w = r, w
    if best is not None:
        return best, best_w
    return best_crashing, best_crashing_w


def save_json(name: str, payload) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    return path


def check(label: str, value: float, lo: float, hi: float) -> str:
    ok = lo <= value <= hi
    return (f"  {'PASS' if ok else 'MISS':4s} {label}: {value:.2f} "
            f"(paper band [{lo:.2f}, {hi:.2f}])")
