"""Tracer overhead benchmark: admission churn with the obs ring on vs off.

The PR-6 scheduler core admits ~17k waiters/sec at depth 1e4 (see
``benchmarks/baselines/sched_scale.json``); the observability subsystem
(ISSUE 8) threads an emission point into every hot-path transition
(park/admit/end/evict/...). This benchmark pins down what that costs, on
the protocol the committed baseline uses — ``flat_churn``'s
fill-then-drain loop over ``MGBAlg3Scheduler`` at depth 1e4 — comparing
three tracer configs:

* **off**      — ``sched._trace is None``: the shipping default. Every
  emission point is one attribute load + None check.
* **disabled** — a ``Tracer(enabled=False)`` attached: one extra boolean
  check per emission (the "left attached but switched off" shape).
* **on**       — an enabled ``Tracer`` sized to hold the whole run: the
  full seq-stamp + clock + ring-slot write per event (end + admit per
  completion on this trace).
* **explain**  — the tracer from **on** plus an attached ``Explainer``
  (ISSUE 9): every admit also records a structured ADMITTED verdict in
  the per-task ring. Gated against **off** with the same 5% budget, and
  against **on** implicitly (same gate, same denominator) — the
  explainability layer must ride inside the tracer's envelope, not
  stack a second one on top.

**The measurement is PAIRED, inside one run.** Config-per-run designs
cannot see a ~3% effect here: container CPU-frequency regimes and
scheduler placement drift the aggregate rate by 10-25% BETWEEN runs of
the identical config (measured), swamping the effect. Instead one drain
loop rotates ``sched._trace`` through off/disabled/on every ``CHUNK``
completions, so all three configs sample the same machine conditions,
the same queue-depth profile, and the same cache state, interleaved at
~2 ms granularity; per-completion latencies land in per-config buckets.
The gated statistic is the best-of-``repeats`` ratio of per-run bucket
MEDIANs: the median shrugs off the few samples that eat a context
switch, and taking the best repeat (pyperf-style) discards runs where
residual drift — which only ever inflates the ratio — leaked through.
The acceptance gate, asserted in smoke AND full runs: tracer-ON median
drain latency within ``MAX_OVERHEAD`` (5%) of tracer-OFF.

    PYTHONPATH=src python -m benchmarks.bench_obs            # full
    PYTHONPATH=src python -m benchmarks.bench_obs --smoke    # CI
"""
from __future__ import annotations

import argparse
import gc
import time
from collections import deque
from statistics import median
from typing import Any, Dict, List, Optional

from benchmarks.bench_sched_scale import FLAT_DEVICES, mk_task
from benchmarks.common import save_json
from repro.core.scheduler import MGBAlg3Scheduler
from repro.core.task import Task
from repro.obs.events import Tracer, attach_tracer
from repro.obs.explain import Explainer, attach_explainer

DEPTH = 10_000          # the committed baseline's depth (sched_scale.json)
MAX_OVERHEAD = 0.05     # tracer/explainer may cost at most 5% median drain lat
CONFIGS = ("off", "disabled", "on", "explain")
CHUNK = 32              # completions per config slice (~2 ms per slice)
# 2 events per traced completion (end + admit, ~6.7k per run at depth 1e4);
# the ring holds the whole run (also proving zero drops) while staying
# cache-resident — a 1 MB ring would bill its own misses to the tracer
RING_CAPACITY = 1 << 14


def paired_churn(depth: int, *, budget_s: float,
                 n_dev: int = FLAT_DEVICES) -> Dict[str, Any]:
    """One fill-then-drain churn run (the ``flat_churn`` protocol): fill
    every device with a 16 GB resident, park ``depth`` homogeneous
    waiters, then drive ``task_end`` churn — each completion admits
    exactly one waiter, so the timed ``task_end`` call isolates the
    per-transition cost, which is where the emission points live. The
    tracer config rotates every ``CHUNK`` completions; setup (fill +
    park) runs untraced so ``tracer.emitted`` counts exactly the traced
    completions' end/admit pairs."""
    sched = MGBAlg3Scheduler(n_dev)
    tr_on = Tracer(capacity=RING_CAPACITY)
    attach_tracer(sched, tr_on)        # binds the clock to sched._clock
    # the explainer is sized to hold every task's verdict ring so uid
    # eviction churn never bills itself to the "explain" slices
    ex = Explainer(max_tasks=depth + n_dev)
    attach_explainer(sched, ex)        # binds the clock, sets sched._explain
    traces = {"off": None,
              "disabled": Tracer(capacity=RING_CAPACITY, enabled=False),
              "on": tr_on,
              "explain": tr_on}
    explainers = {"off": None, "disabled": None, "on": None, "explain": ex}
    sched._trace = None                # setup untraced
    hogs = [mk_task(f"hog{i}") for i in range(n_dev)]
    for h in hogs:
        assert sched.task_begin(h) is not None
    admitted: deque = deque()

    def cb(t: Task, placement, epoch: int) -> None:
        admitted.append(t)

    # park WITH the explainer attached (tracer still off, so the event
    # accounting below is unaffected): each waiter's one-per-episode
    # rejection walk runs here, at submission, exactly as it does in a
    # fleet with explanation enabled from the start — the timed drain
    # then measures the steady-state marginal cost (verdict appends and
    # repeat bumps), not 10k first-episode walks misbilled to task_end
    for i in range(depth):
        sched.admit_or_enqueue(mk_task(f"w{i}"), cb)
    assert sched.waiting_count() == depth
    setup_verdicts = ex.recorded
    sched._explain = None

    lats: Dict[str, List[float]] = {c: [] for c in CONFIGS}
    current: deque = deque(hogs)
    n_adm = 0
    ci = 0
    in_chunk = 0
    sched._trace = traces[CONFIGS[0]]
    sched._explain = explainers[CONFIGS[0]]
    clk = time.perf_counter
    # a GC cycle landing inside one config's slice (10k tasks alive) would
    # masquerade as tracer overhead — collect up front, pause collection
    # for the timed drain
    gc.collect()
    gc.disable()
    try:
        t0 = clk()
        while current and n_adm < depth:
            if clk() - t0 > budget_s:
                break
            vic = current.popleft()
            t1 = clk()
            sched.task_end(vic)
            lats[CONFIGS[ci]].append(clk() - t1)
            while admitted:
                current.append(admitted.popleft())
                n_adm += 1
            in_chunk += 1
            if in_chunk >= CHUNK:
                in_chunk = 0
                ci = (ci + 1) % len(CONFIGS)
                sched._trace = traces[CONFIGS[ci]]
                sched._explain = explainers[CONFIGS[ci]]
        elapsed = max(clk() - t0, 1e-9)
    finally:
        gc.enable()
    return {
        "lats": lats,
        "admissions_per_s": n_adm / elapsed,
        "admitted": n_adm,
        "capped": n_adm < depth,
        "events": tr_on.emitted,
        "dropped": tr_on.dropped,
        "traced_completions": len(lats["on"]) + len(lats["explain"]),
        "verdicts": ex.recorded - setup_verdicts,
        "explain_completions": len(lats["explain"]),
    }


def run(seed: int = 0, smoke: bool = False, depth: int = DEPTH,
        repeats: int = 5, budget_s: float = 60.0) -> List[Dict[str, Any]]:
    t_start = time.time()
    # warm-up (untimed, small): first-run costs — allocator growth, code
    # warm-up — must not land inside the first measured slices
    paired_churn(min(depth, 2_000), budget_s=budget_s)
    pooled: Dict[str, List[float]] = {c: [] for c in CONFIGS}
    ratios: Dict[str, List[float]] = {c: [] for c in CONFIGS}
    rate = 0.0
    for _ in range(repeats):
        r = paired_churn(depth, budget_s=budget_s)
        assert not r["capped"], r
        # the ring was sized for the run: a drop here means the capacity
        # math above went stale, not that the bench should shrug.
        # 2 events (end + admit) per traced completion ("on" AND "explain"
        # share the live tracer), setup untraced; the explainer adds an
        # ADMITTED verdict per "explain"-slice completion plus a REJECTED
        # for the next class head the pass probes (this slice's share of
        # the worst case: a fresh rejection walk per completion), and must
        # NOT add Tracer events (verdict rings are a separate plane).
        assert r["dropped"] == 0, r
        assert r["events"] == 2 * r["traced_completions"], r
        ec = r["explain_completions"]
        assert ec <= r["verdicts"] <= 2 * ec, r
        off_p50 = median(r["lats"]["off"])
        for c in CONFIGS:
            pooled[c].extend(r["lats"][c])
            ratios[c].append((median(r["lats"][c]) / off_p50) - 1.0)
        # the explain guard's pairing: explainer-on vs explainer-off AT
        # FULL TRACING ("explain" vs "on"), isolating the verdict layer's
        # own marginal cost from the tracer's
        ratios.setdefault("explain_vs_on", []).append(
            (median(r["lats"]["explain"]) / median(r["lats"]["on"])) - 1.0)
        rate = max(rate, r["admissions_per_s"])
    rows: List[Dict[str, Any]] = []
    p50 = {c: 1e6 * median(pooled[c]) for c in CONFIGS}
    for c in CONFIGS:
        # gate on the BEST repeat's ratio (pyperf-style best-of-N): even
        # inside a paired run, residual drift only ever INFLATES the
        # ratio, so the minimum is the least-contaminated estimate
        overhead = min(ratios[c])
        row = {"bench": "obs_overhead", "config": c, "depth": depth,
               "repeats": repeats, "drain_p50_us": p50[c],
               "samples": len(pooled[c]), "overhead": overhead,
               "overhead_per_repeat": ratios[c]}
        if c == "explain":
            row["overhead_vs_on"] = min(ratios["explain_vs_on"])
            row["overhead_vs_on_per_repeat"] = ratios["explain_vs_on"]
        rows.append(row)
        print(f"  {c:>8}: drain p50 {p50[c]:7.2f}us  "
              f"({len(pooled[c])} samples, best {overhead * 100:+.1f}% / "
              f"worst {max(ratios[c]) * 100:+.1f}% vs off)")
    print(f"  mixed-config churn rate: {rate:.0f} adm/s at depth {depth}")
    by = {r["config"]: r for r in rows}
    # the acceptance gates (smoke AND full): full tracing costs <=5% vs
    # untraced, and the explain verdict layer costs <=5% on top of full
    # tracing (its enable/disable pair — the tracer's share is gated by
    # the first assert, not double-billed to the explainer)
    assert by["on"]["overhead"] <= MAX_OVERHEAD, (
        f"tracer-on overhead {by['on']['overhead'] * 100:.1f}% exceeds "
        f"{MAX_OVERHEAD * 100:.0f}% at depth {depth}")
    assert by["explain"]["overhead_vs_on"] <= MAX_OVERHEAD, (
        f"explain overhead {by['explain']['overhead_vs_on'] * 100:.1f}% "
        f"over tracer-on exceeds {MAX_OVERHEAD * 100:.0f}% at depth {depth}")
    if not smoke:
        path = save_json("bench_obs.json", rows)
        print(f"  -> {path}")
    print(f"bench_obs{' --smoke' if smoke else ''} OK "
          f"({time.time() - t_start:.1f}s)")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="assert-only run (no JSON artifact); same depth — "
                         "the 5% gate is only meaningful at baseline depth")
    ap.add_argument("--depth", type=int, default=DEPTH)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.seed, smoke=args.smoke, depth=args.depth,
        repeats=args.repeats)


if __name__ == "__main__":
    main()
