"""Continuous-batching vs static-batch serving at saturation (ISSUE 7).

Open-arrival Poisson trace over the simulator backend, one shared arrival
trace, two serving disciplines:

* **static** — today's ``launch/serve.py`` shape: requests accumulate into
  fixed batches of ``batch``; each batch is ONE job (loop + batch·slot HBM,
  prefill + longest-member decode seconds). Every member waits for the
  batch to FILL before the clock even starts, and the whole batch holds its
  rows until the longest member finishes — short requests pay the longest
  member's tail in TPOT.
* **continuous** — ``repro.serve.engine.ServeEngine``: per-device decode
  loops, prefills as short high-priority tasks, each decode-slot join a
  probed KV-delta admitted through the scheduler. Batch composition changes
  between steps; a retire immediately re-drives parked joins.

Both run the NullModel (synthetic probed-shaped vectors, no kernels): this
is an admission/scheduling benchmark — decode ticks advance at the model's
step cadence, so TPOT differences come from batch mechanics (fill waits,
longest-member tails, join parking), not kernel speed.

Reported per discipline: goodput (requests meeting BOTH the TTFT and TPOT
SLOs, per second), p50/p99 TTFT and TPOT, completion counts. The run
asserts the paper-level claims: at saturation continuous beats static on
goodput AND p99 TTFT, and the scheduler's memory-hard guarantee holds over
every batch-growth step (zero violations).

    PYTHONPATH=src python -m benchmarks.bench_serve            # full
    PYTHONPATH=src python -m benchmarks.bench_serve --smoke    # CI
"""
from __future__ import annotations

import argparse
from typing import Dict, List

import numpy as np

from benchmarks.common import save_json
from repro.core.cluster import Cluster, JobStatus
from repro.core.scheduler import MGBAlg3Scheduler
from repro.core.task import Job, ResourceVector, Task, UnitTask
from repro.obs.export import trace_summary
from repro.serve.engine import SLO, NullModel, ServeEngine

GB = 1024**3

# one synthetic serving fleet for both disciplines (NullModel units)
LOOP_HBM = 2 * GB          # decode-loop base (params + workspace)
SLOT_HBM = int(1.25 * GB)  # per-row KV-cache delta
PREFILL_HBM = 1 * GB
PREFILL_S = 0.05
STEP_S = 0.025             # per-token decode step
GEN_RANGE = (4, 33)        # gen_len ~ U[4, 32]


def _pct(xs: List[float], p: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(int(p * (len(xs) - 1) + 0.5), len(xs) - 1)]


def _trace(rate_rps: float, horizon_s: float, seed: int):
    """Shared Poisson arrival trace: (arrival_t, gen_len) per request."""
    rng = np.random.default_rng(seed)
    t, out = 0.0, []
    while True:
        t += rng.exponential(1.0 / rate_rps)
        if t >= horizon_s:
            return out
        out.append((t, int(rng.integers(*GEN_RANGE))))


def _summary(name: str, ttfts, tpots, good, done, total, span_s, violations):
    return {
        "mode": name, "requests": total, "done": done,
        "goodput_rps": good / max(span_s, 1e-9),
        "slo_met_rate": good / max(done, 1),
        "p50_ttft_s": _pct(ttfts, 0.50), "p99_ttft_s": _pct(ttfts, 0.99),
        "p50_tpot_s": _pct(tpots, 0.50), "p99_tpot_s": _pct(tpots, 0.99),
        "violations": violations,
    }


def _failover_drill(cluster: Cluster) -> None:
    """Post-serve epilogue on the traced cluster: one long job lands on a
    device, the device dies mid-run, the evicted job resumes on a
    survivor — the park→admit→evict→requeue→re-admit arc whose
    cross-device flow the exported trace must contain."""
    t0 = cluster.now
    vec = ResourceVector(hbm_bytes=10 * GB, flops=0.0, bytes_accessed=0.0,
                         est_seconds=4.0, core_demand=0.5, bw_demand=0.5)
    task = Task(units=[UnitTask(fn=None, memobjs=frozenset({"victim"}),
                                resources=vec, name="failover/victim")],
                name="failover/victim")
    cluster.submit(Job(tasks=[task], name="failover/victim"))
    cluster.run_until(t0 + 1.0)
    dead = task.device
    assert dead is not None, "failover victim never started"
    cluster.sched.mark_dead(dead)       # evict → requeue → re-admit
    cluster.run_until(t0 + 3.0)         # resumes on a surviving device
    cluster.sched.revive(dead)
    cluster.drain()


def run_continuous(trace, *, devices: int, max_batch: int, slo: SLO,
                   seed: int = 0, trace_path: str = None) -> Dict:
    sched = MGBAlg3Scheduler(devices, hbm_per_device=16 * GB)
    cluster = Cluster(sched, workers=256, backend="sim",
                      trace=bool(trace_path))
    model = NullModel(loop_hbm=LOOP_HBM, slot_hbm=SLOT_HBM,
                      prefill_hbm=PREFILL_HBM, prefill_s=PREFILL_S,
                      step_s=STEP_S)
    eng = ServeEngine(cluster, model, max_batch=max_batch, slo=slo)
    for t_arr, gen in trace:
        eng.run_until(t_arr)
        eng.submit(prompt_len=64, gen_len=gen)
    eng.drain(timeout_s=600.0)
    m = eng.metrics()
    span = max((r.t_done for r in eng.requests if r.t_done >= 0),
               default=0.0) - trace[0][0]
    done = [r for r in eng.requests if r.t_done >= 0]
    good = sum(1 for r in done
               if r.ttft_s <= slo.ttft_s and r.tpot_s <= slo.tpot_s)
    out = _summary("continuous", [r.ttft_s for r in done],
                   [r.tpot_s for r in done if r.n_tokens > 1],
                   good, len(done), len(trace), span, eng.violations)
    out["shed"] = m["shed"]
    out["failed"] = m["failed"]
    eng.shutdown()
    if trace_path:
        _failover_drill(cluster)
        doc = cluster.export_trace(trace_path)
        s = trace_summary(doc)
        # the trace the CI uploads must actually show the fleet: device
        # occupancy tracks plus the drill's cross-device migration flow
        assert s["slices"] > 0 and len(s["devices"]) >= 2, s
        assert s["cross_device_flows"] >= 1, s
        print(f"  trace -> {trace_path}: {s['slices']} slices on devices "
              f"{s['devices']}, {s['flows']} flow(s) "
              f"({s['cross_device_flows']} cross-device)")
    return out


def run_static(trace, *, devices: int, batch: int, slo: SLO) -> Dict:
    """The launch/serve.py discipline as sim jobs: each full batch is one
    monolithic task sized loop + batch·slot HBM, running prefill + the
    LONGEST member's decode."""
    sched = MGBAlg3Scheduler(devices, hbm_per_device=16 * GB)
    cluster = Cluster(sched, workers=256, backend="sim")
    handles, members = [], []
    for i in range(0, len(trace), batch):
        group = trace[i:i + batch]
        t_submit = group[-1][0]          # batch forms when it FILLS
        gen_max = max(g for _, g in group)
        est = PREFILL_S + gen_max * STEP_S
        vec = ResourceVector(
            hbm_bytes=LOOP_HBM + len(group) * SLOT_HBM,
            flops=0.0, bytes_accessed=0.0, est_seconds=est,
            core_demand=1.0, bw_demand=1.0)
        cluster.run_until(t_submit)
        task = Task(units=[UnitTask(fn=None, memobjs=frozenset({f"b{i}"}),
                                    resources=vec, name=f"batch{i}")],
                    name=f"batch{i}")
        handles.append(cluster.submit(Job(tasks=[task], name=f"batch{i}"),
                                      deadline_s=slo.ttft_s))
        members.append((group, gen_max))
    cluster.drain()
    ttfts, tpots, good, done = [], [], 0, 0
    for h, (group, gen_max) in zip(handles, members):
        if h.status is not JobStatus.DONE or not h.records:
            continue
        t_start = h.records[0].t_start
        t_first = t_start + PREFILL_S
        t_done = t_first + gen_max * STEP_S
        for t_arr, gen in group:
            done += 1
            ttft = t_first - t_arr       # includes the batch-fill wait
            # the row is held until the LONGEST member finishes
            tpot = (t_done - t_first) / (gen - 1) if gen > 1 else 0.0
            ttfts.append(ttft)
            if gen > 1:
                tpots.append(tpot)
            if ttft <= slo.ttft_s and tpot <= slo.tpot_s:
                good += 1
    span = max((h.records[-1].t_end for h in handles
                if h.status is JobStatus.DONE and h.records),
               default=0.0) - trace[0][0]
    violations = sum(1 for d in sched.devices if d.used_hbm > d.total_hbm)
    return _summary("static", ttfts, tpots, good, done, len(trace), span,
                    violations)


def run(seed: int = 0, smoke: bool = False, trace_path: str = None) -> Dict:
    if smoke:
        devices, max_batch, rate, horizon = 2, 4, 12.0, 4.0
    else:
        devices, max_batch, rate, horizon = 4, 8, 48.0, 20.0
    slo = SLO(ttft_s=1.0, tpot_s=0.1)
    trace = _trace(rate, horizon, seed)
    cont = run_continuous(trace, devices=devices, max_batch=max_batch,
                          slo=slo, seed=seed, trace_path=trace_path)
    stat = run_static(trace, devices=devices, batch=max_batch, slo=slo)
    for m in (cont, stat):
        print(f"  {m['mode']:10s} done {m['done']}/{m['requests']:4d}  "
              f"goodput {m['goodput_rps']:6.2f} req/s  "
              f"TTFT p50/p99 {m['p50_ttft_s'] * 1e3:6.0f}/"
              f"{m['p99_ttft_s'] * 1e3:6.0f} ms  "
              f"TPOT p50/p99 {m['p50_tpot_s'] * 1e3:5.0f}/"
              f"{m['p99_tpot_s'] * 1e3:5.0f} ms  "
              f"violations {m['violations']}")
    # the tentpole claims, asserted (smoke AND full): continuous wins on
    # goodput and tail TTFT at saturation, with the memory guarantee intact
    assert cont["violations"] == 0, "memory-hard guarantee violated"
    assert cont["goodput_rps"] > stat["goodput_rps"], \
        (cont["goodput_rps"], stat["goodput_rps"])
    assert cont["p99_ttft_s"] < stat["p99_ttft_s"], \
        (cont["p99_ttft_s"], stat["p99_ttft_s"])
    payload = {"seed": seed, "rate_rps": rate, "horizon_s": horizon,
               "devices": devices, "max_batch": max_batch,
               "slo": {"ttft_s": slo.ttft_s, "tpot_s": slo.tpot_s},
               "continuous": cont, "static": stat}
    if not smoke:
        path = save_json("serve.json", payload)
        print(f"  -> {path}")
    return payload


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="record the continuous run's lifecycle events and "
                         "write a Chrome/Perfetto trace-event JSON (with a "
                         "device-failover epilogue so the trace carries a "
                         "cross-device migration flow)")
    args = ap.parse_args()
    run(seed=args.seed, smoke=args.smoke, trace_path=args.trace)


if __name__ == "__main__":
    main()
