"""Executor benchmarks: the event-driven engine vs the old polling loop
(closed batch), plus the open-arrival streaming path.

Closed-batch protocol: N identical single-task jobs (2 GB, demand 0.25,
~10 ms of work) queued at t=0 on a 2-device MGB-Alg3 fleet.

  * **event** — the event-driven engine: admission wakeups, execution pool of
    16 threads regardless of queue depth. Blocked jobs hold no thread.
  * **polling** — the previous protocol: one worker thread per in-flight job
    spinning ``task_begin`` every 2 ms. To give N jobs concurrent admission
    progress it must burn N threads (capped at 256 here so depth 1000 does
    not exhaust the container), and every blocked thread pays a poll attempt
    each tick.

Open-arrival protocol (the serving story): requests arrive at the ``Cluster``
front-end as a Poisson process and are ``submit``-ed while earlier requests
are mid-flight. Reported: p50/p99 queueing delay (admission wait before the
task starts) for the streaming path vs the same N requests declared as one
closed batch — the batch inflates queueing delay because every job waits
behind the whole backlog from t=0.

Reported per closed-batch run: makespan, scheduler admission attempts
(``begin_attempts``: every ``select_device`` probe, successful or not), and
attempts per job — the overhead metric that grows with queue depth under
polling but stays flat under wakeups (the drain memoizes failed resource
vectors, so a homogeneous queue costs O(admitted + 1) probes per wakeup).

    PYTHONPATH=src python -m benchmarks.bench_executor            # full
    PYTHONPATH=src python -m benchmarks.bench_executor --smoke    # CI guard
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import numpy as np

from benchmarks.common import save_json
from repro.core.cluster import Cluster, JobStatus
from repro.core.executor import ExecJob, Executor, PollingExecutor
from repro.core.scheduler import MGBAlg3Scheduler
from repro.core.task import Job, ResourceVector, Task, UnitTask

GB = 1024**3
DEPTHS = (10, 100, 1000)
DEVICES = 2
# execution pool sized to the fleet's co-residency capacity (16 GB / 2 GB
# tasks x 2 devices), NOT to the job count — the event engine's whole point
EVENT_POOL = 16
POLL_CAP = 256          # thread cap for the polling baseline
WORK_S = 0.010
POLL_S = 0.002


def make_jobs(n: int, work_s: float = WORK_S) -> List[ExecJob]:
    vec = ResourceVector(hbm_bytes=2 * GB, flops=1e9, bytes_accessed=1e9,
                         est_seconds=work_s, core_demand=0.25, bw_demand=0.25)
    jobs = []
    for i in range(n):
        unit = UnitTask(fn=None, memobjs=frozenset({f"q{i}"}), resources=vec,
                        name=f"q{i}")
        jobs.append(ExecJob(
            job=Job(tasks=[Task(units=[unit], name=f"q{i}")], name=f"q{i}"),
            runners=[lambda device, s=work_s: time.sleep(s)]))
    return jobs


def one(depth: int, mode: str) -> Dict[str, float]:
    sched = MGBAlg3Scheduler(DEVICES)
    if mode == "event":
        ex = Executor(sched, workers=EVENT_POOL)
    else:
        ex = PollingExecutor(sched, workers=min(depth, POLL_CAP),
                             poll_interval=POLL_S)
    stats = ex.run(make_jobs(depth))
    assert stats["completed"] == depth, (mode, depth, stats)
    return {"depth": depth, "mode": mode,
            "makespan_s": stats["makespan_s"],
            "sched_attempts": stats["sched_attempts"],
            "attempts_per_job": stats["sched_attempts"] / depth,
            "mean_turnaround_s": stats["mean_turnaround_s"]}


def _delays(records_per_job) -> np.ndarray:
    """Queueing delay per task: admission wait before execution started."""
    return np.array([r.t_start - r.t_queue
                     for recs in records_per_job for r in recs
                     if not r.crashed])


def open_arrival(n: int, rate_hz: float, work_s: float = WORK_S
                 ) -> List[Dict[str, float]]:
    """Poisson arrivals at ``rate_hz`` streamed through Cluster.submit vs the
    same N requests declared as one closed batch."""
    rng = np.random.default_rng(0)
    gaps = rng.exponential(1.0 / rate_hz, n)

    # streaming: submit as requests arrive, earlier requests mid-flight
    cluster = Cluster(MGBAlg3Scheduler(DEVICES), workers=EVENT_POOL)
    handles = []
    t0 = time.monotonic()
    for i, gap in enumerate(gaps):
        time.sleep(gap)
        handles.append(cluster.submit(make_jobs(1, work_s)[0],
                                      deadline_s=1.0))
    cluster.drain()
    stream_wall = time.monotonic() - t0
    assert all(h.status is JobStatus.DONE for h in handles)
    stream_d = _delays(h.records for h in handles)
    cluster.shutdown()

    # closed batch: same N jobs, all declared up front
    ex = Executor(MGBAlg3Scheduler(DEVICES), workers=EVENT_POOL)
    t0 = time.monotonic()
    stats = ex.run(make_jobs(n, work_s))
    batch_wall = time.monotonic() - t0
    assert stats["completed"] == n
    batch_d = _delays([ex.records])

    rows = []
    for mode, d, wall in (("stream", stream_d, stream_wall),
                          ("batch", batch_d, batch_wall)):
        rows.append({
            "mode": f"open-{mode}", "n": n, "rate_hz": rate_hz,
            "wall_s": wall,
            "p50_queue_ms": float(np.percentile(d, 50)) * 1e3,
            "p99_queue_ms": float(np.percentile(d, 99)) * 1e3,
        })
        print(f"open-arrival {mode:>7}: n={n} rate={rate_hz:.0f}/s "
              f"wall={wall:.2f}s queue p50={rows[-1]['p50_queue_ms']:.1f}ms "
              f"p99={rows[-1]['p99_queue_ms']:.1f}ms")
    return rows


def run(depths=None, *, arrival_n=None, arrival_rate=None,
        smoke: bool = False) -> List[Dict[str, float]]:
    # smoke picks its own tiny inputs so callers (benchmarks.run --smoke)
    # need only forward the flag; explicit arguments still win
    if depths is None:
        depths = (5, 20) if smoke else DEPTHS
    if arrival_n is None:
        arrival_n = 24 if smoke else 200
    if arrival_rate is None:
        arrival_rate = 400.0 if smoke else 150.0
    rows = []
    print(f"{'depth':>6} {'mode':>8} {'makespan':>10} {'attempts':>9} "
          f"{'att/job':>8} {'turnaround':>11}")
    for depth in depths:
        for mode in ("event", "polling"):
            r = one(depth, mode)
            rows.append(r)
            print(f"{depth:>6} {mode:>8} {r['makespan_s']:>9.3f}s "
                  f"{r['sched_attempts']:>9d} {r['attempts_per_job']:>8.1f} "
                  f"{r['mean_turnaround_s']:>10.3f}s")
    # the acceptance claim: event-driven overhead per job stays flat with
    # queue depth; the polling loop's grows with it
    ev = {r["depth"]: r["attempts_per_job"] for r in rows
          if r["mode"] == "event"}
    po = {r["depth"]: r["attempts_per_job"] for r in rows
          if r["mode"] == "polling"}
    d0, d1 = min(depths), max(depths)
    print(f"\nattempts/job growth {d0} -> {d1}: "
          f"event {ev[d0]:.1f} -> {ev[d1]:.1f} "
          f"({ev[d1] / max(ev[d0], 1e-9):.1f}x), "
          f"polling {po[d0]:.1f} -> {po[d1]:.1f} "
          f"({po[d1] / max(po[d0], 1e-9):.1f}x)")
    rows += open_arrival(arrival_n, arrival_rate)
    if not smoke:
        save_json("bench_executor.json", rows)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny depths + short arrivals; asserts completion "
                         "without writing results (the CI bitrot guard)")
    args = ap.parse_args()
    if args.smoke:
        rows = run(smoke=True)
        assert len(rows) == 6, rows
        print("bench_executor --smoke OK")
    else:
        run()


if __name__ == "__main__":
    main()
