"""Event-driven executor vs the old polling loop: makespan + scheduler
overhead at queue depths 10 / 100 / 1000.

Protocol: N identical single-task jobs (2 GB, demand 0.25, ~3 ms of work)
queued at t=0 on a 2-device MGB-Alg3 fleet.

  * **event** — the event-driven engine: admission wakeups, execution pool of
    4 threads regardless of queue depth. Blocked jobs hold no thread.
  * **polling** — the previous protocol: one worker thread per in-flight job
    spinning ``task_begin`` every 2 ms. To give N jobs concurrent admission
    progress it must burn N threads (capped at 256 here so depth 1000 does
    not exhaust the container), and every blocked thread pays a poll attempt
    each tick.

Reported per run: makespan, scheduler admission attempts (``begin_attempts``:
every ``select_device`` probe, successful or not), and attempts per job — the
overhead metric that grows with queue depth under polling but stays flat
under wakeups (the drain memoizes failed resource vectors, so a homogeneous
queue costs O(admitted + 1) probes per wakeup).

    PYTHONPATH=src python -m benchmarks.bench_executor
"""
from __future__ import annotations

import time
from typing import Dict, List

from benchmarks.common import save_json
from repro.core.executor import ExecJob, Executor, PollingExecutor
from repro.core.scheduler import MGBAlg3Scheduler
from repro.core.task import Job, ResourceVector, Task, UnitTask

GB = 1024**3
DEPTHS = (10, 100, 1000)
DEVICES = 2
# execution pool sized to the fleet's co-residency capacity (16 GB / 2 GB
# tasks x 2 devices), NOT to the job count — the event engine's whole point
EVENT_POOL = 16
POLL_CAP = 256          # thread cap for the polling baseline
WORK_S = 0.010
POLL_S = 0.002


def make_jobs(n: int) -> List[ExecJob]:
    vec = ResourceVector(hbm_bytes=2 * GB, flops=1e9, bytes_accessed=1e9,
                         est_seconds=WORK_S, core_demand=0.25, bw_demand=0.25)
    jobs = []
    for i in range(n):
        unit = UnitTask(fn=None, memobjs=frozenset({f"q{i}"}), resources=vec,
                        name=f"q{i}")
        jobs.append(ExecJob(
            job=Job(tasks=[Task(units=[unit], name=f"q{i}")], name=f"q{i}"),
            runners=[lambda device: time.sleep(WORK_S)]))
    return jobs


def one(depth: int, mode: str) -> Dict[str, float]:
    sched = MGBAlg3Scheduler(DEVICES)
    if mode == "event":
        ex = Executor(sched, workers=EVENT_POOL)
    else:
        ex = PollingExecutor(sched, workers=min(depth, POLL_CAP),
                             poll_interval=POLL_S)
    stats = ex.run(make_jobs(depth))
    assert stats["completed"] == depth, (mode, depth, stats)
    return {"depth": depth, "mode": mode,
            "makespan_s": stats["makespan_s"],
            "sched_attempts": stats["sched_attempts"],
            "attempts_per_job": stats["sched_attempts"] / depth,
            "mean_turnaround_s": stats["mean_turnaround_s"]}


def run(depths=DEPTHS) -> List[Dict[str, float]]:
    rows = []
    print(f"{'depth':>6} {'mode':>8} {'makespan':>10} {'attempts':>9} "
          f"{'att/job':>8} {'turnaround':>11}")
    for depth in depths:
        for mode in ("event", "polling"):
            r = one(depth, mode)
            rows.append(r)
            print(f"{depth:>6} {mode:>8} {r['makespan_s']:>9.3f}s "
                  f"{r['sched_attempts']:>9d} {r['attempts_per_job']:>8.1f} "
                  f"{r['mean_turnaround_s']:>10.3f}s")
    # the acceptance claim: event-driven overhead per job stays flat with
    # queue depth; the polling loop's grows with it
    ev = {r["depth"]: r["attempts_per_job"] for r in rows
          if r["mode"] == "event"}
    po = {r["depth"]: r["attempts_per_job"] for r in rows
          if r["mode"] == "polling"}
    d0, d1 = min(depths), max(depths)
    print(f"\nattempts/job growth {d0} -> {d1}: "
          f"event {ev[d0]:.1f} -> {ev[d1]:.1f} "
          f"({ev[d1] / max(ev[d0], 1e-9):.1f}x), "
          f"polling {po[d0]:.1f} -> {po[d1]:.1f} "
          f"({po[d1] / max(po[d0], 1e-9):.1f}x)")
    save_json("bench_executor.json", rows)
    return rows


if __name__ == "__main__":
    run()
