"""Render EXPERIMENTS.md §Dry-run + §Roofline tables from the dry-run JSON
artifacts. Run after both sweeps:

    PYTHONPATH=src python -m benchmarks.render_experiments
"""
from __future__ import annotations

import glob
import json
import os

DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def load(mesh: str):
    rows = []
    for f in sorted(glob.glob(os.path.join(DIR, f"*__{mesh}.json"))):
        rows.append(json.load(open(f)))
    return rows


def table(mesh: str) -> str:
    rows = load(mesh)
    out = [f"### Mesh {mesh} ({256 if mesh == '16x16' else 512} chips)\n",
           "| arch | shape | status | compute_s | memory_s | collective_s |"
           " dominant | useful ratio | roofline frac | peak GB/dev | fits |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | SKIP (full attention"
                       f" at 500k) | | | | | | | |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | | | | |")
            continue
        rl = r["roofline"]
        pk = r["memory"]["peak_per_device"] / 2**30
        out.append(
            f"| {r['arch']} | {r['shape']} | ok | {rl['compute_s']:.3f} "
            f"| {rl['memory_s']:.3f} | {rl['collective_s']:.3f} "
            f"| {rl['dominant']} | {rl['useful_ratio']:.2f} "
            f"| {rl['roofline_fraction']:.3f} | {pk:.2f} "
            f"| {'Y' if pk <= 16 else 'N'} |")
    ok = sum(1 for r in rows if r.get("status") == "ok")
    skip = sum(1 for r in rows if r.get("status") == "SKIP")
    fail = len(rows) - ok - skip
    out.append(f"\n{ok} ok / {skip} skip / {fail} fail\n")
    return "\n".join(out)


def main():
    print(table("16x16"))
    print()
    print(table("2x16x16"))


if __name__ == "__main__":
    main()
