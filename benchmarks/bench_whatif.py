"""Counterfactual what-if replay benchmark: record an overload trace,
re-run it under alternate policies, report the decision/metric deltas.

The fidelity contract comes first: replaying the recorded trace under
the SAME policy must reproduce the original admission/eviction sequence
EXACTLY (``diff_streams`` silent, every metric delta zero) — that is
what makes the counterfactual legs attributable to the policy change
alone, and it is asserted on every run, smoke and full.

The counterfactuals then strip the scheduler's ordering information
one axis at a time, on the recorded overload mix (urgent deadline
jobs arriving over a parked low-priority backlog):

* **fifo** — no priorities, no deadlines: pure arrival order;
* **edf**  — deadlines only: earliest-deadline-first without the
  priority classes.

For each leg the report carries the makespan / deadline-met /
p99-queueing / eviction deltas against the recorded baseline plus the
first divergent decision (seq, kind, uid, device). ``--report PATH``
writes the report as JSON — CI uploads it as a workflow artifact.

    PYTHONPATH=src python -m benchmarks.bench_whatif            # full
    PYTHONPATH=src python -m benchmarks.bench_whatif --smoke \
        --report benchmarks/results/whatif_delta.json           # CI
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Any, Dict, List, Optional

from benchmarks.common import save_json
from repro.core.cluster import Cluster
from repro.core.scheduler import PreemptiveAlg3Scheduler
from repro.core.workloads import overload_mix
from repro.obs import whatif
from repro.obs.replay import diff_streams

N_DEV = 4
WORKERS = 8

POLICIES = {
    "replay": {},                                     # the fidelity control
    "fifo": {"use_priorities": False, "use_deadlines": False},
    "edf": {"use_priorities": False, "use_deadlines": True},
}


def record_trace(seed: int, *, n_background: int, n_bystander: int,
                 n_urgent: int) -> List[Any]:
    """The recorded bench trace: the preemption benchmark's overload mix
    (urgent deadline arrivals over a parked backlog) driven through a
    traced preemptive cluster on the virtual clock."""
    c = Cluster(PreemptiveAlg3Scheduler(N_DEV), workers=WORKERS,
                backend="sim", shed_late=True, trace=True)
    for row in overload_mix(seed, n_background=n_background,
                            n_bystander=n_bystander, n_urgent=n_urgent):
        c.run_until(row["t"])
        c.submit(row["job"], priority=row["priority"],
                 deadline_s=row["deadline_s"])
    c._sim.drain(1e7)
    return c.trace.events()


def run_one(seed: int, *, n_background: int, n_bystander: int,
            n_urgent: int) -> Dict[str, Any]:
    events = record_trace(seed, n_background=n_background,
                          n_bystander=n_bystander, n_urgent=n_urgent)
    report = whatif.compare(
        events, POLICIES,
        scheduler_factory=lambda: PreemptiveAlg3Scheduler(N_DEV),
        workers=WORKERS, shed_late=True)
    # the fidelity gate: the same-policy leg reproduced the recorded
    # decision sequence exactly — byte-for-byte admission/eviction order
    res = whatif.replay(events,
                        lambda: PreemptiveAlg3Scheduler(N_DEV),
                        workers=WORKERS, shed_late=True)
    assert diff_streams(events, res.events) is None, (
        "same-policy replay diverged from the recorded trace")
    same = report["policies"]["replay"]
    assert same["first_divergence"] is None, same
    assert all(abs(d) < 1e-9 for d in same["delta"].values()), same
    base = report["baseline"]
    assert base["deadline_jobs"] > 0, "fixture must carry deadline jobs"
    # the counterfactuals must actually counter: stripping priorities
    # from an overload trace changes at least one admission decision
    assert report["policies"]["fifo"]["first_divergence"] is not None
    report["seed"] = seed
    report["events"] = len(events)
    return report


def run(seed: int = 0, smoke: bool = False,
        report_path: Optional[str] = None) -> Dict[str, Any]:
    t0 = time.time()
    # full size keeps the fleet contended but NOT saturated: a baseline
    # that meets zero deadlines makes the deadline-met delta vacuous
    sizes = (dict(n_background=5, n_bystander=2, n_urgent=8) if smoke
             else dict(n_background=5, n_bystander=2, n_urgent=20))
    report = run_one(seed, **sizes)
    base = report["baseline"]
    print(f"  baseline: makespan {base['makespan_s']:.2f}s  "
          f"deadline-met {base['deadline_met']:.0%} of "
          f"{base['deadline_jobs']}  "
          f"p99 queueing {base['p99_queueing_s']:.2f}s  "
          f"evictions {base['evictions']}")
    for name in ("replay", "fifo", "edf"):
        leg = report["policies"][name]
        d = leg["delta"]
        div = leg["first_divergence"] or "none"
        print(f"  {name:>6}: d_makespan {d['makespan_s']:+.2f}s  "
              f"d_deadline_met {d['deadline_met']:+.0%}  "
              f"d_p99_queueing {d['p99_queueing_s']:+.2f}s  "
              f"d_evictions {d['evictions']:+.0f}  divergence: {div}")
    if report_path:
        os.makedirs(os.path.dirname(report_path) or ".", exist_ok=True)
        with open(report_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"  -> {report_path}")
    elif not smoke:
        print(f"  -> {save_json('bench_whatif.json', report)}")
    print(f"bench_whatif{' --smoke' if smoke else ''} OK "
          f"({time.time() - t0:.1f}s)")
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small trace, assert-only unless --report is given")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--report", default=None, metavar="PATH",
                    help="write the delta report JSON here (CI artifact)")
    args = ap.parse_args()
    run(args.seed, smoke=args.smoke, report_path=args.report)


if __name__ == "__main__":
    main()
