"""Fig. 6 + §V-E: neural-network workloads — MGB vs schedGPU [11], plus the
128-job mixed NN experiment vs single-assignment.

Paper claims: MGB over schedGPU = 1.4x (predict), 2.2x (generate), 3.1x
(train), ~1.0x (detect, compute not saturated); 128-job mix completes 2.7x
faster than SA with 32 workers.
"""
from __future__ import annotations

from benchmarks import common as C
from repro.core import workloads as W

BANDS = {"predict": (1.15, 1.7), "generate": (1.7, 2.7),
         "train": (2.4, 3.8), "detect": (0.85, 1.25)}


def run() -> dict:
    n_dev = 4          # 4xV100 AWS system
    workers = 8        # 8 homogeneous jobs, all queued
    rows = {}
    for kind in W.NN_KINDS:
        jobs = W.nn_homogeneous(kind, 8)
        sg = C.run_memonly(jobs, n_dev, workers)
        mgb = C.run_mgb(jobs, n_dev, workers, alg=3)
        rows[kind] = {
            "schedgpu_throughput": sg.throughput,
            "mgb_throughput": mgb.throughput,
            "mgb_over_schedgpu": mgb.throughput / sg.throughput,
        }
    # 128-job random mix, 32 workers, vs SA
    mix = W.nn_mix(3, 128)
    sa = C.run_sa(mix, n_dev)
    mgb = C.run_mgb(mix, n_dev, 32, alg=3)
    mix_speedup = sa.makespan / mgb.makespan
    out = {"rows": rows, "mix128_mgb_over_sa": mix_speedup,
           "paper_claim": {"predict": 1.4, "generate": 2.2, "train": 3.1,
                           "detect": 1.0, "mix128_over_sa": 2.7}}
    print("Fig6 MGB over schedGPU (8 homogeneous NN jobs, 4 devices):")
    for kind, r in rows.items():
        print(f"  {kind:9s}: {r['mgb_over_schedgpu']:.2f}x")
        lo, hi = BANDS[kind]
        print(C.check(f"{kind} MGB/schedGPU", r["mgb_over_schedgpu"], lo, hi))
    print(f"  128-job NN mix MGB/SA: {mix_speedup:.2f}x")
    # our simulator has no host-side contention (the paper's 32
    # workers share 32 real cores), so the mix speedup lands a bit
    # above the paper's 2.7x
    print(C.check("mix128 MGB/SA", mix_speedup, 2.0, 3.8))
    C.save_json("fig6.json", out)
    return out


if __name__ == "__main__":
    run()
