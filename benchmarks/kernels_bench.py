"""Kernel micro-benchmarks.

Wall-clock on this CPU container is meaningless for TPU kernels, so each row
reports (a) the compiled cost-analysis roofline estimate for the TARGET (TPU
v5e constants) of the pure-jnp reference vs. the kernel's access pattern, and
(b) CPU wall time of the jnp reference vs the naive formulation — evidence of
the algorithmic win (e.g. flash vs naive attention memory traffic).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.core.probe import HBM_BW, PEAK_FLOPS, vector_from_compiled
from repro.models import layers as L


def _roofline_row(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    vec = vector_from_compiled(compiled)
    return {"flops": vec.flops, "bytes": vec.bytes_accessed,
            "tpu_est_us": vec.est_seconds * 1e6,
            "intensity": vec.flops / max(vec.bytes_accessed, 1)}


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run() -> dict:
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    rows = {}

    # attention: naive vs flash (jnp) — bytes ratio is the flash win
    b, h, s, d = 2, 8, 2048, 64
    q = jax.random.normal(ks[0], (b, h, s, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, h, s, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, h, s, d), jnp.float32)
    naive = _roofline_row(
        lambda *a: L.naive_attention(*a), q, k, v)
    flash = _roofline_row(
        lambda *a: L.flash_attention_jnp(*a, block_k=512), q, k, v)
    rows["attention_naive"] = naive
    rows["attention_flash"] = flash
    rows["attention_bytes_ratio"] = naive["bytes"] / flash["bytes"]

    # rmsnorm fused vs unfused traffic
    x = jax.random.normal(ks[0], (4096, 4096), jnp.float32)
    sc = jax.random.normal(ks[1], (4096,)) * 0.1
    rows["rmsnorm"] = _roofline_row(lambda a, b2: L.rms_norm(a, b2), x, sc)

    # mamba scan: associative-scan reference traffic
    a = jnp.exp(-jnp.abs(jax.random.normal(ks[0], (2, 1024, 512, 16))))
    bb = jax.random.normal(ks[1], (2, 1024, 512, 16))
    from repro.kernels.ref import mamba_scan_ref
    rows["mamba_scan_ref"] = _roofline_row(
        lambda aa, bbb: mamba_scan_ref(aa, bbb, jnp.zeros((2, 512, 16))),
        a, bb)

    # wall-clock sanity on CPU (small shapes)
    qs, kss, vs = q[:, :, :512], k[:, :, :512], v[:, :, :512]
    rows["cpu_us_naive_attn"] = _time(
        jax.jit(lambda *t: L.naive_attention(*t)), qs, kss, vs)
    rows["cpu_us_flash_attn"] = _time(
        jax.jit(lambda *t: L.flash_attention_jnp(*t)), qs, kss, vs)

    print("kernels_bench:")
    print(f"  attention bytes naive/flash: "
          f"{rows['attention_bytes_ratio']:.1f}x less HBM traffic (flash)")
    for name in ("attention_naive", "attention_flash", "rmsnorm",
                 "mamba_scan_ref"):
        r = rows[name]
        print(f"  {name:18s} flops={r['flops']:.3g} bytes={r['bytes']:.3g} "
              f"AI={r['intensity']:.1f} tpu_est={r['tpu_est_us']:.0f}us")
    print(f"  cpu wall: naive {rows['cpu_us_naive_attn']:.0f}us vs "
          f"flash {rows['cpu_us_flash_attn']:.0f}us")
    C.save_json("kernels_bench.json", rows)
    return rows


if __name__ == "__main__":
    run()
