"""Preemption benchmark: preemptive EDF vs shedding vs waiting, on an
OVERLOADED open-arrival trace (workloads.overload_mix).

The scenario memory-binds by construction: long ~10 GB x ~20 s background
jobs saturate every 16 GB device while short ~9 GB x ~1 s urgent jobs (each
with a deadline a couple of seconds past its length) keep arriving. An
urgent arrival therefore cannot co-reside with a background resident — it
can only:

  * **fifo**      — wait its turn with no admission ordering at all;
  * **edf**       — jump the QUEUE (priority/EDF admission) but still wait
                    for a background job many times its length to finish;
  * **edf+shed**  — same, but give up (JobStatus.SHED) once its deadline
                    passes while parked;
  * **edf+preempt** — EVICT the min-cost background resident (checkpoint-
                    based, work-conserving: the victim resumes at its
                    remaining work + restore penalty, possibly on another
                    device) and run immediately.

All four systems replay the SAME seeded workload content and arrival
schedule on the virtual clock. Reported per system: urgent deadline-met
rate, urgent turnaround p50/p99, preemptions/migrations, background mean
turnaround (the price the evicted class pays), and the mean kernel slowdown
of NON-preempted jobs — the paper's <=2.5% co-residency degradation envelope
must keep holding once eviction is in the mix.

    PYTHONPATH=src python -m benchmarks.bench_preempt            # full
    PYTHONPATH=src python -m benchmarks.bench_preempt --smoke    # CI guard
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional

import numpy as np

from benchmarks.common import save_json
from repro.core.cluster import Cluster, JobStatus
from repro.core.executor import ExecJob
from repro.core.preemption import PreemptionPolicy
from repro.core.scheduler import MGBAlg3Scheduler, PreemptiveAlg3Scheduler
from repro.core.task import Job, ResourceVector, Task, UnitTask
from repro.core.workloads import overload_mix

GB = 1024**3
DEVICES = 4


def _pct(vals: List[float], q: float) -> float:
    """Nearest-rank percentile, well-defined when failed requests are
    counted as +inf (np.percentile interpolates inf-inf into NaN)."""
    if not vals:
        return float("inf")
    s = sorted(vals)
    return float(s[max(min(int(np.ceil(q / 100 * len(s))) - 1,
                           len(s) - 1), 0)])
SIM_WORKERS = 256   # never the bottleneck — admission is the story
# eviction budget sized to the full trace: 24 urgents over 8 backgrounds
# needs ~3 evictions/job; 6 leaves headroom so immunity is a guardrail, not
# the common case
POLICY = PreemptionPolicy(min_runtime_s=0.25, budget=6, aging_step=1,
                          checkpoint_penalty_s=0.5)


def run_trace(rows: List[Dict], sched, *, ranked: bool = True,
              shed: bool = False, preempt: Optional[bool] = None,
              n_devices: int = DEVICES) -> Dict[str, float]:
    """Replay one submission trace on the sim backend. ``ranked=False`` is
    the FIFO baseline: priority/deadline stamps are withheld from admission
    (deadlines are still measured against)."""
    c = Cluster(sched, workers=SIM_WORKERS, backend="sim",
                shed_late=shed, preempt=preempt)
    entries = []
    for row in rows:
        c.run_until(row["t"])
        h = c.submit(row["job"],
                     priority=row["priority"] if ranked else 0,
                     deadline_s=row["deadline_s"] if ranked else None)
        entries.append((row, h))
    c.drain()   # raises on a truncated (time-limited) drain
    res = c._sim.result()

    urgent = [(r, h) for r, h in entries if r["kind"] == "urgent"]
    met = [h for r, h in urgent
           if h.status is JobStatus.DONE
           and h.job.finish_t <= r["t"] + r["deadline_s"]]
    # a shed/failed urgent never completes: its turnaround is unbounded, and
    # counting it as inf (rather than dropping it) keeps the percentile
    # comparison honest — shedding must not look fast by failing the slow ones
    u_turn = [h.job.finish_t - h.job.arrival_t
              if h.status is JobStatus.DONE else float("inf")
              for _, h in urgent]
    bg_turn = [h.job.finish_t - h.job.arrival_t for r, h in entries
               if r["kind"] == "background" and h.status is JobStatus.DONE]
    # degradation envelope: per-kernel slowdown of jobs the preemptor never
    # touched (the co-residency cost the paper bounds at <=2.5%)
    untouched = {r["job"].tasks[0].name for r, _ in entries
                 if r["job"].tasks[0].preempt_count == 0}
    slows = [s for name, s in res.slowdowns.items() if name in untouched]
    return {
        "sched": sched.name + ("+shed" if shed else "")
                 + ("" if ranked else " (fifo)"),
        "n_devices": n_devices,
        "makespan_s": res.makespan,
        "completed": res.completed, "crashed": res.crashed,
        "shed": res.shed,
        "urgent_met": len(met), "urgent_total": len(urgent),
        "deadline_met_rate": len(met) / max(len(urgent), 1),
        "urgent_turn_p50_s": _pct(u_turn, 50),
        "urgent_turn_p99_s": _pct(u_turn, 99),
        "bg_mean_turnaround_s": float(np.mean(bg_turn)) if bg_turn else 0.0,
        "preemptions": getattr(sched, "preemptions", 0),
        "migrations": getattr(sched, "migrations", 0),
        "nonpreempted_slowdown_pct":
            100.0 * (float(np.mean(slows)) - 1.0) if slows else 0.0,
    }


def compare(seed: int = 0, *, n_devices: int = DEVICES,
            n_background: int = 8, n_bystander: int = 4,
            n_urgent: int = 24) -> List[Dict[str, float]]:
    """The acceptance comparison. Job objects carry runtime state, so each
    system replays a FRESH materialization of the seeded trace."""
    def fresh() -> List[Dict]:
        return overload_mix(seed, n_background=n_background,
                            n_bystander=n_bystander, n_urgent=n_urgent)

    return [
        run_trace(fresh(), MGBAlg3Scheduler(n_devices), ranked=False,
                  n_devices=n_devices),
        run_trace(fresh(), MGBAlg3Scheduler(n_devices),
                  n_devices=n_devices),
        run_trace(fresh(), MGBAlg3Scheduler(n_devices), shed=True,
                  n_devices=n_devices),
        run_trace(fresh(),
                  PreemptiveAlg3Scheduler(n_devices, preempt_policy=POLICY),
                  preempt=True, n_devices=n_devices),
    ]


def _print_rows(rows: List[Dict[str, float]]) -> None:
    for r in rows:
        print(f"{r['sched']:>22}: met={r['urgent_met']:>2}/"
              f"{r['urgent_total']} ({100 * r['deadline_met_rate']:5.1f}%) "
              f"urgent-turn p50={r['urgent_turn_p50_s']:6.2f}s "
              f"p99={r['urgent_turn_p99_s']:6.2f}s "
              f"bg-turn={r['bg_mean_turnaround_s']:6.2f}s "
              f"shed={r['shed']:>2} preempt={r['preemptions']:>2} "
              f"migr={r['migrations']:>2} "
              f"slowdown={r['nonpreempted_slowdown_pct']:.2f}%")


def run(seed: int = 0, smoke: bool = False) -> List[Dict[str, float]]:
    t0 = time.time()
    if smoke:
        rows = compare(seed, n_devices=2, n_background=3, n_bystander=2,
                       n_urgent=5)
    else:
        rows = compare(seed)
    _print_rows(rows)
    fifo, edf, shed, pre = rows
    assert all(r["crashed"] == 0 for r in rows), rows
    # the acceptance claim: preemptive EDF strictly beats waiting (EDF),
    # shedding, and FIFO on deadline-met rate, and beats them on urgent p99
    # turnaround, while the co-residency degradation of untouched jobs stays
    # inside the paper's <=2.5% envelope
    for other in (fifo, edf, shed):
        if smoke:  # tiny trace: both ends may saturate, allow ties
            assert pre["deadline_met_rate"] >= other["deadline_met_rate"], rows
        else:
            assert pre["deadline_met_rate"] > other["deadline_met_rate"], rows
        assert pre["urgent_turn_p99_s"] <= other["urgent_turn_p99_s"], rows
    assert pre["preemptions"] > 0, rows
    assert pre["nonpreempted_slowdown_pct"] <= 2.5, rows
    print(f"\npreemptive EDF: {100 * pre['deadline_met_rate']:.0f}% deadlines "
          f"met vs {100 * edf['deadline_met_rate']:.0f}% (EDF) / "
          f"{100 * shed['deadline_met_rate']:.0f}% (shed) / "
          f"{100 * fifo['deadline_met_rate']:.0f}% (FIFO); "
          f"non-preempted slowdown {pre['nonpreempted_slowdown_pct']:.2f}% "
          f"({time.time() - t0:.1f}s)")
    if not smoke:
        save_json("bench_preempt.json", rows)
    return rows


# ---------------------------------------------------------------------------
# live/sim eviction-order parity smoke (the CI guard's second leg)
# ---------------------------------------------------------------------------

def _parity_jobs():
    """Hand-built two-device scenario with an unambiguous victim: bg-small
    (10 GB, 5 s left) is strictly cheaper to evict than bg-big (10.5 GB,
    30 s), so both backends must log the same single eviction and the same
    admission order."""
    def mk(name, gb, est, prio=0):
        vec = ResourceVector(hbm_bytes=int(gb * GB), flops=1e9,
                             bytes_accessed=1e9, est_seconds=est,
                             core_demand=0.4, bw_demand=0.3)
        unit = UnitTask(fn=None, memobjs=frozenset({name}), resources=vec,
                        name=name)
        return Job(tasks=[Task(units=[unit], name=name)], name=name,
                   priority=prio)
    return (mk("bg-small", 10.0, 5.0), mk("bg-big", 10.5, 30.0),
            mk("urgent", 9.0, 1.0, prio=5))


def _order(sched, handles) -> List[str]:
    names = {h.job.tasks[0].uid: h.job.name for h in handles}
    return [names[uid] for uid, _ in sched.placements]


def _victims(sched, handles) -> List[str]:
    names = {h.job.tasks[0].uid: h.job.name for h in handles}
    return [names[uid] for uid, _ in sched.preempt_log]


def smoke_parity(seed: int = 0) -> None:
    policy = PreemptionPolicy(min_runtime_s=0.0, budget=3,
                              checkpoint_penalty_s=0.2)

    # sim leg
    sched_sim = PreemptiveAlg3Scheduler(2, preempt_policy=policy)
    sim = Cluster(sched_sim, workers=8, backend="sim")
    s_small, s_big, s_urgent = _parity_jobs()
    hs = [sim.submit(s_small), sim.submit(s_big)]
    sim.run_until(2.0)
    hs.append(sim.submit(s_urgent))
    sim.drain()
    assert all(h.status is JobStatus.DONE for h in hs)
    sim_victims, sim_order = _victims(sched_sim, hs), _order(sched_sim, hs)

    # live leg: cooperative runners — the background blocks until preempted
    # (first attempt) and returns promptly when resumed (second attempt)
    sched_live = PreemptiveAlg3Scheduler(2, preempt_policy=policy)
    live = Cluster(sched_live, workers=4)
    l_small, l_big, l_urgent = _parity_jobs()

    import threading
    release = threading.Event()

    def cooperative(ej_box, attempts):
        def runner(device):
            attempts.append(device)
            if len(attempts) == 1:
                # first dispatch: run "forever" until evicted or released
                while not ej_box[0].preempted.wait(0.01):
                    if release.is_set():
                        return
            # resumed dispatch: remaining work is instant at test scale
        return runner

    small_attempts: List[object] = []
    big_attempts: List[object] = []
    ej_small_box: List[ExecJob] = []
    ej_big_box: List[ExecJob] = []
    ej_small = ExecJob(job=l_small,
                       runners=[cooperative(ej_small_box, small_attempts)])
    ej_small_box.append(ej_small)
    ej_big = ExecJob(job=l_big,
                     runners=[cooperative(ej_big_box, big_attempts)])
    ej_big_box.append(ej_big)
    hl = [live.submit(ej_small), live.submit(ej_big)]
    time.sleep(0.2)   # both resident
    hl.append(live.submit(ExecJob(job=l_urgent,
                                  runners=[lambda d: time.sleep(0.01)])))
    hl[2].result(timeout=30)
    release.set()
    live.drain()
    live.shutdown()
    assert all(h.status is JobStatus.DONE for h in hl), \
        [(h.job.name, h.status) for h in hl]
    live_victims, live_order = _victims(sched_live, hl), _order(sched_live, hl)

    assert sim_victims == live_victims == ["bg-small"], \
        (sim_victims, live_victims)
    assert sim_order == live_order, (sim_order, live_order)
    assert len(small_attempts) == 2, small_attempts   # evicted then resumed
    assert all(d.used_hbm == 0 and d.used_slots == 0
               for d in sched_live.devices)
    print(f"parity smoke: eviction order {live_victims} and admission order "
          f"{live_order} identical on live + sim backends")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny trace on the sim backend plus a live/sim "
                         "eviction-order parity check; asserts without "
                         "writing results (CI guard)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.seed, smoke=args.smoke)
    if args.smoke:
        smoke_parity(args.seed)
        print("bench_preempt --smoke OK")


if __name__ == "__main__":
    main()
