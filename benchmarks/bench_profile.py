"""Profiling-plane benchmark: calibration overhead + accuracy gates.

Two halves, both asserted in smoke AND full runs:

**1. Paired overhead (the bench_obs protocol).** The continuous profiler
is the TRACER — ``obs.profile`` joins events after the fact and adds no
emission sites — so the marginal hot-path cost of the profiling plane is
exactly the calibration store's admission/completion hooks plus the
calib-gated ADMIT/END payload dicts. One fill-then-drain churn run over
``MGBAlg3Scheduler`` (depth 1e4, tracer ON throughout) rotates
``sched._calib`` between ``None`` ("on": tracing-only, the bench_obs
gated config) and a live ``CalibrationStore`` ("profile") every
``CHUNK`` completions; the gate is the best-of-repeats ratio of
per-config drain-latency medians: **profiler-on ≤5% over tracing-on**.
The admission callback stamps ``start_t`` so every completion exercises
the store's full runtime-EWMA path, not just the memory fold.

**2. Calibration accuracy (the ISSUE-10 acceptance gate).** A drifting
sim trace (``workloads.drifting_mix``: per-class true runtime ramps to
2.5x the probes' estimates) runs ONCE with calibration on; the store
scores every calibrated completion against BOTH the raw probe estimate
and the corrected one it fed admission (paired, same completions).
Gates: mean absolute ``est_seconds`` error improvement **≥2x**, memory
violations **== 0** (the never-below-high-water invariant, observed).
The accuracy report is written to ``benchmarks/results/
calibration_accuracy.json`` even in smoke — CI uploads it as an
artifact.

    PYTHONPATH=src python -m benchmarks.bench_profile            # full
    PYTHONPATH=src python -m benchmarks.bench_profile --smoke    # CI
"""
from __future__ import annotations

import argparse
import gc
import time
from collections import deque
from statistics import median
from typing import Any, Dict, List

from benchmarks.bench_sched_scale import FLAT_DEVICES, mk_task
from benchmarks.common import save_json
from repro.core.cluster import Cluster
from repro.core.scheduler import MGBAlg3Scheduler
from repro.core.task import Task
from repro.core.workloads import drifting_mix
from repro.obs.calibrate import CalibrationStore
from repro.obs.events import Tracer, attach_tracer

DEPTH = 10_000          # the committed baseline's depth (sched_scale.json)
MAX_OVERHEAD = 0.05     # calibration may cost at most 5% over tracing-on
MIN_IMPROVEMENT = 2.0   # calibrated admission must halve the est error
CONFIGS = ("on", "profile")
CHUNK = 32              # completions per config slice (~2 ms per slice)
# unlike bench_obs (tracer rotated out 2/3 of the run) the tracer here is
# ON for every slice: the ring must hold all ~2*DEPTH lifecycle events
RING_CAPACITY = 1 << 15


def paired_churn(depth: int, *, budget_s: float,
                 n_dev: int = FLAT_DEVICES) -> Dict[str, Any]:
    """One churn run, tracer ON throughout, rotating the calibration store
    in and out. Setup (fill + park) runs untraced and uncalibrated so the
    event accounting matches bench_obs exactly (end + admit per traced
    completion; the calib-gated payload dicts change event SIZE, never
    event COUNT)."""
    sched = MGBAlg3Scheduler(n_dev)
    tr_on = Tracer(capacity=RING_CAPACITY)
    attach_tracer(sched, tr_on)        # binds the clock to sched._clock
    sched._trace = None                # setup untraced
    # mem_margin=0: the churn's residents exactly fill their 16 GB devices,
    # so a safety inflation would (correctly!) refuse re-admission — this
    # bench measures hook cost, not admission policy
    store = CalibrationStore(mem_margin=0.0)
    hogs = [mk_task(f"hog{i}") for i in range(n_dev)]
    for h in hogs:
        assert sched.task_begin(h) is not None
    admitted: deque = deque()
    clk = time.perf_counter

    def cb(t: Task, placement, epoch: int) -> None:
        # stamp the begin time the backends would: every completion then
        # takes the store's full runtime-EWMA path, not just the memory fold
        t.start_t = clk()
        admitted.append(t)

    for i in range(depth):
        sched.admit_or_enqueue(mk_task(f"w{i}"), cb)
    assert sched.waiting_count() == depth

    lats: Dict[str, List[float]] = {c: [] for c in CONFIGS}
    calibs = {"on": None, "profile": store}
    current: deque = deque(hogs)
    n_adm = 0
    ci = 0
    in_chunk = 0
    sched._trace = tr_on
    sched._calib = calibs[CONFIGS[0]]
    gc.collect()
    gc.disable()
    try:
        t0 = clk()
        while current and n_adm < depth:
            if clk() - t0 > budget_s:
                break
            vic = current.popleft()
            t1 = clk()
            sched.task_end(vic)
            lats[CONFIGS[ci]].append(clk() - t1)
            while admitted:
                current.append(admitted.popleft())
                n_adm += 1
            in_chunk += 1
            if in_chunk >= CHUNK:
                in_chunk = 0
                ci = (ci + 1) % len(CONFIGS)
                sched._calib = calibs[CONFIGS[ci]]
        elapsed = max(clk() - t0, 1e-9)
    finally:
        gc.enable()
    return {
        "lats": lats,
        "admissions_per_s": n_adm / elapsed,
        "capped": n_adm < depth,
        "events": tr_on.emitted,
        "dropped": tr_on.dropped,
        "completions": len(lats["on"]) + len(lats["profile"]),
        "observations": store.observations,
        "corrections": store.corrections,
    }


def overhead_gate(depth: int, repeats: int,
                  budget_s: float) -> List[Dict[str, Any]]:
    # warm-up (untimed, small): allocator growth / code warm-up must not
    # land inside the first measured slices
    paired_churn(min(depth, 2_000), budget_s=budget_s)
    pooled: Dict[str, List[float]] = {c: [] for c in CONFIGS}
    ratios: List[float] = []
    rate = 0.0
    for _ in range(repeats):
        r = paired_churn(depth, budget_s=budget_s)
        assert not r["capped"], r
        assert r["dropped"] == 0, r
        # tracer ON for both configs: 2 events (end + admit) per timed
        # completion, whichever config's slice it landed in — the store
        # must not add or suppress emissions
        assert r["events"] == 2 * r["completions"], r
        # the store actually worked during its slices: completions folded
        # in, and (after min_samples) corrected vectors installed
        assert r["observations"] > 0 and r["corrections"] > 0, r
        on_p50 = median(r["lats"]["on"])
        for c in CONFIGS:
            pooled[c].extend(r["lats"][c])
        ratios.append((median(r["lats"]["profile"]) / on_p50) - 1.0)
        rate = max(rate, r["admissions_per_s"])
    overhead = min(ratios)   # best-of-repeats: drift only inflates ratios
    rows = [{"bench": "profile_overhead", "config": c, "depth": depth,
             "repeats": repeats, "drain_p50_us": 1e6 * median(pooled[c]),
             "samples": len(pooled[c])} for c in CONFIGS]
    rows[1]["overhead_vs_on"] = overhead
    rows[1]["overhead_per_repeat"] = ratios
    for c in CONFIGS:
        p50 = 1e6 * median(pooled[c])
        print(f"  {c:>8}: drain p50 {p50:7.2f}us ({len(pooled[c])} samples)")
    print(f"  profiler overhead best {overhead * 100:+.1f}% / worst "
          f"{max(ratios) * 100:+.1f}% vs tracing-on; churn {rate:.0f} adm/s")
    assert overhead <= MAX_OVERHEAD, (
        f"calibration overhead {overhead * 100:.1f}% over tracing-on "
        f"exceeds {MAX_OVERHEAD * 100:.0f}% at depth {depth}")
    return rows


def accuracy_demo(seed: int = 0, *, n_jobs: int = 120) -> Dict[str, Any]:
    """The drifting-trace acceptance run: one CALIBRATED sim pass; the
    store's paired accounting scores raw-vs-corrected on identical
    completions (no cross-run pairing noise)."""
    store = CalibrationStore()
    c = Cluster(MGBAlg3Scheduler(8), backend="sim", trace=True,
                calibrate=store)
    for row in drifting_mix(seed, n_jobs=n_jobs):
        c.run_until(row["t"])
        c.submit(row["job"])
    c.drain()
    rep = store.accuracy_report()
    rep["bench"] = "calibration_accuracy"
    rep["n_jobs"] = n_jobs
    paired = rep["paired"]
    print(f"  drifting trace: {paired['n']} calibrated completions, "
          f"mae raw {paired['mae_raw_s'] * 1e3:.1f}ms -> corrected "
          f"{paired['mae_used_s'] * 1e3:.1f}ms "
          f"({paired['improvement']:.1f}x), "
          f"violations={rep['violations']}")
    assert rep["violations"] == 0, rep
    assert paired["n"] > 0, rep
    assert paired["improvement"] >= MIN_IMPROVEMENT, (
        f"calibrated admission improved est error only "
        f"{paired['improvement']:.2f}x (< {MIN_IMPROVEMENT}x)")
    # fleet-side attribution must agree the run was memory-clean
    summary = c.profile()
    assert summary["memory_violations"] == 0, summary
    rep["profiler_summary"] = {
        k: summary[k] for k in ("tasks", "completed", "mean_abs_err_s",
                                "mean_abs_err_ratio")}
    return rep


def run(seed: int = 0, smoke: bool = False, depth: int = DEPTH,
        repeats: int = 5, budget_s: float = 60.0) -> List[Dict[str, Any]]:
    t_start = time.time()
    rows = overhead_gate(depth, repeats, budget_s)
    rep = accuracy_demo(seed)
    # the accuracy report is a CI artifact — written in smoke too
    path = save_json("calibration_accuracy.json", rep)
    print(f"  -> {path}")
    rows.append(rep)
    if not smoke:
        path = save_json("bench_profile.json", rows)
        print(f"  -> {path}")
    print(f"bench_profile{' --smoke' if smoke else ''} OK "
          f"({time.time() - t_start:.1f}s)")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="assert-only run (accuracy JSON still written); "
                         "same depth — the 5% gate is only meaningful at "
                         "baseline depth")
    ap.add_argument("--depth", type=int, default=DEPTH)
    ap.add_argument("--repeats", type=int, default=5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.seed, smoke=args.smoke, depth=args.depth,
        repeats=args.repeats)


if __name__ == "__main__":
    main()
