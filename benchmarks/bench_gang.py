"""Gang placement benchmark: topology-aware device-group reservation vs the
chips-oblivious status quo, on a mixed single-chip / multi-chip open-arrival
trace (the W-mix scenario at gang scale).

Two systems replay the SAME seeded workload and arrival schedule on the
virtual clock:

  * **gang-aware** — ``GangScheduler`` on a (pods x rows x cols) topology:
    every ``chips = k`` job is reserved as one contiguous k-chip group
    (memory hard per member, link headroom accounted), parks as ONE waiter
    when it doesn't fit, and its collectives stay on intra-slice ICI;
  * **chips-oblivious** — today's behaviour: each gang is split into k
    independent single-chip jobs (``workloads.split_gangs``) placed by flat
    MGB Alg. 3. Scattered shards lose the contiguity guarantee, so each
    shard's duration is re-roofed at DCN collective speed, and the logical
    job only finishes when its LAST shard does.

Reported per system: makespan, throughput, job turnaround; for the
gang-aware run additionally the gang queueing delay p50/p99 (admission wait
of multi-chip reservations) and the **fragmentation %** — of all events at
which some gang sat parked, the share where the fleet held ENOUGH
member-feasible chips (per-chip memory would fit on >= k chips) and the gang
was blocked anyway: capacity that exists but is too scattered to form a
contiguous group. The complement is honest capacity shortage.

    PYTHONPATH=src python -m benchmarks.bench_gang             # full
    PYTHONPATH=src python -m benchmarks.bench_gang --smoke     # CI guard
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from benchmarks.common import save_json
from repro.core.cluster import Cluster, JobStatus
from repro.core.scheduler import GangScheduler, MGBAlg3Scheduler
from repro.core.simulator import Simulator
from repro.core.task import Job
from repro.core.workloads import gang_mix, split_gangs

# default scenario: one 2x4 pod (8 chips), the acceptance-criterion topology
PODS, ROWS, COLS = 1, 2, 4
MEAN_GAP_S = 0.8          # mean open-arrival gap between job submissions
SIM_WORKERS = 256         # never the bottleneck — admission is the story


def _arrivals(n: int, seed: int, mean_gap: float) -> List[float]:
    rng = np.random.default_rng(seed + 7)
    return list(np.cumsum(rng.exponential(mean_gap, n)))


def run_scenario(batches: Sequence[List[Job]], arrivals: Sequence[float],
                 sched, *, n_chips: int) -> Dict[str, float]:
    """Replay one open-arrival trace: ``batches[i]`` (one logical job — a
    single job, or the shard set of one split gang) is submitted at virtual
    time ``arrivals[i]``. Returns the metrics row, sampling fragmentation at
    every event while any waiter is parked."""
    sim = Simulator(sched, workers=SIM_WORKERS)
    frag: List[float] = []

    def sample() -> None:
        # fragmentation probe: the highest-ranked parked GANG, if any —
        # memory is the only hard per-member constraint, so "k member-
        # feasible chips exist yet the gang is parked" isolates contiguity
        # (fragmentation) from raw capacity shortage. queue_stats' gang_front
        # peeks per class instead of snapshotting the whole queue — this
        # probe runs at EVERY sim event, and waiting_tasks() is the
        # O(n log n) full-queue sort base.py warns against in hot loops
        gf = sched.queue_stats()["gang_front"]
        if gf is None:
            return
        chips, per_chip = gf
        feasible = sum(1 for d in sched.devices
                       if d.alive and per_chip <= d.free_hbm)
        frag.append(1.0 if feasible >= chips else 0.0)

    for batch, t in zip(batches, arrivals):
        sim.run_until(t)
        for job in batch:
            sim.submit(job)
        sample()
    while sim.pending():
        if not sim.step():
            break
        sample()
    res = sim.result()
    gang_delays = [r.t_start - r.t_queue for r in sim.records
                   if r.gang_chips > 1 and not r.crashed]
    row = {
        "sched": sched.name, "n_chips": n_chips,
        "makespan_s": res.makespan, "throughput_jobs_per_s": res.throughput,
        "completed": res.completed, "crashed": res.crashed,
        "mean_turnaround_s": res.mean_turnaround,
        "utilization": res.utilization,
        "frag_pct": 100.0 * float(np.mean(frag)) if frag else 0.0,
    }
    if gang_delays:
        row["gang_queue_p50_s"] = float(np.percentile(gang_delays, 50))
        row["gang_queue_p99_s"] = float(np.percentile(gang_delays, 99))
    return row


def compare(seed: int = 0, *, n_singles: int = 16, n_gangs: int = 12,
            chip_choices=(2, 4, 8), probe_singles: bool = True,
            mean_gap: float = MEAN_GAP_S,
            pods: int = PODS, rows: int = ROWS, cols: int = COLS
            ) -> List[Dict[str, float]]:
    """The acceptance comparison: same workload content + arrival schedule,
    gang-aware vs chips-oblivious. Job objects carry runtime state, so each
    system gets a FRESH materialization of the seeded trace."""
    n_chips = pods * rows * cols

    def fresh() -> List[Job]:
        return gang_mix(seed, n_singles=n_singles, n_gangs=n_gangs,
                        chip_choices=chip_choices,
                        probe_singles=probe_singles)

    n_jobs = n_singles + n_gangs
    arrivals = _arrivals(n_jobs, seed, mean_gap)

    aware = run_scenario([[j] for j in fresh()], arrivals,
                         GangScheduler(pods=pods, rows=rows, cols=cols),
                         n_chips=n_chips)
    # oblivious: one ARRIVAL per logical job — its shards all land together
    oblivious_batches: List[List[Job]] = []
    for job in fresh():
        oblivious_batches.append(split_gangs([job]))
    oblivious = run_scenario(oblivious_batches, arrivals,
                             MGBAlg3Scheduler(n_chips), n_chips=n_chips)
    return [aware, oblivious]


def _print_rows(rows: List[Dict[str, float]]) -> None:
    for r in rows:
        gq = (f" gang-queue p50={r['gang_queue_p50_s']:.2f}s "
              f"p99={r['gang_queue_p99_s']:.2f}s"
              if "gang_queue_p50_s" in r else "")
        print(f"{r['sched']:>14}: makespan={r['makespan_s']:8.2f}s "
              f"thpt={r['throughput_jobs_per_s']:.3f}/s "
              f"turnaround={r['mean_turnaround_s']:.2f}s "
              f"util={r['utilization']:.2f} frag={r['frag_pct']:.1f}%{gq}")


def run(seed: int = 0, smoke: bool = False) -> List[Dict[str, float]]:
    t0 = time.time()
    if smoke:
        rows = compare(seed, n_singles=3, n_gangs=3, chip_choices=(2, 4),
                       probe_singles=False, mean_gap=1.0,
                       pods=1, rows=2, cols=2)
    else:
        rows = compare(seed)
    _print_rows(rows)
    aware, oblivious = rows
    assert aware["crashed"] == 0 and oblivious["crashed"] == 0, rows
    assert aware["completed"] + oblivious["completed"] > 0, rows
    # the acceptance claim: atomic contiguous reservation beats scattering
    # the shards (DCN collectives + last-shard completion) on makespan
    assert aware["makespan_s"] < oblivious["makespan_s"], rows
    speedup = oblivious["makespan_s"] / aware["makespan_s"]
    print(f"\ngang-aware beats chips-oblivious by {speedup:.2f}x on makespan "
          f"({time.time() - t0:.1f}s)")
    if not smoke:
        save_json("bench_gang.json", rows)
    return rows


def smoke_live(seed: int = 0) -> None:
    """Live-backend leg of the CI smoke: the SAME mixed gang trace runs
    end-to-end through the event-driven executor on a tiny mesh — gangs
    dispatch as one bound device group and everything completes."""
    jobs = gang_mix(seed, n_singles=3, n_gangs=3, chip_choices=(2, 4),
                    probe_singles=False)
    with Cluster(GangScheduler(pods=1, rows=2, cols=2), workers=8) as c:
        handles = [c.submit(j, runners=[lambda d: time.sleep(0.002)]
                            * len(j.tasks))
                   for j in jobs]
        c.drain()
    assert all(h.status is JobStatus.DONE for h in handles), \
        [(h.job.name, h.status) for h in handles]
    recs = [r for h in handles for r in h.records]
    gang_recs = [r for r in recs if r.gang_chips > 1]
    assert gang_recs, "no gang dispatched as a bound group"
    assert all(d.used_hbm == 0 and d.used_slots == 0 for d in c.sched.devices)
    print(f"live smoke: {len(handles)} jobs done, "
          f"{len(gang_recs)} gang dispatch(es) "
          f"(max group {max(r.gang_chips for r in gang_recs)} chips)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny mesh + short trace on BOTH backends; asserts "
                         "completion without writing results (CI guard)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    run(args.seed, smoke=args.smoke)
    if args.smoke:
        smoke_live(args.seed)
        print("bench_gang --smoke OK")


if __name__ == "__main__":
    main()
