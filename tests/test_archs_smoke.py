"""Per-architecture smoke tests: reduced config, one forward/train/decode step on
CPU; asserts output shapes and finiteness (required deliverable (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS
from repro.models import decode as D
from repro.models import model as M

BATCH, SEQ = 2, 64


def _batch(cfg, key):
    tok = jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
    if cfg.embedding_frontend_stub:
        batch["embeds"] = jax.random.normal(
            key, (BATCH, SEQ, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch_setup(request):
    cfg = ARCHS[request.param].reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    return cfg, params, _batch(cfg, jax.random.PRNGKey(1))


def test_forward_shapes_finite(arch_setup):
    cfg, params, batch = arch_setup
    hidden, aux = M.forward(params, cfg, batch, attn_impl="naive")
    assert hidden.shape == (BATCH, SEQ, cfg.d_model)
    assert np.all(np.isfinite(np.asarray(hidden, np.float32)))
    assert np.isfinite(float(aux))


def test_loss_and_grad_step(arch_setup):
    cfg, params, batch = arch_setup
    loss, grads = jax.value_and_grad(M.loss_fn)(
        params, cfg, batch, attn_impl="naive")
    assert np.isfinite(float(loss)) and float(loss) > 0
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves and all(np.all(np.isfinite(np.asarray(g, np.float32)))
                          for g in leaves)


def test_flash_matches_naive(arch_setup):
    cfg, params, batch = arch_setup
    h1, _ = M.forward(params, cfg, batch, attn_impl="naive")
    h2, _ = M.forward(params, cfg, batch, attn_impl="flash")
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32),
                               rtol=2e-3, atol=2e-3)


def test_decode_step(arch_setup):
    cfg, params, batch = arch_setup
    cache = D.init_cache(cfg, BATCH, max_seq=32, dtype=jnp.float32)
    tokens = batch["tokens"][:, 0]
    for pos in range(3):
        logits, cache = D.decode_step(params, cfg, cache, tokens,
                                      jnp.asarray(pos, jnp.int32))
        assert logits.shape == (BATCH, cfg.vocab)
        assert np.all(np.isfinite(np.asarray(logits)))
        tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)


def test_param_count_analytic_matches_actual(arch_setup):
    cfg, params, _ = arch_setup
    actual = sum(x.size for x in jax.tree_util.tree_leaves(params))
    analytic = cfg.param_count()
    assert abs(actual - analytic) / actual < 0.05, (actual, analytic)
