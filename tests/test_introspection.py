"""Scheduler introspection battery (ISSUE 9 tentpole): decision
explainability, counterfactual what-if replay, live SLO monitoring.

  * ``Explainer`` ring mechanics: bounded per-task windows, task-map
    eviction, rejection-episode collapse, lazy reason walks;
  * seeded properties: every parked waiter carries at least one
    structured rejection reason for every device the probe attempted;
    every admitted task's final placement verdict matches the placement
    the tracer recorded; every preemption eviction names the real
    preemptor (cross-checked against ``preempt_log``); device-death
    evictions say so; sharded steal refusals and successes are
    explained;
  * what-if fidelity: a same-policy replay of a recorded trace
    reproduces the original admission/eviction sequence EXACTLY
    (``diff_streams`` is silent) on overload, gang, and device-death
    traces; counterfactual legs report metric deltas and the first
    divergent decision;
  * SLO monitor: burn-rate math, edge-triggered alerts (one per
    violation episode), registry subscription, the paper's 2.5%
    slowdown envelope, Prometheus text exposition;
  * export regressions: pod-qualified track names on sharded fleets,
    duplicate-track detection, queue-depth counter coalescing;
  * the flight recorder's metrics/drop-counter dump fields and the
    ``repro-top`` ASCII dashboard.
"""
import json
import random

from _hypothesis_fallback import given, settings, st

from repro.core.cluster import Cluster
from repro.core.scheduler import (
    GangScheduler, MGBAlg3Scheduler, PreemptiveAlg3Scheduler,
    ShardedScheduler,
)
from repro.core.task import Job, ResourceVector, Task, UnitTask
from repro.core.workloads import gang_mix, overload_mix
from repro.launch import top
from repro.obs import events as ev
from repro.obs import explain as obsx
from repro.obs import whatif
from repro.obs.events import Tracer, attach_tracer
from repro.obs.explain import Explainer, attach_explainer, format_verdicts
from repro.obs.export import to_chrome_trace, validate_chrome_trace
from repro.obs.metrics import MetricsRegistry
from repro.obs.replay import diff_streams
from repro.obs.slo import SLOAlert, SLOMonitor, prometheus_text

GB = 1024**3


def mk_task(name, mem_gb=2.0, demand=0.5, chips=1, est=1.0):
    vec = ResourceVector(hbm_bytes=int(mem_gb * GB), flops=1e12,
                         bytes_accessed=1e9, est_seconds=est,
                         core_demand=demand, bw_demand=demand, chips=chips)
    return Task(units=[UnitTask(fn=None, memobjs=frozenset({name}),
                                resources=vec, name=name)], name=name)


def mk_job(name, mem_gb=2.0, est=1.0, chips=1):
    return Job(tasks=[mk_task(name, mem_gb=mem_gb, est=est, chips=chips)],
               name=name)


# ---------------------------------------------------------------------------
# Explainer mechanics
# ---------------------------------------------------------------------------

def test_explainer_ring_is_bounded_per_task():
    ex = Explainer(per_task=4, clock=lambda: 0.0)
    for i in range(10):
        ex.record(1, "t", obsx.ADMITTED, device=i)
    vs = ex.verdicts(1)
    assert len(vs) == 4
    assert [v.device for v in vs] == [6, 7, 8, 9]   # last-K wins
    assert ex.recorded == 10


def test_explainer_task_map_evicts_oldest():
    ex = Explainer(max_tasks=2, clock=lambda: 0.0)
    for uid in (1, 2, 3):
        ex.record(uid, f"t{uid}", obsx.ADMITTED)
    assert ex.verdicts(1) == []        # oldest-inserted ring dropped
    assert ex.verdicts(2) and ex.verdicts(3)
    assert ex.evicted_tasks == 1


def test_reject_is_lazy_and_collapses_the_episode():
    ex = Explainer(clock=lambda: 0.0)
    walks = []

    def reasons():
        walks.append(1)
        return ({"reason": obsx.R_SLOTS_FULL},)
    for _ in range(5):
        ex.reject(7, "w", reasons)
    (v,) = ex.verdicts(7)
    assert v.action == obsx.REJECTED and v.repeats == 5
    assert len(walks) == 1             # the device walk ran ONCE
    # an admission ends the episode; the next rejection walks again
    ex.record(7, "w", obsx.ADMITTED, device=0)
    ex.reject(7, "w", reasons)
    assert len(walks) == 2


def test_skip_extends_the_open_parked_episode():
    ex = Explainer(clock=lambda: 0.0)
    ex.reject(7, "w", lambda: ({"reason": obsx.R_MEMORY_SHORT},))
    for _ in range(3):
        ex.skip(7, "w", ({"reason": obsx.R_HINT_SKIP},))
    (v,) = ex.verdicts(7)              # no second verdict appended
    assert v.action == obsx.REJECTED and v.repeats == 4
    # a fresh episode (post-admission) materializes a SKIPPED verdict
    ex.record(7, "w", obsx.ADMITTED, device=0)
    ex.skip(7, "w", ({"reason": obsx.R_CLASS_MEMO},))
    assert ex.last(7).action == obsx.SKIPPED


def test_annotate_last_and_format():
    ex = Explainer(clock=lambda: 0.0)
    ex.record(1, "t", obsx.ADMITTED, device=3)
    ex.annotate_last(1, "class_memo_skip", 12)
    v = ex.last(1)
    assert v.data == {"class_memo_skip": 12}
    text = format_verdicts(ex.verdicts(1))
    assert "admitted" in text and "dev" in text


def test_attach_explainer_fans_out_to_shards():
    sched = ShardedScheduler(pods=2, rows=2, cols=2)
    ex = attach_explainer(sched, Explainer())
    assert sched._explain is ex
    offs = []
    for sh in sched.shards:
        assert sh._explain is ex
        offs.append(sh._trace_dev_off)
    assert offs == [0, 4]              # global device bases stamped


# ---------------------------------------------------------------------------
# property: parked waiters carry structured reasons per attempted device
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_parked_waiters_have_reasons_per_device(seed):
    rng = random.Random(seed)
    c = Cluster(MGBAlg3Scheduler(2), workers=8, backend="sim", trace=True)
    handles = []
    # two hogs fill the fleet; the rest must park with explanations
    for i in range(6):
        handles.append(c.submit(mk_job(
            f"j{i}", mem_gb=14.0 if i < 2 else rng.choice([6.0, 10.0]),
            est=50.0)))
    c.run_until(1.0)
    queued = [h for h in handles if h.status.name == "QUEUED"]
    assert queued, "fixture must overload the fleet"
    alive = [d.index for d in c.sched.devices if d.alive]
    for h in queued:
        for name, verdicts in c.explain(h).items():
            rejects = [v for v in verdicts if v.action == obsx.REJECTED]
            assert rejects, f"{name}: parked without a rejection verdict"
            # the freshest rejection explains EVERY attempted device
            last = rejects[-1]
            assert last.reasons
            seen = {r.get("device") for r in last.reasons}
            assert seen == set(alive), (name, last.reasons)
            for r in last.reasons:
                assert r["reason"] in (obsx.R_MEMORY_SHORT,
                                       obsx.R_SLOTS_FULL,
                                       obsx.R_MAX_RESIDENTS,
                                       obsx.R_DEVICE_DEAD), r
    c.drain()


def test_explain_requires_explainer():
    import pytest
    c = Cluster(MGBAlg3Scheduler(1), workers=2, backend="sim",
                explain=False)
    h = c.submit(mk_job("x", est=0.1))
    with pytest.raises(RuntimeError):
        c.explain(h)
    c.drain()


# ---------------------------------------------------------------------------
# property: final verdict matches actual placement
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_admitted_verdict_matches_traced_placement(seed):
    rng = random.Random(seed)
    c = Cluster(PreemptiveAlg3Scheduler(2), workers=8, backend="sim",
                shed_late=True, trace=True,
                explain=Explainer(per_task=64))
    c._sim._failure_pending = (rng.uniform(0.3, 0.8), rng.randrange(2))
    for i in range(10):
        c.submit(mk_job(f"j{i}", mem_gb=rng.choice([4.0, 9.0, 12.0]),
                        est=rng.uniform(0.2, 1.5)),
                 priority=rng.randrange(3),
                 deadline_s=rng.choice([None, 2.0, 10.0]))
    c.run_until(2.0)
    c.sched.revive(0)
    c.sched.revive(1)
    c.drain()
    # last ADMIT event per task == last admitted/grown verdict's device
    last_admit = {}
    for e in c.trace.events():
        if e.kind == ev.ADMIT:
            last_admit[e.uid] = e.device
    checked = 0
    for uid, dev in last_admit.items():
        placed = [v for v in c.explainer.verdicts(uid)
                  if v.action in (obsx.ADMITTED, obsx.GROWN)]
        assert placed, f"uid {uid} admitted without a placement verdict"
        assert placed[-1].device == dev
        checked += 1
    assert checked >= 1


# ---------------------------------------------------------------------------
# property: evictions name the real cause
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_eviction_verdicts_name_the_real_preemptor(seed):
    rng = random.Random(seed)
    c = Cluster(PreemptiveAlg3Scheduler(2), workers=8, backend="sim",
                shed_late=True, trace=True,
                explain=Explainer(per_task=64))
    rows = overload_mix(seed, n_background=6, n_bystander=2, n_urgent=10)
    for row in rows:
        c.run_until(row["t"])
        c.submit(row["job"], priority=row["priority"],
                 deadline_s=row["deadline_s"])
    c.drain()
    log = c.sched.preempt_log
    assert log, "overload fixture must preempt"
    for victim_uid, preemptor_uid in log:
        evs = [v for v in c.explainer.verdicts(victim_uid)
               if v.action == obsx.EVICTED]
        assert evs, f"victim {victim_uid} evicted without explanation"
        assert any(r.get("by") == preemptor_uid and "cost_s" in r
                   and r["reason"] == "preempted"
                   for v in evs for r in v.reasons), (victim_uid, evs)


def test_device_death_evictions_say_so():
    c = Cluster(PreemptiveAlg3Scheduler(2), workers=8, backend="sim",
                trace=True)
    c._sim._failure_pending = (0.5, 0)
    for i in range(4):
        c.submit(mk_job(f"j{i}", mem_gb=12.0, est=2.0))
    c.run_until(1.0)
    c.sched.revive(0)
    c.drain()
    dead_evicts = [e.uid for e in c.trace.events()
                   if e.kind == ev.EVICT
                   and e.data and e.data.get("cause") == "device_dead"]
    assert dead_evicts
    for uid in dead_evicts:
        assert any(v.action == obsx.EVICTED
                   and any(r["reason"] == obsx.R_DEVICE_DEAD
                           for r in v.reasons)
                   for v in c.explainer.verdicts(uid))


# ---------------------------------------------------------------------------
# sharded: steal refusals and successes are explained
# ---------------------------------------------------------------------------

def _sharded_fixture():
    sched = ShardedScheduler(pods=2, rows=2, cols=2)
    tracer = attach_tracer(sched, Tracer())
    ex = attach_explainer(sched, Explainer())
    placed = []

    def cb(t, p, epoch):
        if p is not None and not isinstance(p, int):
            p = p.lead
        placed.append((t, p))
    singles = [mk_task(f"s{i}", mem_gb=16.0) for i in range(8)]
    for t in singles:
        assert sched.admit_or_enqueue(t, cb)
    return sched, tracer, ex, placed, cb


def test_steal_refusal_and_success_verdicts():
    sched, tracer, ex, placed, cb = _sharded_fixture()
    gang = mk_task("gang", mem_gb=16.0, chips=2)
    sched.admit_or_enqueue(gang, cb)
    si = sched._owner[gang.uid]
    other = 1 - si
    on_other = [t for t, p in placed if p // 4 == other]
    # one free cell on the other shard: the 2-chip steal must be refused
    sched.task_end(on_other[0])
    acts = [v.action for v in ex.verdicts(gang.uid)]
    assert obsx.STEAL_REFUSED in acts and obsx.STOLEN not in acts
    refusal = next(v for v in ex.verdicts(gang.uid)
                   if v.action == obsx.STEAL_REFUSED)
    assert refusal.reasons[0]["reason"] == "target_refused"
    assert refusal.data == {"src": si, "dst": other}
    assert any(e.kind == ev.RESTORE for e in tracer.events())
    # second free cell: now the steal lands, and says where it went
    sched.task_end(on_other[1])
    verdicts = ex.verdicts(gang.uid)
    stolen = next(v for v in verdicts if v.action == obsx.STOLEN)
    assert stolen.data == {"src": si, "dst": other}
    assert any(v.action == obsx.ADMITTED for v in verdicts)
    assert sched.steals == 1


def test_sharded_explain_queue_probes_owner_shard():
    sched, tracer, ex, placed, cb = _sharded_fixture()
    w = mk_task("parked", mem_gb=16.0)
    sched.admit_or_enqueue(w, cb)
    reasons = sched.explain_queue(w)
    assert reasons and all("reason" in r for r in reasons)
    assert sched.explain_queue(mk_task("stranger")) is None


# ---------------------------------------------------------------------------
# what-if replay: same-policy round-trip is exact
# ---------------------------------------------------------------------------

def _record_overload(seed):
    c = Cluster(PreemptiveAlg3Scheduler(2), workers=8, backend="sim",
                shed_late=True, trace=True)
    rows = overload_mix(seed, n_background=5, n_bystander=2, n_urgent=8)
    for row in rows:
        c.run_until(row["t"])
        c.submit(row["job"], priority=row["priority"],
                 deadline_s=row["deadline_s"])
    c._sim.drain(1e7)
    return c.trace.events()


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_whatif_roundtrip_exact_on_overload(seed):
    events = _record_overload(seed)
    res = whatif.replay(events, lambda: PreemptiveAlg3Scheduler(2),
                        workers=8, shed_late=True)
    assert diff_streams(events, res.events) is None


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_whatif_roundtrip_exact_on_gangs(seed):
    c = Cluster(GangScheduler(pods=1, rows=2, cols=4), workers=32,
                backend="sim", trace=True)
    for j in gang_mix(seed, n_singles=4, n_gangs=4, chip_choices=(2, 4),
                      probe_singles=False):
        c.submit(j)
    c._sim.drain(1e7)
    events = c.trace.events()
    res = whatif.replay(events, lambda: GangScheduler(pods=1, rows=2,
                                                      cols=4), workers=32)
    assert diff_streams(events, res.events) is None


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_whatif_roundtrip_exact_through_device_death(seed):
    rng = random.Random(seed)
    c = Cluster(PreemptiveAlg3Scheduler(2), workers=8, backend="sim",
                trace=True)
    c._sim._failure_pending = (rng.uniform(0.3, 0.8), rng.randrange(2))
    for i in range(8):
        c.submit(mk_job(f"j{i}", mem_gb=rng.choice([4.0, 9.0, 12.0]),
                        est=rng.uniform(0.3, 1.5)),
                 priority=rng.randrange(2))
    c.run_until(2.0)
    c.sched.revive(0)
    c.sched.revive(1)
    c._sim.drain(1e7)
    events = c.trace.events()
    # the death and both revives ride the trace as fleet ops
    trace = whatif.reconstruct(events)
    assert any(op.kind == ev.MARK_DEAD for op in trace.fleet_ops)
    res = whatif.replay(trace, lambda: PreemptiveAlg3Scheduler(2),
                        workers=8)
    assert diff_streams(events, res.events) is None


def test_whatif_reconstruct_requires_enriched_submits():
    import pytest
    tr = Tracer(clock=lambda: 0.0)
    tr.emit(ev.SUBMIT, uid=1, name="x", data={"job": "x"})  # no vector
    with pytest.raises(ValueError):
        whatif.reconstruct(tr.events())


def test_whatif_compare_reports_deltas_and_divergence():
    events = _record_overload(3)
    report = whatif.compare(
        events,
        {"replay": {},
         "fifo": {"use_priorities": False, "use_deadlines": False}},
        scheduler_factory=lambda: PreemptiveAlg3Scheduler(2),
        workers=8, shed_late=True)
    base = report["baseline"]
    assert base["deadline_jobs"] > 0
    same = report["policies"]["replay"]
    assert same["first_divergence"] is None
    assert abs(same["delta"]["makespan_s"]) < 1e-9
    fifo = report["policies"]["fifo"]
    assert set(fifo["delta"]) == {"makespan_s", "deadline_met",
                                  "p99_queueing_s", "evictions"}
    # stripping priorities + deadlines must change SOME decision here
    assert fifo["first_divergence"] is not None


# ---------------------------------------------------------------------------
# SLO monitor
# ---------------------------------------------------------------------------

def test_burn_rate_math():
    mon = SLOMonitor(window=10, deadline_target=0.8, clock=lambda: 0.0)
    for _ in range(9):
        mon.note_deadline(True)
    mon.note_deadline(False)
    s = mon.status()["deadline"]
    # 1 violation / 10 over a 0.2 budget = burn 0.5: inside budget
    assert abs(s["rate"] - 0.1) < 1e-9
    assert abs(s["burn"] - 0.5) < 1e-9
    assert s["healthy"]
    for _ in range(2):                 # 3/10 over 0.2 = burn 1.5
        mon.note_deadline(False)
    assert abs(mon.status()["deadline"]["burn"] - 1.5) < 1e-9
    assert not mon.healthy


def test_alerts_fire_once_per_violation_episode():
    fired = []
    mon = SLOMonitor(window=4, deadline_target=0.5, clock=lambda: 0.0,
                     on_alert=fired.append)
    for _ in range(8):                     # sustained violation: ONE alert
        mon.note_deadline(False)
    assert len(fired) == 1
    assert isinstance(fired[0], SLOAlert)
    assert fired[0].stream == "deadline"
    for _ in range(8):                     # recovery closes the episode
        mon.note_deadline(True)
    assert mon.healthy
    for _ in range(8):                     # a fresh episode re-alerts
        mon.note_deadline(False)
    assert len(fired) == 2
    assert mon.status()["alerts"] == 2


def test_slowdown_envelope_is_the_papers():
    from repro.obs.slo import SLOWDOWN_ENVELOPE
    assert SLOWDOWN_ENVELOPE == 0.025
    mon = SLOMonitor(window=4, latency_target=0.5, clock=lambda: 0.0)
    mon.note_slowdown("ok", observed_s=1.02, roofline_s=1.0)
    assert mon.status()["slowdown"]["rate"] == 0.0
    for _ in range(4):
        mon.note_slowdown("bad", observed_s=1.06, roofline_s=1.0)
    assert not mon.status()["slowdown"]["healthy"]
    worst = mon.status()["worst_slowdown"]
    assert worst["name"] == "bad" and abs(worst["factor"] - 1.06) < 1e-9


def test_for_serving_subscribes_to_registry():
    reg = MetricsRegistry()
    mon = SLOMonitor.for_serving(reg, window=8, ttft_slo_s=0.5,
                                 tpot_slo_s=0.1, clock=lambda: 0.0)
    reg.hist("ttft_s").record(0.2)     # fine
    reg.hist("ttft_s").record(0.9)     # violation
    reg.hist("tpot_s").record(0.05)
    st_ = mon.status()
    assert st_["ttft"]["n"] == 2 and abs(st_["ttft"]["rate"] - 0.5) < 1e-9
    assert st_["tpot"]["n"] == 1 and st_["tpot"]["rate"] == 0.0


def test_prometheus_text_exposition():
    reg = MetricsRegistry()
    reg.counter("events.admit").inc(3)
    reg.gauge("queue_depth").set(7)
    reg.hist("queueing_delay_s").record(0.25)
    mon = SLOMonitor(window=4, clock=lambda: 0.0)
    mon.note_deadline(True)
    text = prometheus_text(reg, mon)
    assert "repro_events_admit_total 3" in text
    assert "repro_queue_depth 7" in text
    assert 'repro_queueing_delay_s{quantile="0.99"}' in text
    assert "repro_queueing_delay_s_count 1" in text
    assert "repro_slo_deadline_burn 0" in text
    assert "repro_slo_deadline_healthy 1" in text
    assert text.endswith("\n")


# ---------------------------------------------------------------------------
# export regressions: pod tracks, duplicate names, counter coalescing
# ---------------------------------------------------------------------------

def test_pod_qualified_track_names_on_sharded_trace():
    sched, tracer, ex, placed, cb = _sharded_fixture()
    for t, _ in list(placed):
        sched.task_end(t)
    doc = to_chrome_trace(tracer.events(), devices_per_pod=4)
    assert not validate_chrome_trace(doc)
    names = {(r["args"] or {}).get("name") for r in doc["traceEvents"]
             if r.get("ph") == "M" and r.get("name") == "process_name"}
    assert "pod0/dev0" in names and "pod1/dev3" in names
    assert not any(n and n.startswith("device ") for n in names)


def test_validator_flags_duplicate_track_names():
    doc = {"traceEvents": [
        {"ph": "M", "pid": 0, "tid": 0, "name": "process_name",
         "args": {"name": "pod0/dev0"}},
        {"ph": "M", "pid": 4, "tid": 0, "name": "process_name",
         "args": {"name": "pod0/dev0"}},     # wrong pod factoring
    ]}
    problems = validate_chrome_trace(doc)
    assert any("duplicate track name" in p for p in problems)


def test_queue_counter_coalesces_unchanged_depth():
    tr = Tracer(clock=lambda: 0.0)
    now = [0.0]
    tr.use_clock(lambda: now[0])
    tr.emit(ev.PARK, uid=1, name="a")          # depth 1
    now[0] = 1.0
    tr.emit(ev.PARK, uid=2, name="b")          # depth 2 ...
    tr.emit(ev.ADMIT, uid=2, name="b", device=0)   # ... back to 1, same ts
    now[0] = 2.0
    tr.emit(ev.STEAL, uid=1, name="a")         # unpark ...
    tr.emit(ev.RESTORE, uid=1, name="a")       # ... repark: nets to 1
    now[0] = 3.0
    tr.emit(ev.ADMIT, uid=1, name="a", device=1)   # depth 0
    counters = [r for r in to_chrome_trace(tr.events())["traceEvents"]
                if r.get("ph") == "C"]
    # only real depth CHANGES appear: 1 (t=0) and 0 (t=3)
    assert [(r["ts"], r["args"]["depth"]) for r in counters] == \
        [(0.0, 1), (3e6, 0)]


# ---------------------------------------------------------------------------
# flight recorder dump fields
# ---------------------------------------------------------------------------

def test_flight_dump_carries_drop_counter_and_metrics(tmp_path):
    flight = str(tmp_path / "flight.json")
    reg = MetricsRegistry()
    reg.counter("custom").inc(5)
    c = Cluster(MGBAlg3Scheduler(1), workers=2, backend="sim",
                trace=Tracer(capacity=4),    # tiny ring: forces drops
                flight_path=flight, metrics=reg)
    for i in range(4):
        c.submit(mk_job(f"j{i}", est=0.1))
    c.drain()
    assert c.flight.dumps
    doc = json.loads(open(c.flight.dumps[-1][1]).read())
    assert doc["dropped"] > 0                  # the ring really dropped
    assert doc["emitted"] > doc["dropped"]
    assert doc["metrics"]["counters"]["custom"] == 5


def test_flight_dump_derives_metrics_without_registry(tmp_path):
    flight = str(tmp_path / "flight.json")
    c = Cluster(MGBAlg3Scheduler(1), workers=2, backend="sim",
                trace=True, flight_path=flight)
    c.submit(mk_job("j0", est=0.1))
    c.drain()
    doc = json.loads(open(c.flight.dumps[-1][1]).read())
    assert doc["dropped"] == 0
    assert doc["metrics"]["counters"][f"events.{ev.ADMIT}"] >= 1


# ---------------------------------------------------------------------------
# JobHandle.explain + repro-top
# ---------------------------------------------------------------------------

def test_job_handle_explain_one_call():
    c = Cluster(MGBAlg3Scheduler(1), workers=2, backend="sim", trace=True)
    c.submit(mk_job("hog", mem_gb=14.0, est=5.0))
    parked = c.submit(mk_job("parked", mem_gb=10.0, est=1.0))
    c.run_until(1.0)
    report = parked.explain()
    (verdicts,) = report.values()
    livemost = verdicts[-1]
    assert livemost.action == obsx.REJECTED
    assert livemost.data == {"live": True}     # probed under the lock NOW
    assert any(r["reason"] == obsx.R_MEMORY_SHORT for r in livemost.reasons)
    c.drain()
    done = parked.explain()
    (verdicts,) = done.values()
    assert verdicts[-1].action == obsx.ADMITTED


def test_top_renders_queue_devices_and_slo():
    c = Cluster(PreemptiveAlg3Scheduler(2), workers=4, backend="sim",
                shed_late=True, trace=True)
    mon = SLOMonitor(window=8, clock=lambda: 0.0)
    mon.note_deadline(False)
    for i in range(4):
        c.submit(mk_job(f"j{i}", mem_gb=12.0, est=3.0))
    c.run_until(0.5)
    frame = top.render(c.sched, slo=mon, stats=c.stats())
    assert "queue" in frame and "dev 0" in frame and "dev 1" in frame
    assert "slo" in frame and "jobs" in frame
    assert "[#" in frame                      # an occupancy bar is drawn
    c.drain()


def test_top_pod_labels_on_sharded_fleet():
    sched, tracer, ex, placed, cb = _sharded_fixture()
    frame = top.render(sched)
    assert "pod0/dev0" in frame and "pod1/dev3" in frame
    assert "shards" in frame
