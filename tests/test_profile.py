"""Profiling & calibration plane (ISSUE 10 tentpole): observed-vs-
predicted attribution joined from the event stream, plus the online
probe-calibration feedback loop.

  * the memory-safety INVARIANT: a corrected reservation never shrinks
    below the class's observed high-water — in both allow_shrink modes,
    checked directly on ``CalibrationStore.corrected_for``;
  * EWMA runtime correction converges on a synthetic drifting trace, and
    calibrated admission cuts the mean absolute ``est_seconds`` error
    >= 2x on ``workloads.drifting_mix`` with zero memory violations;
  * the event-stream join decomposes queueing delay into parked /
    dispatch / execution on hand-built and simulated lifecycles;
  * sim and live backends produce the SAME attribution structure for the
    same submission trace (diffed through ``obs.replay``);
  * ``Cluster.profile()`` / ``JobHandle.profile()`` accessors, Perfetto
    profile-counter tracks, the SLO drift stream, and the dashboard's
    occupancy bars + calibration rows.
"""
import dataclasses

from repro.core.cluster import Cluster
from repro.core.scheduler import MGBAlg3Scheduler
from repro.core.task import (
    Job, ResourceVector, Task, UnitTask, observed_highwater,
    true_work_seconds,
)
from repro.core.workloads import drifting_mix
from repro.launch import top
from repro.obs import events as ev
from repro.obs.calibrate import (
    CalibratedScheduler, CalibrationStore, attach_calibrator,
)
from repro.obs.events import Event, Tracer
from repro.obs.export import to_chrome_trace, trace_summary, \
    validate_chrome_trace
from repro.obs.profile import (
    Profiler, device_occupancy, format_profile, profiles_from_events,
)
from repro.obs.replay import diff_streams, validate_lifecycles
from repro.obs.slo import SLOMonitor

GB = 1024**3


def mk_vec(mem_gb=2.0, est=1.0, demand=0.5):
    return ResourceVector(hbm_bytes=int(mem_gb * GB), flops=1e9,
                          bytes_accessed=1e9, est_seconds=est,
                          core_demand=demand, bw_demand=demand)


def mk_task(name, mem_gb=2.0, est=1.0, demand=0.5, vec=None):
    v = vec if vec is not None else mk_vec(mem_gb, est, demand)
    return Task(units=[UnitTask(fn=None, memobjs=frozenset({name}),
                                resources=v, name=name)], name=name)


def mk_job(name, mem_gb=2.0, est=1.0, demand=0.5):
    return Job(tasks=[mk_task(name, mem_gb, est, demand)], name=name)


def feed_end(store, vec, *, observed_s, hw_gb, calibrate=False, t0=0.0):
    """Run one synthetic task through the store's admission + completion
    hooks: apply() stamps probe_vec (and any correction), note_end folds
    the observation with the given true runtime/high-water."""
    t = mk_task("synth", vec=vec)
    if calibrate:
        store.apply(t)
    else:
        t.probe_vec = vec              # stamp without installing corrections
    t.true_vec = dataclasses.replace(vec, hbm_bytes=int(hw_gb * GB))
    t.start_t = t0
    store.note_end(t, t0 + observed_s)
    return t


# ---------------------------------------------------------------------------
# the memory invariant: corrected reservations never shrink below high-water
# ---------------------------------------------------------------------------

def test_corrected_memory_never_below_highwater_inflate_mode():
    """Default mode: corrected hbm >= max(probe, hw x (1+margin)) — never
    below the probe's own figure, never below observed high-water."""
    store = CalibrationStore(mem_margin=0.10)
    vec = mk_vec(mem_gb=4.0, est=1.0)
    # under-reservation observed: tasks actually touch 5 GB
    for _ in range(5):
        feed_end(store, vec, observed_s=1.0, hw_gb=5.0)
    corrected = store.corrected_for(vec)
    assert corrected is not None
    hw = store.highwater(vec)
    assert hw == 5 * GB
    assert corrected.hbm_bytes >= hw                  # THE invariant
    assert corrected.hbm_bytes >= vec.hbm_bytes       # inflate-only mode
    assert corrected.hbm_bytes == int(5 * GB * 1.10)


def test_corrected_memory_shrink_mode_floors_at_highwater():
    """allow_shrink=True may cut an over-reservation, but the floor stays
    the observed high-water even with mem_margin=0."""
    store = CalibrationStore(mem_margin=0.0, allow_shrink=True,
                             min_samples=3)
    vec = mk_vec(mem_gb=8.0, est=1.0)
    # over-reservation: the probe says 8 GB, tasks only touch 3 GB
    for _ in range(4):
        feed_end(store, vec, observed_s=1.0, hw_gb=3.0)
    corrected = store.corrected_for(vec)
    assert corrected is not None
    assert corrected.hbm_bytes < vec.hbm_bytes        # shrink happened
    assert corrected.hbm_bytes >= store.highwater(vec)  # but never below hw
    assert corrected.hbm_bytes == 3 * GB


def test_shrink_waits_for_min_samples():
    """One observation must not shrink a reservation — shrinking needs
    min_samples history (inflating is always safe and starts immediately)."""
    store = CalibrationStore(mem_margin=0.0, allow_shrink=True,
                             min_samples=3)
    vec = mk_vec(mem_gb=8.0, est=1.0)
    feed_end(store, vec, observed_s=1.0, hw_gb=3.0)
    corrected = store.corrected_for(vec)
    # below min_samples the memory fold is inflate-only: 3 GB < 8 GB probe
    # means no memory change, and one runtime sample means no est change
    assert corrected is None or corrected.hbm_bytes >= vec.hbm_bytes


def test_highwater_invariant_fuzz():
    """Whatever mix of margins/modes/observations: corrected hbm is never
    below the class's observed hw_max."""
    for margin in (0.0, 0.05, 0.5):
        for shrink in (False, True):
            store = CalibrationStore(mem_margin=margin, allow_shrink=shrink,
                                     min_samples=1)
            vec = mk_vec(mem_gb=4.0, est=0.5)
            for hw_gb in (1.0, 6.0, 2.0, 5.5, 3.0):
                feed_end(store, vec, observed_s=1.0, hw_gb=hw_gb)
                corrected = store.corrected_for(vec)
                if corrected is not None:
                    assert corrected.hbm_bytes >= store.highwater(vec), (
                        margin, shrink, hw_gb)


# ---------------------------------------------------------------------------
# EWMA runtime correction
# ---------------------------------------------------------------------------

def test_ewma_converges_on_drifted_runtime():
    """Probes say 1 s, reality says 2 s: the class ratio converges to ~2
    and corrected estimates follow."""
    store = CalibrationStore(alpha=0.5, min_samples=3)
    vec = mk_vec(mem_gb=2.0, est=1.0)
    for _ in range(12):
        feed_end(store, vec, observed_s=2.0, hw_gb=1.0)
    ratio = store.ratio_ewma(vec)
    assert ratio is not None and abs(ratio - 2.0) < 1e-6
    corrected = store.corrected_for(vec)
    assert corrected is not None
    assert abs(corrected.est_seconds - 2.0) < 1e-6


def test_apply_is_idempotent_and_keys_by_probe_vec():
    """A corrected vector must never mint a new class or feed its own
    statistics: apply() stamps the ORIGINAL probe vector as the key, and a
    second apply is a no-op. fold_batch=1 folds each completion eagerly —
    the default defers folding to batches/reads (the hot-path budget)."""
    store = CalibrationStore(min_samples=1, alpha=1.0, fold_batch=1)
    vec = mk_vec(mem_gb=2.0, est=1.0)
    for _ in range(3):
        feed_end(store, vec, observed_s=3.0, hw_gb=1.0)
    t = mk_task("t", vec=vec)
    store.apply(t)
    assert t.probe_vec is vec
    assert t.calibrated_vec is not None
    assert t.resources.est_seconds != vec.est_seconds
    first = t.calibrated_vec
    store.apply(t)                       # idempotent: guard on probe_vec
    assert t.calibrated_vec is first
    # a completion of the calibrated task folds into the ORIGINAL class
    t.true_vec = dataclasses.replace(vec, hbm_bytes=1 * GB)
    t.start_t = 0.0
    store.note_end(t, 3.0)
    assert store.accuracy_report()["classes"] == 1


def test_observation_feed_reaches_subscribers():
    store = CalibrationStore()
    seen = []
    store.on_observe(seen.append)
    feed_end(store, mk_vec(est=1.0), observed_s=2.0, hw_gb=1.0)
    (o,) = seen
    assert o.predicted_s == 1.0 and abs(o.observed_s - 2.0) < 1e-9
    assert o.hw_bytes == 1 * GB


# ---------------------------------------------------------------------------
# the acceptance gate: calibrated admission on a drifting trace
# ---------------------------------------------------------------------------

def test_calibrated_sim_halves_est_error_with_zero_violations():
    """The ISSUE-10 acceptance criterion, at test scale: one calibrated
    sim pass over the drifting mix cuts mean absolute est_seconds error
    >= 2x (paired: the same completions scored raw vs corrected) and the
    memory invariant holds — zero violations, store-side AND profiler-
    side."""
    store = CalibrationStore()
    c = Cluster(MGBAlg3Scheduler(8), backend="sim", trace=True,
                calibrate=store)
    for row in drifting_mix(0, n_jobs=120):
        c.run_until(row["t"])
        c.submit(row["job"])
    c.drain()
    rep = store.accuracy_report()
    assert rep["violations"] == 0
    assert rep["corrections"] > 0
    paired = rep["paired"]
    assert paired["n"] > 0
    assert paired["improvement"] >= 2.0, rep
    summary = c.profile()
    assert summary["memory_violations"] == 0
    assert summary["completed"] == summary["tasks"] == 120
    assert summary["calibration"]["corrections"] == rep["corrections"]
    # the lifecycle stream itself stays legal with calibration attached
    assert validate_lifecycles(c.trace.events(), require_terminal=True) == []


def test_true_vec_drives_sim_physics_not_admission():
    """A task whose true_vec says 2 s but probe says 1 s RUNS for 2 s of
    virtual time while admission reserved by the probe."""
    vec = mk_vec(mem_gb=2.0, est=1.0)
    t = mk_task("drifty", vec=vec)
    t.true_vec = dataclasses.replace(vec, est_seconds=2.0,
                                     hbm_bytes=1 * GB)
    assert true_work_seconds(t) == 2.0
    assert observed_highwater(t) == 1 * GB
    assert t.resources is vec            # admission still sees the probe
    c = Cluster(MGBAlg3Scheduler(1), backend="sim", trace=True)
    h = c.submit(Job(tasks=[t], name="drifty"))
    c.drain()
    (p,) = h.profile().values()
    assert p.completed and abs(p.exec_s - 2.0) < 1e-6
    assert p.pred_est_s == 1.0


# ---------------------------------------------------------------------------
# the event-stream join
# ---------------------------------------------------------------------------

def _evt(seq, t, kind, uid=1, name="t", device=0, data=None):
    return Event(seq, t, kind, uid, name, device, 0, data)


def test_profile_join_decomposes_delays():
    """Hand-built lifecycle: submit 0.0, park until 1.0, begin 1.25,
    end 3.25 — park/dispatch/exec land in the right buckets."""
    events = [
        _evt(0, 0.0, ev.SUBMIT, data={"job": "j", "est_seconds": 2.5,
                                      "hbm_bytes": 4 * GB,
                                      "core_demand": 0.5, "bw_demand": 0.25}),
        _evt(1, 0.0, ev.PARK),
        _evt(2, 1.0, ev.ADMIT),
        _evt(3, 1.25, ev.BEGIN),
        _evt(4, 3.25, ev.END, data={"hw": 3 * GB}),
    ]
    (p,) = profiles_from_events(events).values()
    assert p.park_s == 1.0
    assert p.dispatch_s == 0.25
    assert p.exec_s == 2.0
    assert p.queueing_s == 1.25
    assert p.completed and not p.memory_violation
    assert p.pred_est_s == 2.5 and p.hw_bytes == 3 * GB
    assert p.reserved_hbm == 4 * GB      # falls back to the SUBMIT payload
    assert abs(p.err_s - (-0.5)) < 1e-9
    assert p.demand == 0.5
    line = format_profile(p)
    assert "predicted 2.500s -> observed 2.000s" in line
    assert "parked 1.000s" in line and "dispatch 0.250s" in line


def test_profile_join_eviction_accumulates_partial_exec():
    events = [
        _evt(0, 0.0, ev.SUBMIT, data={"job": "j", "est_seconds": 2.0,
                                      "hbm_bytes": GB}),
        _evt(1, 0.0, ev.ADMIT),
        _evt(2, 0.0, ev.BEGIN),
        _evt(3, 0.5, ev.EVICT),          # 0.5 s of lost work
        _evt(4, 0.5, ev.REQUEUE),
        _evt(5, 1.0, ev.ADMIT, device=1),
        _evt(6, 1.0, ev.BEGIN, device=1),
        _evt(7, 3.0, ev.END, device=1),
    ]
    (p,) = profiles_from_events(events).values()
    assert p.evictions == 1 and p.incarnations == 2
    assert p.devices == [0, 1]
    assert abs(p.exec_s - 2.5) < 1e-9    # 0.5 lost + 2.0 final
    assert abs(p.park_s - 0.5) < 1e-9    # requeue -> re-admit
    assert p.completed


def test_profile_join_calibrated_admit_payload_wins():
    """The calib-gated ADMIT payload carries the ACTUAL (possibly
    inflated) reservation — it overrides the SUBMIT prediction and flags
    the profile calibrated; memory violations compare against it."""
    events = [
        _evt(0, 0.0, ev.SUBMIT, data={"job": "j", "est_seconds": 1.0,
                                      "hbm_bytes": 2 * GB}),
        _evt(1, 0.0, ev.ADMIT, data={"hbm": 3 * GB}),
        _evt(2, 0.0, ev.BEGIN),
        _evt(3, 1.0, ev.END, data={"hw": int(2.5 * GB)}),
    ]
    (p,) = profiles_from_events(events).values()
    assert p.calibrated and p.reserved_hbm == 3 * GB
    assert not p.memory_violation        # 2.5 GB hw <= 3 GB reserved
    bad = profiles_from_events(events[:1] + [
        _evt(1, 0.0, ev.ADMIT),          # uncalibrated: reserved = 2 GB
        _evt(2, 0.0, ev.BEGIN),
        _evt(3, 1.0, ev.END, data={"hw": int(2.5 * GB)}),
    ])
    (q,) = bad.values()
    assert q.memory_violation


def test_device_occupancy_timeline_integrates_residency():
    """Two tasks of demand 0.5 overlapping on device 0: occupancy steps
    0.5 -> 1.0 -> 0.5 -> 0, busy the whole window, mean 0.75."""
    events = [
        _evt(0, 0.0, ev.SUBMIT, uid=1, name="a",
             data={"core_demand": 0.5, "bw_demand": 0.1}),
        _evt(1, 0.0, ev.SUBMIT, uid=2, name="b",
             data={"core_demand": 0.5, "bw_demand": 0.1}),
        _evt(2, 0.0, ev.ADMIT, uid=1),
        _evt(3, 1.0, ev.ADMIT, uid=2),
        _evt(4, 3.0, ev.END, uid=1),
        _evt(5, 4.0, ev.END, uid=2),
    ]
    occ = device_occupancy(events)
    a = occ[0]
    assert abs(a["busy_frac"] - 1.0) < 1e-9
    # 1s@0.5 + 2s@1.0 + 1s@0.5 over 4s = 0.75
    assert abs(a["mean_occupancy"] - 0.75) < 1e-9
    assert a["last"] == 0.0
    assert [o for _, o in a["timeline"]] == [0.5, 1.0, 0.5, 0.0]


# ---------------------------------------------------------------------------
# sim/live attribution parity
# ---------------------------------------------------------------------------

def test_sim_live_attribution_parity():
    """The same submission trace through both backends: identical admission
    decision streams (obs.replay differ) and structurally identical
    attribution joins — same tasks, same completion/eviction flags, same
    incarnation counts. (Times differ: virtual vs wall clock.)"""
    def run(backend):
        c = Cluster(MGBAlg3Scheduler(2), workers=4, backend=backend,
                    trace=True)
        for i in range(6):
            c.submit(mk_job(f"j{i}", mem_gb=9.0, est=0.01))
        c.drain()
        evs = c.trace.events()
        profs = Profiler(c.trace).by_name()
        c.shutdown()
        return evs, profs

    sim_evs, sim_profs = run("sim")
    live_evs, live_profs = run("live")
    div = diff_streams(sim_evs, live_evs, kinds=(ev.ADMIT,))
    assert div is None, div
    assert set(sim_profs) == set(live_profs) == {f"j{i}" for i in range(6)}
    for name in sim_profs:
        s, l = sim_profs[name], live_profs[name]
        assert (s.completed, s.evictions, s.incarnations) == \
               (l.completed, l.evictions, l.incarnations), name
        assert s.pred_est_s == l.pred_est_s == 0.01
        assert l.exec_s > 0.0 and s.exec_s > 0.0


# ---------------------------------------------------------------------------
# surfaces: accessors, export counters, SLO drift, the dashboard
# ---------------------------------------------------------------------------

def test_cluster_profile_accessors():
    c = Cluster(MGBAlg3Scheduler(2), backend="sim", trace=True,
                calibrate=True)
    h = c.submit(mk_job("a", est=0.5))
    c.submit(mk_job("b", est=0.2))
    c.drain()
    per_task = h.profile()
    assert set(per_task) == {"a"}
    assert per_task["a"].completed and per_task["a"].exec_s > 0
    summary = c.profile()
    assert summary["tasks"] == 2 and summary["completed"] == 2
    assert "calibration" in summary      # calibrate=True rides along
    assert 0 in summary["device_occupancy"]


def test_profile_requires_trace():
    c = Cluster(MGBAlg3Scheduler(1), backend="sim")
    h = c.submit(mk_job("a", est=0.1))
    c.drain()
    for fn in (c.profile, h.profile):
        try:
            fn()
            raise AssertionError("profile() without trace= must raise")
        except RuntimeError as e:
            assert "trace" in str(e)


def test_export_profile_counters():
    """profile_counters=True adds per-device occupancy-% and est-error-%
    counter tracks; the document stays valid and off-by-default output is
    unchanged."""
    c = Cluster(MGBAlg3Scheduler(2), backend="sim", trace=True)
    for i in range(4):
        c.submit(mk_job(f"j{i}", mem_gb=9.0, est=0.5))
    c.drain()
    evs = c.trace.events()
    base = to_chrome_trace(evs)
    doc = to_chrome_trace(evs, profile_counters=True)
    assert validate_chrome_trace(doc) == []
    assert to_chrome_trace(evs) == base  # off-path byte-identical
    names = {r["name"] for r in doc["traceEvents"] if r.get("ph") == "C"}
    assert "occupancy %" in names and "est error %" in names
    assert trace_summary(doc)["counter_samples"] > \
        trace_summary(base)["counter_samples"]


def test_slo_drift_alert_edge_triggered():
    """Persistent misprediction burns the drift window and fires ONE
    alert; accurate probes never do."""
    mon = SLOMonitor(window=8, drift_tolerance=0.25, drift_target=0.9)
    for _ in range(8):
        mon.note_drift("ok", 1.0, 1.1)       # within tolerance
    assert mon.alerts == []
    for _ in range(16):
        mon.note_drift("bad", 1.0, 2.0)      # 2x off: violation
    assert len(mon.alerts) == 1              # edge-triggered
    assert mon.alerts[0].stream == "drift"
    assert "drift" in mon.status()
    assert not mon.status()["drift"]["healthy"]


def test_slo_for_calibration_subscribes_to_store():
    store = CalibrationStore()
    mon = SLOMonitor.for_calibration(store, window=4, drift_target=0.5)
    vec = mk_vec(est=1.0)
    for _ in range(8):
        feed_end(store, vec, observed_s=2.0, hw_gb=1.0)
    assert len(mon.alerts) == 1
    assert mon.alerts[0].stream == "drift"


def test_top_renders_occupancy_bars_and_calib_rows():
    """A traced + calibrated scheduler renders observed-occupancy device
    bars and per-class accuracy rows; the demo frame still works."""
    store = CalibrationStore(min_samples=1)
    c = Cluster(MGBAlg3Scheduler(2), backend="sim", trace=True,
                calibrate=store)
    for row in drifting_mix(1, n_jobs=16):
        c.run_until(row["t"])
        c.submit(row["job"])
    c.drain()
    frame = top.render(c.sched, stats=c.stats())
    assert " occ " in frame              # observed-occupancy bar suffix
    assert "calib" in frame and "mae raw" in frame
    bare = top.render(MGBAlg3Scheduler(2))
    assert " occ " not in bare and "calib" not in bare
    assert isinstance(top._demo(), str)


def test_calibrated_scheduler_wrapper_is_drop_in():
    """CalibratedScheduler(sched) composes with Cluster: hooks land on the
    inner scheduler, the store is discovered (not double-attached), and
    attribute traffic forwards."""
    sched = CalibratedScheduler(MGBAlg3Scheduler(2), min_samples=1,
                                fold_batch=1)
    c = Cluster(sched, backend="sim", trace=True)
    assert c.calibration is sched.store
    for row in drifting_mix(2, n_jobs=12):
        c.run_until(row["t"])
        c.submit(row["job"])
    c.drain()
    assert sched.store.observations == 12
    assert sched.store.corrections > 0
    assert sched.waiting_count() == 0    # forwarded read


def test_attach_calibrator_fans_out_to_shards():
    from repro.core.scheduler import ShardedScheduler
    sched = ShardedScheduler(pods=2, rows=2, cols=2)
    store = attach_calibrator(sched)
    assert sched._calib is store
    assert all(sh._calib is store for sh in sched.shards)
