"""Concurrency test battery for the event-driven executor + the scheduler
waiter/wakeup substrate (ISSUE 2 tentpole):

  * N jobs >> workers all complete — a blocked task holds NO thread;
  * no starvation under FIFO wakeups: whoever waited longest gets first
    claim on freed capacity;
  * wakeup ordering is deterministic under a seeded arrival order;
  * the OOM crash path still records ``ExecRecord(crashed=True)`` and
    releases scheduler resources;
  * fault tolerance: ``mark_dead`` re-enqueues blocked/resident tasks through
    the waiter queue onto surviving devices; ``revive`` lets waiters land on
    the revived device;
  * ``Executor.run([])`` returns a zeroed metrics dict instead of raising.
"""
import threading
import time

from repro.core.executor import ExecJob, Executor, PollingExecutor
from repro.core.scheduler import (
    CGScheduler, MGBAlg2Scheduler, MGBAlg3Scheduler,
)
from repro.core.task import Job, ResourceVector, Task, UnitTask

GB = 1024**3


def mk_task(name, mem_gb=2.0, demand=0.5, est=0.005):
    vec = ResourceVector(hbm_bytes=int(mem_gb * GB), flops=1e9,
                         bytes_accessed=1e9, est_seconds=est,
                         core_demand=demand, bw_demand=demand)
    return Task(units=[UnitTask(fn=None, memobjs=frozenset({name}),
                                resources=vec, name=name)], name=name)


def mk_job(i, mem_gb=2.0, demand=0.5, sleep=0.003, body=None):
    name = f"j{i}"
    runner = body if body is not None else (
        lambda device, s=sleep: time.sleep(s))
    return ExecJob(job=Job(tasks=[mk_task(name, mem_gb, demand)], name=name),
                   runners=[runner])


# ---------------------------------------------------------------------------
# capacity: N jobs >> workers, blocked tasks hold no thread
# ---------------------------------------------------------------------------

def test_64_jobs_complete_with_two_workers():
    """Acceptance criterion: 64 queued single-task jobs on a 2-thread
    execution pool all complete under MGB — blocked jobs park in the waiter
    queue instead of holding a worker."""
    sched = MGBAlg3Scheduler(2)
    ex = Executor(sched, workers=2)
    stats = ex.run([mk_job(i) for i in range(64)])
    assert stats["completed"] == 64 and stats["crashed"] == 0
    assert {d for _, d in sched.placements} == {0, 1}
    # every resource was released
    assert all(d.used_hbm == 0 and d.used_slots == 0 for d in sched.devices)
    assert sched.waiting_count() == 0


def test_blocked_jobs_hold_no_thread():
    """With 32 queued jobs and a pool of 2, the process never runs more than
    pool + constant threads: waiting is a queue entry, not a thread."""
    base = threading.active_count()
    peak = [0]

    def body(device):
        peak[0] = max(peak[0], threading.active_count())
        time.sleep(0.002)

    # memory admits only 2 tasks at a time -> 30 jobs always blocked
    sched = MGBAlg3Scheduler(1)
    stats = Executor(sched, workers=2).run(
        [mk_job(i, mem_gb=7.5, body=body) for i in range(32)])
    assert stats["completed"] == 32
    # the two pool threads plus (at most) one unrelated background thread —
    # NOT one thread per blocked job, which would add ~30
    assert peak[0] <= base + 3


def test_bounded_pool_respects_worker_count():
    running = [0]
    peak = [0]
    lock = threading.Lock()

    def body(device):
        with lock:
            running[0] += 1
            peak[0] = max(peak[0], running[0])
        time.sleep(0.002)
        with lock:
            running[0] -= 1

    stats = Executor(MGBAlg3Scheduler(4), workers=3).run(
        [mk_job(i, mem_gb=0.5, body=body) for i in range(24)])
    assert stats["completed"] == 24
    assert peak[0] <= 3  # execution concurrency == pool size, not job count


# ---------------------------------------------------------------------------
# fairness / wakeup ordering
# ---------------------------------------------------------------------------

def test_fifo_wakeup_no_starvation():
    """Whoever waited longest is admitted first when capacity frees: with a
    single exclusive device (Alg2, demand 1.0) the admission order must equal
    the arrival order exactly."""
    sched = MGBAlg2Scheduler(1)
    order = []

    def body_for(i):
        def body(device, i=i):
            order.append(i)
            time.sleep(0.001)
        return body

    jobs = [mk_job(i, mem_gb=1.0, demand=1.0, body=body_for(i))
            for i in range(12)]
    stats = Executor(sched, workers=1).run(jobs)
    assert stats["completed"] == 12
    assert order == list(range(12))


def test_big_task_not_starved_by_small_stream():
    """A large waiter is always probed before younger small waiters (FIFO
    scan), so it lands as soon as its capacity frees — the small tasks behind
    it cannot leapfrog forever."""
    sched = MGBAlg3Scheduler(1)
    blockers = [mk_task(f"b{i}", mem_gb=7.0) for i in range(2)]
    for b in blockers:
        assert sched.task_begin(b) == 0
    admitted = []
    cb = lambda t, dev, epoch: admitted.append(t.name)
    big = mk_task("big", mem_gb=14.0)
    assert not sched.admit_or_enqueue(big, cb)           # 14 > 2 free
    for i in range(4):
        assert not sched.admit_or_enqueue(
            mk_task(f"s{i}", mem_gb=3.0), cb)            # 3 > 2 free
    sched.task_end(blockers[0])   # 9 free: big still blocked, s0..s2 fit
    assert admitted == ["s0", "s1", "s2"]
    sched.task_end(blockers[1])   # 16-9=7... s0-s2 resident: big waits
    sched.task_end(sched.devices[0].residents[
        next(iter(sched.devices[0].residents))])
    # keep releasing the small residents; the moment 14 GB frees, big lands
    for t in list(sched.devices[0].residents.values()):
        if t.name != "big":
            sched.task_end(t)
    assert "big" in admitted and "s3" in admitted
    assert sched.waiting_count() == 0


def test_wakeup_order_deterministic_under_seeded_arrivals():
    """Same seeded arrival order => identical placement sequence, run to
    run (the waiter queue is FIFO and the drain is a deterministic scan)."""
    import random

    def one_run():
        rng = random.Random(7)
        sched = MGBAlg2Scheduler(2)
        admitted = []
        waiters = []
        for i in range(24):
            d = rng.choice([0.3, 0.6, 1.0])
            t = mk_task(f"t{i}", mem_gb=1.0, demand=d)
            sched.admit_or_enqueue(
                t, lambda t, dev, epoch: admitted.append((t.name, dev)))
            waiters.append(t)
        # release every resident in a seeded order until all 24 admitted
        while len(admitted) < 24:
            resident = [t for d_ in sched.devices
                        for t in d_.residents.values()]
            sched.task_end(resident[rng.randrange(len(resident))])
        return admitted

    assert one_run() == one_run()


# ---------------------------------------------------------------------------
# OOM crash path
# ---------------------------------------------------------------------------

def test_oom_crash_records_and_releases():
    sched = CGScheduler(1, ratio=3)
    ex = Executor(sched, workers=3)
    jobs = [mk_job(i, mem_gb=12.0, sleep=0.05) for i in range(3)]
    stats = ex.run(jobs)
    assert stats["crashed"] >= 1           # 3 x 12 GB on one 16 GB device
    assert stats["completed"] + stats["crashed"] == 3
    crashed_recs = [r for r in ex.records if r.crashed]
    assert len(crashed_recs) >= 1
    # the crash released everything it held
    assert all(d.used_hbm == 0 and d.used_slots == 0 for d in sched.devices)
    assert sched.waiting_count() == 0


def test_never_feasible_task_crashes_instead_of_waiting_forever():
    sched = MGBAlg3Scheduler(2)
    ex = Executor(sched, workers=2)
    jobs = [mk_job(0, mem_gb=20.0), mk_job(1, mem_gb=1.0)]
    stats = ex.run(jobs)
    assert stats["crashed"] == 1 and stats["completed"] == 1
    assert any(r.crashed and r.device == -1 for r in ex.records)


# ---------------------------------------------------------------------------
# fault tolerance: mark_dead / revive through the waiter queue
# ---------------------------------------------------------------------------

def test_mark_dead_requeues_resident_and_blocked_tasks():
    sched = MGBAlg3Scheduler(2)
    ex = Executor(sched, workers=4)
    jobs = [mk_job(i, mem_gb=6.0, sleep=0.08) for i in range(6)]
    t_kill = [0.0]

    def killer():
        time.sleep(0.03)
        t_kill[0] = time.monotonic()
        sched.mark_dead(0)

    th = threading.Thread(target=killer)
    th.start()
    stats = ex.run(jobs)
    th.join()
    assert stats["completed"] == 6 and stats["crashed"] == 0
    # every record finishing after the kill ran on the surviving device
    for r in ex.records:
        if not r.crashed and r.t_start > t_kill[0]:
            assert r.device == 1
    assert all(d.used_hbm == 0 for d in sched.devices)


def test_mark_dead_with_blocked_waiters_lands_on_survivor():
    sched = MGBAlg3Scheduler(2)
    admitted = []
    cb = lambda t, dev, epoch: admitted.append((t.name, dev))
    resident = mk_task("res", mem_gb=9.0)
    assert sched.admit_or_enqueue(resident, cb)        # -> device 0
    dev0 = resident.device
    other = mk_task("other", mem_gb=9.0)
    assert sched.admit_or_enqueue(other, cb)           # -> device 1
    blocked = mk_task("blocked", mem_gb=9.0)
    assert not sched.admit_or_enqueue(blocked, cb)     # both full
    evicted = sched.mark_dead(dev0)
    assert [t.name for t in evicted] == ["res"]
    # the evicted resident re-entered the waiter queue with restart priority:
    # it is FIRST in line when the survivor frees
    sched.task_end(other)
    assert admitted[-1][0] == "res"
    assert admitted[-1][1] == other.device
    sched.task_end(resident)
    assert admitted[-1][0] == "blocked"                # then the blocked task
    assert sched.waiting_count() == 0


def test_stale_completion_from_evicted_run_is_fenced():
    """A task evicted mid-run whose old incarnation later calls task_end must
    not release the re-admitted incarnation's resources (epoch fence)."""
    sched = MGBAlg3Scheduler(2)
    epochs = []
    cb = lambda t, dev, epoch: epochs.append((dev, epoch))
    t = mk_task("t", mem_gb=9.0)
    sched.admit_or_enqueue(t, cb)
    dev0, epoch0 = epochs[-1]
    sched.mark_dead(dev0)                 # evict + auto re-enqueue + drain
    assert len(epochs) == 2               # re-admitted on the survivor
    dev1, epoch1 = epochs[-1]
    assert dev1 != dev0 and epoch1 == epoch0 + 1
    # stale completion from the superseded run: fenced no-op
    assert sched.task_end(t, epoch=epoch0) is False
    assert sched.devices[dev1].used_hbm == t.resources.hbm_bytes
    # current completion releases for real
    assert sched.task_end(t, epoch=epoch1) is True
    assert sched.devices[dev1].used_hbm == 0


def test_revive_lets_waiters_land_on_revived_device():
    sched = MGBAlg2Scheduler(2)
    sched.mark_dead(1)
    hog = mk_task("hog", demand=1.0)
    assert sched.task_begin(hog) == 0        # device 0 compute-exclusive
    admitted = []
    w = mk_task("w", demand=1.0)
    assert not sched.admit_or_enqueue(
        w, lambda t, dev, epoch: admitted.append(dev))
    sched.revive(1)                          # wakeup: waiter fits on device 1
    assert admitted == [1]
    assert w.device == 1


def test_mark_dead_fails_never_feasible_waiters_instead_of_deadlock():
    """If the fleet shrinks to where a parked waiter can NEVER run, the
    waiter's callback fires with placement None (give up) — without this the
    executor would wait for a wakeup that can never come."""
    sched = MGBAlg3Scheduler(2)
    results = []
    cb = lambda t, dev, epoch: results.append((t.name, dev))
    hog = mk_task("hog", mem_gb=9.0)
    assert sched.admit_or_enqueue(hog, cb)
    waiter = mk_task("w", mem_gb=9.0)
    sched.task_begin(mk_task("hog2", mem_gb=9.0))     # fill the other device
    assert not sched.admit_or_enqueue(waiter, cb)
    # kill the OTHER device: waiter still feasible on hog's -> stays parked
    sched.mark_dead(1 - hog.device)
    assert sched.waiting_count() >= 1
    # kill hog's device too: nothing alive can ever host 9 GB -> cb(None)
    sched.mark_dead(hog.device)
    assert ("w", None) in results
    assert ("hog", None) in results                   # evicted hog gives up too
    assert sched.waiting_count() == 0


def test_executor_crashes_jobs_when_fleet_dies_no_hang():
    sched = MGBAlg3Scheduler(2)
    ex = Executor(sched, workers=2)
    jobs = [mk_job(i, mem_gb=9.0, sleep=0.05) for i in range(6)]

    def killer():
        time.sleep(0.02)
        sched.mark_dead(0)
        sched.mark_dead(1)

    th = threading.Thread(target=killer)
    th.start()
    stats = ex.run(jobs)                              # must NOT hang
    th.join()
    assert stats["completed"] + stats["crashed"] == 6
    assert stats["crashed"] >= 1


def test_task_begin_blocking_wakes_on_task_end():
    sched = MGBAlg3Scheduler(1)
    hog = mk_task("hog", mem_gb=10.0)
    assert sched.task_begin(hog) == 0
    got = []

    def waiter():
        got.append(sched.task_begin_blocking(mk_task("w", mem_gb=10.0)))

    th = threading.Thread(target=waiter)
    th.start()
    time.sleep(0.02)
    assert not got                       # still parked, no spinning
    sched.task_end(hog)                  # the wakeup
    th.join(timeout=5.0)
    assert got == [0]


def test_task_begin_blocking_timeout_cancels_waiter():
    sched = MGBAlg3Scheduler(1)
    hog = mk_task("hog", mem_gb=10.0)
    assert sched.task_begin(hog) == 0
    assert sched.task_begin_blocking(mk_task("w", mem_gb=10.0),
                                     timeout=0.02) is None
    assert sched.waiting_count() == 0    # cancelled, not leaked


# ---------------------------------------------------------------------------
# run() edge cases + executor parity
# ---------------------------------------------------------------------------

def test_run_empty_returns_zeroed_metrics():
    for cls in (Executor, PollingExecutor):
        stats = cls(MGBAlg3Scheduler(2), workers=2).run([])
        assert stats["completed"] == 0 and stats["crashed"] == 0
        assert stats["makespan_s"] == 0.0
        assert stats["throughput_jobs_per_s"] == 0.0
        assert stats["mean_turnaround_s"] == 0.0


def test_multi_task_jobs_run_tasks_in_order():
    seen = []

    def body_for(tag):
        def body(device):
            seen.append(tag)
            time.sleep(0.001)
        return body

    tasks = [mk_task(f"j0.{k}", mem_gb=1.0) for k in range(3)]
    job = ExecJob(job=Job(tasks=tasks, name="j0"),
                  runners=[body_for(k) for k in range(3)])
    stats = Executor(MGBAlg3Scheduler(2), workers=2).run([job])
    assert stats["completed"] == 1
    assert seen == [0, 1, 2]


def test_event_and_polling_agree_on_outcome():
    jobs = lambda: [mk_job(i, mem_gb=3.0) for i in range(10)]
    ev = Executor(MGBAlg3Scheduler(2), workers=4).run(jobs())
    po = PollingExecutor(MGBAlg3Scheduler(2), workers=4).run(jobs())
    assert ev["completed"] == po["completed"] == 10
    assert ev["crashed"] == po["crashed"] == 0
