"""Distribution-layer tests that need >1 host device: run in a subprocess
with XLA_FLAGS so the main pytest process keeps seeing 1 device (per the
dry-run isolation rule)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_in_subprocess(code: str) -> None:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    proc = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                          capture_output=True, text=True, env=env,
                          timeout=500)
    assert proc.returncode == 0, proc.stderr[-3000:]


def test_pipeline_parallel_matches_sequential():
    _run_in_subprocess("""
        import jax, jax.numpy as jnp
        from repro.dist.pipeline import make_pipeline_forward, \\
            stack_stage_params
        mesh = jax.make_mesh((4,), ("stage",))
        L, d = 8, 32
        w = jax.random.normal(jax.random.PRNGKey(0), (L, d, d)) * 0.1
        def layer_fn(sp, x):
            h, _ = jax.lax.scan(lambda h, wl: (jnp.tanh(h @ wl), None), x, sp)
            return h
        for n_micro in (4, 8):
            pipe = make_pipeline_forward(layer_fn, mesh, n_micro=n_micro)
            x = jax.random.normal(jax.random.PRNGKey(1), (8, 16, d))
            y = pipe(stack_stage_params(w, 4), x)
            ref, _ = jax.lax.scan(
                lambda h, wl: (jnp.tanh(h @ wl), None), x, w)
            assert jnp.abs(y - ref).max() < 1e-5, n_micro
    """)


def test_elastic_reshard_preserves_state():
    _run_in_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs.registry import get_arch
        from repro.models.model import init_params
        from repro.optim import adamw
        from repro.train.elastic import reshard_state, rescale_batch_size
        cfg = get_arch("llama3-405b").reduced()
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init_state(adamw.AdamWConfig(), params)
        mesh_big = jax.make_mesh((4, 2), ("data", "model"))
        mesh_small = jax.make_mesh((2, 2), ("data", "model"))
        p1, o1 = reshard_state(cfg, params, opt, mesh_big)
        p2, o2 = reshard_state(cfg, p1, o1, mesh_small)   # shrink 8 -> 4
        ok = jax.tree_util.tree_all(jax.tree_util.tree_map(
            lambda a, b: bool(jnp.allclose(a, b)), params, p2))
        assert ok
        assert rescale_batch_size(256, 16, 8) == 128
    """)


def test_sharded_train_step_matches_single_device():
    """The same train step on a 4-device mesh must produce the same loss
    trajectory as unsharded execution (SPMD correctness)."""
    _run_in_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ShapeConfig
        from repro.configs.registry import get_arch
        from repro.dist import sharding as SH
        from repro.models.model import init_params
        from repro.optim import adamw
        from repro.train.train_step import make_train_step
        cfg = get_arch("qwen1.5-32b").reduced()
        opt_cfg = adamw.AdamWConfig()
        step = make_train_step(cfg, opt_cfg, attn_impl="flash_jnp")
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init_state(opt_cfg, params)
        tok = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)
        batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
        # unsharded reference
        p1, o1, m1 = jax.jit(step)(params, opt, batch)
        # sharded on (data=4, model=2)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        with SH.activation_mesh(mesh):
            psh = SH.to_named(SH.param_specs(cfg, params, mesh), mesh)
            bsh = SH.to_named(SH.batch_specs(cfg, batch, mesh), mesh)
            params_s = jax.tree_util.tree_map(jax.device_put, params, psh)
            opt_s = {
                "mu": jax.tree_util.tree_map(
                    jax.device_put, opt["mu"], psh),
                "nu": jax.tree_util.tree_map(
                    jax.device_put, opt["nu"], psh),
                "step": jax.device_put(opt["step"],
                                       NamedSharding(mesh, P())),
            }
            batch_s = {k: jax.device_put(v, bsh[k]) for k, v in batch.items()}
            p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch_s)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
        diffs = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                       - b.astype(jnp.float32)).max()),
            p1, p2)
        assert max(jax.tree_util.tree_leaves(diffs)) < 1e-2
    """)


def test_gradient_compression_in_train_step():
    _run_in_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs.registry import get_arch
        from repro.dist import compression as C
        from repro.models.model import init_params
        from repro.optim import adamw
        from repro.train.train_step import make_train_step
        cfg = get_arch("qwen1.5-32b").reduced()
        opt_cfg = adamw.AdamWConfig()
        step = make_train_step(cfg, opt_cfg, attn_impl="flash_jnp",
                               grad_compressor=lambda g: jax.tree_util.
                               tree_map(C.compress_decompress, g))
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init_state(opt_cfg, params)
        tok = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab)
        batch = {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}
        losses = []
        jstep = jax.jit(step)
        for _ in range(4):
            params, opt, m = jstep(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]   # still optimizes under compression
    """)
