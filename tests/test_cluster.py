"""Concurrency + ordering battery for the open-arrival ``Cluster`` API
(ISSUE 3 tentpole): streaming submission over both backends.

  * ``submit`` is legal while earlier jobs are mid-flight, on the live
    executor AND the virtual-clock simulator;
  * ``JobHandle.cancel()`` of a parked waiter removes it from the scheduler's
    admission queue without leaking ``_admit_cbs``/epoch state;
  * priority inversion: a high-priority job submitted late overtakes parked
    low-priority waiters — enforced by the waiter queue itself;
  * EDF: within one priority class, earliest absolute deadline first;
  * ``drain()`` vs late ``submit()`` race: nothing is lost, nothing hangs;
  * live and sim backends produce the SAME admission order for the same
    submission trace;
  * empty-``tasks`` jobs finish immediately with a zeroed record;
  * property tests: stable FIFO within a priority class, eviction-restart
    jumps to the front of its class (not above higher classes).
"""
import threading
import time

from _hypothesis_fallback import given, settings, st

from repro.core.cluster import Cluster, JobStatus
from repro.core.executor import ExecJob, Executor
from repro.core.scheduler import MGBAlg2Scheduler, MGBAlg3Scheduler
from repro.core.simulator import Simulator
from repro.core.task import Job, ResourceVector, Task, UnitTask
from repro.obs.replay import admission_order, first_divergence

GB = 1024**3


def mk_task(name, mem_gb=2.0, demand=0.5, est=0.005):
    vec = ResourceVector(hbm_bytes=int(mem_gb * GB), flops=1e9,
                         bytes_accessed=1e9, est_seconds=est,
                         core_demand=demand, bw_demand=demand)
    return Task(units=[UnitTask(fn=None, memobjs=frozenset({name}),
                                resources=vec, name=name)], name=name)


def mk_job(name, mem_gb=2.0, demand=0.5, est=0.005, n_tasks=1):
    tasks = [mk_task(f"{name}.{k}" if n_tasks > 1 else name, mem_gb, demand,
                     est) for k in range(n_tasks)]
    return Job(tasks=tasks, name=name)


def live_ej(name, mem_gb=2.0, demand=0.5, sleep=0.003, body=None):
    job = mk_job(name, mem_gb, demand)
    runner = body if body is not None else (
        lambda device, s=sleep: time.sleep(s))
    return ExecJob(job=job, runners=[runner])


# ---------------------------------------------------------------------------
# open arrival: submit while prior jobs are executing
# ---------------------------------------------------------------------------

def test_live_submit_while_running():
    """Acceptance criterion: new jobs enter while earlier ones are mid-
    flight — no pre-declared batch."""
    started = threading.Event()

    def slow(device):
        started.set()
        time.sleep(0.05)

    c = Cluster(MGBAlg3Scheduler(2), workers=2)
    h1 = c.submit(live_ej("a", body=slow))
    assert started.wait(5.0)
    assert h1.status is JobStatus.RUNNING
    h2 = c.submit(live_ej("b", sleep=0.001))   # mid-flight submission
    assert h2.result(timeout=5.0)[0].task == "b"
    c.drain()
    assert h1.status is JobStatus.DONE and h2.status is JobStatus.DONE
    c.shutdown()


def test_sim_submit_while_running():
    """Same property on the virtual clock: a job submitted at t>0 while an
    earlier job is mid-flight is admitted at the current virtual time."""
    c = Cluster(MGBAlg3Scheduler(2), workers=4, backend="sim")
    h1 = c.submit(mk_job("a", est=5.0, n_tasks=2))
    assert c.step()                      # completes a.0 at t=5; a.1 starts
    assert h1.status is JobStatus.RUNNING
    assert 0.0 < c.now < 10.0
    h2 = c.submit(mk_job("b", est=1.0))  # arrives mid-flight of job a
    assert h2.job.arrival_t == c.now
    c.drain()
    assert h1.status is JobStatus.DONE and h2.status is JobStatus.DONE
    assert h2.records[0].t_start >= h2.job.arrival_t


def test_sim_result_advances_virtual_clock():
    c = Cluster(MGBAlg2Scheduler(1), workers=2, backend="sim")
    h1 = c.submit(mk_job("a", demand=1.0, est=3.0))
    h2 = c.submit(mk_job("b", demand=1.0, est=3.0))
    recs = h2.result()                  # drives the clock until b resolves
    assert h2.status is JobStatus.DONE
    assert recs[0].t_start >= 3.0 - 1e-9   # b waited for exclusive a


# ---------------------------------------------------------------------------
# cancellation
# ---------------------------------------------------------------------------

def test_cancel_parked_waiter_leaves_no_scheduler_state():
    """cancel() of a parked waiter: admission queue entry, _admit_cbs and
    _epochs all cleaned (the satellite leak check)."""
    release = threading.Event()
    c = Cluster(MGBAlg3Scheduler(1), workers=2)
    hog = c.submit(live_ej("hog", mem_gb=10.0,
                           body=lambda d: release.wait(5.0)))
    w = c.submit(live_ej("w", mem_gb=10.0))
    deadline = time.monotonic() + 5.0
    while c.sched.waiting_count() == 0 and time.monotonic() < deadline:
        time.sleep(0.001)               # wait until w is parked
    assert w.status is JobStatus.QUEUED
    assert w.cancel() is True
    assert w.status is JobStatus.CANCELLED
    uid = w.job.tasks[0].uid
    assert c.sched.waiting_count() == 0
    assert uid not in c.sched._admit_cbs and uid not in c.sched._epochs
    release.set()
    c.drain()
    assert hog.status is JobStatus.DONE
    assert c.stats()["cancelled"] == 1 and c.stats()["completed"] == 1
    # cancelled waiter never executed
    assert w.records == []
    c.shutdown()


def test_cancel_running_job_stops_after_current_task():
    seen = []
    c = Cluster(MGBAlg3Scheduler(1), workers=1)
    job = mk_job("j", n_tasks=3)
    h = c.submit(ExecJob(job=job, runners=[
        lambda d: (seen.append(0), time.sleep(0.05)),
        lambda d: seen.append(1),
        lambda d: seen.append(2)]))
    deadline = time.monotonic() + 5.0
    while not seen and time.monotonic() < deadline:
        time.sleep(0.001)
    h.cancel()
    c.drain()
    assert h.status is JobStatus.CANCELLED
    assert seen in ([0], [0, 1])      # never ran the full job
    # current task's resources were released on cancel
    assert all(d.used_hbm == 0 and d.used_slots == 0
               for d in c.sched.devices)
    c.shutdown()


def test_cancel_of_evicted_restart_keeps_epoch_fence():
    """Cancelling a parked eviction-restart waiter must NOT delete its
    bumped epoch: the superseded run may still be mid-kernel, and its late
    task_end(epoch=old) has to stay fenced."""
    sched = MGBAlg3Scheduler(2)
    fired = []
    cb = lambda t, dev, epoch: fired.append((dev, epoch))
    t = mk_task("t", mem_gb=9.0)
    assert sched.admit_or_enqueue(t, cb)             # admitted, epoch 0
    assert sched.task_begin(mk_task("hog", mem_gb=9.0)) is not None
    sched.mark_dead(t.device)                        # evict: epoch -> 1,
    assert sched.waiting_count() == 1                # re-parked (hog full)
    assert sched.cancel_wait(t) is True
    # the old incarnation's completion arrives late: still a fenced no-op
    assert sched.task_end(t, epoch=0) is False


def test_sim_cancel_parked_waiter():
    c = Cluster(MGBAlg3Scheduler(1), workers=4, backend="sim")
    hog = c.submit(mk_job("hog", mem_gb=10.0, est=4.0))
    w = c.submit(mk_job("w", mem_gb=10.0, est=1.0))
    assert c.sched.waiting_count() == 1
    assert w.cancel() is True
    assert w.status is JobStatus.CANCELLED
    assert c.sched.waiting_count() == 0
    uid = w.job.tasks[0].uid
    assert uid not in c.sched._admit_cbs and uid not in c.sched._epochs
    r = c._sim.drain()
    assert hog.status is JobStatus.DONE
    assert r.completed == 1 and r.cancelled == 1 and r.crashed == 0


# ---------------------------------------------------------------------------
# priority / deadline ordering (enforced in the waiter queue itself)
# ---------------------------------------------------------------------------

def _ordering_trace(cluster, *, est=0.01, body=None):
    """One exclusive device; jobs park while 'first' runs, then are admitted
    strictly in queue-rank order. Returns expected admission order."""
    mk = (lambda n: live_ej(n, demand=1.0, sleep=0.004, body=body)) \
        if cluster.backend == "live" else \
        (lambda n: mk_job(n, demand=1.0, est=est))
    cluster.submit(mk("first"))
    cluster.submit(mk("low-a"), priority=0)
    cluster.submit(mk("low-b"), priority=0)
    cluster.submit(mk("hi-late"), priority=5)        # overtakes low-a/low-b
    cluster.submit(mk("hi-edf-9"), priority=5, deadline_s=9.0)
    cluster.submit(mk("hi-edf-1"), priority=5, deadline_s=1.0)
    cluster.submit(mk("low-edf"), priority=0, deadline_s=3.0)
    return ["first", "hi-edf-1", "hi-edf-9", "hi-late",
            "low-edf", "low-a", "low-b"]


def test_priority_inversion_high_submitted_late_overtakes():
    """A high-priority job submitted AFTER parked low-priority waiters is
    admitted before them — the queue reorders, not the caller."""
    gate = threading.Event()
    c = Cluster(MGBAlg2Scheduler(1), workers=1, trace=True)
    # only "first" actually waits on the gate — everyone else starts after
    # gate.set() and returns immediately
    expected = _ordering_trace(c, body=lambda d: gate.wait(0.2))
    gate.set()
    c.drain()
    assert admission_order(c.trace.events()) == expected
    assert all(h.status is JobStatus.DONE for h in c.handles)
    c.shutdown()


def test_sim_edf_and_priority_ordering():
    c = Cluster(MGBAlg2Scheduler(1), workers=8, backend="sim", trace=True)
    expected = _ordering_trace(c)
    c.drain()
    assert admission_order(c.trace.events()) == expected


def test_live_and_sim_same_admission_order_for_same_trace():
    """Acceptance criterion: the two backends replay one submission trace
    into the SAME admission order (they share the scheduler's queue) —
    asserted through the obs.replay parity differ over each backend's
    event stream."""
    live = Cluster(MGBAlg2Scheduler(1), workers=1, trace=True)
    _ordering_trace(live)
    live.drain()
    live.shutdown()
    sim = Cluster(MGBAlg2Scheduler(1), workers=8, backend="sim", trace=True)
    _ordering_trace(sim)
    sim.drain()
    div = first_divergence(admission_order(live.trace.events()),
                           admission_order(sim.trace.events()))
    assert div is None, div


def test_deadline_is_ordering_hint_not_enforcement():
    """A missed deadline does not kill the job — EDF only ranks admission."""
    c = Cluster(MGBAlg2Scheduler(1), workers=4, backend="sim")
    c.submit(mk_job("hog", demand=1.0, est=10.0))
    late = c.submit(mk_job("late", demand=1.0, est=1.0), deadline_s=0.5)
    c.drain()
    assert late.status is JobStatus.DONE          # ran anyway, late
    assert late.records[0].t_start > 0.5


# ---------------------------------------------------------------------------
# drain() vs late submit()
# ---------------------------------------------------------------------------

def test_drain_vs_late_submit_race():
    """A submit racing drain() is never lost: drain returns only when the
    in-flight count is zero, so the late job either extends the drain or
    lands after it — both complete."""
    c = Cluster(MGBAlg3Scheduler(2), workers=2)
    for i in range(8):
        c.submit(live_ej(f"early{i}", sleep=0.01))
    late = []

    def late_submitter():
        for i in range(8):
            late.append(c.submit(live_ej(f"late{i}", sleep=0.002)))
            time.sleep(0.004)

    th = threading.Thread(target=late_submitter)
    th.start()
    c.drain()
    th.join()
    c.drain()                                     # catch stragglers
    assert all(h.status is JobStatus.DONE for h in c.handles)
    assert len(c.handles) == 16
    assert all(d.used_hbm == 0 for d in c.sched.devices)
    c.shutdown()


def test_submit_after_drain_and_shutdown_restarts_pool():
    c = Cluster(MGBAlg3Scheduler(1), workers=1)
    h1 = c.submit(live_ej("a", sleep=0.001))
    c.shutdown()
    assert h1.status is JobStatus.DONE
    h2 = c.submit(live_ej("b", sleep=0.001))      # pool restarts
    assert h2.result(timeout=5.0)[0].task == "b"
    c.shutdown()


# ---------------------------------------------------------------------------
# empty-tasks jobs (satellite regression)
# ---------------------------------------------------------------------------

def test_empty_job_finishes_immediately_live():
    c = Cluster(MGBAlg3Scheduler(1), workers=1)
    h = c.submit(ExecJob(job=Job(tasks=[], name="empty"), runners=[]))
    recs = h.result(timeout=5.0)
    assert h.status is JobStatus.DONE
    assert len(recs) == 1 and recs[0].device == -1 and not recs[0].crashed
    assert recs[0].t_start == recs[0].t_end
    c.shutdown()


def test_empty_job_finishes_immediately_sim():
    c = Cluster(MGBAlg3Scheduler(1), workers=1, backend="sim")
    h = c.submit(Job(tasks=[], name="empty"))
    assert h.status is JobStatus.DONE
    assert len(h.records) == 1 and h.records[0].device == -1
    r = c._sim.drain()
    assert r.completed == 1 and r.crashed == 0


def test_executor_run_empty_tasks_job_zeroed_record():
    """The batch shim path hits the same fix: no runners[0] IndexError."""
    ex = Executor(MGBAlg3Scheduler(2), workers=2)
    jobs = [ExecJob(job=Job(tasks=[], name="e0"), runners=[]),
            ExecJob(job=mk_job("real"), runners=[lambda d: None])]
    stats = ex.run(jobs)
    assert stats["completed"] == 2 and stats["crashed"] == 0
    assert any(r.job == "e0" and r.device == -1 and not r.crashed
               for r in ex.records)


def test_simulator_run_empty_metrics_guarded():
    """Satellite: SimResult means stay finite with zero completions."""
    r = Simulator(MGBAlg3Scheduler(2), workers=2).run([])
    assert r.completed == 0 and r.crashed == 0
    assert r.makespan == 0.0 and r.throughput == 0.0
    assert r.mean_turnaround == 0.0 and r.mean_slowdown_pct == 0.0
    assert r.utilization == 0.0
    r2 = Simulator(MGBAlg3Scheduler(2), workers=2).run(
        [Job(tasks=[], name="e")])
    assert r2.completed == 1 and r2.mean_slowdown_pct == 0.0


# ---------------------------------------------------------------------------
# compatibility shim
# ---------------------------------------------------------------------------

def test_run_shim_metrics_keys_unchanged():
    ex = Executor(MGBAlg3Scheduler(2), workers=2)
    stats = ex.run([live_ej(f"j{i}", sleep=0.002) for i in range(6)])
    assert set(stats) >= {"makespan_s", "throughput_jobs_per_s", "completed",
                          "crashed", "mean_turnaround_s", "sched_attempts"}
    assert stats["completed"] == 6 and stats["crashed"] == 0
    # run() is submit-all-then-drain: the pool is torn down afterwards
    assert not ex._running


# ---------------------------------------------------------------------------
# property tests: queue-rank invariants
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000), n=st.integers(3, 16))
@settings(max_examples=20, deadline=None)
def test_property_stable_fifo_within_class(seed, n):
    """Same priority, no deadlines => admission order is exactly arrival
    order, whatever the priorities of OTHER classes interleaved."""
    import random
    rng = random.Random(seed)
    sched = MGBAlg2Scheduler(1)
    hog = mk_task("hog", demand=1.0)
    assert sched.task_begin(hog) == 0
    admitted = []
    cb = lambda t, dev, epoch: admitted.append(t.name)
    arrivals = []
    for i in range(n):
        pri = rng.choice([0, 0, 0, 3])
        t = mk_task(f"t{i}", demand=1.0)
        t.priority = pri
        arrivals.append((pri, t.name))
        assert not sched.admit_or_enqueue(t, cb)
    sched.task_end(hog)
    while sched.waiting_count():
        resident = [t for d in sched.devices for t in d.residents.values()]
        sched.task_end(resident[0])
    for t in [t for d in sched.devices for t in d.residents.values()]:
        sched.task_end(t)
    per_class = lambda p: [nm for pr, nm in arrivals if pr == p]
    got_class = lambda p: [nm for nm in admitted
                           if nm in set(per_class(p))]
    assert got_class(0) == per_class(0)
    assert got_class(3) == per_class(3)
    # and every class-3 task beat every class-0 task
    if per_class(3) and per_class(0):
        assert max(admitted.index(nm) for nm in per_class(3)) \
            < min(admitted.index(nm) for nm in per_class(0))


@given(seed=st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_property_eviction_restart_front_of_its_class_only(seed):
    """An evicted resident re-enters at the front of ITS priority class:
    ahead of same-priority waiters (even deadlined ones), never ahead of a
    higher class."""
    import random
    rng = random.Random(seed)
    sched = MGBAlg3Scheduler(2)
    admitted = []
    cb = lambda t, dev, epoch: admitted.append((t.name, dev))
    victim = mk_task("victim", mem_gb=9.0)
    victim.priority = 1
    assert sched.admit_or_enqueue(victim, cb)
    dev0 = victim.device
    other = mk_task("other", mem_gb=9.0)
    assert sched.admit_or_enqueue(other, cb)      # fills the second device
    # park waiters in seeded order: some class 1 (victim's), some class 2
    waiters = []
    for i in range(rng.randint(2, 6)):
        pri = rng.choice([1, 1, 2])
        t = mk_task(f"w{i}", mem_gb=9.0)
        t.priority = pri
        t.deadline_t = rng.choice([None, float(i)])
        waiters.append((pri, t.name))
        assert not sched.admit_or_enqueue(t, cb)
    sched.mark_dead(dev0)                         # victim re-enters class 1
    order = [t.name for t in sched.waiting_tasks()]
    pos = {nm: i for i, nm in enumerate(order)}
    assert "victim" in pos                        # still parked (no room)
    for pri, nm in waiters:
        if pri == 1:      # victim leads its own class, even past deadlines
            assert pos["victim"] < pos[nm]
        else:             # ...but never jumps the higher class
            assert pos[nm] < pos["victim"]
    # release everything; nothing deadlocks and accounting zeroes out
    sched.task_end(other)
    while sched.waiting_count():
        resident = [t for d in sched.devices for t in d.residents.values()]
        if not resident:
            break
        sched.task_end(resident[0])
    for t in [t for d in sched.devices for t in d.residents.values()]:
        sched.task_end(t)
    assert all(d.used_hbm == 0 and d.used_slots == 0 for d in sched.devices)
