"""Optional-dependency shim for ``hypothesis``.

``hypothesis`` is a test-extra (pyproject ``[test]``), not a runtime
dependency; test collection must never hard-fail when it is absent. Modules
do ``from _hypothesis_fallback import given, settings, st``: when hypothesis
is installed they get the real thing, otherwise a tiny deterministic stand-in
that still RUNS each property test against ``max_examples`` seeded
pseudo-random examples (weaker than hypothesis — no shrinking, no coverage
guidance — but far better than skipping the module).

The fallback covers exactly the API surface this suite uses: ``given`` /
``settings`` and the strategies integers, floats, booleans, sampled_from,
lists, sets, tuples.
"""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:
    import random

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda r: bool(r.getrandbits(1)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

        @staticmethod
        def lists(elements, min_size=0, max_size=10):
            return _Strategy(lambda r: [elements.draw(r) for _ in
                                        range(r.randint(min_size, max_size))])

        @staticmethod
        def sets(elements, min_size=0, max_size=10):
            def draw(r):
                out = set()
                target = r.randint(min_size, max_size)
                for _ in range(100 * (target + 1)):
                    if len(out) >= target:
                        break
                    out.add(elements.draw(r))
                return out
            return _Strategy(draw)

        @staticmethod
        def tuples(*elements):
            return _Strategy(lambda r: tuple(e.draw(r) for e in elements))

    st = _Strategies()

    def settings(max_examples=20, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*arg_strategies, **kw_strategies):
        def deco(fn):
            # No functools.wraps: the wrapper must expose a ZERO-arg
            # signature or pytest would treat the strategy params as
            # fixtures.
            def wrapper():
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 20))
                for i in range(n):
                    rng = random.Random(f"{fn.__name__}:{i}")
                    args = [s.draw(rng) for s in arg_strategies]
                    kwargs = {k: s.draw(rng)
                              for k, s in kw_strategies.items()}
                    try:
                        fn(*args, **kwargs)
                    except BaseException as e:
                        e.args = (f"falsifying example #{i}: args={args!r} "
                                  f"kwargs={kwargs!r}: {e}",) + e.args[1:] \
                            if e.args else (f"example #{i}",)
                        raise
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = getattr(fn, "_max_examples", 20)
            return wrapper
        return deco
