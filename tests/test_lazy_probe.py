"""Lazy runtime + compiler-guided probe tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import lazy
from repro.core.probe import HBM_BW, PEAK_FLOPS, probe_fn


def test_lazy_buffer_records_without_allocation():
    buf = lazy.LazyBuffer("x").alloc((8, 8), jnp.float32)
    assert buf._real is None and buf.nbytes == 256
    buf.fill(3.0)
    assert buf._real is None  # still nothing on device


def test_lazy_replay_h2d():
    host = np.arange(16, dtype=np.float32).reshape(4, 4)
    buf = lazy.LazyBuffer("x").h2d(host)
    dev = jax.devices()[0]
    arr = buf.bind(dev)
    np.testing.assert_array_equal(np.asarray(arr), host)


def test_lazy_rebind_to_other_device_after_free():
    buf = lazy.LazyBuffer("x").fill(2.5).alloc((4,), jnp.float32)
    # alloc after fill resets shape; do it properly
    buf2 = lazy.LazyBuffer("y").alloc((4,), jnp.float32).fill(2.5)
    dev = jax.devices()[0]
    a = buf2.bind(dev)
    np.testing.assert_allclose(np.asarray(a), 2.5)
    buf2.free()
    assert buf2._real is None
    b = buf2.bind(dev)  # replay again — the paper's device reassignment
    np.testing.assert_allclose(np.asarray(b), 2.5)


def test_kernel_launch_prepare_binds_all():
    bufs = {"a": lazy.LazyBuffer("a").h2d(np.ones((2, 2), np.float32)),
            "b": lazy.LazyBuffer("b").alloc((2, 2), jnp.float32)}
    arrs = lazy.kernel_launch_prepare(bufs, jax.devices()[0])
    assert set(arrs) == {"a", "b"}
    np.testing.assert_allclose(np.asarray(arrs["b"]), 0.0)  # bare alloc=zeros


def test_probe_memory_matches_analytic():
    n = 256

    def f(x, y):
        return x @ y

    sds = jax.ShapeDtypeStruct((n, n), jnp.float32)
    vec = probe_fn(f, sds, sds)
    # 2 args + 1 output of n*n*4 bytes; temps small for a single matmul
    expect = 3 * n * n * 4
    assert expect <= vec.hbm_bytes <= expect * 1.5
    # flops ~= 2 n^3
    assert 0.5 <= vec.flops / (2 * n**3) <= 1.5
    assert 0 < vec.core_demand <= 1 and 0 < vec.bw_demand <= 1
    assert vec.est_seconds > 0


def test_probe_efficiency_scales_demand():
    def f(x):
        return jnp.sum(x * 2.0)  # memory-bound

    sds = jax.ShapeDtypeStruct((1 << 20,), jnp.float32)
    full = probe_fn(f, sds)
    half = probe_fn(f, sds, efficiency=(1.0, 0.5))
    assert half.est_seconds > full.est_seconds * 1.8
    assert half.bw_demand <= 0.55


def test_probe_work_scale():
    def f(x):
        return x + 1

    sds = jax.ShapeDtypeStruct((1024,), jnp.float32)
    v1 = probe_fn(f, sds, work_scale=1.0)
    v10 = probe_fn(f, sds, work_scale=10.0)
    assert abs(v10.est_seconds - 10 * v1.est_seconds) < 1e-9
    assert v10.hbm_bytes == v1.hbm_bytes  # footprint does not scale
