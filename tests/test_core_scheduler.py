"""Unit + property tests for the paper's schedulers and task framework."""
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core.scheduler import (
    CGScheduler, MemOnlyScheduler, MGBAlg2Scheduler, MGBAlg3Scheduler,
    SAScheduler, SliceScheduler,
)
from repro.core.task import Job, ResourceVector, Task, UnitTask
from repro.core.taskgraph import build_gpu_tasks

GB = 1024**3


def mk_task(mem_gb=1.0, demand=0.5, est=10.0, name="t", chips=1):
    vec = ResourceVector(hbm_bytes=int(mem_gb * GB), flops=1e12,
                         bytes_accessed=1e9, est_seconds=est,
                         core_demand=demand, bw_demand=demand, chips=chips)
    return Task(units=[UnitTask(fn=None, memobjs=frozenset({name}),
                                resources=vec, name=name)], name=name)


# ---------------------------------------------------------------------------
# memory safety (the paper's core guarantee)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cls", [SAScheduler, MemOnlyScheduler,
                                 MGBAlg2Scheduler, MGBAlg3Scheduler])
def test_memory_safe_schedulers_never_oversubscribe(cls):
    sched = cls(2)
    admitted = []
    for i in range(10):
        t = mk_task(mem_gb=7.0, name=f"t{i}")
        if sched.task_begin(t) is not None:
            admitted.append(t)
        for d in sched.devices:
            assert not d.oom()
    # 2 devices x 16 GB: at most 2 tasks of 7 GB fit per device
    assert len(admitted) <= 4


def test_cg_is_memory_unsafe():
    sched = CGScheduler(1, ratio=8)
    for i in range(4):
        t = mk_task(mem_gb=6.0, name=f"t{i}")
        assert sched.task_begin(t) == 0
    assert sched.devices[0].oom()  # 24 GB admitted on a 16 GB device


def test_oversized_task_never_admitted_by_safe_schedulers():
    for cls in (MemOnlyScheduler, MGBAlg2Scheduler, MGBAlg3Scheduler):
        sched = cls(2)
        assert sched.task_begin(mk_task(mem_gb=20.0)) is None


@given(mems=st.lists(st.floats(0.1, 15.9), min_size=1, max_size=30))
@settings(max_examples=50, deadline=None)
def test_property_mgb_memory_invariant(mems):
    """No sequence of task_begin/task_end calls oversubscribes memory."""
    sched = MGBAlg3Scheduler(3)
    live = []
    for i, m in enumerate(mems):
        t = mk_task(mem_gb=m, name=f"t{i}")
        if sched.task_begin(t) is not None:
            live.append(t)
        for d in sched.devices:
            assert d.used_hbm <= d.total_hbm
        if len(live) > 4:  # retire oldest
            sched.task_end(live.pop(0))
    for d in sched.devices:
        assert d.used_hbm <= d.total_hbm


# ---------------------------------------------------------------------------
# policy behaviour
# ---------------------------------------------------------------------------

def test_sa_one_job_per_device():
    sched = SAScheduler(2)
    assert sched.task_begin(mk_task(name="a")) == 0
    assert sched.task_begin(mk_task(name="b")) == 1
    assert sched.task_begin(mk_task(name="c")) is None


def test_alg3_picks_least_loaded():
    sched = MGBAlg3Scheduler(2)
    sched.task_begin(mk_task(demand=0.9, name="heavy"))    # -> dev 0
    d = sched.task_begin(mk_task(demand=0.1, name="light"))
    assert d == 1


def test_alg2_compute_is_hard_constraint():
    sched = MGBAlg2Scheduler(1)
    assert sched.task_begin(mk_task(demand=0.9, name="a")) == 0
    # 0.9 + 0.9 > 1.0 of the chip's compute slots -> must wait
    assert sched.task_begin(mk_task(demand=0.9, name="b")) is None
    # a small task still fits
    assert sched.task_begin(mk_task(demand=0.05, name="c")) == 0


def test_alg3_compute_is_soft_constraint():
    sched = MGBAlg3Scheduler(1)
    assert sched.task_begin(mk_task(demand=0.9, name="a")) == 0
    assert sched.task_begin(mk_task(demand=0.9, name="b")) == 0  # optimistic


def test_memonly_first_fit_never_balances():
    sched = MemOnlyScheduler(4)
    for i in range(8):
        assert sched.task_begin(mk_task(mem_gb=1.0, name=f"t{i}")) == 0


def test_mark_dead_evicts_and_excludes():
    sched = MGBAlg3Scheduler(2)
    t = mk_task(name="a")
    assert sched.task_begin(t) == 0
    evicted = sched.mark_dead(0)
    assert evicted == [t] and t.device is None
    assert sched.devices[0].used_hbm == 0
    t2 = mk_task(name="b")
    assert sched.task_begin(t2) == 1  # dead device never selected
    sched.revive(0)
    assert sched.task_begin(mk_task(name="c")) == 0


# ---------------------------------------------------------------------------
# Alg. 1 task construction
# ---------------------------------------------------------------------------

def mk_unit(name, objs, mem=1.0):
    vec = ResourceVector(hbm_bytes=int(mem * GB), flops=1e9,
                         bytes_accessed=1e9, est_seconds=1.0)
    return UnitTask(fn=None, memobjs=frozenset(objs), resources=vec,
                    name=name)


def test_alg1_merges_shared_memobjs():
    units = [mk_unit("k1", {"a", "b"}), mk_unit("k2", {"b", "c"}),
             mk_unit("k3", {"d"})]
    tasks = build_gpu_tasks(units)
    assert len(tasks) == 2
    sizes = sorted(len(t.units) for t in tasks)
    assert sizes == [1, 2]


def test_alg1_transitive_merge():
    units = [mk_unit("k1", {"a"}), mk_unit("k2", {"a", "b"}),
             mk_unit("k3", {"b", "c"}), mk_unit("k4", {"c"})]
    tasks = build_gpu_tasks(units)
    assert len(tasks) == 1 and len(tasks[0].units) == 4


@given(st.lists(st.sets(st.integers(0, 12), min_size=1, max_size=4),
                min_size=1, max_size=20))
@settings(max_examples=100, deadline=None)
def test_property_alg1_partition(objsets):
    """Merge result is a partition; tasks share no memobjs across tasks."""
    units = [mk_unit(f"k{i}", {str(o) for o in objs})
             for i, objs in enumerate(objsets)]
    tasks = build_gpu_tasks(units)
    # partition: every unit in exactly one task
    all_units = [u.uid for t in tasks for u in t.units]
    assert sorted(all_units) == sorted(u.uid for u in units)
    # cross-task memobj disjointness (the whole point of Alg. 1)
    for i, t1 in enumerate(tasks):
        for t2 in tasks[i + 1:]:
            assert not (t1.memobjs & t2.memobjs)


# ---------------------------------------------------------------------------
# slice scheduler (beyond-paper)
# ---------------------------------------------------------------------------

def test_slice_scheduler_places_contiguous():
    sched = SliceScheduler(pods=1, rows=4, cols=4)
    t = mk_task(mem_gb=8 * 4, name="big", chips=4)  # 8 GB/chip on 4 chips
    rect = sched.task_begin(t)
    assert rect is not None and rect.chips == 4
    for cell in rect.cells():
        assert sched.chips[cell].used_hbm == 8 * GB
    sched.task_end(t)
    assert all(d.used_hbm == 0 for d in sched.chips.values())


def test_slice_scheduler_packs_disjoint():
    sched = SliceScheduler(pods=1, rows=4, cols=4)
    t1 = mk_task(mem_gb=10 * 8, name="a", chips=8)
    t2 = mk_task(mem_gb=10 * 8, name="b", chips=8)
    r1, r2 = sched.task_begin(t1), sched.task_begin(t2)
    assert r1 is not None and r2 is not None
    assert not (set(r1.cells()) & set(r2.cells()))


def test_slice_scheduler_chip_failure_evicts_whole_slice():
    sched = SliceScheduler(pods=1, rows=4, cols=4)
    t = mk_task(mem_gb=8 * 16, name="whole", chips=16)
    rect = sched.task_begin(t)
    assert rect is not None
    dead_cell = next(iter(rect.cells()))
    evicted = sched.mark_dead(dead_cell)
    assert [e.uid for e in evicted] == [t.uid]
    alive_used = [d.used_hbm for d in sched.chips.values()]
    assert all(u == 0 for u in alive_used)
