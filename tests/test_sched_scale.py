"""Fleet-scale scheduler core battery (ISSUE 6 tentpole): the indexed
admission queue, the incremental gang-placement index, and the sharded
per-pod control plane must be BEHAVIOR-PRESERVING rewrites.

  * trace replay: seeded open-arrival traces (priority / EDF deadlines /
    anti-starvation aging / deadline shedding / device-death restarts /
    cancels) driven through the pre-refactor sorted-list engine
    (``scheduler.reference``) and the indexed engine must produce the
    IDENTICAL admission sequence, placements, shed set, hint-skip count,
    probe count, and final queue;
  * gang placement: ``_find_group`` against the incremental tile index must
    match a test-local copy of the historical full-enumeration oracle —
    same feasibility verdict and same (demand, link-pressure) score — after
    every step of random reserve / release / death / revive sequences;
  * sharded control plane: no task lost across shard boundaries, stealing
    actually fires for imbalanced completions, pod death re-homes both
    evicted residents and parked waiters, pod-spanning gangs fail fast;
  * ``Cluster.stats()`` O(1) counters must equal a full recompute from the
    handle list across mixed DONE / CRASHED / CANCELLED / SHED outcomes.
"""
import random

import pytest
from _hypothesis_fallback import given, settings, st

from repro.core.cluster import Cluster, JobStatus
from repro.core.scheduler import (
    GangScheduler, MGBAlg2Scheduler, MGBAlg3Scheduler,
    ReferenceAlg2Scheduler, ReferenceAlg3Scheduler, ShardedScheduler,
)
from repro.core.scheduler.base import DEADLINE_SHED, SLOTS, slots_needed
from repro.core.task import Job, ResourceVector, Task, UnitTask

GB = 1024**3


def mk_task(name, mem_gb=2.0, demand=0.5, chips=1, est=10.0):
    vec = ResourceVector(hbm_bytes=int(mem_gb * GB), flops=1e12,
                         bytes_accessed=1e9, est_seconds=est,
                         core_demand=demand, bw_demand=demand, chips=chips)
    return Task(units=[UnitTask(fn=None, memobjs=frozenset({name}),
                                resources=vec, name=name)], name=name)


# ---------------------------------------------------------------------------
# trace replay: indexed queue vs the verbatim pre-refactor engine
# ---------------------------------------------------------------------------

# few distinct vectors => distinct failing classes stay far below the
# indexed drain's memo width, so even begin_attempts must match exactly
TRACE_MEMS = (2.0, 4.0, 7.0)


def gen_trace(rng, n_ops):
    """Abstract op list; indices are resolved against the replay's own
    resident/waiting bookkeeping so both engines see literally the same
    call sequence as long as they admit identically."""
    ops = []
    for _ in range(n_ops):
        r = rng.random()
        if r < 0.45:
            ops.append(("submit", rng.choice(TRACE_MEMS), rng.randrange(4),
                        rng.choice([None, None, rng.uniform(1.0, 60.0)]),
                        rng.choice([0, 0, 0, 2])))      # age_boost (aging)
        elif r < 0.70:
            ops.append(("end", rng.randrange(1 << 30)))
        elif r < 0.78:
            ops.append(("cancel", rng.randrange(1 << 30)))
        elif r < 0.88:
            ops.append(("tick", rng.uniform(0.5, 15.0)))
        elif r < 0.94:
            ops.append(("dead", rng.randrange(1 << 30)))
        else:
            ops.append(("revive", rng.randrange(1 << 30)))
    return ops


def replay(cls, ops, *, n_dev=3, shed=False):
    """Drive one engine through the trace under a fake clock; returns the
    full observable event log and the engine (for counter comparison)."""
    sched = cls(n_dev)
    clock = [0.0]
    sched._clock = lambda: clock[0]
    sched.shed_expired = shed
    log, resident, waiting = [], [], []
    gone = set()                          # uids that reached shed/fail

    def cb(t, placement, epoch):
        if t in waiting:
            waiting.remove(t)
        if placement is DEADLINE_SHED:
            gone.add(t.uid)
            log.append(("shed", t.name))
        elif placement is None:
            gone.add(t.uid)
            log.append(("fail", t.name))
        else:
            log.append(("admit", t.name, placement))
            resident.append(t)

    k = 0
    for op in ops:
        kind = op[0]
        if kind == "submit":
            _, mem, prio, dl, boost = op
            t = mk_task(f"t{k}", mem_gb=mem)
            k += 1
            t.priority = prio
            t.deadline_t = clock[0] + dl if dl is not None else None
            if boost:
                t.age_boost = boost
            waiting.append(t)
            sched.admit_or_enqueue(t, cb)
        elif kind == "end" and resident:
            sched.task_end(resident.pop(op[1] % len(resident)))
        elif kind == "cancel" and waiting:
            t = waiting.pop(op[1] % len(waiting))
            assert sched.cancel_wait(t)
        elif kind == "tick":
            clock[0] += op[1]
            sched.notify()
        elif kind == "dead":
            # mark_dead requeues waiter-path residents itself (restart
            # priority, callback re-fires); an evicted task is re-admitted
            # synchronously (second resident entry), re-parked, or failed
            evicted = sched.mark_dead(op[1] % n_dev)
            for t in evicted:
                resident.remove(t)
            for t in evicted:
                if t not in resident and t.uid not in gone \
                        and t not in waiting:
                    waiting.append(t)
        elif kind == "revive":
            sched.revive(op[1] % n_dev)
            sched.notify()
    while resident:                       # final drain empties the queue
        sched.task_end(resident.pop())
    return log, sched


PAIRS = [(ReferenceAlg2Scheduler, MGBAlg2Scheduler),
         (ReferenceAlg3Scheduler, MGBAlg3Scheduler)]


def assert_engines_agree(ref_cls, idx_cls, ops, shed):
    log_r, s_r = replay(ref_cls, ops, shed=shed)
    log_i, s_i = replay(idx_cls, ops, shed=shed)
    # the preserved contract, bit-for-bit: admission sequence WITH
    # placements, shed sequence, fail sequence. (Within a single drain the
    # indexed engine sheds every expired waiter before admitting, where the
    # scan interleaved both by rank — the only tolerated difference.)
    for kind in ("admit", "shed", "fail"):
        assert [e for e in log_r if e[0] == kind] \
            == [e for e in log_i if e[0] == kind], kind
    assert s_r.hint_skips == s_i.hint_skips
    assert s_r.begin_attempts == s_i.begin_attempts
    assert s_r.waiting_count() == s_i.waiting_count()
    assert ([t.name for t in s_r.waiting_tasks()]
            == [t.name for t in s_i.waiting_tasks()])
    assert s_r.queue_stats()["depth"] == s_i.queue_stats()["depth"]


@pytest.mark.parametrize("ref_cls,idx_cls", PAIRS,
                         ids=["alg2", "alg3"])
@pytest.mark.parametrize("shed", [False, True], ids=["keep", "shed"])
@pytest.mark.parametrize("seed", range(6))
def test_trace_replay_matches_reference(ref_cls, idx_cls, shed, seed):
    ops = gen_trace(random.Random(seed), 150)
    assert_engines_agree(ref_cls, idx_cls, ops, shed)


@given(seed=st.integers(0, 100_000))
@settings(max_examples=25, deadline=None)
def test_property_trace_replay_parity(seed):
    """Property form: ANY seeded trace replays identically (shedding on —
    the strictest mode, since it adds the expiry sweep to every drain)."""
    ops = gen_trace(random.Random(seed), 120)
    assert_engines_agree(ReferenceAlg3Scheduler, MGBAlg3Scheduler, ops,
                         shed=True)


# ---------------------------------------------------------------------------
# gang placement: tile index vs the historical enumeration oracle
# ---------------------------------------------------------------------------

def oracle_find_group(sched, task):
    """Test-local copy of the pre-refactor ``_find_group``: full candidate
    enumeration, per-member walks, per-candidate resident-demand sums."""
    r = task.resources
    k = max(r.chips, 1)
    per_chip = r.hbm_bytes // k
    need = slots_needed(task)
    best, best_key = None, (float("inf"), float("inf"))
    for group in sched.topo.candidate_groups(k):
        if not all(sched._member_ok(c, per_chip, need)
                   for c in group.cells()):
            continue
        if sched.policy == "alg2" \
                and not sched.topo.link_headroom_ok(group, r):
            continue
        key = (sum(sched.topo.cells[c].in_use_demand
                   for c in group.cells()),
               sched.topo.max_link_load(group))
        if key < best_key:
            best, best_key = group, key
        if key == (0.0, 0.0):
            return group
    return best


def group_score(sched, group):
    return (sum(sched.topo.cells[c].in_use_demand for c in group.cells()),
            sched.topo.max_link_load(group))


@pytest.mark.parametrize("policy", ["alg2", "alg3"])
@pytest.mark.parametrize("seed", range(4))
def test_find_group_matches_enumeration_oracle(policy, seed):
    """After every mutation, the indexed probe and the full enumeration must
    agree on feasibility and on the placement SCORE (ties may pick different
    groups of equal score; the score is the policy-visible contract)."""
    rng = random.Random(seed)
    sched = GangScheduler(pods=2, rows=4, cols=4, policy=policy)
    n = sched.topo.total_chips
    probes = [mk_task(f"p{c}", mem_gb=2.0 * c, chips=c, demand=0.4)
              for c in (1, 2, 4, 8, 16)]
    resident = []
    for step in range(50):
        r = rng.random()
        if r < 0.5:
            chips = rng.choice((1, 2, 4, 8))
            t = mk_task(f"g{step}", mem_gb=3.0 * chips, chips=chips,
                        demand=rng.choice((0.2, 0.5)))
            if sched.task_begin(t) is not None:
                resident.append(t)
        elif r < 0.8 and resident:
            sched.task_end(resident.pop(rng.randrange(len(resident))))
        elif r < 0.9:
            for t in sched.mark_dead(rng.randrange(n)):
                resident.remove(t)
        else:
            sched.revive(rng.randrange(n))
        for probe in probes:
            g_idx = sched._find_group(probe)
            g_ora = oracle_find_group(sched, probe)
            assert (g_idx is None) == (g_ora is None), \
                (step, probe.name, g_idx, g_ora)
            if g_idx is not None:
                assert group_score(sched, g_idx) \
                    == group_score(sched, g_ora), (step, probe.name)


def test_invalidate_index_recovers_from_external_mutation():
    """The escape hatch: out-of-band cell mutation + invalidate_index()
    must leave the probe agreeing with the oracle again."""
    sched = GangScheduler(pods=1, rows=4, cols=4)
    t = mk_task("g", mem_gb=8.0, chips=4)
    assert sched.task_begin(t) is not None
    # simulate an external actor flipping liveness without set_alive
    cell = next(iter(sched.topo.cells))
    sched.topo.cells[cell].alive = False
    sched.topo.invalidate_index()
    probe = mk_task("p", mem_gb=2.0, chips=4)
    g_idx = sched._find_group(probe)
    g_ora = oracle_find_group(sched, probe)
    assert (g_idx is None) == (g_ora is None)
    if g_idx is not None:
        assert group_score(sched, g_idx) == group_score(sched, g_ora)


# ---------------------------------------------------------------------------
# sharded control plane
# ---------------------------------------------------------------------------

def _collector():
    """Admission log with placements normalized to flat device indices
    (the gang shards deliver ``GangReservation``s; ``lead`` is the
    globally-translated audit index)."""
    admitted = []

    def cb(t, placement, epoch):
        if placement is not None and placement is not DEADLINE_SHED \
                and not isinstance(placement, int):
            placement = placement.lead
        admitted.append((t, placement))
    return admitted, cb


def test_sharded_no_task_lost():
    """Every submitted task is admitted exactly once, whatever shard it
    lands on, under full-fleet churn."""
    sched = ShardedScheduler(pods=2, rows=2, cols=2)   # 2 shards x 4 chips
    admitted, cb = _collector()
    tasks = [mk_task(f"t{i}", mem_gb=8.0) for i in range(30)]
    for t in tasks:
        sched.admit_or_enqueue(t, cb)
    guard = 0
    while len(admitted) < len(tasks):
        guard += 1
        assert guard < 200, f"stalled at {len(admitted)}/{len(tasks)}"
        t, _ = admitted[guard - 1]
        sched.task_end(t)
    assert sorted(t.name for t, _ in admitted) \
        == sorted(t.name for t in tasks)
    assert len({t.uid for t, _ in admitted}) == len(tasks)
    assert sched.waiting_count() == 0


def test_sharded_steals_fire_on_imbalanced_completions():
    """Completions land only on shard 0: once its local queue drains, every
    further admission there must be a cross-shard steal."""
    sched = ShardedScheduler(pods=2, rows=2, cols=2)
    admitted, cb = _collector()
    n_dev = len(sched.devices)
    for i in range(n_dev + 10):                 # fill fleet + park 10
        sched.admit_or_enqueue(mk_task(f"t{i}", mem_gb=16.0), cb)
    assert sched.waiting_count() == 10
    ended = set()
    guard = 0
    while sched.waiting_count() and guard < 100:
        guard += 1
        vic = next(t for t, p in admitted
                   if p < 4 and t.uid not in ended)
        ended.add(vic.uid)
        sched.task_end(vic)
    assert sched.waiting_count() == 0
    assert sched.steals > 0
    assert len(admitted) == n_dev + 10
    # stats surface the stealing activity
    qs = sched.queue_stats()
    assert qs["steals"] == sched.steals
    assert qs["depth"] == 0


def test_sharded_pod_death_rehomes_evicted_and_parked():
    """Killing every chip of shard 0 must leave nothing stranded: a waiter
    parked there is pulled by the live shard, and an evicted resident
    resubmitted after the death lands on shard 1."""
    sched = ShardedScheduler(pods=2, rows=2, cols=2)
    admitted, cb = _collector()
    for i in range(8):                          # exactly fill both shards
        sched.admit_or_enqueue(mk_task(f"t{i}", mem_gb=16.0), cb)
    assert len(admitted) == 8 and sched.waiting_count() == 0
    parked = mk_task("parked", mem_gb=16.0)
    sched.admit_or_enqueue(parked, cb)          # parks (fleet is full)
    evicted = []
    for d in range(4):                          # shard 0's global indices
        evicted.extend(sched.mark_dead(d))
    assert len(evicted) == 4
    # the 4 evicted residents were requeued by the shard, declared
    # impossible there as it died, and re-homed to the live shard's queue;
    # the parked waiter survived wherever it was
    assert sched.waiting_count() == 5
    assert sched.rehomes >= 4
    # churn the live shard: every stranded task must land on shard 1
    ended = set()
    guard = 0
    while sched.waiting_count() and guard < 20:
        guard += 1
        vic = next(t for t, p in admitted if p >= 4 and t.uid not in ended)
        ended.add(vic.uid)
        sched.task_end(vic)
    assert sched.waiting_count() == 0
    post_death = admitted[8:]
    assert {t.name for t, _ in post_death} \
        == {t.name for t in evicted} | {"parked"}
    assert all(isinstance(p, int) and p >= 4 for _, p in post_death)


def test_sharded_spanning_gang_fails_fast():
    """A gang wider than one pod shard can never exist: the feasibility
    surface says so up front, and the cluster turns that into a crashed
    job instead of parking it forever."""
    sched = ShardedScheduler(pods=2, rows=2, cols=2)
    wide = mk_task("wide", mem_gb=8.0 * 8, chips=8)
    assert not sched.can_ever_fit(wide)
    assert "pod" in sched.infeasible_reason(wide)
    c = Cluster(ShardedScheduler(pods=2, rows=2, cols=2), workers=2,
                backend="sim")
    h = c.submit(Job(tasks=[mk_task("wide2", mem_gb=64.0, chips=8,
                                    est=1.0)], name="wide2"))
    c.drain()
    assert h.status is JobStatus.CRASHED


def test_sharded_placement_translation_is_global():
    """Shard-local placements must surface as flat fleet indices: two
    single-chip fills land 4 placements < 4 and 4 placements >= 4."""
    sched = ShardedScheduler(pods=2, rows=2, cols=2)
    admitted, cb = _collector()
    for i in range(8):
        sched.admit_or_enqueue(mk_task(f"t{i}", mem_gb=16.0), cb)
    places = sorted(p for _, p in admitted)
    assert places == list(range(8))
    assert len(sched.devices) == 8


# ---------------------------------------------------------------------------
# Cluster.stats(): O(1) counters vs recompute from handles
# ---------------------------------------------------------------------------

def _recompute_from_handles(c):
    sts = [h.status for h in c.handles]
    done = [h for h in c.handles if h.status is JobStatus.DONE]
    t0 = min(h.job.arrival_t for h in c.handles)
    t1 = max((h.job.finish_t for h in c.handles if h.job.finish_t >= 0),
             default=t0)
    makespan = max(t1 - t0, 1e-9)
    turn = sum(h.job.finish_t - h.job.arrival_t for h in done)
    return {
        "completed": len(done),
        "crashed": sum(s is JobStatus.CRASHED for s in sts),
        "cancelled": sum(s is JobStatus.CANCELLED for s in sts),
        "shed": sum(s is JobStatus.SHED for s in sts),
        "makespan_s": makespan,
        "mean_turnaround_s": turn / max(len(done), 1),
    }


def test_cluster_stats_counters_match_handle_scan_sim():
    c = Cluster(MGBAlg3Scheduler(1), workers=4, backend="sim",
                shed_late=True)
    for i in range(6):                          # plain jobs -> DONE
        c.submit(Job(tasks=[mk_task(f"ok{i}", mem_gb=4.0, est=1.0)],
                     name=f"ok{i}"))
    c.submit(Job(tasks=[mk_task("big", mem_gb=64.0, est=1.0)],
                 name="big"))                   # never feasible -> CRASHED
    parked = c.submit(Job(tasks=[mk_task("park", mem_gb=14.0, est=1.0)],
                          name="park"))
    parked.cancel()                             # -> CANCELLED
    c.submit(Job(tasks=[mk_task("late", mem_gb=14.0, est=1.0)],
                 name="late"), deadline_s=1e-6)  # parks, expires -> SHED
    c.drain()
    got = c.stats()
    want = _recompute_from_handles(c)
    for key, val in want.items():
        assert got[key] == pytest.approx(val), (key, got, want)
    assert want["completed"] >= 1 and want["crashed"] >= 1
    assert want["cancelled"] >= 1 and want["shed"] >= 1
    assert got["throughput_jobs_per_s"] \
        == pytest.approx(want["completed"] / want["makespan_s"])


def test_cluster_stats_counters_match_handle_scan_live():
    c = Cluster(MGBAlg3Scheduler(2), workers=2)
    for i in range(5):
        c.submit(Job(tasks=[mk_task(f"j{i}", mem_gb=4.0)], name=f"j{i}"),
                 runners=[lambda device: None])
    c.drain()
    got = c.stats()
    want = _recompute_from_handles(c)
    for key in ("completed", "crashed", "cancelled", "shed"):
        assert got[key] == want[key]
    assert got["mean_turnaround_s"] \
        == pytest.approx(want["mean_turnaround_s"])


def test_cluster_stats_empty_is_zeroed():
    c = Cluster(MGBAlg3Scheduler(1), workers=1, backend="sim")
    s = c.stats()
    assert s["completed"] == 0 and s["makespan_s"] == 0.0
