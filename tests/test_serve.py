"""Continuous-batching serving battery (ISSUE 7): the ServeEngine tentpole
plus the serving-path bugfix regressions.

  * ring-cache prefill/decode handoff parity — prefill-then-decode matches
    pure step-by-step decode for S > window AND S < window (the S < w case
    used to leave the cache seq dim at S, silently changing the ring
    modulus under the decode loop);
  * ``greedy_generate`` with ``gen_len=1`` (zero decode steps) returns a
    [B, 0] token array instead of tracing a zero-length scan by accident;
  * crashed-before-start ExecRecords carry the NEVER_STARTED sentinel (and
    ``started`` False) on BOTH backends — a crash injected mid-run keeps
    its real start stamp;
  * launch/serve token accounting: padded rows of a ragged final batch are
    not counted as served tokens;
  * scheduler grow/shrink: bind_resident, budget/memory parking, EDF drain
    order on retire, exact accounting after leaves, eviction settling
    ``grown_now``;
  * property: random join/leave sequences never violate device HBM or the
    per-host row budget;
  * live and sim backends admit the SAME slot-join order for the same
    submission trace;
  * engine end-to-end on a real model: per-request streamed tokens equal
    the one-shot prefill + greedy_generate reference.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st

from repro.configs.registry import get_arch
from repro.core.cluster import Cluster, JobStatus
from repro.core.executor import NEVER_STARTED, ExecJob
from repro.core.scheduler import MGBAlg3Scheduler
from repro.core.scheduler.base import DEADLINE_SHED
from repro.core.task import Job, ResourceVector, Task, UnitTask
from repro.models import decode as D
from repro.models.model import init_params
from repro.obs.events import GROW
from repro.obs.replay import decisions, first_divergence
from repro.serve.decode import greedy_generate, make_prefill_step
from repro.serve.engine import (
    SLO, JaxModel, NullModel, RequestStatus, ServeEngine,
)

GB = 1024**3


def vec(mem_gb=1.0, demand=0.25, est=0.01):
    return ResourceVector(hbm_bytes=int(mem_gb * GB), flops=1e9,
                          bytes_accessed=1e6, est_seconds=est,
                          core_demand=demand, bw_demand=demand)


def solo(name, mem_gb=1.0, demand=0.25, est=0.01, **kw):
    return Task(units=[UnitTask(fn=None, memobjs=frozenset({name}),
                                resources=vec(mem_gb, demand, est),
                                name=name)], name=name, **kw)


# ---------------------------------------------------------------------------
# ring-cache prefill/decode handoff (satellite 1)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def ring_setup():
    # pure-SWA config; moe=None because top-k expert-routing discontinuity
    # amplifies bf16 noise past any usable logit tolerance
    cfg = dataclasses.replace(get_arch("mixtral-8x7b").reduced(),
                              n_layers=2, sliding_window=8, moe=None)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.mark.parametrize("S", [13, 5, 8])  # > window, < window (the bug), ==
def test_ring_prefill_decode_parity(ring_setup, S):
    cfg, params = ring_setup
    n_dec = 3
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab)
    prefill = jax.jit(make_prefill_step(cfg, attn_impl="naive"))
    logits_p, cache_p = prefill(params, {"tokens": toks})
    # reference: pure decode from an empty ring, token by token
    cache_r = D.init_cache(cfg, 1, S + n_dec + 1)
    lg = None
    for i in range(S):
        lg, cache_r = D.decode_step(params, cfg, cache_r, toks[:, i], i)
    nxt_p = jnp.argmax(logits_p, -1).astype(jnp.int32)
    nxt_r = jnp.argmax(lg, -1).astype(jnp.int32)
    assert (nxt_p == nxt_r).all()
    for j in range(n_dec):
        lp, cache_p = D.decode_step(params, cfg, cache_p, nxt_p, S + j)
        lr, cache_r = D.decode_step(params, cfg, cache_r, nxt_r, S + j)
        assert float(jnp.abs(lp - lr).max()) < 0.1, (S, j)
        nxt_p = jnp.argmax(lp, -1).astype(jnp.int32)
        nxt_r = jnp.argmax(lr, -1).astype(jnp.int32)
        assert (nxt_p == nxt_r).all(), (S, j)


def test_greedy_generate_single_token(ring_setup):
    cfg, params = ring_setup
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 4), 0, cfg.vocab)
    prefill = jax.jit(make_prefill_step(cfg, attn_impl="naive"))
    logits, cache = prefill(params, {"tokens": toks})
    first = jnp.argmax(logits, -1).astype(jnp.int32)
    out, cache2 = greedy_generate(cfg, params, cache, first, 4, 0)
    assert out.shape == (2, 0)
    assert jax.tree_util.tree_structure(cache2) \
        == jax.tree_util.tree_structure(cache)


# ---------------------------------------------------------------------------
# crashed-task timing sentinel (satellite 2)
# ---------------------------------------------------------------------------

def _infeasible_job(name):
    # more HBM than any device will ever have -> crashes before starting
    return Job(tasks=[solo(name, mem_gb=10_000.0)], name=name)


def test_never_started_sentinel_live():
    c = Cluster(MGBAlg3Scheduler(1), workers=1)
    h = c.submit(_infeasible_job("doomed"))
    h.result()
    c.shutdown()
    assert h.status is JobStatus.CRASHED
    (rec,) = h.records
    assert rec.crashed
    assert rec.t_start == NEVER_STARTED
    assert not rec.started


def test_never_started_sentinel_sim():
    c = Cluster(MGBAlg3Scheduler(1), workers=1, backend="sim")
    h = c.submit(_infeasible_job("doomed-sim"))
    h.result()
    assert h.status is JobStatus.CRASHED
    (rec,) = h.records
    assert rec.crashed
    assert rec.t_start == NEVER_STARTED
    assert not rec.started


def test_midrun_crash_keeps_real_start():
    c = Cluster(MGBAlg3Scheduler(1), workers=1)

    def boom(device):
        raise RuntimeError("injected kernel crash")

    h = c.submit(ExecJob(job=Job(tasks=[solo("boom")], name="boom"),
                         runners=[boom]))
    h.result()
    c.shutdown()
    assert h.status is JobStatus.CRASHED
    (rec,) = h.records
    assert rec.crashed and rec.started
    assert rec.t_start >= 0.0 and rec.t_end >= rec.t_start


# ---------------------------------------------------------------------------
# launch/serve token accounting (satellite 3)
# ---------------------------------------------------------------------------

def test_serve_counts_only_real_rows():
    from repro.launch.serve import serve
    # 5 requests, batch 2 -> 3 batches, final one carries a padding row
    res = serve("gemma2-9b", requests=5, batch=2, prompt_len=8, gen_len=2,
                num_devices=1, deadline_s=600.0)
    assert res["completed"] == 3
    assert res["tokens_generated"] == 5 * 2  # NOT 3 * 2 * 2 = 12
    assert res["p99_ttft_s"] > 0.0
    assert res["p99_tpot_s"] > 0.0


# ---------------------------------------------------------------------------
# scheduler grow/shrink (tentpole substrate)
# ---------------------------------------------------------------------------

def _host(sched, dev, budget=2, mem_gb=2.0):
    h = solo(f"loop{dev}", mem_gb=mem_gb, demand=0.5, slot_budget=budget)
    assert sched.bind_resident(h, dev)
    return h


def test_bind_resident_checked():
    s = MGBAlg3Scheduler(1, hbm_per_device=4 * GB)
    h1 = solo("a", mem_gb=3.0, slot_budget=1)
    assert s.bind_resident(h1, 0)
    assert s.devices[0].used_hbm == 3 * GB
    # second loop does not fit -> refused WITHOUT queueing
    assert not s.bind_resident(solo("b", mem_gb=3.0), 0)
    assert s.task_end(h1)
    assert s.devices[0].used_hbm == 0


def test_grow_parks_on_budget_and_memory():
    s = MGBAlg3Scheduler(1, hbm_per_device=16 * GB)
    host = _host(s, 0, budget=2)
    got = []
    cb = lambda t, p, e: got.append((t.name, p))
    assert s.task_grow(solo("s1", mem_gb=1.0), [host], cb)
    assert s.task_grow(solo("s2", mem_gb=1.0), [host], cb)
    assert host.grown_now == 2
    # budget full -> parks even though memory is plentiful
    s3 = solo("s3", mem_gb=1.0)
    assert not s.task_grow(s3, [host], cb)
    assert [g for g in got if g[0] == "s3"] == []
    # a retire drains the parked join onto the freed row
    (t1,) = [t for t in s.devices[0].residents.values() if t.name == "s1"]
    s.task_shrink(t1)
    assert got[-1] == ("s3", 0)
    assert host.grown_now == 2
    # memory parking: budget free but bytes aren't
    s4 = solo("s4", mem_gb=10_000.0)
    assert not s.task_grow(s4, [host], cb)
    assert s.devices[0].used_hbm <= s.devices[0].total_hbm


def test_grow_edf_drain_order():
    s = MGBAlg3Scheduler(1, hbm_per_device=16 * GB)
    host = _host(s, 0, budget=1)
    order = []
    cb = lambda t, p, e: order.append(t.name)
    first = solo("first", mem_gb=1.0)
    assert s.task_grow(first, [host], cb)
    # three parked joins, deadlines out of submission order
    for name, dl in (("late", 30.0), ("early", 5.0), ("mid", 12.0)):
        assert not s.task_grow(solo(name, mem_gb=1.0, deadline_t=dl),
                               [host], cb)
    s.task_shrink(first)          # frees exactly one row -> EDF winner
    assert order == ["first", "early"]


def test_grow_accounting_exact_after_leaves():
    s = MGBAlg3Scheduler(2, hbm_per_device=16 * GB)
    hosts = [_host(s, 0, budget=3), _host(s, 1, budget=3)]
    base = [d.used_hbm for d in s.devices]
    slots = []
    for i in range(6):
        t = solo(f"r{i}", mem_gb=1.5)
        assert s.task_grow(t, hosts, lambda *a: None)
        slots.append(t)
    assert hosts[0].grown_now == 3 and hosts[1].grown_now == 3
    for t in slots:
        s.task_shrink(t)
    assert hosts[0].grown_now == 0 and hosts[1].grown_now == 0
    assert [d.used_hbm for d in s.devices] == base


def test_eviction_settles_grown_now():
    s = MGBAlg3Scheduler(2, hbm_per_device=16 * GB)
    hosts = [_host(s, 0, budget=2), _host(s, 1, budget=2)]
    results = []
    t = solo("s", mem_gb=1.0)
    assert s.task_grow(t, hosts, lambda tt, p, e: results.append(p))
    victim_host = hosts[results[0]]
    assert victim_host.grown_now == 1
    s.mark_dead(results[0])
    # release path settled the dead host's budget even though nothing called
    # shrink; the evicted slot then RE-ADMITTED via eviction restart onto
    # the surviving host (its callback fires again — serve.engine treats
    # that re-admission as stale and shrinks it, since KV rows don't move)
    assert victim_host.grown_now == 0
    other = hosts[1 - results[0]]
    assert len(results) == 2 and results[1] == other.device
    assert t.placed_host is other and other.grown_now == 1
    s.task_shrink(t)
    assert other.grown_now == 0 and t.placed_host is None


def test_grow_deadline_shed():
    s = MGBAlg3Scheduler(1, hbm_per_device=16 * GB)
    s.shed_expired = True
    clock = [0.0]
    s._clock = lambda: clock[0]
    host = _host(s, 0, budget=1)
    got = []
    blocker = solo("blocker", mem_gb=1.0)
    assert s.task_grow(blocker, [host], lambda *a: None)
    assert not s.task_grow(solo("late", mem_gb=1.0, deadline_t=1.0),
                           [host], lambda t, p, e: got.append(p))
    clock[0] = 2.0                # deadline passes while parked
    s.task_shrink(blocker)
    assert got == [DEADLINE_SHED]


@settings(max_examples=20, deadline=None)
@given(st.lists(st.tuples(st.booleans(), st.integers(0, 5),
                          st.integers(1, 40)), min_size=1, max_size=60),
       st.integers(1, 4))
def test_property_grow_never_violates_hbm(ops, budget):
    """Random join/leave interleavings: admitted slot deltas never push any
    device past its HBM, and per-host rows never exceed the budget."""
    s = MGBAlg3Scheduler(2, hbm_per_device=8 * GB)
    hosts = [_host(s, d, budget=budget, mem_gb=1.0) for d in range(2)]
    live, k = [], 0
    for is_leave, idx, tenths in ops:
        if is_leave and live:
            s.task_shrink(live.pop(idx % len(live)))
        else:
            t = solo(f"g{k}", mem_gb=tenths / 10.0)
            k += 1
            s.task_grow(t, hosts, lambda *a: None)
            if t.device is not None:
                live.append(t)
        for d in s.devices:
            assert d.used_hbm <= d.total_hbm
        for h in hosts:
            assert 0 <= h.grown_now <= budget


# ---------------------------------------------------------------------------
# engine: live/sim parity + end-to-end
# ---------------------------------------------------------------------------

GENS = (7, 3, 5, 2, 4, 6)


def _run_trace(backend):
    sched = MGBAlg3Scheduler(2, hbm_per_device=16 * GB)
    c = Cluster(sched, workers=1, backend=backend, trace=True)
    model = NullModel(prefill_s=0.01, step_s=0.01)
    eng = ServeEngine(c, model, max_batch=2,
                      slo=SLO(ttft_s=600.0, tpot_s=600.0))
    reqs = [eng.submit(prompt_len=8, gen_len=g) for g in GENS]
    eng.drain(timeout_s=120.0)
    # slot joins are GROW decisions in the event stream; each leg draws
    # fresh rids from the engine-global counter, so remap the slot names
    # ("slot/{rid}") onto this leg's request INDEX before diffing
    rid_to_idx = {r.rid: i for i, r in enumerate(reqs)}
    joins = [(rid_to_idx[int(name.split("/", 1)[1])], dev)
             for name, dev in decisions(c.trace.events(), kinds=(GROW,),
                                        with_device=True)]
    if backend == "live":
        c.shutdown()
    return reqs, joins


def test_live_sim_slot_admission_parity():
    live_reqs, live_joins = _run_trace("live")
    sim_reqs, sim_joins = _run_trace("sim")
    assert all(r.status is RequestStatus.DONE for r in live_reqs + sim_reqs)
    assert all(r.n_tokens == r.gen_len for r in live_reqs + sim_reqs)
    # identical slot-admission order (request index, device) on both
    # backends: same prefill completion order (1 worker), same EDF ranking
    # of parked joins, same least-loaded host choice — asserted through
    # the obs.replay parity differ
    div = first_divergence(live_joins, sim_joins)
    assert div is None, div


def test_engine_saturation_parks_and_completes():
    sched = MGBAlg3Scheduler(1, hbm_per_device=8 * GB)
    c = Cluster(sched, workers=64, backend="sim")
    model = NullModel(loop_hbm=2 * GB, slot_hbm=2 * GB,
                      prefill_hbm=GB // 2, prefill_s=0.01, step_s=0.01)
    eng = ServeEngine(c, model, max_batch=2, slo=SLO(600.0, 600.0))
    reqs = [eng.submit(prompt_len=8, gen_len=5) for _ in range(8)]
    eng.drain(timeout_s=120.0)
    assert all(r.status is RequestStatus.DONE for r in reqs)
    assert eng.violations == 0
    eng.shutdown()
    assert sched.devices[0].used_hbm == 0


def test_engine_e2e_matches_reference():
    cfg = dataclasses.replace(get_arch("gemma2-9b").reduced(), n_layers=1)
    params = init_params(cfg, jax.random.PRNGKey(0))
    max_seq = 24
    model = JaxModel(cfg, params, max_batch=2, max_seq=max_seq,
                     attn_impl="naive")
    assert model.slot_bytes > 0
    c = Cluster(MGBAlg3Scheduler(1, hbm_per_device=64 * GB), workers=2)
    eng = ServeEngine(c, model, max_batch=2, slo=SLO(600.0, 600.0))
    rng = np.random.default_rng(0)
    prompts = [jnp.asarray(rng.integers(0, cfg.vocab, (1, s)), jnp.int32)
               for s in (6, 9, 4)]
    gens = [5, 3, 1]
    reqs = [eng.submit(prompt=p, gen_len=g) for p, g in zip(prompts, gens)]
    eng.drain(timeout_s=300.0)
    prefill = jax.jit(make_prefill_step(cfg, attn_impl="naive"))
    for p, g, r in zip(prompts, gens, reqs):
        assert r.status is RequestStatus.DONE, (r.status, r.error)
        logits, cache = prefill(params, {"tokens": p})
        first = jnp.argmax(logits, -1).astype(jnp.int32)
        big = D.cache_insert(D.init_cache(cfg, 1, max_seq), cache, 0)
        toks, _ = greedy_generate(cfg, params, big, first,
                                  jnp.asarray([p.shape[1]], jnp.int32),
                                  g - 1)
        ref = [int(first[0])] + [int(t) for t in np.asarray(toks)[0]]
        assert r.tokens == ref, (r.rid, r.tokens, ref)
    c.shutdown()
