"""Make ``repro`` importable without an editable install.

The tier-1 command exports PYTHONPATH=src, but a plain ``pytest`` from the
repo root (or an IDE runner) must work too, so insert src/ ahead of
site-packages. A properly installed ``repro`` still wins nothing here —
src/ simply shadows it, which is what a source checkout should do.
"""
import os
import sys

_SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), os.pardir, "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)
