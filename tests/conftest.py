"""Make ``repro`` importable without an editable install, and guard every
test with a timeout so a deadlocked waiter queue fails fast instead of
hanging the CI job.

The tier-1 command exports PYTHONPATH=src, but a plain ``pytest`` from the
repo root (or an IDE runner) must work too, so insert src/ ahead of
site-packages. A properly installed ``repro`` still wins nothing here —
src/ simply shadows it, which is what a source checkout should do.

Timeout guard: when ``pytest-timeout`` is installed (CI passes
``--timeout=300``) it owns the job. Otherwise a faulthandler-based fallback
arms ``dump_traceback_later`` around each test: a hung test dumps every
thread's stack and kills the process — exactly the fail-fast behaviour a
deadlock needs. Tune with REPRO_TEST_TIMEOUT (seconds, 0 disables).
"""
import faulthandler
import os
import sys

import pytest

_SRC = os.path.abspath(
    os.path.join(os.path.dirname(__file__), os.pardir, "src"))
if _SRC not in sys.path:
    sys.path.insert(0, _SRC)

_TIMEOUT_S = int(os.environ.get("REPRO_TEST_TIMEOUT", "300"))
_HAVE_TIMEOUT_PLUGIN = False


def pytest_configure(config):
    """Defer to pytest-timeout only when it is actually CONFIGURED (via
    --timeout or a timeout ini value) — an installed-but-idle plugin must
    not silently disable the fallback guard."""
    global _HAVE_TIMEOUT_PLUGIN
    configured = False
    if config.pluginmanager.hasplugin("timeout"):
        try:
            val = config.getoption("--timeout")
            if val is None:
                val = config.getini("timeout")
            configured = bool(val and float(val) > 0)
        except (ValueError, TypeError, KeyError):
            configured = False
    _HAVE_TIMEOUT_PLUGIN = configured


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_protocol(item, nextitem):
    if _HAVE_TIMEOUT_PLUGIN or _TIMEOUT_S <= 0:
        yield
        return
    faulthandler.dump_traceback_later(_TIMEOUT_S, exit=True)
    try:
        yield
    finally:
        faulthandler.cancel_dump_traceback_later()
