"""Integration tests: live executor, train loop + checkpoint resume,
prefill->decode consistency, elastic reshard, pipeline parallelism, MoE
capacity, sharding rules."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS, get_arch
from repro.models import decode as D
from repro.models import model as M


# ---------------------------------------------------------------------------
# live executor under schedulers (real jitted jobs on virtual devices)
# ---------------------------------------------------------------------------

def _exec_jobs(n):
    from repro.core.executor import ExecJob
    from repro.core.probe import probe_fn
    from repro.core.task import Job, Task, UnitTask
    out = []
    sds = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def f(x):
        return jnp.tanh(x @ x).sum()

    vec = probe_fn(f, sds)
    for i in range(n):
        x = jax.random.normal(jax.random.PRNGKey(i), (256, 256))

        def runner(device, x=x):
            jax.block_until_ready(jax.jit(f)(x))

        unit = UnitTask(fn=None, memobjs=frozenset({f"j{i}"}),
                        resources=vec, name=f"j{i}")
        out.append(ExecJob(job=Job(tasks=[Task(units=[unit], name=f"j{i}")],
                                   name=f"j{i}"), runners=[runner]))
    return out


def test_executor_completes_under_mgb():
    from repro.core.executor import Executor
    from repro.core.scheduler import MGBAlg3Scheduler
    sched = MGBAlg3Scheduler(2)
    stats = Executor(sched, workers=3).run(_exec_jobs(6))
    assert stats["completed"] == 6 and stats["crashed"] == 0
    devs = {d for _, d in sched.placements}
    assert devs == {0, 1}  # balanced over both virtual devices


def test_executor_cg_oom_crashes_job():
    from repro.core.executor import ExecJob, Executor, OOMError
    from repro.core.scheduler import CGScheduler
    from repro.core.task import Job, ResourceVector, Task, UnitTask
    import time as _time
    vec = ResourceVector(hbm_bytes=12 * 1024**3, flops=1e9,
                         bytes_accessed=1e9, est_seconds=0.01)
    jobs = []
    for i in range(3):
        unit = UnitTask(fn=None, memobjs=frozenset({f"j{i}"}), resources=vec,
                        name=f"j{i}")
        jobs.append(ExecJob(
            job=Job(tasks=[Task(units=[unit], name=f"j{i}")], name=f"j{i}"),
            runners=[lambda device: _time.sleep(0.3)]))  # hold memory briefly
    stats = Executor(CGScheduler(1, ratio=3), workers=3).run(jobs)
    assert stats["crashed"] >= 1  # 3 x 12 GB on one 16 GB device


# ---------------------------------------------------------------------------
# prefill -> decode consistency (the serving contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["gemma2-9b", "falcon-mamba-7b",
                                  "zamba2-2.7b", "mixtral-8x7b"])
def test_prefill_then_decode_matches_full_forward(arch):
    from repro.serve.decode import make_prefill_step
    import dataclasses
    # exact-consistency test: pin the fp cache path (int8 quantization noise
    # is covered separately in test_int8_kv.py)
    cfg = dataclasses.replace(get_arch(arch).reduced(),
                              kv_cache_dtype="bfloat16")
    if cfg.moe is not None:
        # capacity-dispatch drops are GROUP-SIZE dependent, so prefill(32)
        # and forward(64) legitimately differ at cf=1.25; disable drops to
        # test the cache contract itself
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # both s0 and s0+extra must divide the SSM chunk (32 in reduced configs)
    s0, extra = 32, 32
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (2, s0 + extra), np.int32))
    batch = {"tokens": tok}
    if cfg.embedding_frontend_stub:
        emb = jnp.asarray(rng.standard_normal((2, s0 + extra, cfg.d_model),
                                              np.float32))
        batch["embeds"] = emb

    # reference: full forward over s0+extra, logits at each position
    hidden, _ = M.forward(params, cfg, batch, attn_impl="naive")
    ref_logits = M.logits_from_hidden(cfg, params, hidden)

    # prefill on s0 then decode the remaining tokens one at a time
    pre_batch = {k: v[:, :s0] for k, v in batch.items()}
    prefill = make_prefill_step(cfg, attn_impl="naive")
    logits, cache = prefill(params, pre_batch)
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(ref_logits[:, s0 - 1]),
                               rtol=2e-2, atol=2e-2)
    # grow the cache to full length for decode (prefill returns exactly s0)
    cache_full = D.init_cache(cfg, 2, s0 + extra, dtype=jnp.float32)

    def graft(dst, src):
        if dst.ndim >= 4 and dst.shape[-2] != src.shape[-2] \
                and dst.shape[:-2] == src.shape[:-2]:
            pad = dst.shape[-2] - src.shape[-2]
            return jnp.pad(src.astype(dst.dtype),
                           [(0, 0)] * (src.ndim - 2) + [(0, pad), (0, 0)])
        return src.astype(dst.dtype)

    cache = jax.tree_util.tree_map(graft, cache_full, cache)
    for t in range(extra):
        pos = s0 + t
        logits, cache = D.decode_step(params, cfg, cache, tok[:, pos],
                                      jnp.asarray(pos, jnp.int32))
        # decode_step consumed token at `pos`; its logits predict pos+1 and
        # must match the full-forward logits at `pos`
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(ref_logits[:, pos]),
                                   rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# train loop + checkpoint resume equivalence
# ---------------------------------------------------------------------------

def test_train_resume_matches_uninterrupted():
    from repro.launch.train import train
    with tempfile.TemporaryDirectory() as d:
        full = train("qwen1.5-32b", steps=6, batch=2, seq=32,
                     attn_impl="flash_jnp", log_every=100)
        part = train("qwen1.5-32b", steps=4, batch=2, seq=32, ckpt_dir=d,
                     ckpt_every=4, attn_impl="flash_jnp", log_every=100)
        resumed = train("qwen1.5-32b", steps=6, batch=2, seq=32, ckpt_dir=d,
                        resume=True, attn_impl="flash_jnp", log_every=100)
    # the resumed run sees the same data (step-indexed pipeline) and state
    np.testing.assert_allclose(resumed["final_loss"], full["final_loss"],
                               rtol=2e-4)


# ---------------------------------------------------------------------------
# MoE capacity dispatch sanity
# ---------------------------------------------------------------------------

def test_moe_matches_dense_at_high_capacity():
    """With capacity >> tokens and top_k == E, MoE == mean of expert MLPs."""
    from repro.configs.base import MoEConfig
    from repro.models import moe as MOE
    from repro.models.layers import mlp_apply
    key = jax.random.PRNGKey(0)
    d, f, e = 32, 64, 2
    cfg = MoEConfig(num_experts=e, top_k=e, capacity_factor=4.0)
    ks = jax.random.split(key, 4)
    p = {"router": jnp.zeros((d, e)),
         "wi": jax.random.normal(ks[0], (e, d, f)) * 0.1,
         "wg": jax.random.normal(ks[1], (e, d, f)) * 0.1,
         "wo": jax.random.normal(ks[2], (e, f, d)) * 0.1}
    x = jax.random.normal(ks[3], (1, 64, d))
    out, aux = MOE.moe_apply(p, x, cfg, "silu_gated", group_size=64)
    # router logits all equal -> every token goes to both experts, weight 1/2
    dense = sum(
        mlp_apply({"wi": p["wi"][i], "wg": p["wg"][i], "wo": p["wo"][i]},
                  x, "silu_gated")
        for i in range(e)) / e
    np.testing.assert_allclose(np.asarray(out), np.asarray(dense),
                               rtol=1e-4, atol=1e-5)


def test_moe_drops_tokens_over_capacity():
    from repro.configs.base import MoEConfig
    from repro.models.moe import capacity, combine_tensor
    cfg = MoEConfig(num_experts=2, top_k=1, capacity_factor=1.0)
    cap = capacity(cfg, 64)
    # all 64 tokens choose expert 0 -> only `cap` survive
    idx = jnp.zeros((1, 64, 1), jnp.int32)
    w = jnp.ones((1, 64, 1))
    comb = combine_tensor(idx, w, 2, cap)
    kept = float((comb > 0).sum())
    assert kept == cap


# ---------------------------------------------------------------------------
# sharding rules: divisibility invariant over every arch on a 16x16 mesh
# ---------------------------------------------------------------------------

def test_param_specs_divisibility_all_archs():
    from jax.sharding import AbstractMesh
    from repro.dist.sharding import param_specs
    from repro.optim.adamw import AdamWConfig
    from repro.train.train_step import abstract_train_state
    try:
        mesh = AbstractMesh((16, 16), ("data", "model"))
    except TypeError:  # jax <= 0.4.x: AbstractMesh(((name, size), ...))
        mesh = AbstractMesh((("data", 16), ("model", 16)))
    for name, cfg in ARCHS.items():
        params_sds, _ = abstract_train_state(cfg, AdamWConfig())
        specs = param_specs(cfg, params_sds, mesh)

        def ok(path, leaf, spec):
            for dim, ax in enumerate(spec):
                if ax is None:
                    continue
                size = mesh.shape[ax] if isinstance(ax, str) else \
                    int(np.prod([mesh.shape[a] for a in ax]))
                assert leaf.shape[dim] % size == 0, (name, path, leaf.shape,
                                                     spec)
        jax.tree_util.tree_map_with_path(
            lambda p, l, s: ok(p, l, s), params_sds, specs)
