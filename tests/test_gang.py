"""Gang placement battery (ISSUE 4 tentpole): topology-aware atomic
device-group reservation end to end.

  * a ``chips=4`` task submitted through ``Cluster.submit`` on an 8-chip
    topology lands on ONE contiguous 4-chip group — never 4 independent
    single-chip placements — on both backends;
  * live executor and virtual-clock simulator replay one mixed
    single-chip/gang trace into the SAME admission order;
  * property tests: gang admission never leaks partial reservations across
    ``cancel_wait``/``mark_dead``/``revive`` — per-cell ``used_hbm``/
    ``used_slots`` and the link ledger return exactly to baseline;
  * infeasible gang shapes (too many chips, no feasible factorization, fleet
    shrunk by death) fail fast with a clear error instead of parking forever;
  * ICI/DCN link accounting: hard headroom under alg2, soft + simulated
    dilation under alg3, DCN edges for pod-spanning gangs;
  * drain-scan hinting skips waiters the freed device/cells cannot satisfy;
  * deadline shedding: a parked waiter past its deadline is SHED at the next
    drain (both backends), and only when the operator opts in.
"""
import threading
import time

from _hypothesis_fallback import given, settings, st

from repro.core import interference
from repro.core.cluster import Cluster, JobStatus
from repro.core.executor import ExecJob
from repro.core.scheduler import (
    GangScheduler, MemOnlyScheduler, MGBAlg3Scheduler,
)
from repro.core.scheduler.base import SLOTS, slots_needed
from repro.core.simulator import Simulator
from repro.core.task import Job, ResourceVector, Task, UnitTask
from repro.core.topology import ICI_BW, Topology
from repro.core.workloads import make_gang_job, split_gangs
from repro.obs.replay import admission_order, first_divergence

GB = 1024**3


def mk_gang(name, chips=4, per_chip_gb=2.0, demand=0.5, est=1.0,
            link_share=0.0, priority=0, deadline_t=None):
    """A chips-sized gang task; ``link_share`` sets the steady ICI fraction
    its collectives occupy per internal link."""
    vec = ResourceVector(
        hbm_bytes=int(per_chip_gb * GB * chips), flops=1e12,
        bytes_accessed=1e9, collective_bytes=link_share * est * ICI_BW,
        est_seconds=est, core_demand=demand, bw_demand=demand, chips=chips)
    t = Task(units=[UnitTask(fn=None, memobjs=frozenset({name}),
                             resources=vec, name=name)],
             name=name, gang_id=name if chips > 1 else None)
    t.priority = priority
    t.deadline_t = deadline_t
    return t


def mk_gang_job(name, **kw):
    t = mk_gang(name, **kw)
    return Job(tasks=[t], name=name, gang_id=t.gang_id)


def assert_no_partial_reservations(sched):
    """The leak check: every bound gang is resident on EXACTLY its group's
    cells, every resident maps to a bound gang, and per-cell accounting
    equals the per-chip shares of its residents."""
    bound_cells = {uid: set(g.cells()) for uid, g in sched.bound.items()}
    for cell, dev in sched.topo.cells.items():
        expect_hbm = 0
        expect_slots = 0
        for uid, t in dev.residents.items():
            assert uid in bound_cells, f"resident {uid} not bound"
            assert cell in bound_cells[uid], \
                f"resident {uid} on {cell} outside its group"
            r = t.resources
            expect_hbm += r.hbm_bytes // max(r.chips, 1)
            expect_slots += slots_needed(t)
        assert dev.used_hbm == expect_hbm, (cell, dev.used_hbm, expect_hbm)
        assert dev.used_slots == expect_slots
        assert 0 <= dev.used_hbm <= dev.total_hbm  # memory hard per member
    for uid, cells in bound_cells.items():
        for cell in cells:
            assert uid in sched.topo.cells[cell].residents, \
                f"gang {uid} missing from member {cell} (partial reservation)"


# ---------------------------------------------------------------------------
# acceptance: contiguous atomic placement through the Cluster front door
# ---------------------------------------------------------------------------

def test_chips4_is_one_contiguous_group_sim():
    """A chips=4 submit on an 8-chip topology: ONE placement entry, one
    4-chip record, and the reservation is a contiguous rect."""
    sched = GangScheduler(pods=1, rows=2, cols=4)
    seen = {}
    orig_admit = sched._admit_locked

    def spy(task):
        group = orig_admit(task)
        if group is not None:
            seen[task.name] = group
        return group

    sched._admit_locked = spy
    c = Cluster(sched, workers=4, backend="sim")
    h = c.submit(mk_gang_job("g4", chips=4))
    c.drain()
    assert h.status is JobStatus.DONE
    assert h.records[0].gang_chips == 4
    # never 4 independent single-chip placements: one audit entry
    assert len(sched.placements) == 1
    group = seen["g4"]
    assert len(group.rects) == 1 and group.rects[0].chips == 4
    assert set(group.cells()) == set(group.rects[0].cells())  # contiguous
    assert h.job.tasks[0].gang_id == "g4"  # identity survived the stack


def test_chips4_live_dispatches_one_bound_group():
    bound = []

    def runner(devices):
        bound.append(devices)

    sched = GangScheduler(pods=1, rows=2, cols=4)
    with Cluster(sched, workers=4) as c:
        h = c.submit(mk_gang_job("g4", chips=4, est=0.001),
                     runners=[runner])
        assert h.result(timeout=10)[0].gang_chips == 4
    assert h.status is JobStatus.DONE
    # the gang's unit group ran as ONE dispatch bound to 4 devices
    assert len(bound) == 1 and isinstance(bound[0], list)
    assert len(bound[0]) == 4
    assert len(sched.placements) == 1
    assert all(d.used_hbm == 0 and d.used_slots == 0 for d in sched.devices)


def test_single_chip_rides_the_same_path():
    sched = GangScheduler(pods=1, rows=2, cols=2)
    group = sched.task_begin(mk_gang("solo", chips=1))
    assert group is not None and group.chips == 1
    assert len(group.device_indices) == 1


# ---------------------------------------------------------------------------
# live/sim gang admission-order parity (extends the PR 3 guarantee)
# ---------------------------------------------------------------------------

def _gang_trace(cluster, *, gate=None):
    """Mixed 1-chip/2-chip trace on a 2-chip topology where per-chip memory
    makes every job exclusive: admission order == queue rank order."""
    def mk(name, chips):
        job = mk_gang_job(name, chips=chips, per_chip_gb=9.0, est=0.01)
        if cluster.backend == "live":
            body = ((lambda d, g=gate: g.wait(0.5)) if name == "first"
                    else (lambda d: time.sleep(0.002)))
            return ExecJob(job=job, runners=[body])
        return job
    cluster.submit(mk("first", 2))
    cluster.submit(mk("lo-a", 1), priority=0)
    cluster.submit(mk("lo-gang", 2), priority=0)
    cluster.submit(mk("hi-late", 2), priority=5)
    cluster.submit(mk("hi-edf", 1), priority=5, deadline_s=1.0)
    # when "first" releases both chips the drain walks rank order: hi-edf
    # (1 chip) lands, hi-late (2 chips) is BLOCKED by hi-edf's residency but
    # does not block the queue behind it, so lo-a takes the other chip;
    # hi-late then outranks lo-gang for the next full release
    return ["first", "hi-edf", "lo-a", "hi-late", "lo-gang"]


def test_live_and_sim_same_gang_admission_order():
    gate = threading.Event()
    live = Cluster(GangScheduler(pods=1, rows=1, cols=2), workers=2,
                   trace=True)
    expected = _gang_trace(live, gate=gate)
    gate.set()
    live.drain()
    live.shutdown()
    assert admission_order(live.trace.events()) == expected

    sim = Cluster(GangScheduler(pods=1, rows=1, cols=2), workers=8,
                  backend="sim", trace=True)
    assert _gang_trace(sim) == expected
    sim.drain()
    assert admission_order(sim.trace.events()) == expected
    div = first_divergence(admission_order(live.trace.events()),
                           admission_order(sim.trace.events()))
    assert div is None, div
    assert all(h.status is JobStatus.DONE for h in sim.handles)


# ---------------------------------------------------------------------------
# infeasible gang shapes fail fast (satellite)
# ---------------------------------------------------------------------------

def test_impossible_shape_fails_fast_with_clear_error_sim():
    # 5 chips on a 4x4 pod: no 1x5/5x1 fits, and 5 is not a pod multiple
    sched = GangScheduler(pods=1, rows=4, cols=4)
    assert not sched.can_ever_fit(mk_gang("g5", chips=5))
    c = Cluster(sched, workers=2, backend="sim")
    h = c.submit(mk_gang_job("g5", chips=5))
    assert h.status is JobStatus.CRASHED
    assert "no 5-chip" in h.job.error and "4x4" in h.job.error
    # and it never parked: the queue is empty, nothing leaked
    assert sched.waiting_count() == 0
    assert all(d.used_hbm == 0 for d in sched.devices)


def test_too_many_chips_fails_fast_live():
    sched = GangScheduler(pods=1, rows=2, cols=2)
    with Cluster(sched, workers=2) as c:
        h = c.submit(mk_gang_job("g32", chips=32, est=0.001),
                     runners=[lambda d: None])
        c.drain()
    assert h.status is JobStatus.CRASHED
    assert "infeasible placement" in h.job.error
    assert h.records[0].crashed and h.records[0].device == -1


def test_gang_never_feasible_after_death_gives_up():
    """mark_dead shrinks a 2x2 fleet below a parked 4-chip gang's needs: its
    callback fires with placement None (give up), not an eternal park."""
    sched = GangScheduler(pods=1, rows=2, cols=2)
    hog = mk_gang("hog", chips=4, per_chip_gb=9.0)
    assert sched.task_begin(hog) is not None
    results = []
    waiter = mk_gang("waiter", chips=4, per_chip_gb=9.0)
    assert not sched.admit_or_enqueue(
        waiter, lambda t, g, e: results.append(g))
    sched.mark_dead((0, 0, 0))   # 3 alive chips: a 4-gang can never form
    # the evicted hog also needs 4 chips: both must have been given up on
    assert sched.waiting_count() == 0
    assert None in results
    assert "4 chips" in sched.infeasible_reason(waiter)
    assert_no_partial_reservations(sched)


def test_oversized_per_chip_memory_infeasible():
    sched = GangScheduler(pods=1, rows=2, cols=2)
    too_fat = mk_gang("fat", chips=2, per_chip_gb=20.0)
    assert not sched.can_ever_fit(too_fat)
    assert "GB HBM per chip" in sched.infeasible_reason(too_fat)


# ---------------------------------------------------------------------------
# link accounting: hard under alg2, soft + dilation under alg3, DCN spanning
# ---------------------------------------------------------------------------

def test_link_charges_reserved_and_released():
    sched = GangScheduler(pods=1, rows=2, cols=2)
    g = mk_gang("g", chips=4, link_share=0.5)
    assert sched.task_begin(g) is not None
    # a 2x2 rect has 4 internal ICI links, each charged the ring share
    assert len(sched.topo.link_used) == 4
    assert all(abs(v - 0.5) < 1e-9 for v in sched.topo.link_used.values())
    sched.task_end(g)
    assert sched.topo.link_used == {}


def test_alg2_rejects_link_oversubscription_alg3_tolerates():
    for policy, admits in (("alg2", False), ("alg3", True)):
        sched = GangScheduler(pods=1, rows=1, cols=2, policy=policy)
        a = mk_gang("a", chips=2, per_chip_gb=1.0, demand=0.1,
                    link_share=0.7)
        b = mk_gang("b", chips=2, per_chip_gb=1.0, demand=0.1,
                    link_share=0.7)
        assert sched.task_begin(a) is not None
        got = sched.task_begin(b) is not None
        assert got == admits, policy
        if admits:  # soft links: the shared link is now oversubscribed
            assert sched.link_pressure(b) > 1.3
        else:
            assert sched.link_pressure(a) == 1.0  # headroom held


def test_sim_dilates_gangs_sharing_an_oversubscribed_link():
    sched = GangScheduler(pods=1, rows=1, cols=2, policy="alg3")
    sim = Simulator(sched, workers=4)
    for name in ("a", "b"):
        sim.submit(mk_gang_job(name, chips=2, per_chip_gb=1.0, demand=0.2,
                               est=10.0, link_share=0.7))
    res = sim.drain()
    assert res.completed == 2
    # busiest shared link at 1.4 => both gangs ~1.4x wall dilation
    for name in ("a", "b"):
        assert 1.3 < res.dilations[name] < 1.55, res.dilations
    assert interference.ici_slowdown([1.4]) == 1.4
    assert interference.ici_slowdown([]) == 1.0


def test_pod_spanning_gang_charges_dcn_edge():
    sched = GangScheduler(pods=2, rows=1, cols=2)   # pod size 2
    g = mk_gang("span", chips=4, per_chip_gb=2.0, link_share=0.4)
    group = sched.task_begin(g)
    assert group is not None and len(group.rects) == 2
    assert {r.pod for r in group.rects} == {0, 1}
    assert ("dcn", 0, 1) in sched.topo.link_used
    sched.task_end(g)
    assert sched.topo.link_used == {}


def test_fragmentation_capacity_exists_but_no_contiguous_group():
    """The fragmentation phenomenon bench_gang measures: >= k member-feasible
    chips exist, yet every aligned contiguous group contains a blocker."""
    sched = GangScheduler(pods=1, rows=2, cols=4)
    for cell in ((0, 0, 0), (0, 1, 2)):   # one blocker per candidate group
        sched.topo.cells[cell].used_hbm = 15 * GB
    g = mk_gang("g4", chips=4, per_chip_gb=8.0)
    per_chip = g.resources.hbm_bytes // 4
    feasible = sum(1 for d in sched.devices
                   if d.alive and per_chip <= d.free_hbm)
    assert feasible == 6 >= 4          # capacity exists...
    assert sched.task_begin(g) is None  # ...but no contiguous group forms


# ---------------------------------------------------------------------------
# drain-scan hinting (satellite)
# ---------------------------------------------------------------------------

def test_flat_drain_hint_skips_waiters_freed_device_cannot_fit():
    sched = MemOnlyScheduler(2)       # first fit: placements deterministic
    a = mk_gang("a", chips=1, per_chip_gb=6.0)
    b = mk_gang("b", chips=1, per_chip_gb=6.0)
    c = mk_gang("c", chips=1, per_chip_gb=15.0)
    for t in (a, b, c):
        assert sched.task_begin(t) is not None
    assert (a.device, b.device, c.device) == (0, 0, 1)
    admitted = []
    cb = lambda t, dev, epoch: admitted.append(t.name)
    w_big = mk_gang("w_big", chips=1, per_chip_gb=12.0)    # > 10 GB freed
    w_small = mk_gang("w_small", chips=1, per_chip_gb=9.0)
    assert not sched.admit_or_enqueue(w_big, cb)
    assert not sched.admit_or_enqueue(w_small, cb)
    skips0, attempts0 = sched.hint_skips, sched.begin_attempts
    sched.task_end(a)   # frees 6 GB on dev0 -> 10 GB free
    # w_big (12 GB) provably cannot use dev0: skipped WITHOUT a probe;
    # w_small probed and admitted
    assert admitted == ["w_small"]
    assert sched.hint_skips == skips0 + 1
    assert sched.begin_attempts == attempts0 + 1
    assert sched.waiting_count() == 1   # w_big still parked


def test_gang_drain_hint_skips_waiters_freed_cells_cannot_fit():
    sched = GangScheduler(pods=1, rows=1, cols=2)
    small = mk_gang("small", chips=1, per_chip_gb=4.0)
    hog = mk_gang("hog", chips=1, per_chip_gb=11.0)
    assert sched.task_begin(small) is not None
    assert sched.task_begin(hog) is not None
    admitted = []
    cb = lambda t, g, e: admitted.append(t.name)
    # per-chip 10 GB gang: fits neither chip now (free: 12 and 5)... park
    w = mk_gang("w", chips=2, per_chip_gb=13.0)
    assert not sched.admit_or_enqueue(w, cb)
    skips0 = sched.hint_skips
    sched.task_end(small)   # frees cell 0 -> 16 GB free; cell 1 still 5 GB
    # w needs 13 GB per chip on BOTH cells; the freed cell alone passes the
    # member check, so it IS probed (hint conservative), but admission fails
    assert sched.hint_skips == skips0 and admitted == []
    sched.task_end(hog)     # both cells free -> admitted
    assert admitted == ["w"]
    assert_no_partial_reservations(sched)


def test_gang_hint_skip_when_no_freed_cell_passes_member_check():
    sched = GangScheduler(pods=1, rows=1, cols=2)
    hog = mk_gang("hog", chips=1, per_chip_gb=15.0, demand=0.5)   # cell 0
    a = mk_gang("a", chips=1, per_chip_gb=6.0, demand=0.1)        # cell 1
    b = mk_gang("b", chips=1, per_chip_gb=4.0, demand=0.3)        # cell 1
    for t in (hog, a, b):
        assert sched.task_begin(t) is not None
    assert a.device == b.device != hog.device
    admitted = []
    w = mk_gang("w", chips=1, per_chip_gb=12.0)   # free: 1 and 6 -> parks
    assert not sched.admit_or_enqueue(w, lambda t, g, e: admitted.append(1))
    skips0, attempts0 = sched.hint_skips, sched.begin_attempts
    sched.task_end(b)    # frees cell 1 to 10 GB free: still < 12 -> SKIPPED
    assert admitted == [] and sched.hint_skips == skips0 + 1
    assert sched.begin_attempts == attempts0   # no probe was paid
    sched.task_end(a)    # cell 1 fully free: probed and admitted
    assert admitted == [1]
    assert_no_partial_reservations(sched)


# ---------------------------------------------------------------------------
# deadline shedding (satellite)
# ---------------------------------------------------------------------------

def test_sim_sheds_expired_parked_waiter_at_drain():
    c = Cluster(MGBAlg3Scheduler(1), workers=4, backend="sim",
                shed_late=True)
    hog = c.submit(mk_gang_job("hog", chips=1, per_chip_gb=10.0, est=10.0))
    late = c.submit(mk_gang_job("late", chips=1, per_chip_gb=10.0, est=1.0),
                    deadline_s=0.5)
    c.drain()
    assert hog.status is JobStatus.DONE
    assert late.status is JobStatus.SHED       # failed, never admitted late
    assert late.records == []                  # consumed no device time
    stats = c.stats()
    assert stats["shed"] == 1 and stats["completed"] == 1
    assert stats["crashed"] == 0 and stats["cancelled"] == 0


def test_live_sheds_expired_parked_waiter_at_drain():
    gate = threading.Event()
    c = Cluster(MGBAlg3Scheduler(1), workers=2, shed_late=True)
    hog = c.submit(ExecJob(job=mk_gang_job("hog", chips=1, per_chip_gb=10.0),
                           runners=[lambda d: gate.wait(5.0)]))
    late = c.submit(ExecJob(job=mk_gang_job("late", chips=1,
                                            per_chip_gb=10.0),
                            runners=[lambda d: None]),
                    deadline_s=0.02)
    deadline = time.monotonic() + 5.0
    while c.sched.waiting_count() == 0 and time.monotonic() < deadline:
        time.sleep(0.001)
    time.sleep(0.05)      # let the deadline expire while parked
    gate.set()            # hog's task_end drives the shedding drain
    c.drain()
    assert hog.status is JobStatus.DONE
    assert late.status is JobStatus.SHED
    assert late.records == []          # parity with sim: no record, no time
    assert c.stats()["shed"] == 1
    c.shutdown()


def test_no_shedding_unless_opted_in():
    """Default stays PR 3 semantics: a deadline is an ordering hint; the
    late waiter still runs."""
    c = Cluster(MGBAlg3Scheduler(1), workers=4, backend="sim")
    c.submit(mk_gang_job("hog", chips=1, per_chip_gb=10.0, est=10.0))
    late = c.submit(mk_gang_job("late", chips=1, per_chip_gb=10.0, est=1.0),
                    deadline_s=0.5)
    c.drain()
    assert late.status is JobStatus.DONE


def test_shed_gang_waiter_holds_no_reservation():
    """A shed gang never held chips: shedding is pure queue removal."""
    sched = GangScheduler(pods=1, rows=1, cols=2)
    sched.shed_expired = True
    clock = {"t": 0.0}
    sched._clock = lambda: clock["t"]
    hog = mk_gang("hog", chips=2, per_chip_gb=9.0)
    assert sched.task_begin(hog) is not None
    out = []
    w = mk_gang("w", chips=2, per_chip_gb=9.0, deadline_t=1.0)
    assert not sched.admit_or_enqueue(w, lambda t, g, e: out.append(g))
    clock["t"] = 2.0            # deadline passed while parked
    sched.task_end(hog)         # the drain sheds instead of admitting
    from repro.core.scheduler.base import DEADLINE_SHED
    assert out == [DEADLINE_SHED]
    assert sched.waiting_count() == 0
    assert_no_partial_reservations(sched)
    assert all(d.used_hbm == 0 for d in sched.devices)


# ---------------------------------------------------------------------------
# property tests: no partial reservations across churn/cancel/death/revive
# ---------------------------------------------------------------------------

@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_gang_reservations_never_partial(seed):
    """Seeded churn of 1/2/4-chip gangs through admit_or_enqueue +
    task_end/cancel_wait/mark_dead/revive: after every event the reservation
    map is all-or-nothing per gang, and at quiesce every cell and the link
    ledger return exactly to baseline."""
    import random
    rng = random.Random(seed)
    for policy in ("alg2", "alg3"):
        sched = GangScheduler(pods=1, rows=2, cols=2, policy=policy)
        cells = list(sched.topo.cells)
        held, parked, dead = {}, {}, []

        def cb(t, group, epoch):
            # admission wakeup (or give-up) — fired outside the lock
            parked.pop(t.uid, None)
            if group is None or not hasattr(group, "device_indices"):
                held.pop(t.uid, None)
            else:
                held[t.uid] = t

        for i in range(60):
            op = rng.random()
            if op < 0.30 and held:
                uid = rng.choice(list(held))
                sched.task_end(held.pop(uid))
            elif op < 0.40 and parked:
                uid = rng.choice(list(parked))
                if sched.cancel_wait(parked[uid]):
                    del parked[uid]
            elif op < 0.50 and len(dead) < 3:
                cell = rng.choice(cells)
                if cell not in dead:
                    dead.append(cell)
                    sched.mark_dead(cell)
            elif op < 0.60 and dead:
                sched.revive(dead.pop(rng.randrange(len(dead))))
            else:
                chips = rng.choice([1, 1, 2, 4])
                t = mk_gang(f"t{seed}.{i}", chips=chips,
                            per_chip_gb=rng.uniform(1.0, 9.0),
                            demand=rng.choice([0.1, 0.5, 1.0]),
                            link_share=rng.choice([0.0, 0.3, 0.8]))
                if sched.admit_or_enqueue(t, cb):
                    held[t.uid] = t
                elif t.uid not in held:   # cb may have fired give-up inline
                    parked[t.uid] = t
            assert_no_partial_reservations(sched)
        # quiesce: revive everything, drain all work, drop leftover waiters
        for cell in dead:
            sched.revive(cell)
        while held:
            uid = next(iter(held))
            sched.task_end(held.pop(uid))
            assert_no_partial_reservations(sched)
        for t in list(parked.values()):
            sched.cancel_wait(t)
        sched.cancel_all_waiters()
        # drain any still-running admissions fired by the last wakeups
        while held:
            uid = next(iter(held))
            sched.task_end(held.pop(uid))
        assert sched.bound == {}
        assert sched.topo.link_used == {}, (policy, sched.topo.link_used)
        for d in sched.topo.cells.values():
            assert d.used_hbm == 0 and d.used_slots == 0 and not d.residents


@given(seed=st.integers(0, 10_000))
@settings(max_examples=10, deadline=None)
def test_property_alg2_gang_slots_stay_hard(seed):
    """Under the alg2 policy no member chip ever exceeds SLOTS, whatever
    gang mix is admitted."""
    import random
    rng = random.Random(seed)
    sched = GangScheduler(pods=1, rows=2, cols=2, policy="alg2")
    held = []
    for i in range(60):
        if held and rng.random() < 0.4:
            sched.task_end(held.pop(rng.randrange(len(held))))
        else:
            t = mk_gang(f"s{i}", chips=rng.choice([1, 2, 4]),
                        per_chip_gb=rng.uniform(0.5, 6.0),
                        demand=rng.choice([0.05, 0.3, 0.8, 1.0]))
            if sched.task_begin(t) is not None:
                held.append(t)
        for d in sched.topo.cells.values():
            assert d.used_slots <= SLOTS
    for t in held:
        sched.task_end(t)
    assert all(d.used_slots == 0 for d in sched.topo.cells.values())


# ---------------------------------------------------------------------------
# open-arrival clock driver + workload helpers
# ---------------------------------------------------------------------------

def test_run_until_advances_clock_exactly():
    sim = Simulator(MGBAlg3Scheduler(2), workers=2)
    sim.submit(mk_gang_job("a", chips=1, est=3.0))
    sim.run_until(1.25)
    assert abs(sim.now - 1.25) < 1e-6
    sim.submit(mk_gang_job("b", chips=1, est=1.0))
    assert sim.pending()
    res = sim.drain()
    assert res.completed == 2
    # job a still completed at its own pace despite the bounded stepping
    assert abs(res.turnaround["a"] - 3.0) < 0.1


def test_split_gangs_oblivious_view():
    import numpy as np
    rng = np.random.default_rng(0)
    gang = make_gang_job(rng, chips=4, name="g")
    shards = split_gangs([gang])
    assert len(shards) == 4
    r0 = gang.tasks[0].resources
    for s in shards:
        r = s.tasks[0].resources
        assert r.chips == 1
        assert r.hbm_bytes == r0.hbm_bytes // 4
        # scattered shards re-roof their collectives at DCN speed
        assert r.est_seconds >= r0.est_seconds
        assert s.gang_id == "g"   # gang identity survives the split
