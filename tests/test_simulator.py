"""Simulator invariants + fault tolerance."""
import copy

import pytest
from _hypothesis_fallback import given, settings, st

from repro.core.scheduler import (
    CGScheduler, MGBAlg2Scheduler, MGBAlg3Scheduler, SAScheduler,
)
from repro.core.simulator import Simulator
from repro.core.task import Job, ResourceVector, Task, UnitTask

GB = 1024**3


def mk_job(name, mem_gb=2.0, demand=0.4, est=5.0, n_tasks=1):
    tasks = []
    for i in range(n_tasks):
        vec = ResourceVector(hbm_bytes=int(mem_gb * GB), flops=1e12,
                             bytes_accessed=1e9, est_seconds=est,
                             core_demand=demand, bw_demand=demand)
        tasks.append(Task(units=[UnitTask(
            fn=None, memobjs=frozenset({f"{name}/{i}"}), resources=vec,
            name=f"{name}.{i}")], name=f"{name}.{i}"))
    return Job(tasks=tasks, name=name)


def test_conservation_and_makespan_sa():
    jobs = [mk_job(f"j{i}", est=5.0) for i in range(4)]
    r = Simulator(SAScheduler(2), workers=2).run(jobs)
    assert r.completed == 4 and r.crashed == 0
    # 4 jobs x 5 s over 2 dedicated devices = 10 s (+ poll slack)
    assert 9.9 <= r.makespan <= 10.6


def test_sharing_beats_sa_for_low_demand():
    jobs = [mk_job(f"j{i}", demand=0.2, est=5.0) for i in range(8)]
    sa = Simulator(SAScheduler(2), workers=2).run(copy.deepcopy(jobs))
    mgb = Simulator(MGBAlg3Scheduler(2), workers=8).run(copy.deepcopy(jobs))
    assert mgb.makespan < sa.makespan / 1.8
    assert mgb.completed == sa.completed == 8


def test_oversubscription_dilates_wall_not_kernels():
    jobs = [mk_job(f"j{i}", demand=0.6, est=10.0) for i in range(4)]
    r = Simulator(MGBAlg3Scheduler(1), workers=4).run(jobs)
    assert r.completed == 4
    # 4 x 0.6 demand on one chip -> ~2.4x wall dilation
    assert max(r.dilations.values()) > 1.8
    # but per-kernel slowdown stays at the eta overhead (<3%)
    assert max(r.slowdowns.values()) < 1.04


def test_cg_crashes_jobs_memory_safe_do_not():
    jobs = [mk_job(f"j{i}", mem_gb=9.0, est=5.0) for i in range(6)]
    cg = Simulator(CGScheduler(2, ratio=3), workers=6).run(
        copy.deepcopy(jobs))
    assert cg.crashed > 0
    for cls in (SAScheduler, MGBAlg2Scheduler, MGBAlg3Scheduler):
        r = Simulator(cls(2), workers=6).run(copy.deepcopy(jobs))
        assert r.crashed == 0 and r.completed == 6, cls.__name__


def test_multi_task_jobs_run_in_order():
    jobs = [mk_job("j0", n_tasks=3, est=2.0)]
    r = Simulator(MGBAlg3Scheduler(2), workers=1).run(jobs)
    assert r.completed == 1
    t = jobs[0].tasks
    assert t[0].finish_t <= t[1].start_t + 1e-9
    assert t[1].finish_t <= t[2].start_t + 1e-9


def test_failure_injection_reschedules():
    jobs = [mk_job(f"j{i}", est=5.0, demand=0.3) for i in range(4)]
    r = Simulator(MGBAlg3Scheduler(2), workers=4).run(
        jobs, failure_at=(2.0, 0))
    # all jobs complete despite losing a device mid-run
    assert r.completed == 4 and r.crashed == 0
    # everything after the failure ran on device 1
    for j in jobs:
        for t in j.tasks:
            if t.start_t >= 2.0:
                assert t.device == 1


def test_infeasible_job_counted_crashed_not_livelocked():
    jobs = [mk_job("big", mem_gb=20.0)]
    r = Simulator(MGBAlg3Scheduler(1), workers=1).run(jobs)
    assert r.crashed == 1 and r.completed == 0


@given(n_jobs=st.integers(1, 12), demand=st.floats(0.05, 1.0),
       workers=st.integers(1, 12))
@settings(max_examples=30, deadline=None)
def test_property_all_feasible_jobs_complete(n_jobs, demand, workers):
    jobs = [mk_job(f"j{i}", mem_gb=3.0, demand=demand, est=2.0)
            for i in range(n_jobs)]
    r = Simulator(MGBAlg3Scheduler(2), workers=workers).run(jobs)
    assert r.completed == n_jobs and r.crashed == 0
    # a job can never finish faster than its solo estimate...
    assert r.makespan >= 2.0 - 1e-9
    # ...and the batch can never take longer than fully-serial + poll slack
    assert r.makespan <= n_jobs * 2.0 * 1.2 + 1.0
    assert max(r.device_busy) >= 2.0 - 1e-9
