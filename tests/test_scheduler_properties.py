"""Property tests (hypothesis, with the deterministic fallback): scheduler
accounting invariants under random CONCURRENT begin/end interleavings.

For every scheduler class, whatever interleaving of task_begin / task_end /
admit_or_enqueue the threads produce, each device must always satisfy

    used_hbm   == sum(resident task footprints)   (never negative)
    used_slots == sum(resident slots_needed)      (never negative)

and after every task ends, all counters return to exactly zero.
"""
import random
import threading

from _hypothesis_fallback import given, settings, st

from repro.core.scheduler import (
    CGScheduler, MemOnlyScheduler, MGBAlg2Scheduler, MGBAlg3Scheduler,
    SAScheduler, SliceScheduler,
)
from repro.core.scheduler.base import slots_needed
from repro.core.task import ResourceVector, Task, UnitTask

GB = 1024**3

ALL_CLASSES = [SAScheduler, CGScheduler, MemOnlyScheduler,
               MGBAlg2Scheduler, MGBAlg3Scheduler]
MEMORY_SAFE = [SAScheduler, MemOnlyScheduler,
               MGBAlg2Scheduler, MGBAlg3Scheduler]


def mk_task(name, mem_gb, demand, chips=1):
    vec = ResourceVector(hbm_bytes=int(mem_gb * GB), flops=1e9,
                         bytes_accessed=1e9, est_seconds=0.001,
                         core_demand=demand, bw_demand=demand, chips=chips)
    return Task(units=[UnitTask(fn=None, memobjs=frozenset({name}),
                                resources=vec, name=name)], name=name)


def assert_consistent(sched, *, memory_safe):
    """Accounting invariant, checked atomically under the scheduler lock."""
    with sched._lock:
        devices = (sched.devices if hasattr(sched, "devices")
                   else sched.chips.values())
        for d in devices:
            foot = sum(t.resources.hbm_bytes for t in d.residents.values())
            slots = sum(slots_needed(t) for t in d.residents.values())
            if not isinstance(sched, SliceScheduler):
                assert d.used_hbm == foot, \
                    f"dev {d.index}: used_hbm {d.used_hbm} != {foot}"
            assert d.used_slots == slots, \
                f"dev {d.index}: used_slots {d.used_slots} != {slots}"
            assert d.used_hbm >= 0 and d.used_slots >= 0
            if memory_safe:
                assert d.used_hbm <= d.total_hbm


def _worker(sched, seed, n_ops, memory_safe, errors):
    rng = random.Random(seed)
    held = []
    try:
        for i in range(n_ops):
            if held and rng.random() < 0.45:
                sched.task_end(held.pop(rng.randrange(len(held))))
            else:
                t = mk_task(f"w{seed}.{i}", rng.uniform(0.25, 10.0),
                            rng.choice([0.0, 0.1, 0.5, 1.0]))
                if rng.random() < 0.5:
                    if sched.task_begin(t) is not None:
                        held.append(t)
                else:
                    # waiter path: admission may fire later from another
                    # thread's task_end; callbacks record the placement
                    admitted = threading.Event()

                    def cb(task, dev, epoch, admitted=admitted):
                        admitted.set()

                    if sched.admit_or_enqueue(t, cb):
                        held.append(t)
                    elif admitted.wait(0.001):
                        held.append(t)
                    else:
                        # still parked: cancel so shutdown is clean
                        if sched.cancel_wait(t):
                            pass
                        elif admitted.wait(1.0):
                            held.append(t)
            if i % 5 == 0:
                assert_consistent(sched, memory_safe=memory_safe)
        for t in held:
            sched.task_end(t)
    except BaseException as e:  # surfaced by the main thread
        errors.append(e)


@given(seed=st.integers(0, 10_000), n_threads=st.integers(2, 4))
@settings(max_examples=8, deadline=None)
def test_property_concurrent_interleavings_all_schedulers(seed, n_threads):
    for cls in ALL_CLASSES:
        sched = cls(3)
        memory_safe = cls in MEMORY_SAFE
        errors = []
        threads = [threading.Thread(
            target=_worker, args=(sched, seed * 13 + k, 30, memory_safe,
                                  errors))
            for k in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, f"{cls.__name__}: {errors[0]}"
        # quiesce: drop any waiters left by racing cancels, then all zero
        sched.cancel_all_waiters()
        assert_consistent(sched, memory_safe=memory_safe)
        for d in sched.devices:
            assert d.used_hbm == 0 and d.used_slots == 0, cls.__name__


@given(seed=st.integers(0, 10_000))
@settings(max_examples=8, deadline=None)
def test_property_sequential_begin_end_interleavings(seed):
    """Single-threaded seeded churn, heavier op count: exact accounting on
    every scheduler class after every single event."""
    for cls in ALL_CLASSES:
        sched = cls(3)
        memory_safe = cls in MEMORY_SAFE
        rng = random.Random(seed)
        held = []
        for i in range(120):
            if held and rng.random() < 0.4:
                sched.task_end(held.pop(rng.randrange(len(held))))
            else:
                t = mk_task(f"s{i}", rng.uniform(0.25, 12.0),
                            rng.choice([0.0, 0.25, 0.75, 1.0]))
                if sched.task_begin(t) is not None:
                    held.append(t)
            assert_consistent(sched, memory_safe=memory_safe)
        for t in held:
            sched.task_end(t)
        for d in sched.devices:
            assert d.used_hbm == 0 and d.used_slots == 0, cls.__name__


@given(seed=st.integers(0, 10_000))
@settings(max_examples=6, deadline=None)
def test_property_slice_scheduler_interleavings(seed):
    """Slice scheduler: per-chip accounting stays consistent under seeded
    begin/end churn of multi-chip tasks."""
    sched = SliceScheduler(pods=1, rows=4, cols=4)
    rng = random.Random(seed)
    held = []
    for i in range(60):
        if held and rng.random() < 0.4:
            sched.task_end(held.pop(rng.randrange(len(held))))
        else:
            chips = rng.choice([1, 2, 4])
            t = mk_task(f"sl{i}", rng.uniform(0.5, 8.0) * chips,
                        rng.choice([0.1, 0.5, 1.0]), chips=chips)
            if sched.task_begin(t) is not None:
                held.append(t)
        assert_consistent(sched, memory_safe=True)
        # per-chip share never oversubscribes a chip
        for d in sched.chips.values():
            assert 0 <= d.used_hbm <= d.total_hbm
    for t in held:
        sched.task_end(t)
    assert all(d.used_hbm == 0 and d.used_slots == 0
               for d in sched.chips.values())
