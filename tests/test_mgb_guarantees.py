"""Regression tests for the MGB schedulers' memory-hard guarantee.

The paper's central safety property: a task is NEVER placed on a device that
cannot hold its declared peak memory, so co-scheduled jobs cannot OOM each
other. Alg. 2 additionally treats compute slots as a hard constraint. These
tests drive both schedulers through a deterministic random begin/end stream
and check the invariants after every event, including the demand boundary
cases 0 and 1.0, and cross-check the O(1) ``DeviceState.used_slots`` cache
against a recount.
"""
import random

from repro.core.scheduler import MGBAlg2Scheduler, MGBAlg3Scheduler
from repro.core.scheduler.base import SLOTS, slots_needed
from repro.core.task import ResourceVector, Task, UnitTask

GB = 1024**3


def _task(mem_bytes, demand, name="", chips=1):
    vec = ResourceVector(hbm_bytes=int(mem_bytes), flops=1e9,
                         bytes_accessed=1e9, est_seconds=0.01,
                         core_demand=demand, bw_demand=demand, chips=chips)
    return Task(units=[UnitTask(fn=None, memobjs=frozenset(), resources=vec,
                                name=name)], name=name)


def _assert_invariants(sched, *, slots_hard):
    for dev in sched.devices:
        assert dev.used_hbm <= dev.total_hbm, \
            f"device {dev.index} oversubscribed: {dev.used_hbm}"
        assert dev.used_hbm >= 0 and dev.used_slots >= 0
        recount = sum(slots_needed(t) for t in dev.residents.values())
        assert dev.used_slots == recount, \
            f"used_slots cache diverged: {dev.used_slots} != {recount}"
        if slots_hard:
            assert dev.used_slots <= SLOTS, \
                f"Alg2 oversubscribed slots: {dev.used_slots}"


def _run_stream(sched, *, slots_hard, seed=0, events=400):
    rng = random.Random(seed)
    resident = []
    for _ in range(events):
        if resident and rng.random() < 0.4:
            sched.task_end(resident.pop(rng.randrange(len(resident))))
        else:
            demand = rng.choice([0.0, 0.05, 0.25, 0.5, 0.75, 1.0])
            t = _task(rng.uniform(0.25, 12.0) * GB, demand)
            free_before = {d.index: d.free_hbm for d in sched.devices}
            dev = sched.task_begin(t)
            if dev is not None:
                # placement respected the pre-admission free memory
                assert t.resources.hbm_bytes <= free_before[dev]
                resident.append(t)
        _assert_invariants(sched, slots_hard=slots_hard)
    for t in resident:
        sched.task_end(t)
    _assert_invariants(sched, slots_hard=slots_hard)
    for dev in sched.devices:
        assert dev.used_hbm == 0 and dev.used_slots == 0


def test_alg2_memory_and_slots_hard_under_churn():
    _run_stream(MGBAlg2Scheduler(4), slots_hard=True)


def test_alg3_memory_hard_under_churn():
    _run_stream(MGBAlg3Scheduler(4), slots_hard=False)


def test_alg2_zero_demand_still_occupies_one_slot():
    sched = MGBAlg2Scheduler(2)
    placed = [sched.task_begin(_task(GB, 0.0)) for _ in range(2 * SLOTS)]
    assert None not in placed  # 16 issue slots per device, 2 devices
    assert all(d.used_slots == SLOTS for d in sched.devices)
    # every slot is held: one more zero-demand task must wait
    assert sched.task_begin(_task(GB, 0.0)) is None


def test_alg2_full_demand_gets_device_exclusively():
    sched = MGBAlg2Scheduler(1)
    big = _task(GB, 1.0)
    assert sched.task_begin(big) == 0
    assert sched.devices[0].used_slots == SLOTS
    # compute-exclusive: even an epsilon task cannot co-place...
    assert sched.task_begin(_task(GB, 0.05)) is None
    sched.task_end(big)
    # ...but fits immediately once the resident leaves
    assert sched.task_begin(_task(GB, 0.05)) == 0


def test_alg3_rejects_on_memory_even_when_idle():
    sched = MGBAlg3Scheduler(2)
    assert sched.task_begin(_task(17 * GB, 0.0)) is None  # > 16 GB HBM
    half = _task(9 * GB, 0.0)
    assert sched.task_begin(half) is not None
    # 9 + 9 > 16: second task must land on the OTHER device
    other = _task(9 * GB, 0.0)
    assert sched.task_begin(other) not in (None, half.device)
    # a third 9 GB task fits nowhere, regardless of zero compute demand
    assert sched.task_begin(_task(9 * GB, 0.0)) is None


def test_slice_scheduler_maintains_slot_cache():
    """SliceScheduler bypasses DeviceState.admit (per-chip memory charging),
    so it must maintain the used_slots cache itself on all three paths."""
    from repro.core.scheduler import SliceScheduler
    sched = SliceScheduler(pods=1, rows=4, cols=4)
    t = _task(4 * GB, 0.5, chips=4)
    rect = sched.task_begin(t)
    assert rect is not None and rect.chips == 4
    for cell in rect.cells():
        dev = sched.chips[cell]
        assert dev.used_slots == slots_needed(t) > 0
    sched.task_end(t)
    assert all(d.used_slots == 0 and d.used_hbm == 0
               for d in sched.chips.values())
    # eviction path (chip failure) must release slots on every slice cell
    t2 = _task(4 * GB, 1.0, chips=4)
    rect2 = sched.task_begin(t2)
    evicted = sched.mark_dead(next(iter(rect2.cells())))
    assert evicted == [t2]
    assert all(d.used_slots == 0 and d.used_hbm == 0
               for d in sched.chips.values())


def test_alg3_oversubscribes_compute_but_never_memory():
    sched = MGBAlg3Scheduler(1)
    tasks = [_task(GB, 1.0) for _ in range(4)]
    for t in tasks:  # compute is soft: all four co-resident at demand 1.0
        assert sched.task_begin(t) == 0
    assert sched.devices[0].used_hbm == 4 * GB
    assert sched.task_begin(_task(13 * GB, 0.0)) is None  # memory stays hard
