"""int8 KV-cache tests (beyond-paper feature, EXPERIMENTS.md §Perf P10)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.configs.registry import get_arch
from repro.models import decode as D
from repro.models import layers as L
from repro.models import model as M


def test_quantize_kv_roundtrip_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 8, 64))
    codes, scale = L.quantize_kv(x, jnp.float32)
    assert codes.dtype == jnp.int8 and scale.shape == (2, 4, 8)
    recon = codes.astype(jnp.float32) * scale[..., None]
    err = np.abs(np.asarray(recon - x))
    bound = np.asarray(jnp.abs(x).max(axis=-1) / 127.0)
    assert (err <= bound[..., None] * 0.51 + 1e-6).all()


@given(seed=st.integers(0, 50))
@settings(max_examples=15, deadline=None)
def test_property_decode_attention_q8_close_to_fp(seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    b, hq, hkv, s, d = 2, 4, 2, 64, 32
    q = jax.random.normal(ks[0], (b, hq, 1, d))
    k = jax.random.normal(ks[1], (b, hkv, s, d))
    v = jax.random.normal(ks[2], (b, hkv, s, d))
    cl = jnp.asarray(48)
    ref = L.decode_attention(q, k, v, cl)
    kq, ksa = L.quantize_kv(k, jnp.float32)
    vq, vsa = L.quantize_kv(v, jnp.float32)
    out = L.decode_attention_q8(q, kq, ksa, vq, vsa, cl)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0.05, atol=0.02)


@pytest.mark.parametrize("arch", ["qwen1.5-32b", "llama3-405b"])
def test_int8_decode_matches_bf16_decode(arch):
    cfg8 = dataclasses.replace(get_arch(arch).reduced(),
                               kv_cache_dtype="int8")
    cfg16 = dataclasses.replace(cfg8, kv_cache_dtype="bfloat16")
    params = M.init_params(cfg8, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2,), 0, cfg8.vocab)
    c8 = D.init_cache(cfg8, 2, 32, dtype=jnp.float32)
    c16 = D.init_cache(cfg16, 2, 32, dtype=jnp.float32)
    assert c8["k"].dtype == jnp.int8 and "k_s" in c8
    t = tok
    for pos in range(5):
        l8, c8 = D.decode_step(params, cfg8, c8, t,
                               jnp.asarray(pos, jnp.int32))
        l16, c16 = D.decode_step(params, cfg16, c16, t,
                                 jnp.asarray(pos, jnp.int32))
        err = float(jnp.abs(jax.nn.softmax(l8) - jax.nn.softmax(l16)).max())
        assert err < 0.03, (pos, err)
        t = jnp.argmax(l16, -1).astype(jnp.int32)


def test_int8_prefill_then_decode():
    from repro.serve.decode import make_prefill_step
    cfg = dataclasses.replace(get_arch("gemma2-9b").reduced(),
                              kv_cache_dtype="int8")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    prefill = make_prefill_step(cfg, attn_impl="naive")
    logits, cache = prefill(params, {"tokens": tok})
    assert cache["k"].dtype == jnp.int8
    # grow seq dim for decode and take a step
    grown = D.init_cache(cfg, 2, 20, dtype=jnp.bfloat16)

    def graft(dst, src):
        pad_dim = 3 if src.ndim == 5 else 3
        pad = dst.shape[pad_dim] - src.shape[pad_dim]
        cfgpad = [(0, 0)] * src.ndim
        cfgpad[pad_dim] = (0, pad)
        return jnp.pad(src, cfgpad).astype(dst.dtype)
    cache = jax.tree_util.tree_map(graft, grown, cache)
    nxt = jnp.argmax(logits, -1).astype(jnp.int32)
    l2, cache = D.decode_step(params, cfg, cache, nxt,
                              jnp.asarray(16, jnp.int32))
    assert np.isfinite(np.asarray(l2)).all()
