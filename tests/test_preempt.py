"""Preemption subsystem battery: eviction correctness across both backends.

Covers the guarantees the preemptive layer must add WITHOUT breaking the
existing ones: no task is lost or duplicated across preempt -> resume
(including gang victims and a mark_dead racing a preemption), memory/slot
accounting stays exact through eviction and rollback, the min-runtime and
budget guards hold, live and sim replay identical eviction + admission
order, the simulator's resume is work-conserving (remaining work + penalty,
not a from-scratch restart), and an aged low-priority job eventually
completes under sustained high-priority arrivals (starvation freedom).
"""
import threading
import time

from _hypothesis_fallback import given, settings, st

from repro.core.cluster import Cluster, JobStatus
from repro.core.executor import ExecJob
from repro.core.preemption import (
    PreemptionPolicy, ProgressLedger, outranks, preemption_cost,
)
from repro.core.scheduler import (
    MGBAlg3Scheduler, PreemptiveAlg2Scheduler, PreemptiveAlg3Scheduler,
    PreemptiveGangScheduler,
)
from repro.core.scheduler.base import slots_needed
from repro.core.simulator import Simulator
from repro.core.task import Job, ResourceVector, Task, UnitTask
from repro.obs.replay import (admission_order, eviction_order,
                              first_divergence)
from repro.core.workloads import overload_mix

GB = 1024**3

FAST = PreemptionPolicy(min_runtime_s=0.0, budget=3, aging_step=1,
                        checkpoint_penalty_s=0.5)


def mk_task(name, gb, est, prio=0, chips=1, demand=0.5, deadline=None):
    vec = ResourceVector(hbm_bytes=int(gb * GB), flops=1e9,
                         bytes_accessed=1e9, est_seconds=est,
                         core_demand=demand, bw_demand=0.3, chips=chips)
    return Task(units=[UnitTask(fn=None, memobjs=frozenset({name}),
                                resources=vec, name=name)],
                name=name, priority=prio, deadline_t=deadline,
                gang_id=name if chips > 1 else None)


def mk_job(name, gb, est, prio=0, chips=1, demand=0.5):
    t = mk_task(name, gb, est, prio=prio, chips=chips, demand=demand)
    return Job(tasks=[t], name=name, priority=prio, gang_id=t.gang_id)


def assert_zeroed(sched):
    assert all(d.used_hbm == 0 and d.used_slots == 0 and not d.residents
               for d in sched.devices), \
        [(d.index, d.used_hbm, d.used_slots) for d in sched.devices]


# ---------------------------------------------------------------------------
# decision rule / cost model units
# ---------------------------------------------------------------------------

def test_outranks_is_strict_priority_then_edf():
    lo, hi = mk_task("lo", 1, 1), mk_task("hi", 1, 1, prio=5)
    assert outranks(hi, lo) and not outranks(lo, hi)
    assert not outranks(lo, mk_task("lo2", 1, 1))      # tie: never
    e1 = mk_task("e1", 1, 1, deadline=5.0)
    e2 = mk_task("e2", 1, 1, deadline=9.0)
    none = mk_task("none", 1, 1)
    assert outranks(e1, e2) and not outranks(e2, e1)   # EDF within class
    assert outranks(e1, none)                          # deadline beats none
    assert not outranks(none, e1)                      # none never outranks


def test_cost_model_remaining_times_memory():
    big_near_done = mk_task("big", 10, 100.0)
    small_fresh = mk_task("small", 1, 100.0)
    ledger = ProgressLedger()
    ledger.set_remaining(big_near_done.uid, 1.0)
    assert preemption_cost(big_near_done, ledger.remaining(big_near_done)) \
        < preemption_cost(small_fresh, ledger.remaining(small_fresh))


# ---------------------------------------------------------------------------
# work-conserving resume (sim timeline is exact)
# ---------------------------------------------------------------------------

def test_sim_resume_is_work_conserving():
    sched = PreemptiveAlg3Scheduler(1, preempt_policy=FAST)
    c = Cluster(sched, workers=8, backend="sim")
    h_bg = c.submit(mk_job("bg", 10, 10.0))
    c.run_until(2.0)
    h_hi = c.submit(mk_job("hi", 10, 1.0, prio=5))
    c.drain()
    assert h_hi.status is JobStatus.DONE and h_bg.status is JobStatus.DONE
    # bg ran [0,2), hi [2,3), bg resumes with 8s remaining + 0.5s penalty
    assert abs(h_hi.job.finish_t - 3.0) < 1e-6
    assert abs(h_bg.job.finish_t - 11.5) < 1e-6, h_bg.job.finish_t
    assert sched.preemptions == 1 and sched.preempt_log
    assert h_bg.job.tasks[0].preempt_count == 1
    assert len(sched.ledger) == 0    # cleared on completion
    assert_zeroed(sched)


def test_sim_migration_counted_when_resumed_elsewhere():
    # dev0: bg (victim), dev1: blocker finishing right after the preemption;
    # bg's re-admission lands on the freed dev1 -> migration. The blocker
    # shares the preemptor's priority class so it can never be the victim.
    sched = PreemptiveAlg3Scheduler(2, preempt_policy=FAST)
    c = Cluster(sched, workers=8, backend="sim")
    h_bg = c.submit(mk_job("bg", 10, 10.0))
    h_blk = c.submit(mk_job("blocker", 10, 3.0, prio=5))
    c.run_until(2.0)
    h_hi = c.submit(mk_job("hi", 10, 5.0, prio=5))
    c.drain()
    assert all(h.status is JobStatus.DONE for h in (h_bg, h_blk, h_hi))
    assert sched.preemptions == 1
    assert sched.migrations == 1     # bg moved from dev0 to dev1
    assert_zeroed(sched)


# ---------------------------------------------------------------------------
# guardrails
# ---------------------------------------------------------------------------

def test_min_runtime_guard_blocks_fresh_victims():
    pol = PreemptionPolicy(min_runtime_s=100.0, budget=3)
    sched = PreemptiveAlg3Scheduler(1, preempt_policy=pol)
    c = Cluster(sched, workers=8, backend="sim")
    c.submit(mk_job("bg", 10, 5.0))
    c.run_until(1.0)    # resident for 1s << min_runtime
    c.submit(mk_job("hi", 10, 1.0, prio=5))
    c.drain()
    assert sched.preemptions == 0   # guard held: hi waited instead
    assert all(h.status is JobStatus.DONE for h in c.handles)
    assert_zeroed(sched)


def test_budget_makes_job_immune_after_n_evictions():
    pol = PreemptionPolicy(min_runtime_s=0.0, budget=1, aging_step=0,
                           checkpoint_penalty_s=0.1)
    sched = PreemptiveAlg3Scheduler(1, preempt_policy=pol)
    c = Cluster(sched, workers=8, backend="sim")
    h_bg = c.submit(mk_job("bg", 10, 10.0))
    c.run_until(1.0)
    c.submit(mk_job("hi1", 10, 1.0, prio=5))   # evicts bg (budget -> 0 left)
    c.run_until(3.0)                           # hi1 done, bg resumed
    c.submit(mk_job("hi2", 10, 1.0, prio=5))   # bg now immune: must wait
    c.drain()
    assert sched.preemptions == 1
    assert h_bg.job.tasks[0].preempt_count == 1
    assert all(h.status is JobStatus.DONE for h in c.handles)
    assert_zeroed(sched)


def test_starvation_aged_low_priority_job_completes_under_pressure():
    # sustained priority-3 arrivals (1.0s of work every 1.2s) over a single
    # device: the priority-0 job is evicted at most `budget` times — aging
    # promotes it a class per eviction and the spent budget then makes it
    # immune, so once re-admitted it runs to completion despite the stream
    pol = PreemptionPolicy(min_runtime_s=0.0, budget=3, aging_step=1,
                           checkpoint_penalty_s=0.1)
    sched = PreemptiveAlg3Scheduler(1, preempt_policy=pol)
    c = Cluster(sched, workers=64, backend="sim")
    h_lo = c.submit(mk_job("lo", 10, 5.0))
    for i in range(14):
        c.run_until(0.2 + 1.2 * i)
        c.submit(mk_job(f"hi{i:02d}", 10, 1.0, prio=3))
    c.drain()
    assert h_lo.status is JobStatus.DONE
    lo_task = h_lo.job.tasks[0]
    assert lo_task.preempt_count == pol.budget          # then immune
    assert lo_task.age_boost == pol.budget * pol.aging_step  # aged upwards
    assert lo_task.priority == 0   # aging never touches the raw class
    # it finished well before the arrival stream ended
    assert h_lo.job.finish_t < 0.2 + 1.2 * 13, h_lo.job.finish_t
    assert all(h.status is JobStatus.DONE for h in c.handles)
    assert_zeroed(sched)


def test_simultaneous_completion_racing_a_preemption():
    # two co-residents finish at the SAME virtual event; the first task_end's
    # drain preempts the second (done but not yet ended) for a parked urgent
    # whose min-runtime guard blocked it at arrival. The sim must tolerate
    # the eviction notice having already removed the co-completer from its
    # running set (regression: KeyError), and everything still resolves.
    pol = PreemptionPolicy(min_runtime_s=8.0, budget=3,
                           checkpoint_penalty_s=0.5)
    sched = PreemptiveAlg3Scheduler(1, preempt_policy=pol)
    c = Cluster(sched, workers=8, backend="sim")
    c.submit(mk_job("small", 1, 10.0, demand=0.3))
    c.submit(mk_job("big", 10, 10.0, demand=0.3))
    c.run_until(5.0)
    c.submit(mk_job("urgent", 9, 1.0, prio=5))
    c.drain()
    assert all(h.status is JobStatus.DONE for h in c.handles), \
        [(h.job.name, h.status) for h in c.handles]
    assert len(sched.ledger) == 0
    assert_zeroed(sched)


def test_shed_after_preemption_drops_banked_state():
    # a request that is preempted and THEN shed (deadline passed while
    # re-parked) must not leak its ledger/bookkeeping entries
    pol = PreemptionPolicy(min_runtime_s=0.0, budget=3,
                           checkpoint_penalty_s=0.5)
    sched = PreemptiveAlg3Scheduler(1, preempt_policy=pol)
    c = Cluster(sched, workers=8, backend="sim", shed_late=True)
    h_bg = c.submit(mk_job("bg", 10, 10.0), deadline_s=4.0)
    c.run_until(2.0)
    h_hi = c.submit(mk_job("hi", 10, 5.0, prio=5))   # evicts bg
    c.drain()
    # bg was evicted at t=2, re-parked, and its deadline (t=4) passed while
    # hi ran to t=7: shed, with no banked remaining left behind
    assert h_hi.status is JobStatus.DONE
    assert h_bg.status is JobStatus.SHED, h_bg.status
    assert len(sched.ledger) == 0
    assert not sched._evicted_from and not sched._resident_since
    assert_zeroed(sched)


# ---------------------------------------------------------------------------
# accounting exactness through evict / rollback
# ---------------------------------------------------------------------------

def test_memory_and_slots_exact_after_eviction_and_rollback():
    sched = PreemptiveAlg3Scheduler(2, preempt_policy=FAST)
    fired = []
    for name, gb in (("a", 10.0), ("b", 12.0)):
        assert sched.admit_or_enqueue(mk_task(name, gb, 5.0),
                                      lambda *a: fired.append(a))
    # urgent arrival needs an eviction; plan trial + rollback + commit must
    # leave every untouched device byte-exact
    urgent = mk_task("urgent", 9.0, 1.0, prio=5)
    assert sched.admit_or_enqueue(urgent, lambda *a: fired.append(a))
    assert sched.preemptions == 1
    for d in sched.devices:
        foot = sum(t.resources.hbm_bytes for t in d.residents.values())
        slots = sum(slots_needed(t) for t in d.residents.values())
        assert d.used_hbm == foot and d.used_slots == slots
    # the victim holds nothing anywhere; the preemptor holds its device
    victim_uid = sched.preempt_log[0][0]
    assert all(victim_uid not in d.residents for d in sched.devices)
    assert urgent.device is not None
    # failed preemption (nothing outranked: class-0 arrival, class-0 and
    # class-5 residents) must also be a no-op on state
    before = [(d.used_hbm, d.used_slots) for d in sched.devices]
    later = mk_task("later", 9.0, 1.0)
    assert not sched.admit_or_enqueue(later, lambda *a: fired.append(a))
    assert [(d.used_hbm, d.used_slots) for d in sched.devices] == before


def test_gang_victim_evicted_whole_never_partial():
    sched = PreemptiveGangScheduler(pods=1, rows=2, cols=2,
                                    preempt_policy=FAST)
    fired = []
    glo = mk_task("glo", 40, 10.0, chips=4)     # 10 GB on each of 4 chips
    assert sched.admit_or_enqueue(glo, lambda *a: fired.append(a))
    ghi = mk_task("ghi", 40, 1.0, prio=5, chips=4)
    assert sched.admit_or_enqueue(ghi, lambda *a: fired.append(a))
    assert sched.preemptions == 1
    # the victim's reservation is gone from EVERY cell and the link ledger;
    # the preemptor holds every cell — no partial state on either side
    assert glo.uid not in sched.bound
    assert all(glo.uid not in d.residents for d in sched.devices)
    assert not sched.topo.task_link_loads(glo.uid)
    assert sched.bound[ghi.uid].chips == 4
    assert all(ghi.uid in d.residents for d in sched.devices)
    for d in sched.devices:
        assert d.used_hbm == 10 * GB and d.used_slots == slots_needed(ghi)
    # victim parked at the front of its class as ONE waiter
    assert [w.uid for w in sched.waiting_tasks()] == [glo.uid]


def test_mark_dead_racing_a_preemption():
    # preempt bg for urgent, then IMMEDIATELY kill the device the urgent
    # landed on: both end up queued/readmitted, nothing is lost or double-
    # accounted, and the stale epoch fences the superseded runs
    sched = PreemptiveAlg3Scheduler(2, preempt_policy=FAST)
    admissions = []

    def cb(tag):
        return lambda t, placement, epoch: admissions.append(
            (tag, placement, epoch))

    bg = mk_task("bg", 10, 5.0)
    # blocker shares the urgent's class: never a victim, so the mark_dead
    # drain cannot cascade into a second eviction
    blocker = mk_task("blocker", 10, 5.0, prio=5)
    assert sched.admit_or_enqueue(bg, cb("bg"))
    assert sched.admit_or_enqueue(blocker, cb("blocker"))
    urgent = mk_task("urgent", 9, 1.0, prio=5)
    assert sched.admit_or_enqueue(urgent, cb("urgent"))
    assert sched.preemptions == 1
    dead = urgent.device
    old_epoch = sched.admission_epoch(urgent)
    evicted = sched.mark_dead(dead)
    assert urgent in evicted
    # stale task_end from the superseded urgent run is fenced
    assert not sched.task_end(urgent, epoch=old_epoch)
    # nothing resides on the dead device; accounting exact on the survivor
    assert not sched.devices[dead].residents
    live_dev = sched.devices[1 - dead]
    assert live_dev.used_hbm == sum(t.resources.hbm_bytes
                                    for t in live_dev.residents.values())
    # survivors: blocker resident, urgent + bg parked (urgent outranks)
    waiting = [t.uid for t in sched.waiting_tasks()]
    assert waiting[0] == urgent.uid and bg.uid in waiting
    # let the blocker finish: urgent preempts nothing (empty device revived
    # is not needed — it lands on the freed survivor), then bg follows
    assert sched.task_end(blocker)
    assert sched.task_end(urgent)
    assert sched.task_end(bg)
    assert not sched.waiting_tasks()
    assert_zeroed(sched)


# ---------------------------------------------------------------------------
# no lost / duplicated tasks across preempt -> resume (property battery)
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_no_lost_or_duplicated_tasks_sim(seed):
    rows = overload_mix(seed, n_background=3, n_bystander=2, n_urgent=5)
    sched = PreemptiveAlg3Scheduler(2, preempt_policy=FAST)
    c = Cluster(sched, workers=64, backend="sim")
    handles = []
    for row in rows:
        c.run_until(row["t"])
        handles.append(c.submit(row["job"], priority=row["priority"],
                                deadline_s=row["deadline_s"]))
    c.drain()
    res = c._sim.result()
    assert not res.truncated
    # every job resolves exactly once, as DONE (nothing can crash here)
    assert all(h.status is JobStatus.DONE for h in handles), \
        [(h.job.name, h.status) for h in handles]
    assert res.completed == len(handles)
    # exactly ONE completion record per task — a preempted task's superseded
    # attempt must not produce a duplicate completion
    done_names = [r.task for r in c._sim.records if not r.crashed]
    assert sorted(done_names) == sorted({r["job"].tasks[0].name
                                         for r in rows})
    assert len(sched.ledger) == 0
    assert_zeroed(sched)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_no_lost_tasks_with_gangs_and_device_failure(seed):
    # gang victims + a device failure injected mid-churn: every job still
    # resolves exactly once (DONE, or CRASHED only via the failure path)
    sched = PreemptiveGangScheduler(pods=1, rows=1, cols=2,
                                    preempt_policy=FAST)
    sim = Simulator(sched, workers=64)
    jobs = [mk_job("solo-a", 12, 6.0), mk_job("solo-b", 12, 6.0),
            mk_job("gang-lo", 20, 4.0, chips=2)]
    states = [sim.submit(j) for j in jobs[:2]]
    sim.run_until(1.0)
    states.append(sim.submit(jobs[2]))           # parks behind the solos
    sim.run_until(2.0)
    hi = mk_job("gang-hi", 20, 1.0, prio=5, chips=2)
    states.append(sim.submit(hi))                # preempts both solos
    sim._failure_pending = (2.5 + (seed % 5) * 0.2, 0)  # kill chip 0
    res = sim.drain()
    assert not res.truncated
    resolved = [s for s in states if s.done]
    assert len(resolved) == len(states), [s.job.name for s in states
                                          if not s.done]
    # 1x1 pod remains: solos can still run; 2-chip gangs crash at the sweep
    done_names = [r.task for r in sim.records if not r.crashed]
    assert len(done_names) == len(set(done_names))
    for s in states:
        assert s.done and (not s.job.crashed or s.job.error or True)
    assert all(not d.residents for d in sched.devices)


# ---------------------------------------------------------------------------
# live backend: cooperative checkpoint, resume, parity with sim
# ---------------------------------------------------------------------------

def _parity_jobs():
    return (mk_job("bg-small", 10.0, 5.0), mk_job("bg-big", 10.5, 30.0),
            mk_job("urgent", 9.0, 1.0, prio=5))


def _names(handles, uids):
    table = {h.job.tasks[0].uid: h.job.name for h in handles}
    return [table[uid] for uid in uids]


def test_live_and_sim_replay_identical_eviction_order():
    pol = PreemptionPolicy(min_runtime_s=0.0, budget=3,
                           checkpoint_penalty_s=0.2)

    # sim leg
    s_sched = PreemptiveAlg3Scheduler(2, preempt_policy=pol)
    sim = Cluster(s_sched, workers=8, backend="sim", trace=True)
    s_jobs = _parity_jobs()
    hs = [sim.submit(s_jobs[0]), sim.submit(s_jobs[1])]
    sim.run_until(2.0)
    hs.append(sim.submit(s_jobs[2]))
    sim.drain()
    sim_victims = eviction_order(sim.trace.events())
    sim_order = admission_order(sim.trace.events())

    # live leg: the backgrounds are cooperative runners that block until
    # preempted (first attempt) and return promptly when re-dispatched
    l_sched = PreemptiveAlg3Scheduler(2, preempt_policy=pol)
    live = Cluster(l_sched, workers=4, trace=True)
    l_jobs = _parity_jobs()
    release = threading.Event()
    checkpoints = []

    def cooperative(attempts):
        box = []

        def runner(device):
            attempts.append(device)
            if len(attempts) == 1:
                while not box[0].preempted.wait(0.01):
                    if release.is_set():
                        return
        return box, runner

    box_s, run_s = cooperative(small_attempts := [])
    box_b, run_b = cooperative(big_attempts := [])
    ej_s = ExecJob(job=l_jobs[0], runners=[run_s],
                   on_preempt=lambda t: checkpoints.append(t.name))
    ej_b = ExecJob(job=l_jobs[1], runners=[run_b])
    box_s.append(ej_s)
    box_b.append(ej_b)
    hl = [live.submit(ej_s), live.submit(ej_b)]
    time.sleep(0.2)
    hl.append(live.submit(l_jobs[2], runners=[lambda d: time.sleep(0.01)]))
    hl[2].result(timeout=30)
    release.set()
    live.drain()
    live.shutdown()
    assert all(h.status is JobStatus.DONE for h in hl), \
        [(h.job.name, h.status) for h in hl]
    live_victims = eviction_order(live.trace.events())
    live_order = admission_order(live.trace.events())

    # cheapest victim is unambiguous (5s x 10GB << 30s x 10.5GB): both
    # backends must evict bg-small, once, and admit in the same order —
    # parity asserted through the obs.replay differ over the two streams
    assert sim_victims == live_victims == ["bg-small"]
    div = first_divergence(sim_order, live_order)
    assert div is None, div
    assert checkpoints == ["bg-small"]     # cooperative checkpoint fired
    assert len(small_attempts) == 2        # evicted, then resumed
    assert len(big_attempts) == 1          # untouched
    assert_zeroed(l_sched)


def test_live_preempted_while_queued_for_pool_not_duplicated():
    # eviction between admission and pool pickup: the stale _Ready must be
    # dropped (epoch fence) and the job still completes exactly once
    pol = PreemptionPolicy(min_runtime_s=0.0, budget=3)
    sched = PreemptiveAlg3Scheduler(1, preempt_policy=pol)
    c = Cluster(sched, workers=2)
    runs = []
    bg = mk_job("bg", 10, 1.0)
    ej = ExecJob(job=bg, runners=[lambda d: runs.append("bg")])
    # occupy the single pool differently: submit, then immediately preempt
    # by a high-priority arrival before draining
    h_bg = c.submit(ej)
    h_hi = c.submit(mk_job("hi", 10, 1.0, prio=5),
                    runners=[lambda d: runs.append("hi")])
    c.drain()
    c.shutdown()
    assert h_bg.status is JobStatus.DONE and h_hi.status is JobStatus.DONE
    assert runs.count("hi") == 1
    assert runs.count("bg") >= 1           # may legitimately re-run
    # but it completed exactly once:
    assert len([r for r in h_bg.records if not r.crashed]) == 1
    assert_zeroed(sched)


# ---------------------------------------------------------------------------
# front-end plumbing
# ---------------------------------------------------------------------------

def test_cluster_preempt_flag_validation():
    try:
        Cluster(MGBAlg3Scheduler(2), workers=2, backend="sim", preempt=True)
        assert False, "expected ValueError"
    except ValueError as e:
        assert "preemption-capable" in str(e)
    # preempt=False disables a capable scheduler; None keeps its setting
    sched = PreemptiveAlg3Scheduler(2, preempt_policy=FAST)
    Cluster(sched, workers=2, backend="sim", preempt=False)
    assert sched.preempt_enabled is False
    sched2 = PreemptiveAlg3Scheduler(2, preempt_policy=FAST)
    Cluster(sched2, workers=2, backend="sim")
    assert sched2.preempt_enabled is True


def test_preempt_disabled_capable_scheduler_never_evicts():
    sched = PreemptiveAlg3Scheduler(1, preempt_policy=FAST)
    c = Cluster(sched, workers=8, backend="sim", preempt=False)
    c.submit(mk_job("bg", 10, 5.0))
    c.run_until(1.0)
    c.submit(mk_job("hi", 10, 1.0, prio=5))
    c.drain()
    assert sched.preemptions == 0
    assert all(h.status is JobStatus.DONE for h in c.handles)


def test_preemptive_alg2_respects_slot_hardness():
    # alg2: compute slots are hard — preemption must free slots too, and the
    # accounting stays exact through it
    sched = PreemptiveAlg2Scheduler(1, preempt_policy=FAST)
    fired = []
    # demand 1.0 -> all 16 slots: nothing else fits until evicted
    big = mk_task("big", 2, 5.0, demand=1.0)
    assert sched.admit_or_enqueue(big, lambda *a: fired.append(a))
    hi = mk_task("hi", 2, 1.0, prio=5, demand=1.0)
    assert sched.admit_or_enqueue(hi, lambda *a: fired.append(a))
    assert sched.preemptions == 1
    d = sched.devices[0]
    assert d.used_slots == slots_needed(hi)
    assert list(d.residents) == [hi.uid]


# ---------------------------------------------------------------------------
# Simulator.drain truncation is explicit (satellite)
# ---------------------------------------------------------------------------

def test_drain_time_limit_sets_truncated_flag():
    sched = MGBAlg3Scheduler(1)
    sim = Simulator(sched, workers=4)
    sim.submit(mk_job("long", 1, 100.0))
    res = sim.drain(time_limit=1.0)
    assert res.truncated
    assert sim.pending()
    res2 = sim.drain()           # let it finish: flag clears state forward
    assert res2.completed == 1


def test_cluster_drain_raises_on_truncation():
    # three sequential 6e6-second jobs on one device blow through drain's
    # 1e7-virtual-second default limit with work still pending: the cluster
    # must raise, not return as if the trace had finished
    sched = MGBAlg3Scheduler(1)
    c = Cluster(sched, workers=4, backend="sim")
    for i in range(3):   # 10 GB each: they serialize on the 16 GB device
        c.submit(mk_job(f"epic{i}", 10, 6e6))
    try:
        c.drain()
        assert False, "expected RuntimeError on truncated drain"
    except RuntimeError as e:
        assert "truncated" in str(e)
