"""Per-kernel allclose tests: sweep shapes/dtypes against the ref.py oracles
(interpret=True executes the Pallas kernel bodies in Python on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as R

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_SHAPES = [
    # (b, hq, hkv, sq, sk, d)
    (2, 4, 2, 128, 128, 64),
    (1, 8, 1, 256, 256, 32),
    (2, 2, 2, 128, 384, 64),     # cross Sq != Sk
    (1, 4, 4, 512, 512, 128),    # MHA, larger head dim
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(shape, dtype):
    b, hq, hkv, sq, sk, d = shape
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (b, hq, sq, d), dtype)
    k = jax.random.normal(ks[1], (b, hkv, sk, d), dtype)
    v = jax.random.normal(ks[2], (b, hkv, sk, d), dtype)
    out = ops.flash_attention(q, k, v, interpret=True)
    ref = R.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 96, 128])
def test_flash_attention_window(window):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    out = ops.flash_attention(q, k, v, window=window, interpret=True)
    ref = R.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("cap", [20.0, 50.0])
def test_flash_attention_softcap(cap):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 128, 64)) * 3
    k = jax.random.normal(ks[1], (1, 2, 128, 64)) * 3
    v = jax.random.normal(ks[2], (1, 2, 128, 64))
    out = ops.flash_attention(q, k, v, logit_softcap=cap, interpret=True)
    ref = R.flash_attention_ref(q, k, v, logit_softcap=cap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_block_size_invariance():
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    o1 = ops.flash_attention(q, k, v, block_q=64, block_k=64, interpret=True)
    o2 = ops.flash_attention(q, k, v, block_q=128, block_k=256,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 256), (4, 96, 256), (2, 3, 5, 128),
                                   (1000, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    sc = (jax.random.normal(ks[1], (shape[-1],)) * 0.1).astype(dtype)
    out = ops.rmsnorm(x, sc, interpret=True)
    ref = R.rmsnorm_ref(x, sc)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# mamba scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(1, 64, 128, 16), (2, 128, 256, 16),
                                   (2, 96, 128, 64)])
@pytest.mark.parametrize("chunk", [16, 32])
def test_mamba_scan(shape, chunk):
    b, s, e, n = shape
    ks = jax.random.split(KEY, 2)
    a = jnp.exp(-jnp.abs(jax.random.normal(ks[0], shape)))
    bb = jax.random.normal(ks[1], shape)
    h_all, h_last = ops.mamba_scan(a, bb, chunk=chunk, interpret=True)
    ra, rl = R.mamba_scan_ref(a, bb, jnp.zeros((b, e, n)))
    np.testing.assert_allclose(np.asarray(h_all), np.asarray(ra),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(rl),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# moe grouped matmul
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("groups", [
    (128, 256, 0, 128), (512, 0, 0, 0), (128, 128, 128, 128)])
def test_moe_gmm(groups):
    t = sum(groups)
    d, f = 64, 128
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (t, d))
    w = jax.random.normal(ks[1], (len(groups), d, f))
    gs = jnp.array(groups, jnp.int32)
    out = ops.moe_gmm(x, w, gs, interpret=True)
    ref = R.moe_gmm_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


def test_moe_gmm_bf16():
    t, d, f, e = 256, 64, 128, 2
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (t, d), jnp.bfloat16)
    w = jax.random.normal(ks[1], (e, d, f), jnp.bfloat16)
    gs = jnp.array([128, 128], jnp.int32)
    out = ops.moe_gmm(x, w, gs, interpret=True)
    ref = R.moe_gmm_ref(x, w, gs)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
# pallas attention inside the full model path
# ---------------------------------------------------------------------------

def test_model_forward_pallas_matches_naive():
    from repro.configs.registry import get_arch
    from repro.models import model as M
    cfg = get_arch("llama3-405b").reduced()
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 128), 0, cfg.vocab)
    batch = {"tokens": tok}
    h1, _ = M.forward(params, cfg, batch, attn_impl="naive")
    h2, _ = M.forward(params, cfg, batch, attn_impl="pallas")
    np.testing.assert_allclose(np.asarray(h1, np.float32),
                               np.asarray(h2, np.float32),
                               rtol=2e-3, atol=2e-3)
