"""Observability subsystem battery (ISSUE 8 tentpole): the event-sourced
telemetry layer must tell the truth about the scheduler stack.

  * ``Tracer`` ring-buffer mechanics: bounded, drop-counting, seq-monotonic,
    free when disabled;
  * lifecycle state machine: every task's events walk a legal path — no
    lost, duplicated, or out-of-order transitions — across seeded overload
    (preemption + device death + deadline shedding), gang reservation with
    cell death, sharded work stealing, and serve-engine grow/shrink traces;
  * Chrome trace-event export validates, carries per-device tracks, and
    stitches an evicted task's cross-device arc as a flow;
  * the parity differ pinpoints the first divergent decision (and stays
    silent on identical streams);
  * log-bucketed histograms and the event-derived metrics registry.
"""
from _hypothesis_fallback import given, settings, st

from repro.core.cluster import Cluster
from repro.core.scheduler import (
    GangScheduler, MGBAlg3Scheduler, PreemptiveAlg3Scheduler,
    ShardedScheduler,
)
from repro.core.task import Job, ResourceVector, Task, UnitTask
from repro.core.workloads import gang_mix
from repro.obs import events as ev
from repro.obs.events import Tracer, attach_tracer
from repro.obs.export import (
    to_chrome_trace, trace_summary, validate_chrome_trace,
)
from repro.obs.metrics import Histogram, MetricsRegistry, metrics_from_events
from repro.obs.replay import (
    Divergence, admission_order, diff_streams, first_divergence,
    validate_lifecycles,
)

GB = 1024**3


def mk_task(name, mem_gb=2.0, demand=0.5, chips=1, est=1.0):
    vec = ResourceVector(hbm_bytes=int(mem_gb * GB), flops=1e12,
                         bytes_accessed=1e9, est_seconds=est,
                         core_demand=demand, bw_demand=demand, chips=chips)
    return Task(units=[UnitTask(fn=None, memobjs=frozenset({name}),
                                resources=vec, name=name)], name=name)


def mk_job(name, mem_gb=2.0, est=1.0, chips=1):
    return Job(tasks=[mk_task(name, mem_gb=mem_gb, est=est, chips=chips)],
               name=name)


def _assert_sound(tracer, *, require_terminal=True):
    evs = tracer.events()
    assert tracer.dropped == 0
    assert [e.seq for e in evs] == sorted(e.seq for e in evs)
    problems = validate_lifecycles(evs, require_terminal=require_terminal)
    assert not problems, problems
    return evs


# ---------------------------------------------------------------------------
# Tracer ring-buffer mechanics
# ---------------------------------------------------------------------------

def test_tracer_ring_bounds_and_drop_count():
    tr = Tracer(capacity=8, clock=lambda: 0.0)
    for i in range(20):
        tr.emit(ev.SUBMIT, uid=i, name=f"t{i}")
    assert tr.emitted == 20
    assert tr.dropped == 12
    window = tr.events()
    assert len(window) == 8
    # the SURVIVING window is the most recent 8, in seq order
    assert [e.uid for e in window] == list(range(12, 20))


def test_tracer_disabled_is_noop():
    tr = Tracer(capacity=4, enabled=False)
    tr.emit(ev.ADMIT, uid=1)
    assert tr.emitted == 0 and tr.events() == [] and len(tr) == 0


def test_tracer_clock_rebind_followed():
    now = [1.5]
    tr = Tracer(capacity=4, clock=lambda: now[0])
    tr.emit(ev.SUBMIT, uid=1)
    tr.use_clock(lambda: 9.0)
    tr.emit(ev.ADMIT, uid=1)
    ts = [e.t for e in tr.events()]
    assert ts == [1.5, 9.0]


def test_tracer_clear_keeps_sequencing():
    tr = Tracer(capacity=8, clock=lambda: 0.0)
    tr.emit(ev.SUBMIT, uid=1)
    tr.clear()
    tr.emit(ev.ADMIT, uid=1)
    (only,) = tr.events()
    assert only.seq == 1 and only.kind == ev.ADMIT


# ---------------------------------------------------------------------------
# parity differ
# ---------------------------------------------------------------------------

def test_first_divergence_identical_and_mismatch():
    assert first_divergence(["a", "b"], ["a", "b"]) is None
    d = first_divergence(["a", "b", "c"], ["a", "x", "c"])
    assert isinstance(d, Divergence)
    assert (d.index, d.a, d.b) == (1, "b", "x")
    assert "b" in str(d) and "x" in str(d)


def test_first_divergence_flags_length_mismatch():
    d = first_divergence(["a", "b"], ["a"])
    assert d is not None and d.index == 1 and d.b is None


def test_diff_streams_catches_planted_divergence():
    a = Tracer(clock=lambda: 0.0)
    b = Tracer(clock=lambda: 0.0)
    for t in (a, b):
        t.emit(ev.ADMIT, uid=1, name="x", device=0)
    a.emit(ev.ADMIT, uid=2, name="y", device=0)
    b.emit(ev.ADMIT, uid=2, name="z", device=0)
    assert diff_streams(a.events(), a.events()) is None
    d = diff_streams(a.events(), b.events())
    assert d is not None and (d.a, d.b) == ("y", "z")


# ---------------------------------------------------------------------------
# lifecycle soundness over seeded scenario traces
# ---------------------------------------------------------------------------

@settings(max_examples=5, deadline=None)
@given(st.integers(0, 10_000))
def test_lifecycle_sound_under_overload_death_and_shedding(seed):
    """Preemptive scheduler, overload, a mid-run device death + revive,
    deadline shedding: every event path stays legal and terminal."""
    import random
    rng = random.Random(seed)
    c = Cluster(PreemptiveAlg3Scheduler(2), workers=8, backend="sim",
                shed_late=True, trace=True)
    c._sim._failure_pending = (rng.uniform(0.3, 0.8), rng.randrange(2))
    for i in range(10):
        c.submit(mk_job(f"j{i}", mem_gb=rng.choice([4.0, 9.0, 12.0]),
                        est=rng.uniform(0.2, 1.5)),
                 priority=rng.randrange(3),
                 deadline_s=rng.choice([None, 0.5, 2.0, 10.0]))
    c.run_until(2.0)
    c.sched.revive(0)
    c.sched.revive(1)
    c.drain()
    evs = _assert_sound(c.trace)
    assert sum(1 for e in evs if e.kind == ev.SUBMIT) == 10


@settings(max_examples=3, deadline=None)
@given(st.integers(0, 10_000))
def test_lifecycle_sound_for_gangs_with_cell_death(seed):
    """Gang reservations on a 2x4 pod with a cell death mid-trace: reserve/
    release pair up, evicted gang members requeue and terminate legally."""
    c = Cluster(GangScheduler(pods=1, rows=2, cols=4), workers=32,
                backend="sim", trace=True)
    jobs = gang_mix(seed, n_singles=4, n_gangs=4, chip_choices=(2, 4),
                    probe_singles=False)
    c._sim._failure_pending = (0.5, seed % 8)
    for j in jobs:
        c.submit(j)
    c.run_until(3.0)
    c.sched.revive(seed % 8)
    c.drain()
    evs = _assert_sound(c.trace)
    reserves = sum(1 for e in evs if e.kind == ev.GANG_RESERVE)
    releases = sum(1 for e in evs if e.kind == ev.GANG_RELEASE)
    assert reserves > 0
    # every reservation is eventually released (eviction included)
    assert releases == reserves


def test_lifecycle_sound_across_work_stealing():
    """Sharded fleet, completions only on shard 0: stolen waiters show
    park -> steal -> admit and nothing is lost or duplicated."""
    sched = ShardedScheduler(pods=2, rows=2, cols=2)
    tracer = attach_tracer(sched, Tracer())
    admitted = []

    def cb(t, placement, epoch):
        if placement is not None and not isinstance(placement, int):
            placement = placement.lead
        admitted.append((t, placement))
    n_dev = len(sched.devices)
    tasks = [mk_task(f"t{i}", mem_gb=16.0) for i in range(n_dev + 10)]
    for t in tasks:
        sched.admit_or_enqueue(t, cb)
    # completions land only on shard 0 (global devices 0-3): once its
    # local queue drains, further admissions there must be steals
    ended = set()
    guard = 0
    while sched.waiting_count() and guard < 100:
        guard += 1
        vic = next(t for t, p in admitted if p < 4 and t.uid not in ended)
        ended.add(vic.uid)
        sched.task_end(vic)
    for t, _ in admitted:
        if t.uid not in ended:
            sched.task_end(t)
    assert sched.steals > 0
    evs = _assert_sound(tracer)
    steals = [e for e in evs if e.kind == ev.STEAL]
    assert len(steals) >= sched.steals
    # a successful steal crosses shards and re-admits on the target side
    assert all(e.data["src"] != e.data["dst"] for e in steals)


def test_lifecycle_sound_for_serve_grow_shrink():
    """ServeEngine trace: decode-loop residents bind, slots grow and
    shrink; the stream validates and grows pair with shrinks."""
    from repro.serve.engine import SLO, NullModel, ServeEngine
    c = Cluster(MGBAlg3Scheduler(2, hbm_per_device=8 * GB), workers=4,
                backend="sim", trace=True)
    model = NullModel(loop_hbm=2 * GB, slot_hbm=1 * GB,
                      prefill_hbm=GB // 2, prefill_s=0.01, step_s=0.01)
    eng = ServeEngine(c, model, max_batch=2, slo=SLO(600.0, 600.0))
    reqs = [eng.submit(prompt_len=8, gen_len=g) for g in (5, 3, 4, 2, 6)]
    eng.drain(timeout_s=120.0)
    eng.shutdown()
    # loop hosts are released by shutdown; everything must be terminal
    evs = _assert_sound(c.trace)
    grows = sum(1 for e in evs if e.kind == ev.GROW)
    shrinks = sum(1 for e in evs if e.kind == ev.SHRINK)
    assert grows == shrinks == sum(1 for r in reqs if r.gen_len > 1)


# ---------------------------------------------------------------------------
# Chrome trace export
# ---------------------------------------------------------------------------

def _traced_failover_run():
    """Sim run where a task is admitted on device 0, evicted by its death,
    and resumed on device 1 — the cross-device flow fixture."""
    c = Cluster(PreemptiveAlg3Scheduler(2), workers=8, backend="sim",
                trace=True)
    c._sim._failure_pending = (0.5, 0)
    for i in range(6):
        c.submit(mk_job(f"j{i}", mem_gb=12.0, est=1.0), priority=i % 2)
    c.run_until(1.0)
    c.sched.revive(0)
    c.drain()
    return c


def test_chrome_export_validates_with_tracks_and_flows():
    c = _traced_failover_run()
    doc = to_chrome_trace(c.trace.events())
    problems = validate_chrome_trace(doc)
    assert not problems, problems
    s = trace_summary(doc)
    assert s["devices"] == [0, 1]
    assert s["slices"] > 0 and s["counter_samples"] > 0
    # the evicted task's park -> re-admit arc crosses devices as a flow
    assert s["cross_device_flows"] >= 1


def test_chrome_export_validator_rejects_malformed():
    c = _traced_failover_run()
    doc = to_chrome_trace(c.trace.events())
    doc["traceEvents"].append({"ph": "Q", "name": "bogus"})
    assert validate_chrome_trace(doc)


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------

def test_histogram_log_buckets_and_quantiles():
    h = Histogram(least=1e-3, growth=2.0, buckets=16)
    for v in (0.0005, 0.002, 0.002, 0.004, 0.1):
        h.record(v)
    snap = h.snapshot()
    assert snap["n"] == 5
    assert snap["max"] == 0.1
    assert h.quantile(0.0) <= 0.002
    assert h.quantile(0.5) <= 0.008
    assert h.quantile(1.0) == 0.1


def test_metrics_from_events_derives_queueing_delay():
    c = _traced_failover_run()
    reg = metrics_from_events(c.trace.events())
    snap = reg.snapshot()
    assert snap["histograms"]["queueing_delay_s"]["n"] > 0
    assert snap["counters"]["events.admit"] >= 6
    # the device-death migration shows up in eviction cost + migrations
    assert snap["histograms"]["eviction_cost_s"]["n"] >= 1
    assert snap["counters"]["migrations"] >= 1


def test_registry_snapshot_roundtrip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc(3)
    reg.gauge("g").set(1.25)
    reg.hist("h").record(0.5)
    path = tmp_path / "metrics.json"
    reg.save_json(str(path))
    import json
    doc = json.loads(path.read_text())
    assert doc["counters"]["c"] == 3
    assert doc["gauges"]["g"] == 1.25
    assert doc["histograms"]["h"]["n"] == 1


# ---------------------------------------------------------------------------
# flight recorder
# ---------------------------------------------------------------------------

def test_flight_recorder_dumps_on_crash_and_drain(tmp_path):
    from repro.obs.replay import load_flight
    flight = str(tmp_path / "flight.json")
    c = Cluster(MGBAlg3Scheduler(1), workers=2, backend="sim",
                trace=True, flight_path=flight)
    c.submit(mk_job("fits", mem_gb=2.0, est=0.1))
    c.submit(mk_job("never", mem_gb=99.0, est=0.1))   # infeasible -> crash
    c.drain()
    reasons = [r for r, _ in c.flight.dumps]
    assert reasons == ["crash", "drain"]
    for _, path in c.flight.dumps:
        evs = load_flight(path)
        assert any(e.kind == ev.CRASH for e in evs)
    assert admission_order(load_flight(c.flight.dumps[-1][1])) == ["fits"]
