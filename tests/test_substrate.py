"""Substrate tests: checkpoint, compression, straggler, interference, data
pipeline determinism."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import interference
from repro.dist import compression as C
from repro.train import checkpoint as CK
from repro.train.straggler import StragglerDetector


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "b": jnp.zeros((4,), jnp.bfloat16)},
            "step": jnp.int32(3)}


def test_checkpoint_roundtrip():
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        CK.save(d, 10, s)
        step, r = CK.restore(d, s)
        assert step == 10
        np.testing.assert_array_equal(np.asarray(r["params"]["w"]),
                                      np.asarray(s["params"]["w"]))
        assert r["params"]["b"].dtype == jnp.bfloat16


def test_checkpoint_restores_latest_committed():
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        CK.save(d, 1, s)
        CK.save(d, 2, s)
        # a torn write (no COMMITTED marker) must be ignored
        os.makedirs(os.path.join(d, "step_00000099"))
        assert CK.latest_step(d) == 2


def test_checkpoint_prune_keeps_newest():
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        for i in range(5):
            CK.save(d, i, s)
        CK.prune(d, keep=2)
        assert CK.latest_step(d) == 4
        steps = [n for n in os.listdir(d) if n.startswith("step_")]
        assert len(steps) == 2


def test_async_checkpointer_overlap_and_backpressure():
    s = _state()
    with tempfile.TemporaryDirectory() as d:
        ck = CK.AsyncCheckpointer(d, keep=2)
        for i in range(3):
            ck.save(i, s)
        ck.wait()
        assert ck.last_committed == 2
        step, _ = CK.restore(d, s)
        assert step == 2


def test_checkpoint_shape_mismatch_raises():
    with tempfile.TemporaryDirectory() as d:
        CK.save(d, 1, {"w": jnp.zeros((4,))})
        with pytest.raises(AssertionError):
            CK.restore(d, {"w": jnp.zeros((5,))})


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_compression_roundtrip_accuracy():
    g = jax.random.normal(jax.random.PRNGKey(0), (4096,))
    q = C.compress_decompress(g)
    cos = float(jnp.vdot(q, g) / (jnp.linalg.norm(q) * jnp.linalg.norm(g)))
    assert cos > 0.999


@given(seed=st.integers(0, 100), size=st.sampled_from([64, 300, 1024]))
@settings(max_examples=20, deadline=None)
def test_property_quantization_error_bounded(seed, size):
    """Per-element error <= scale/2 = absmax/254 per block."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (size,))
    q = C.compress_decompress(g)
    err = np.abs(np.asarray(q - g))
    bound = np.abs(np.asarray(g)).max() / 127.0
    assert err.max() <= bound * 0.51 + 1e-7


def test_error_feedback_telescopes():
    """Sum of applied (compressed) grads + final error == sum of true grads."""
    key = jax.random.PRNGKey(1)
    g_total = jnp.zeros((512,))
    applied = jnp.zeros((512,))
    err = C.init_error_state({"g": g_total})["g"]
    for i in range(10):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (512,))
        g_total = g_total + g
        q, err = C.apply_with_error_feedback({"g": g}, {"g": err})
        q, err = q["g"], err["g"]
        applied = applied + q
    np.testing.assert_allclose(np.asarray(applied + err),
                               np.asarray(g_total), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# straggler / interference
# ---------------------------------------------------------------------------

def test_straggler_detects_slow_host():
    det = StragglerDetector(4, threshold=1.5)
    for _ in range(10):
        for h in range(4):
            det.record_step(h, 2.0 if h == 1 else 1.0)
    assert det.stragglers() == [1]


def test_straggler_quiet_when_uniform():
    det = StragglerDetector(3)
    for _ in range(10):
        for h in range(3):
            det.record_step(h, 1.0 + 0.01 * h)
    assert det.stragglers() == []


def test_interference_single_resident_free():
    assert interference.slowdown([(0.9, 0.9)]) == 1.0


def test_interference_undersubscribed_cheap():
    s = interference.slowdown([(0.3, 0.3), (0.3, 0.3)])
    assert 1.0 <= s <= 1.02


def test_interference_oversubscription_dilates():
    s = interference.slowdown([(0.8, 0.2), (0.8, 0.2)])
    assert s >= 1.6


@given(st.lists(st.tuples(st.floats(0.01, 1.0), st.floats(0.01, 1.0)),
                min_size=1, max_size=8))
@settings(max_examples=50, deadline=None)
def test_property_interference_monotone(demands):
    """Adding a resident never speeds anyone up."""
    s0 = interference.slowdown(demands)
    s1 = interference.slowdown(demands + [(0.2, 0.2)])
    assert s1 >= s0 - 1e-12


# ---------------------------------------------------------------------------
# data pipeline determinism (fault-tolerance contract)
# ---------------------------------------------------------------------------

def test_pipeline_restart_bit_identical():
    from repro.configs.base import ShapeConfig
    from repro.configs.registry import get_arch
    from repro.data.pipeline import TokenPipeline
    cfg = get_arch("gemma2-9b").reduced()
    shape = ShapeConfig("t", 64, 4, "train")
    p1 = TokenPipeline(cfg, shape, seed=7)
    batches = [p1.batch_at(i) for i in range(5)]
    p2 = TokenPipeline(cfg, shape, seed=7, start_step=3)
    np.testing.assert_array_equal(p2.batch_at(3)["tokens"],
                                  batches[3]["tokens"])
    np.testing.assert_array_equal(p2.batch_at(4)["labels"],
                                  batches[4]["labels"])
