"""Continuous-batching decode serving under the memory-safe scheduler.

The sglang/LightLLM-style front-end over this repo's compiler-guided
fleet: per-device decode loops whose batch composition changes BETWEEN
steps. Requests stream in through ``Cluster.submit`` with SLO deadlines and
split into two task classes:

  * **prefill** — a short, high-priority task (class ``prefill_priority``)
    that ingests the prompt and produces the first token + a batch-1 KV
    cache. It runs through the normal backend (live: real jitted compute on
    the execution pool; sim: virtual-time work) with a TTFT deadline.
  * **decode slot** — a long-lived RESIDENT delta: joining a running batch
    is ``Scheduler.task_grow`` with a probed ResourceVector whose
    ``hbm_bytes`` are the slot's KV-cache footprint (``abstract_cache``, not
    a guess) and whose compute share encodes one batch row. A join that
    would OOM the device — or exceed the loop's row budget — PARKS in the
    same admission queue as everything else and is admitted by the
    ``task_end``/``task_shrink`` freed-capacity drain when a row retires.
    The scheduler's memory-hard guarantee therefore covers batch GROWTH,
    not just task admission.

Each decode loop itself is one long-lived resident task
(``Scheduler.bind_resident``) carrying ``slot_budget = max_batch``: the
scheduler's grow admission — not engine bookkeeping — is what bounds a loop
to ``max_batch`` concurrent rows (`Task.grown_now` vs the budget, settled on
every release path including eviction).

Per-request metrics: TTFT (arrival → first token, i.e. prefill completion)
and TPOT (mean inter-token time over the decode tail), the two serving SLOs
``benchmarks/bench_serve.py`` drives to saturation.

The engine is driven explicitly: ``pump()`` advances every decode loop one
step (live mode — call it in a loop; also what the deterministic live/sim
parity tests use), and ``run_until(t)`` advances a sim-backend cluster's
virtual clock with decode ticks interleaved at the model's step cadence.
"""
from __future__ import annotations

import dataclasses
import enum
import itertools
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.cluster import Cluster, JobHandle, JobStatus
from repro.core.scheduler.base import DEADLINE_SHED, SLOTS, Scheduler
from repro.core.task import Job, ResourceVector, Task, UnitTask
from repro.obs.metrics import MetricsRegistry

_rids = itertools.count()


@dataclasses.dataclass(frozen=True)
class SLO:
    """Per-request service objectives: time-to-first-token and
    time-per-output-token (both seconds)."""
    ttft_s: float = 2.0
    tpot_s: float = 0.2


class RequestStatus(enum.Enum):
    PREFILLING = "prefilling"      # prefill task submitted / running
    WAITING_SLOT = "waiting_slot"  # prefilled; decode-slot join parked
    DECODING = "decoding"          # resident row in a decode loop
    DONE = "done"
    SHED = "shed"                  # deadline shed (prefill or join)
    FAILED = "failed"              # crashed / fleet cannot host it


@dataclasses.dataclass
class ServeRequest:
    """One streaming generation request and its lifecycle timestamps."""
    rid: int
    prompt_len: int
    gen_len: int                   # TOTAL tokens incl. the prefill's first
    arrival_t: float
    status: RequestStatus = RequestStatus.PREFILLING
    tokens: List[int] = dataclasses.field(default_factory=list)
    n_tokens: int = 0
    t_first: float = -1.0          # first token emitted (prefill done)
    t_done: float = -1.0
    error: str = ""
    # internals
    prompt: Any = None             # [1, S] tokens (real model) or None
    cache: Any = None              # batch-1 prefill cache (real model)
    first_token: Optional[int] = None
    slot_task: Optional[Task] = None
    join_epoch: int = 0
    device: Optional[int] = None
    row: Optional[int] = None

    @property
    def ttft_s(self) -> float:
        return self.t_first - self.arrival_t if self.t_first >= 0 else -1.0

    @property
    def tpot_s(self) -> float:
        """Mean inter-token time over the decode tail (0 for 1-token
        requests — there is no tail)."""
        if self.t_done < 0 or self.t_first < 0 or self.n_tokens <= 1:
            return 0.0
        return (self.t_done - self.t_first) / (self.n_tokens - 1)


# ---------------------------------------------------------------------------
# Model backends
# ---------------------------------------------------------------------------

class NullModel:
    """No-compute model backend: synthetic resource vectors and token
    counting only. The scheduler-facing shape is identical to the real
    backend (probed-shaped loop/slot/prefill vectors), so benches and
    live/sim parity tests exercise the full admission machinery without
    paying for kernels."""

    def __init__(self, *, loop_hbm: int = 2 << 30, slot_hbm: int = 1 << 30,
                 prefill_hbm: int = 1 << 30, prefill_s: float = 0.05,
                 step_s: float = 0.025):
        self.loop_hbm = loop_hbm
        self.slot_hbm = slot_hbm
        self.prefill_hbm = prefill_hbm
        self.prefill_s = prefill_s
        self.step_seconds = step_s

    def loop_vec(self, max_batch: int) -> ResourceVector:
        # compute share of the loop base; rows carry 1/SLOTS each. The row
        # CAP is the host task's slot_budget (set by ServeEngine), not this.
        d = (SLOTS - max_batch) / SLOTS
        return ResourceVector(hbm_bytes=self.loop_hbm, flops=0.0,
                              bytes_accessed=0.0, core_demand=d, bw_demand=d)

    def slot_vec(self, req: ServeRequest) -> ResourceVector:
        return ResourceVector(hbm_bytes=self.slot_hbm, flops=0.0,
                              bytes_accessed=0.0, est_seconds=self.step_seconds,
                              core_demand=1 / SLOTS, bw_demand=1 / SLOTS)

    def prefill_vec(self, req: ServeRequest) -> ResourceVector:
        return ResourceVector(hbm_bytes=self.prefill_hbm, flops=0.0,
                              bytes_accessed=0.0, est_seconds=self.prefill_s,
                              core_demand=2 / SLOTS, bw_demand=2 / SLOTS)

    def prefill(self, req: ServeRequest) -> None:
        req.first_token = 0

    def make_loop_state(self, rows: int) -> Any:
        return None

    def adopt(self, state: Any, row: int, req: ServeRequest) -> None:
        pass

    def step(self, state: Any, rows: List[Optional[ServeRequest]]) -> None:
        pass


class JaxModel:
    """Real-model backend: jitted prefill + per-row-position decode over a
    resident batch cache (``models.decode`` slot-wise insert/extract).

    Resource vectors are honest: the prefill vector is probed from the
    compiled prefill executable; the per-slot delta is the request's
    KV-cache bytes from ``abstract_cache``; the loop base is the probed
    full-batch decode footprint minus the rows' share.
    """

    def __init__(self, cfg, params, *, max_batch: int, max_seq: int,
                 attn_impl: str = "flash_jnp"):
        import jax
        import jax.numpy as jnp
        from repro.core.probe import probe_fn
        from repro.models import decode as D
        from repro.serve.decode import abstract_cache, make_prefill_step

        self.cfg = cfg
        self.params = params
        self.max_batch = max_batch
        self.max_seq = max_seq
        self._jnp = jnp
        self._D = D
        self._prefill = jax.jit(make_prefill_step(cfg, attn_impl=attn_impl))

        def _decode(params, cache, tokens, pos):
            return D.decode_step(params, cfg, cache, tokens, pos)

        self._decode = jax.jit(_decode)
        self._insert = jax.jit(D.cache_insert)

        # per-slot KV delta: one row's cache bytes at the loop's max_seq
        row_cache = abstract_cache(cfg, 1, max_seq)
        self.slot_bytes = int(sum(
            int(np_prod(t.shape)) * t.dtype.itemsize
            for t in jax.tree_util.tree_leaves(row_cache)))
        # loop base: probed full-batch decode footprint minus the rows'
        # share (params + workspace — what the loop costs with zero rows)
        full_cache = abstract_cache(cfg, max_batch, max_seq)
        tok_sds = jax.ShapeDtypeStruct((max_batch,), jnp.int32)
        pos_sds = jax.ShapeDtypeStruct((max_batch,), jnp.int32)
        dvec = probe_fn(_decode, params, full_cache, tok_sds, pos_sds)
        self.step_vec = dvec
        self.loop_hbm = max(dvec.hbm_bytes - max_batch * self.slot_bytes, 0)
        self.step_seconds = max(dvec.est_seconds, 1e-4)

    def loop_vec(self, max_batch: int) -> ResourceVector:
        d = (SLOTS - max_batch) / SLOTS
        return dataclasses.replace(self.step_vec, hbm_bytes=self.loop_hbm,
                                   core_demand=d, bw_demand=d)

    def slot_vec(self, req: ServeRequest) -> ResourceVector:
        return ResourceVector(
            hbm_bytes=self.slot_bytes,
            flops=self.step_vec.flops / max(self.max_batch, 1),
            bytes_accessed=self.step_vec.bytes_accessed
            / max(self.max_batch, 1),
            est_seconds=self.step_seconds,
            core_demand=1 / SLOTS, bw_demand=1 / SLOTS)

    def prefill_vec(self, req: ServeRequest) -> ResourceVector:
        from repro.core.probe import probe_fn
        return probe_fn(self._prefill, self.params, {"tokens": req.prompt})

    def prefill(self, req: ServeRequest) -> None:
        import jax
        jnp = self._jnp
        logits, cache = self._prefill(self.params, {"tokens": req.prompt})
        req.first_token = int(jnp.argmax(logits[0]))
        req.cache = jax.tree_util.tree_map(lambda t: t, cache)

    def make_loop_state(self, rows: int) -> Dict[str, Any]:
        import numpy as np
        return {
            "cache": self._D.init_cache(self.cfg, rows, self.max_seq),
            "tokens": np.zeros((rows,), np.int32),
            "pos": np.zeros((rows,), np.int32),
        }

    def adopt(self, state: Dict[str, Any], row: int,
              req: ServeRequest) -> None:
        state["cache"] = self._insert(state["cache"], req.cache, row)
        state["tokens"][row] = req.first_token
        state["pos"][row] = req.prompt_len
        req.cache = None  # adopted: the row owns the KV now

    def step(self, state: Dict[str, Any],
             rows: List[Optional[ServeRequest]]) -> None:
        jnp = self._jnp
        logits, state["cache"] = self._decode(
            self.params, state["cache"],
            jnp.asarray(state["tokens"]), jnp.asarray(state["pos"]))
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        import numpy as np
        nxt = np.asarray(nxt)
        for row, req in enumerate(rows):
            if req is None:
                continue
            state["tokens"][row] = nxt[row]
            state["pos"][row] += 1
            req.tokens.append(int(nxt[row]))


def np_prod(shape: Sequence[int]) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class _Loop:
    device: int
    host: Task
    rows: List[Optional[ServeRequest]]
    state: Any
    pending: List[ServeRequest] = dataclasses.field(default_factory=list)
    # backend timestamp of this loop's previous decode step; -1 while the
    # loop is idle, so per-step TPOT attribution never charges idle gaps
    last_step_t: float = -1.0

    @property
    def n_active(self) -> int:
        return sum(1 for r in self.rows if r is not None)


class ServeEngine:
    """Continuous-batching serving over a ``Cluster`` (either backend).

    One decode loop per device (``loop_devices`` to restrict), each a
    ``bind_resident`` scheduler resident; requests enter via ``submit`` and
    flow prefill → slot join (``task_grow``) → per-step decode → retire
    (``task_shrink``). Joins that would overrun a device park in the
    scheduler's admission queue; ``violations`` counts device-capacity
    breaches observed after any engine action (always 0 under a memory-safe
    scheduler — asserted by bench_serve)."""

    def __init__(self, cluster: Cluster, model, *, max_batch: int = 8,
                 slo: SLO = SLO(), loop_devices: Optional[Sequence[int]] = None,
                 prefill_priority: int = 10, decode_priority: int = 5,
                 metrics_registry: Optional[MetricsRegistry] = None):
        if max_batch < 1 or max_batch >= SLOTS:
            raise ValueError(f"max_batch must be in [1, {SLOTS - 1}]")
        self.cluster = cluster
        # optional obs.metrics sink: per-request ttft_s/tpot_s histograms
        # recorded as requests resolve (streaming — no end-of-run scan)
        self.metrics_registry = metrics_registry
        self.sched: Scheduler = cluster.sched
        self.model = model
        self.max_batch = max_batch
        self.slo = slo
        self.prefill_priority = prefill_priority
        self.decode_priority = decode_priority
        self._lock = threading.Lock()
        self.requests: List[ServeRequest] = []
        self.loops: Dict[int, _Loop] = {}
        self.join_log: List[Tuple[int, int]] = []  # (rid, device) admissions
        self.violations = 0
        self._sim_tick: Optional[float] = None
        devices = list(loop_devices) if loop_devices is not None \
            else [d.index for d in self.sched.devices]
        for d in devices:
            host = Task(
                units=[UnitTask(fn=None,
                                memobjs=frozenset({f"decode-loop/{d}"}),
                                resources=model.loop_vec(max_batch),
                                name=f"decode-loop/{d}")],
                name=f"decode-loop/{d}", priority=decode_priority,
                slot_budget=max_batch)
            if not self.sched.bind_resident(host, d):
                raise RuntimeError(
                    f"device {d} cannot host a decode loop "
                    f"({model.loop_vec(max_batch).hbm_bytes / 1e9:.2f} GB "
                    f"base + {max_batch} rows)")
            self.loops[d] = _Loop(device=d, host=host,
                                  rows=[None] * max_batch,
                                  state=model.make_loop_state(max_batch))
        self._hosts = tuple(lp.host for lp in self.loops.values())
        self._check_capacity()

    # -- submission ---------------------------------------------------------
    def submit(self, *, prompt=None, prompt_len: Optional[int] = None,
               gen_len: int = 16, deadline_s: Optional[float] = None,
               runner_sleep: bool = False) -> ServeRequest:
        """Stream one request in. ``prompt``: [S] or [1, S] token array (real
        backend) — or pass ``prompt_len`` alone for a NullModel. ``gen_len``
        counts ALL output tokens including the prefill's first. The prefill
        task carries ``deadline_s`` (default: the TTFT SLO) for EDF ranking /
        shedding."""
        if prompt is not None and prompt_len is None:
            prompt = prompt.reshape(1, -1) if prompt.ndim == 1 else prompt
            prompt_len = int(prompt.shape[-1])
        req = ServeRequest(rid=next(_rids), prompt_len=int(prompt_len),
                           gen_len=int(gen_len), arrival_t=self.cluster.now,
                           prompt=prompt)
        with self._lock:
            self.requests.append(req)
        vec = self.model.prefill_vec(req)
        task = Task(units=[UnitTask(fn=None,
                                    memobjs=frozenset({f"req/{req.rid}"}),
                                    resources=vec,
                                    name=f"prefill/{req.rid}")],
                    name=f"prefill/{req.rid}")
        job = Job(tasks=[task], name=f"prefill/{req.rid}")

        def runner(device, req=req):
            self.model.prefill(req)

        runners = [runner] if self.cluster.backend == "live" else None
        self.cluster.submit(
            job, runners=runners, priority=self.prefill_priority,
            deadline_s=deadline_s if deadline_s is not None
            else self.slo.ttft_s,
            on_done=lambda h, req=req: self._on_prefill_done(req, h))
        return req

    def _on_prefill_done(self, req: ServeRequest, handle: JobHandle) -> None:
        status = handle.status
        if status is JobStatus.SHED:
            req.status = RequestStatus.SHED
            return
        if status is not JobStatus.DONE:
            req.status = RequestStatus.FAILED
            req.error = handle.job.error or f"prefill {status.value}"
            return
        req.t_first = self.cluster.now
        req.n_tokens = 1
        if self.metrics_registry is not None:
            self.metrics_registry.hist("ttft_s").record(
                req.t_first - req.arrival_t)
        if req.first_token is not None:
            req.tokens.append(req.first_token)
        if req.gen_len <= 1:
            # single-token request: served entirely by prefill — no slot
            req.t_done = req.t_first
            req.status = RequestStatus.DONE
            return
        req.status = RequestStatus.WAITING_SLOT
        self._request_join(req)

    def _request_join(self, req: ServeRequest) -> None:
        """Grow a decode loop by this request's probed slot delta. The join
        deadline is the request's decode-completion budget under the TPOT
        SLO — EDF then hands freed rows to the tightest-budget joiner."""
        vec = self.model.slot_vec(req)
        slot = Task(units=[UnitTask(fn=None,
                                    memobjs=frozenset({f"slot/{req.rid}"}),
                                    resources=vec,
                                    name=f"slot/{req.rid}")],
                    name=f"slot/{req.rid}", priority=self.decode_priority,
                    deadline_t=req.t_first
                    + self.slo.tpot_s * (req.gen_len - 1))
        req.slot_task = slot
        self.sched.task_grow(slot, self._hosts, self._on_slot_admitted(req))

    def _on_slot_admitted(self, req: ServeRequest):
        def cb(task: Task, placement, epoch: int) -> None:
            if placement is DEADLINE_SHED:
                req.status = RequestStatus.SHED
                req.error = "slot join shed past deadline"
                return
            if placement is None:
                req.status = RequestStatus.FAILED
                req.error = "no decode loop can ever host this slot"
                return
            with self._lock:
                if req.status is not RequestStatus.WAITING_SLOT:
                    # stale re-admission (evicted mid-decode and re-grown):
                    # this engine does not migrate KV rows across devices —
                    # release the fresh admission and fail the request
                    stale = True
                else:
                    stale = False
                    req.join_epoch = epoch
                    req.device = placement
                    self.join_log.append((req.rid, placement))
                    self.loops[placement].pending.append(req)
            if stale:
                self.sched.task_shrink(task, epoch=epoch)
                req.status = RequestStatus.FAILED
                req.error = req.error or "decode row evicted (device died)"
            self._check_capacity()
        return cb

    # -- decode loops -------------------------------------------------------
    def _adopt_pending_locked(self, loop: _Loop) -> None:
        while loop.pending:
            req = loop.pending.pop(0)
            row = loop.rows.index(None)  # slot ledger guarantees a free row
            loop.rows[row] = req
            req.row = row
            req.status = RequestStatus.DECODING
            self.model.adopt(loop.state, row, req)

    def pump(self) -> int:
        """Advance every decode loop one step: adopt admitted joins, decode
        one token per active row, retire finished rows (``task_shrink`` —
        which re-drives parked joins/prefills). Returns the number of tokens
        emitted."""
        emitted = 0
        retired: List[ServeRequest] = []
        # per-decode-step TPOT attribution: observed inter-step gap vs the
        # model's predicted step_seconds, fed to an attached calibration
        # store (one attribute read when profiling is off). Deliberately
        # NOT fed to the SLO drift stream — the live busy-loop pumps
        # faster than the step cadence, which is pacing, not drift.
        store = getattr(self.sched, "_calib", None)
        pred_step = self.model.step_seconds
        with self._lock:
            for loop in self.loops.values():
                self._adopt_pending_locked(loop)
                if loop.n_active == 0:
                    loop.last_step_t = -1.0
                    continue
                self.model.step(loop.state, loop.rows)
                now = self.cluster.now
                if loop.last_step_t >= 0:
                    obs_step = now - loop.last_step_t
                    if store is not None:
                        store.note_step(loop.device, pred_step, obs_step)
                    if self.metrics_registry is not None:
                        self.metrics_registry.hist("decode_step_s").record(
                            obs_step)
                loop.last_step_t = now
                for row, req in enumerate(loop.rows):
                    if req is None:
                        continue
                    req.n_tokens += 1
                    emitted += 1
                    if req.n_tokens >= req.gen_len:
                        loop.rows[row] = None
                        req.row = None
                        req.t_done = now
                        req.status = RequestStatus.DONE
                        if self.metrics_registry is not None \
                                and req.n_tokens > 1:
                            self.metrics_registry.hist("tpot_s").record(
                                req.tpot_s)
                        retired.append(req)
        for req in retired:
            # outside the engine lock: the shrink's drain fires join
            # callbacks inline, which re-enter the engine
            self.sched.task_shrink(req.slot_task, epoch=req.join_epoch)
        if retired:
            self._check_capacity()
        return emitted

    # -- drivers ------------------------------------------------------------
    def run_until(self, t: float) -> None:
        """Sim backend: advance the virtual clock to ``t``, pumping every
        decode loop at the model's step cadence between events."""
        step = self.model.step_seconds
        if self._sim_tick is None:
            self._sim_tick = self.cluster.now + step
        while self._sim_tick <= t + 1e-12:
            self.cluster.run_until(self._sim_tick)
            self.pump()
            self._sim_tick += step
        self.cluster.run_until(t)

    def drain(self, timeout_s: float = 300.0) -> None:
        """Run until every submitted request resolves (DONE/SHED/FAILED)."""
        if self.cluster.backend == "sim":
            limit = self.cluster.now + timeout_s
            while self._unresolved() and self.cluster.now < limit:
                self.run_until(min(self.cluster.now
                                   + self.model.step_seconds, limit))
        else:
            deadline = time.monotonic() + timeout_s
            while self._unresolved():
                self.pump()
                if time.monotonic() > deadline:
                    break
                time.sleep(0)
        left = self._unresolved()
        if left:
            raise TimeoutError(
                f"{len(left)} request(s) unresolved after drain "
                f"(first: {left[0].rid} {left[0].status.value})")

    def _unresolved(self) -> List[ServeRequest]:
        terminal = (RequestStatus.DONE, RequestStatus.SHED,
                    RequestStatus.FAILED)
        with self._lock:
            return [r for r in self.requests if r.status not in terminal]

    def shutdown(self) -> None:
        """Release the loop residents (the cluster itself is the caller's)."""
        for loop in self.loops.values():
            self.sched.task_end(loop.host)
        self.loops.clear()

    # -- invariants / metrics ----------------------------------------------
    def _check_capacity(self) -> None:
        # the MEMORY-hard guarantee is the invariant (compute slots may be
        # legitimately oversubscribed under Alg. 3's time-sharing); the
        # per-loop row bound is asserted separately at adopt time
        for dev in self.sched.devices:
            if dev.used_hbm > dev.total_hbm:
                self.violations += 1
            if self.loops.get(dev.index) is not None \
                    and self.loops[dev.index].host.grown_now \
                    > self.max_batch:
                self.violations += 1

    def metrics(self) -> Dict[str, Any]:
        """Aggregate serving metrics over all resolved requests: goodput is
        DONE requests meeting BOTH SLOs per second of trace time."""
        with self._lock:
            reqs = list(self.requests)
        done = [r for r in reqs if r.status is RequestStatus.DONE]
        ttfts = sorted(r.ttft_s for r in done)
        tpots = sorted(r.tpot_s for r in done if r.n_tokens > 1)
        good = [r for r in done if r.ttft_s <= self.slo.ttft_s
                and r.tpot_s <= self.slo.tpot_s]
        t0 = min((r.arrival_t for r in reqs), default=0.0)
        t1 = max((r.t_done for r in done), default=t0)
        span = max(t1 - t0, 1e-9)

        def pct(xs: List[float], p: float) -> float:
            if not xs:
                return 0.0
            i = min(int(p * (len(xs) - 1) + 0.5), len(xs) - 1)
            return xs[i]

        store = getattr(self.sched, "_calib", None)
        step_attr = store.accuracy_report()["serve_steps"] \
            if store is not None else {}
        return {
            "requests": len(reqs),
            "done": len(done),
            "step_attribution": step_attr,
            "shed": sum(1 for r in reqs
                        if r.status is RequestStatus.SHED),
            "failed": sum(1 for r in reqs
                          if r.status is RequestStatus.FAILED),
            "tokens": sum(r.n_tokens for r in done),
            "goodput_rps": len(good) / span,
            "slo_met_rate": len(good) / max(len(done), 1),
            "p50_ttft_s": pct(ttfts, 0.50),
            "p99_ttft_s": pct(ttfts, 0.99),
            "p50_tpot_s": pct(tpots, 0.50),
            "p99_tpot_s": pct(tpots, 0.99),
            "violations": self.violations,
        }
