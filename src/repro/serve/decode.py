"""Serving steps: prefill (context ingestion -> logits + cache) and one-token
decode. These are the "GPU task" bodies for inference workloads.

Ring-cache note: pure-SWA archs (mixtral) keep an O(window) ring buffer; after
a prefill of S tokens the last ``window`` K/V rows are rotated into ring order
(slot = position % window) so decode can continue writing at ``pos % window``.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import decode as D
from repro.models.model import forward, logits_from_hidden


def make_prefill_step(cfg: ArchConfig, *, attn_impl: str = "flash"):
    """prefill(params, batch) -> (last-token logits [B, V], cache).

    Sequence-sharded activations are DISABLED for prefill: inference saves
    nothing for a backward pass, so SP buys no memory here and its per-layer
    gathers only add collective traffic (qwen prefill_32k: 87 GB/device with
    SP vs 13 GB without).
    """
    import dataclasses
    if cfg.seq_shard_activations:
        cfg = dataclasses.replace(cfg, seq_shard_activations=False)

    def prefill(params, batch):
        hidden, _, cache = forward(params, cfg, batch, attn_impl=attn_impl,
                                   collect_cache=True)
        logits = logits_from_hidden(cfg, params, hidden[:, -1:])[:, 0]
        if D.uses_ring(cfg) and "k" in cache:
            w = cfg.sliding_window
            s = hidden.shape[1]
            if s >= w:
                # the last w positions land at ring slots (s-w+i) % w;
                # rolling the tail by s % w puts position p at slot p % w,
                # exactly where decode_step resumes writing (verified
                # slot-by-slot against a pure-decode ring in tests)
                tail = jax.tree_util.tree_map(
                    lambda t: jnp.roll(t[:, :, :, -w:], s % w, axis=3),
                    {"k": cache["k"], "v": cache["v"]})
                cache = tail
            else:
                # ring not yet full: slots 0..s-1 already hold positions
                # 0..s-1 (p % w == p for p < w) — but the ring MODULUS that
                # decode_step uses is the cache's seq dim, so handing back an
                # s-deep cache would wrap the ring at s instead of w. Pad to
                # the full ring size; the empty slots are masked (cache_len)
                # until decode writes them.
                cache = jax.tree_util.tree_map(
                    lambda t: jnp.pad(t, [(0, 0)] * 3 + [(0, w - s)]
                                      + [(0, 0)] * (t.ndim - 4)),
                    {"k": cache["k"], "v": cache["v"]})
        if cfg.kv_cache_dtype == "int8" and "k" in cache \
                and cfg.family != "hybrid":
            from repro.models.layers import quantize_kv
            kq, ks = quantize_kv(cache["k"])
            vq, vs = quantize_kv(cache["v"])
            cache = {"k": kq, "v": vq, "k_s": ks, "v_s": vs}
        return logits, cache

    return prefill


def make_serve_step(cfg: ArchConfig):
    """serve_step(params, cache, tokens [B], pos) -> (logits [B,V], cache).

    One new token against a KV/SSM cache — the ``decode_*``/``long_*`` shapes
    lower THIS function, not train_step.
    """

    def serve_step(params, cache, tokens, pos):
        return D.decode_step(params, cfg, cache, tokens, pos)

    return serve_step


def greedy_generate(cfg: ArchConfig, params, cache, first_tokens, start_pos,
                    num_steps: int):
    """Greedy generation loop (lax.scan over steps) for the examples.

    ``num_steps=0`` (a gen_len-1 request) is a valid degenerate call and
    returns an empty [B, 0] token block with the cache untouched.
    """
    if num_steps <= 0:
        b = first_tokens.shape[0]
        return jnp.zeros((b, 0), jnp.int32), cache
    serve = make_serve_step(cfg)

    def body(carry, _):
        tokens, pos, cache = carry
        logits, cache = serve(params, cache, tokens, pos)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return (nxt, pos + 1, cache), nxt

    (_, _, cache), toks = jax.lax.scan(
        body, (first_tokens, jnp.asarray(start_pos, jnp.int32), cache),
        None, length=num_steps)
    return jnp.moveaxis(toks, 0, 1), cache  # [B, num_steps]


def abstract_cache(cfg: ArchConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16):
    return jax.eval_shape(
        functools.partial(D.init_cache, cfg, batch, max_seq, dtype))
