"""Sharded AdamW with dtype-configurable moments, global-norm clipping and a
warmup-cosine schedule. Moment tensors inherit the parameter PartitionSpecs, so
optimizer state is FSDP+TP sharded exactly like the weights.

For >=100B-param archs the configs select bfloat16 moments (DESIGN.md §5): with
16 GB/chip v5e HBM, fp32 moments alone would not fit at 256 chips.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

OptState = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    moment_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10000


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    frac = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * jnp.clip(frac, 0.0, 1.0)))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(cfg: AdamWConfig, params) -> OptState:
    dt = jnp.dtype(cfg.moment_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree_util.tree_map(zeros, params),
        "nu": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state: OptState
                  ) -> Tuple[Any, OptState, Dict[str, jax.Array]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    b1t = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2t = 1 - cfg.b2 ** step.astype(jnp.float32)
    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu_n = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu_n = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        mu_hat = mu_n / b1t
        nu_hat = nu_n / b2t
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_n = p.astype(jnp.float32) - lr * delta
        return p_n.astype(p.dtype), mu_n.astype(mdt), nu_n.astype(mdt)

    flat = jax.tree_util.tree_map(upd, params, grads, state["mu"], state["nu"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], flat,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree_util.tree_map(lambda t: t[1], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree_util.tree_map(lambda t: t[2], flat,
                                    is_leaf=lambda x: isinstance(x, tuple))
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"mu": new_mu, "nu": new_nu, "step": step}, metrics
