"""Deterministic synthetic token pipeline with host-side prefetch.

Produces next-token-prediction batches for any arch/shape; for frontend-stub
archs ([vlm]/[audio]) it also emits precomputed frame/patch embeddings. The
pipeline is seeded and step-indexed, so restarts resume bit-identically from a
checkpointed step (fault-tolerance contract, tested in test_fault_tolerance).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig


class TokenPipeline:
    def __init__(self, cfg: ArchConfig, shape: ShapeConfig, *, seed: int = 0,
                 start_step: int = 0, batch_override: Optional[int] = None,
                 seq_override: Optional[int] = None):
        self.cfg = cfg
        self.shape = shape
        self.seed = seed
        self.step = start_step
        self.batch = batch_override or shape.global_batch
        self.seq = seq_override or shape.seq_len

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng((self.seed, step))

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = self._rng(step)
        tokens = rng.integers(0, self.cfg.vocab,
                              (self.batch, self.seq), dtype=np.int32)
        labels = np.roll(tokens, -1, axis=1)
        out = {"tokens": tokens, "labels": labels}
        if self.cfg.embedding_frontend_stub:
            # modality frontend stub: pretend an encoder produced embeddings
            out["embeds"] = rng.standard_normal(
                (self.batch, self.seq, self.cfg.d_model),
                dtype=np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            b = self.batch_at(self.step)
            self.step += 1
            yield b


class Prefetcher:
    """Host-side prefetch thread: overlaps batch synthesis with device compute."""

    def __init__(self, pipeline: TokenPipeline, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._it = iter(pipeline)
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _fill(self):
        for batch in self._it:
            if self._stop.is_set():
                return
            self._q.put(batch)

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass


def shard_batch(batch: Dict[str, np.ndarray], shardings) -> Dict[str, jax.Array]:
    return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}
