"""Mixtral 8x7B — MoE, 8 experts top-2, sliding-window attention. [arXiv:2401.04088]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    moe=MoEConfig(num_experts=8, top_k=2),
    sliding_window=4096,
    mlp_act="silu_gated",
    rope_theta=1e6,
    optimizer_moment_dtype="float32",
    remat_policy="full",
    seq_shard_activations=True,
    num_microbatches=4,
)
