"""InternVL2 76B — VLM; InternLM2 decoder backbone, ViT frontend stubbed.
[arXiv:2404.16821]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    mlp_act="silu_gated",
    embedding_frontend_stub=True,
    rope_theta=1e6,
    optimizer_moment_dtype="bfloat16",
    remat_policy="full",
    seq_shard_activations=True,
    num_microbatches=4,
    kv_cache_dtype="int8",
)
