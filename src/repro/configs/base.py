"""Architecture configuration system.

Every assigned architecture is expressed as an ``ArchConfig``. The model code in
``repro.models`` is driven entirely by this dataclass, so adding an architecture is
config-only. ``reduced()`` produces the CPU smoke-test variant of the same family.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# Layer kinds used by the hybrid/SSM families.
ATTN = "attn"           # attention + mlp block
MAMBA1 = "mamba1"       # Mamba-1 block (attention-free)
MAMBA2 = "mamba2"       # Mamba-2 (SSD) block
SHARED_ATTN = "shared_attn"  # zamba2: shared-weight attention block


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    # capacity factor for GShard-style dispatch (tokens per expert =
    # capacity_factor * tokens * top_k / num_experts)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int          # N: per-channel state size
    conv_width: int = 4
    expand: int = 2         # d_inner = expand * d_model
    headdim: int = 64       # mamba2 head dim (P)
    chunk: int = 256        # mamba2 SSD chunk length


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str             # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int            # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0       # 0 -> d_model // n_heads
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Attention options
    qkv_bias: bool = False
    sliding_window: int = 0          # 0 = full attention
    local_global_alternate: bool = False  # gemma2: even layers local, odd global
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    # MLP
    mlp_act: str = "silu_gated"      # silu_gated | squared_relu | gelu_gated
    # Hybrid layout (zamba2): one shared attn block applied every k mamba blocks
    hybrid_shared_every: int = 0
    # Embedding frontend stub for [vlm]/[audio]: inputs are precomputed embeddings
    embedding_frontend_stub: bool = False
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # memory policy (per §5 of DESIGN.md)
    optimizer_moment_dtype: str = "float32"   # bf16 for >=100B archs
    remat_policy: str = "nothing"             # nothing | dots | full
    # gradient-accumulation microbatches for the production train step (the
    # live activation set shrinks by this factor; SSM archs use this instead
    # of sequence-sharded activations, which fight the seq-dim scan)
    num_microbatches: int = 1
    # KV-cache storage dtype for decode ("bfloat16" | "int8"). int8 stores
    # per-(position, head) absmax scales alongside and dequantizes at the
    # attention read — halves the decode-task HBM footprint, which doubles
    # how many decode jobs the paper's scheduler can pack per chip
    kv_cache_dtype: str = "bfloat16"
    # Megatron-style sequence parallelism for the residual stream: the carry
    # between layers is sharded [batch->data, seq->model], so the remat-saved
    # activation stack shrinks by the model-axis size (all-gather at layer
    # entry / reduce-scatter at exit, inserted by GSPMD).
    seq_shard_activations: bool = False

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        assert self.n_heads > 0
        return self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind for the full stack."""
        if self.family == "ssm":
            return (MAMBA1,) * self.n_layers
        if self.family == "hybrid":
            kinds = []
            k = self.hybrid_shared_every or 6
            for i in range(self.n_layers):
                kinds.append(SHARED_ATTN if (i % k == k - 1) else MAMBA2)
            return tuple(kinds)
        return (ATTN,) * self.n_layers

    def param_count(self) -> int:
        """Analytic parameter count (total, incl. all experts)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim if self.n_heads else 0
        total = v * d  # embed
        if not self.tie_embeddings:
            total += v * d
        for kind in self.layer_kinds():
            if kind == ATTN:
                attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                    + (self.n_heads * hd) * d
                if self.moe is not None:
                    mlp = self.moe.num_experts * self.mlp_params_per_expert() \
                        + d * self.moe.num_experts  # router
                else:
                    mlp = self.mlp_params_per_expert()
                total += attn + mlp + 2 * d
            elif kind in (MAMBA1, MAMBA2):
                assert self.ssm is not None
                e = self.ssm.expand * d
                n = self.ssm.state_dim
                if kind == MAMBA1:
                    # in_proj (2e), conv, x_proj(dt,B,C), dt_proj, out_proj, A, D
                    total += d * 2 * e + e * self.ssm.conv_width \
                        + e * (n * 2 + e // 16) + (e // 16) * e + e * d + e * n + e
                else:
                    nh = e // self.ssm.headdim
                    total += d * (2 * e + 2 * n + nh) + e * self.ssm.conv_width \
                        + e * d + 2 * nh
                total += d
            elif kind == SHARED_ATTN:
                total += 2 * d  # norms only; weights shared (counted once below)
        if self.family == "hybrid":
            hd2 = self.resolved_head_dim
            total += self.d_model * (self.n_heads * hd2) * 2 \
                + 2 * self.d_model * (self.n_kv_heads * hd2) \
                + self.mlp_params_per_expert()
        return total

    def mlp_params_per_expert(self) -> int:
        d, f = self.d_model, self.d_ff
        if self.mlp_act.endswith("gated"):
            return 3 * d * f
        return 2 * d * f

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top_k experts)."""
        if self.moe is None:
            return self.param_count()
        inactive = (self.moe.num_experts - self.moe.top_k) * \
            self.mlp_params_per_expert() * self.n_layers
        return self.param_count() - inactive

    def reduced(self) -> "ArchConfig":
        """Small same-family variant for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 4 if self.family != "hybrid" else 6),
            d_model=128,
            n_heads=min(self.n_heads, 4) if self.n_heads else 0,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            head_dim=32 if self.n_heads else 0,
            d_ff=256 if self.d_ff else 0,
            vocab=512,
            moe=None if self.moe is None else dataclasses.replace(
                self.moe, num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2)),
            ssm=None if self.ssm is None else dataclasses.replace(
                self.ssm, state_dim=min(self.ssm.state_dim, 16), headdim=32,
                chunk=32),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            hybrid_shared_every=3 if self.hybrid_shared_every else 0,
            optimizer_moment_dtype="float32",
            remat_policy="nothing",
            num_microbatches=1,
            seq_shard_activations=False,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}
