"""Falcon-Mamba 7B — pure Mamba-1, attention-free. [arXiv:2410.05355]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=65024,
    ssm=SSMConfig(state_dim=16, expand=2),
    optimizer_moment_dtype="float32",
    remat_policy="full",
    num_microbatches=8,
)
