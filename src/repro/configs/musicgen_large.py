"""MusicGen-large — decoder-only over EnCodec tokens; frame-embedding frontend stub.
[arXiv:2306.05284]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    mlp_act="gelu_gated",
    embedding_frontend_stub=True,
    optimizer_moment_dtype="float32",
    remat_policy="full",
    seq_shard_activations=True,
    kv_cache_dtype="int8",
)
