"""Gemma-2 9B — local(4096)+global alternating attention, logit softcaps.
[arXiv:2408.00118]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-9b",
    family="dense",
    n_layers=42,
    d_model=3584,
    n_heads=16,
    n_kv_heads=8,
    d_ff=14336,
    vocab=256000,
    head_dim=256,
    sliding_window=4096,
    local_global_alternate=True,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    mlp_act="gelu_gated",
    tie_embeddings=True,
    optimizer_moment_dtype="float32",
    remat_policy="full",
    seq_shard_activations=True,
    kv_cache_dtype="int8",
)
