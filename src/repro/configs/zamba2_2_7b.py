"""Zamba2 2.7B — hybrid: Mamba2 backbone + shared attention block. [arXiv:2411.15242]"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab=32000,
    ssm=SSMConfig(state_dim=64, headdim=64),
    hybrid_shared_every=6,
    mlp_act="gelu_gated",
    optimizer_moment_dtype="float32",
    remat_policy="full",
    num_microbatches=4,
)
