"""Registry of the assigned architectures (``--arch <id>``)."""
from __future__ import annotations

from typing import Dict

from repro.configs.base import (
    ALL_SHAPES, ArchConfig, SHAPES_BY_NAME, ShapeConfig,
)
from repro.configs import (
    mixtral_8x7b, dbrx_132b, internvl2_76b, musicgen_large, nemotron_4_340b,
    llama3_405b, gemma2_9b, qwen1_5_32b, zamba2_2_7b, falcon_mamba_7b,
)

ARCHS: Dict[str, ArchConfig] = {
    c.name: c for c in [
        mixtral_8x7b.CONFIG,
        dbrx_132b.CONFIG,
        internvl2_76b.CONFIG,
        musicgen_large.CONFIG,
        nemotron_4_340b.CONFIG,
        llama3_405b.CONFIG,
        gemma2_9b.CONFIG,
        qwen1_5_32b.CONFIG,
        zamba2_2_7b.CONFIG,
        falcon_mamba_7b.CONFIG,
    ]
}

# long_500k applicability (see DESIGN.md §Arch-applicability / long_500k):
# run only for sub-quadratic-per-token archs with bounded/shardable cache.
LONG_OK = frozenset({"falcon-mamba-7b", "zamba2-2.7b", "mixtral-8x7b"})


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES_BY_NAME[name]


def cells():
    """All 40 (arch, shape) cells with applicability flag."""
    out = []
    for arch in ARCHS.values():
        for shape in ALL_SHAPES:
            skip = shape.name == "long_500k" and arch.name not in LONG_OK
            out.append((arch, shape, skip))
    return out
