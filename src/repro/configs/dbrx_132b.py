"""DBRX 132B — fine-grained MoE, 16 experts top-4. [hf:databricks/dbrx-base]"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="dbrx-132b",
    family="moe",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=10752,
    vocab=100352,
    moe=MoEConfig(num_experts=16, top_k=4),
    mlp_act="silu_gated",
    rope_theta=5e5,
    optimizer_moment_dtype="bfloat16",
    remat_policy="full",
    seq_shard_activations=True,
    num_microbatches=4,
    kv_cache_dtype="int8",
)
