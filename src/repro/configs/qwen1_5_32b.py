"""Qwen1.5 32B — dense, QKV bias. [hf:Qwen/Qwen1.5-0.5B family]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
    mlp_act="silu_gated",
    rope_theta=1e6,
    optimizer_moment_dtype="float32",
    remat_policy="full",
    num_microbatches=4,
    seq_shard_activations=True,
    kv_cache_dtype="int8",
)
