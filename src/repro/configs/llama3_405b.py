"""Llama-3 405B — dense, GQA kv=8, 128k vocab. [arXiv:2407.21783]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53248,
    vocab=128256,
    mlp_act="silu_gated",
    rope_theta=5e5,
    optimizer_moment_dtype="bfloat16",
    remat_policy="full",
    seq_shard_activations=True,
    num_microbatches=16,
    kv_cache_dtype="int8",
)
