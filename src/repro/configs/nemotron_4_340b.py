"""Nemotron-4 340B — dense, GQA kv=8, squared-ReLU MLP (ungated). [arXiv:2402.16819]"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="nemotron-4-340b",
    family="dense",
    n_layers=96,
    d_model=18432,
    n_heads=96,
    n_kv_heads=8,
    d_ff=73728,
    vocab=256000,
    mlp_act="squared_relu",
    optimizer_moment_dtype="bfloat16",
    remat_policy="full",
    seq_shard_activations=True,
    num_microbatches=16,
    kv_cache_dtype="int8",
)
