"""Core transformer layers: RMSNorm, RoPE, GQA attention (full / sliding-window /
softcap / bias), memory-efficient chunked ("flash") attention in pure jnp, and MLP
variants (silu-gated, gelu-gated, squared-ReLU).

Everything is purely functional: params are nested dicts of jnp arrays.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)  # [head_dim/2]


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, head_dim]; positions: [..., S] (broadcastable).

    Interleaved-pair convention: pairs are ADJACENT lanes (2i, 2i+1), so a
    head_dim sharded over the ``model`` mesh axis never splits a rotation pair
    across shards (halved-dim rope forces a cross-shard reshuffle per layer —
    observed as SPMD "involuntary full rematerialization").
    """
    half = x.shape[-1] // 2
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    xr = x.astype(jnp.float32).reshape(x.shape[:-1] + (half, 2))
    x1, x2 = xr[..., 0], xr[..., 1]
    out = jnp.stack([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def softcap(x: jax.Array, cap: float) -> jax.Array:
    return cap * jnp.tanh(x / cap) if cap else x


# ---------------------------------------------------------------------------
# Attention (jnp reference + chunked flash)
# ---------------------------------------------------------------------------

def _repeat_kv(k: jax.Array, n_q: int) -> jax.Array:
    """[B, Hkv, S, D] -> [B, Hq, S, D] by repeating each KV head.

    GQA via broadcast of the (model-axis-replicated) KV heads keeps the query
    heads dim intact, so its ``model`` sharding survives the attention einsums
    with zero resharding (a q reshape to [Hkv, G] splits the sharded dim).
    """
    b, hkv, s, d = k.shape
    if hkv == n_q:
        return k
    k = jnp.broadcast_to(k[:, :, None], (b, hkv, n_q // hkv, s, d))
    return k.reshape(b, n_q, s, d)


def attention_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
                   window, k_len: Optional[jax.Array] = None) -> jax.Array:
    """Boolean [.., Sq, Sk] mask; True = attend.

    ``window`` may be a python int or a traced scalar (gemma2 alternates the
    window per layer inside a scan); <= 0 means no windowing.
    """
    m = jnp.ones(q_pos.shape + k_pos.shape, dtype=bool)
    delta = q_pos[:, None] - k_pos[None, :]
    if causal:
        m &= delta >= 0
    if window is not None:
        w = jnp.asarray(window)
        m &= (w <= 0) | (delta < w)
    if k_len is not None:
        m &= k_pos[None, :] < k_len
    return m


def naive_attention(q, k, v, *, causal=True, window=0, logit_softcap=0.0,
                    q_offset=0, k_len=None):
    """Oracle attention. q: [B, Hq, Sq, D]; k, v: [B, Hkv, Sk, D]."""
    b, hq, sq, d = q.shape
    sk = k.shape[2]
    scale = 1.0 / math.sqrt(d)
    kr = _repeat_kv(k, hq).astype(jnp.float32)
    vr = _repeat_kv(v, hq).astype(jnp.float32)
    scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kr) * scale
    scores = softcap(scores, logit_softcap)
    q_pos = q_offset + jnp.arange(sq)
    k_pos = jnp.arange(sk)
    mask = attention_mask(q_pos, k_pos, causal=causal, window=window, k_len=k_len)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, vr)
    return out.astype(q.dtype)


def flash_attention_jnp(q, k, v, *, causal=True, window=0, logit_softcap=0.0,
                        q_offset=0, block_k: int = 512):
    """Memory-efficient attention: lax.scan over KV blocks with online softmax.

    Never materialises the [Sq, Sk] score matrix for the full sequence — peak
    live memory is O(Sq * block_k). This is the production train/prefill path
    (and the shape-semantics model for the Pallas kernel in repro.kernels).
    """
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    if sk % block_k:
        pad = block_k - sk % block_k
        kpad = [(0, 0), (0, 0), (0, pad), (0, 0)]
        k = jnp.pad(k, kpad)
        v = jnp.pad(v, kpad)
        sk_p = sk + pad
    else:
        sk_p = sk
    nblocks = sk_p // block_k
    scale = 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32)  # [B,Hq,Sq,D]
    q_pos = q_offset + jnp.arange(sq)

    kb = k.reshape(b, hkv, nblocks, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nblocks, block_k, d).transpose(2, 0, 1, 3, 4)

    def body(carry, blk):
        acc, m_prev, l_prev, j = carry
        kj, vj = blk  # [B,Hkv,block_k,D]
        kj = _repeat_kv(kj, hq).astype(jnp.float32)
        vj = _repeat_kv(vj, hq).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kj) * scale
        s = softcap(s, logit_softcap)
        k_pos = j * block_k + jnp.arange(block_k)
        mask = attention_mask(q_pos, k_pos, causal=causal, window=window,
                              k_len=jnp.asarray(sk))
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vj)
        return (acc, m_new, l_new, j + 1), None

    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    (acc, _, l, _), _ = jax.lax.scan(body, (acc0, m0, l0, 0), (kb, vb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.astype(q.dtype)


def _flash_fwd_scan(q, k, v, window, *, causal, logit_softcap, q_offset,
                    block_k, sk_valid):
    """Online-softmax forward over KV blocks; returns (o f32, lse f32)."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    sk_p = k.shape[2]
    nblocks = sk_p // block_k
    scale = 1.0 / math.sqrt(d)
    qf = q.astype(jnp.float32)
    q_pos = q_offset + jnp.arange(sq)
    kb = k.reshape(b, hkv, nblocks, block_k, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hkv, nblocks, block_k, d).transpose(2, 0, 1, 3, 4)

    def body(carry, blk):
        acc, m_prev, l_prev, j = carry
        kj, vj = blk
        kj = _repeat_kv(kj, hq).astype(jnp.float32)
        vj = _repeat_kv(vj, hq).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kj) * scale
        s = softcap(s, logit_softcap)
        k_pos = j * block_k + jnp.arange(block_k)
        mask = attention_mask(q_pos, k_pos, causal=causal, window=window,
                              k_len=jnp.asarray(sk_valid))
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vj)
        return (acc, m_new, l_new, j + 1), None

    acc0 = jnp.zeros((b, hq, sq, d), jnp.float32)
    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    (acc, m, l, _), _ = jax.lax.scan(body, (acc0, m0, l0, 0), (kb, vb))
    l_safe = jnp.maximum(l, 1e-30)
    o = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    return o, lse


import functools


@functools.lru_cache(maxsize=256)
def _make_flash_cvjp(causal: bool, logit_softcap: float, q_offset: int,
                     block_k: int, sk_valid: int):
    """Flash attention with RECOMPUTE backward (custom_vjp).

    Plain AD of the forward scan stacks the [B,H,Sq,block_k] probability
    blocks over all KV blocks for the transpose pass — observed 11 GB/device
    at gemma2 train_4k. The FlashAttention backward instead saves only
    (q, k, v, o, lse) and regenerates each block's scores in the reverse
    sweep. ``window`` stays an OPERAND (gemma2 alternates it per layer inside
    a scan, so it can be a tracer).
    """

    @jax.custom_vjp
    def flash(q, k, v, window):
        o, _ = _flash_fwd_scan(q, k, v, window, causal=causal,
                               logit_softcap=logit_softcap, q_offset=q_offset,
                               block_k=block_k, sk_valid=sk_valid)
        return o.astype(q.dtype)

    def fwd(q, k, v, window):
        o, lse = _flash_fwd_scan(q, k, v, window, causal=causal,
                                 logit_softcap=logit_softcap,
                                 q_offset=q_offset, block_k=block_k,
                                 sk_valid=sk_valid)
        o16 = o.astype(q.dtype)
        return o16, (q, k, v, window, o16, lse)

    def bwd(res, do):
        q, k, v, window, o, lse = res
        b, hq, sq, d = q.shape
        hkv = k.shape[1]
        g = hq // hkv
        sk_p = k.shape[2]
        nblocks = sk_p // block_k
        scale = 1.0 / math.sqrt(d)
        qf = q.astype(jnp.float32)
        dof = do.astype(jnp.float32)
        of = o.astype(jnp.float32)
        delta = jnp.sum(dof * of, axis=-1)  # [B,Hq,Sq]
        q_pos = q_offset + jnp.arange(sq)
        kb = k.reshape(b, hkv, nblocks, block_k, d).transpose(2, 0, 1, 3, 4)
        vb = v.reshape(b, hkv, nblocks, block_k, d).transpose(2, 0, 1, 3, 4)

        def body(dq, blk):
            kj, vj, j = blk
            kjr = _repeat_kv(kj, hq).astype(jnp.float32)
            vjr = _repeat_kv(vj, hq).astype(jnp.float32)
            s_pre = jnp.einsum("bhqd,bhkd->bhqk", qf, kjr) * scale
            s = softcap(s_pre, logit_softcap)
            k_pos = j * block_k + jnp.arange(block_k)
            mask = attention_mask(q_pos, k_pos, causal=causal, window=window,
                                  k_len=jnp.asarray(sk_valid))
            s = jnp.where(mask, s, NEG_INF)
            p = jnp.exp(s - lse[..., None])                      # [B,Hq,Sq,K]
            dv = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
            dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vjr)
            ds = p * (dp - delta[..., None])
            if logit_softcap:
                ds = ds * (1.0 - jnp.square(s / logit_softcap))
            ds = jnp.where(mask, ds, 0.0)
            dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kjr) * scale
            dk = jnp.einsum("bhqk,bhqd->bhkd", ds, qf) * scale
            # fold grouped-query heads back onto KV heads
            dkh = dk.reshape(b, hkv, g, block_k, d).sum(axis=2)
            dvh = dv.reshape(b, hkv, g, block_k, d).sum(axis=2)
            return dq, (dkh, dvh)

        dq0 = jnp.zeros((b, hq, sq, d), jnp.float32)
        dq, (dks, dvs) = jax.lax.scan(body, dq0,
                                      (kb, vb, jnp.arange(nblocks)))
        dk = dks.transpose(1, 2, 0, 3, 4).reshape(b, hkv, sk_p, d)
        dv = dvs.transpose(1, 2, 0, 3, 4).reshape(b, hkv, sk_p, d)
        dwin = np.zeros((), jax.dtypes.float0)
        # pin cotangent head sharding: custom_vjp hides the forward pins
        # from GSPMD, and unpinned dq/dk/dv make the wq/wk/wv gradient
        # einsums produce UNSHARDED f32 dW (1 GB/layer/device at llama3)
        from repro.dist.sharding import constrain
        dq = constrain(dq, "batch", "model", None, None)
        return (dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype),
                dwin)

    flash.defvjp(fwd, bwd)
    return flash


def flash_attention_cvjp(q, k, v, *, causal=True, window=0, logit_softcap=0.0,
                         q_offset=0, block_k: int = 512):
    """Production flash attention: memory-efficient forward AND backward."""
    sk = k.shape[2]
    if sk % block_k:
        pad = block_k - sk % block_k
        kpad = [(0, 0), (0, 0), (0, pad), (0, 0)]
        k = jnp.pad(k, kpad)
        v = jnp.pad(v, kpad)
    fn = _make_flash_cvjp(causal, float(logit_softcap), int(q_offset),
                          int(min(block_k, k.shape[2])), int(sk))
    win = jnp.asarray(-1 if window is None else window, jnp.int32)
    return fn(q, k, v, win)


def _decode_valid_mask(smax, cache_len, window):
    """[B or 1, Smax] bool mask of attendable cache slots. ``cache_len`` may
    be a scalar (whole batch at one position — the classic decode loop) or a
    per-row [B] vector (continuous batching: each resident request sits at
    its own position)."""
    cl = jnp.reshape(jnp.asarray(cache_len), (-1, 1))  # [B or 1, 1]
    k_pos = jnp.arange(smax)[None, :]                  # [1, Smax]
    valid = k_pos < cl
    if window is not None:
        w = jnp.asarray(window)
        valid &= (w <= 0) | (k_pos >= cl - w)
    return valid


def decode_attention(q, k_cache, v_cache, cache_len, *, window=0,
                     logit_softcap=0.0):
    """One-token decode. q: [B, Hq, 1, D]; caches: [B, Hkv, Smax, D].

    ``cache_len`` is the number of valid cache entries (the new token's K/V
    must already be written at position cache_len - 1) — a scalar, or a [B]
    vector when rows of a continuously-batched decode sit at different
    sequence positions.

    GQA is contracted GROUPED — q reshaped to [B, Hkv, G, D] — so the KV
    cache is never materialized repeated to Hq heads, and the einsums read
    the cache in its stored dtype with f32 ACCUMULATION
    (preferred_element_type) instead of an f32 copy. At llama3 decode_32k
    the old path peaked 382 GB/device; this one reads the cache once.
    """
    b, hq, _, d = q.shape
    hkv, smax = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q[:, :, 0, :].reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, logit_softcap)
    valid = _decode_valid_mask(smax, cache_len, window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, 1, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# int8 KV cache (beyond-paper: halves the decode task's HBM footprint)
# ---------------------------------------------------------------------------

def quantize_kv(x: jax.Array, scale_dtype=jnp.bfloat16):
    """x: [..., D] -> (int8 codes [..., D], scales [...]).

    Per-(position, head) absmax scaling: k = k_q * scale, exact within one
    int8 ulp per lane. D stays contiguous so the dequant fuses into the
    attention contraction's operand load on TPU.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax / 127.0, 1e-8)
    codes = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    return codes, scale.astype(scale_dtype)


def decode_attention_q8(q, k_q, k_s, v_q, v_s, cache_len, *, window=0,
                        logit_softcap=0.0):
    """One-token decode over an int8 cache.

    q: [B, Hq, 1, D]; k_q/v_q: int8 [B, Hkv, Smax, D]; k_s/v_s: [B, Hkv,
    Smax]. The scales factor OUT of the contractions —
    ``q·k = (q·k_q)·k_s`` and ``Σ p·v = Σ (p·v_s)·v_q`` — so the int8 codes
    are the only cache-sized operand either einsum reads.
    """
    b, hq, _, d = q.shape
    hkv, smax = k_q.shape[1], k_q.shape[2]
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    qg = q[:, :, 0, :].reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_q.astype(q.dtype),
                   preferred_element_type=jnp.float32)
    s = s * k_s[:, :, None, :].astype(jnp.float32) * scale
    s = softcap(s, logit_softcap)
    valid = _decode_valid_mask(smax, cache_len, window)
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    pv = (p * v_s[:, :, None, :].astype(jnp.float32)).astype(q.dtype)
    out = jnp.einsum("bhgk,bhkd->bhgd", pv, v_q.astype(q.dtype),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, hq, 1, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp_apply(p: dict, x: jax.Array, act: str) -> jax.Array:
    """x: [..., d]. p: {'wi': [d,f], 'wo': [f,d], optional 'wg': [d,f]}.

    The hidden activation is PINNED to [batch->data, ..., f->model]: with
    sequence-sharded residuals GSPMD otherwise keeps S on ``model`` through
    the MLP and computes the wi/wo gradients UNSHARDED (observed 3.25 GB
    f32[53248,16384] per layer per device at llama3 train_4k). Pinning f on
    ``model`` makes the einsums Megatron-TP shaped in both passes.
    """
    from repro.dist.sharding import constrain
    pin = (("batch",) + (None,) * (x.ndim - 2) + ("model",))
    if act == "silu_gated":
        h = jax.nn.silu(x @ p["wi"]) * (x @ p["wg"])
    elif act == "gelu_gated":
        h = jax.nn.gelu(x @ p["wi"]) * (x @ p["wg"])
    elif act == "squared_relu":
        h = jnp.square(jax.nn.relu(x @ p["wi"]))
    else:
        raise ValueError(f"unknown mlp act {act!r}")
    h = constrain(h, *pin)
    return h @ p["wo"]
