"""Composable decoder model covering all assigned architecture families.

Design:
  * params are nested dicts of jnp arrays; layer weights are STACKED on a leading
    [L] (or [G] group) dim and the decoder runs ``lax.scan`` over layers, so the
    lowered HLO is O(1) in depth — critical for 96–126-layer dry-run compiles.
  * families: ATTN stacks (dense/moe/vlm/audio), MAMBA1 stacks (ssm), and the
    zamba2 hybrid (grouped Mamba-2 + shared-weight attention block).
  * ``forward`` handles train/prefill (full sequence); ``decode_step`` handles
    one-token decode over a cache (KV ring-buffer for pure-SWA archs, recurrent
    states for SSM/hybrid).
  * remat: the scan body is wrapped in ``jax.checkpoint`` per config policy.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ATTN, MAMBA1, MAMBA2, SHARED_ATTN
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------

def _init(key, shape, dtype, scale=None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def _keys(key, n):
    return list(jax.random.split(key, n))


def _attn_params(key, cfg: ArchConfig, stack: Tuple[int, ...], dtype) -> Params:
    d, h, kv = cfg.d_model, cfg.n_heads, cfg.n_kv_heads
    hd = cfg.resolved_head_dim
    ks = _keys(key, 4)
    p = {
        "wq": _init(ks[0], stack + (d, h, hd), dtype, d ** -0.5),
        "wk": _init(ks[1], stack + (d, kv, hd), dtype, d ** -0.5),
        "wv": _init(ks[2], stack + (d, kv, hd), dtype, d ** -0.5),
        "wo": _init(ks[3], stack + (h, hd, d), dtype, (h * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros(stack + (h, hd), dtype)
        p["bk"] = jnp.zeros(stack + (kv, hd), dtype)
        p["bv"] = jnp.zeros(stack + (kv, hd), dtype)
    return p


def _mlp_params(key, cfg: ArchConfig, stack, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = _keys(key, 3)
    p = {"wi": _init(ks[0], stack + (d, f), dtype),
         "wo": _init(ks[1], stack + (f, d), dtype)}
    if cfg.mlp_act.endswith("gated"):
        p["wg"] = _init(ks[2], stack + (d, f), dtype)
    return p


def _moe_params(key, cfg: ArchConfig, stack, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = _keys(key, 4)
    p = {"router": _init(ks[0], stack + (d, e), dtype),
         "wi": _init(ks[1], stack + (e, d, f), dtype),
         "wo": _init(ks[2], stack + (e, f, d), dtype)}
    if cfg.mlp_act.endswith("gated"):
        p["wg"] = _init(ks[3], stack + (e, d, f), dtype)
    return p


def _mamba1_params(key, cfg: ArchConfig, stack, dtype) -> Params:
    d = cfg.d_model
    e = cfg.ssm.expand * d
    n = cfg.ssm.state_dim
    w = cfg.ssm.conv_width
    r = max(1, d // 16)  # dt_rank
    ks = _keys(key, 5)
    a_init = jnp.log(jnp.broadcast_to(
        jnp.arange(1, n + 1, dtype=jnp.float32), stack + (e, n)))
    return {
        "in_proj": _init(ks[0], stack + (d, 2 * e), dtype),
        "conv_w": _init(ks[1], stack + (e, w), dtype, 0.2),
        "conv_b": jnp.zeros(stack + (e,), dtype),
        "x_proj": _init(ks[2], stack + (e, r + 2 * n), dtype),
        "dt_proj_w": _init(ks[3], stack + (r, e), dtype),
        "dt_proj_b": jnp.full(stack + (e,), -4.0, dtype),
        "A_log": a_init.astype(jnp.float32),
        "D": jnp.ones(stack + (e,), jnp.float32),
        "out_proj": _init(ks[4], stack + (e, d), dtype),
    }


def _mamba2_params(key, cfg: ArchConfig, stack, dtype) -> Params:
    d = cfg.d_model
    e = cfg.ssm.expand * d
    n = cfg.ssm.state_dim
    w = cfg.ssm.conv_width
    nh = e // cfg.ssm.headdim
    ks = _keys(key, 3)
    return {
        "in_proj": _init(ks[0], stack + (d, 2 * e + 2 * n + nh), dtype),
        "conv_w": _init(ks[1], stack + (e + 2 * n, w), dtype, 0.2),
        "conv_b": jnp.zeros(stack + (e + 2 * n,), dtype),
        "dt_bias": jnp.zeros(stack + (nh,), jnp.float32),
        "A_log": jnp.zeros(stack + (nh,), jnp.float32),
        "D": jnp.ones(stack + (nh,), jnp.float32),
        "norm": jnp.zeros(stack + (e,), dtype),
        "out_proj": _init(ks[2], stack + (e, d), dtype),
    }


def init_params(cfg: ArchConfig, key: jax.Array,
                param_dtype=jnp.float32) -> Params:
    d, v = cfg.d_model, cfg.vocab
    ks = _keys(key, 8)
    params: Params = {"embed": _init(ks[0], (v, d), param_dtype, 1.0)}
    if cfg.family == "hybrid":
        k = cfg.hybrid_shared_every
        assert cfg.n_layers % k == 0, "hybrid needs n_layers % shared_every == 0"
        g = cfg.n_layers // k
        params["groups"] = {
            "mamba": _mamba2_params(ks[1], cfg, (g, k - 1), param_dtype),
            "norm_m": jnp.zeros((g, k - 1, d), param_dtype),
            "norm_attn": jnp.zeros((g, d), param_dtype),
            "norm_mlp": jnp.zeros((g, d), param_dtype),
        }
        params["shared"] = {
            "attn": _attn_params(ks[2], cfg, (), param_dtype),
            "mlp": _mlp_params(ks[3], cfg, (), param_dtype),
        }
    elif cfg.family == "ssm":
        nl = (cfg.n_layers,)
        params["layers"] = {
            "norm": jnp.zeros(nl + (d,), param_dtype),
            "mamba": _mamba1_params(ks[1], cfg, nl, param_dtype),
        }
    else:
        nl = (cfg.n_layers,)
        lp: Params = {
            "norm1": jnp.zeros(nl + (d,), param_dtype),
            "norm2": jnp.zeros(nl + (d,), param_dtype),
            "attn": _attn_params(ks[1], cfg, nl, param_dtype),
        }
        if cfg.moe is not None:
            lp["moe"] = _moe_params(ks[2], cfg, nl, param_dtype)
        else:
            lp["mlp"] = _mlp_params(ks[2], cfg, nl, param_dtype)
        params["layers"] = lp
    params["final_norm"] = jnp.zeros((d,), param_dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = _init(ks[4], (d, v), param_dtype)
    return params


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _project_qkv(p: Params, x: jax.Array):
    q = jnp.einsum("bsd,dhk->bhsk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bhsk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bhsk", x, p["wv"])
    if "bq" in p:
        q = q + p["bq"][None, :, None, :]
        k = k + p["bk"][None, :, None, :]
        v = v + p["bv"][None, :, None, :]
    # pin heads on `model` so the seq-sharded residual's S->model sharding
    # does not leak into attention (it forces unsharded w[qkv] gradients)
    q = constrain(q, "batch", "model", None, None)
    k = constrain(k, "batch", "model", None, None)
    v = constrain(v, "batch", "model", None, None)
    return q, k, v


def attn_block(p: Params, x: jax.Array, cfg: ArchConfig, *, positions,
               window: int, attn_impl: str, return_kv: bool = False):
    """Full-sequence attention (train/prefill). x: [B, S, d]."""
    q, k, v = _project_qkv(p, x)
    q = L.apply_rope(q, positions[None, None, :], cfg.rope_theta)
    k = L.apply_rope(k, positions[None, None, :], cfg.rope_theta)
    kwargs = dict(causal=True, window=window, logit_softcap=cfg.attn_logit_softcap)
    if attn_impl == "flash":
        o = L.flash_attention_cvjp(q, k, v, **kwargs)
    elif attn_impl == "flash_jnp":
        o = L.flash_attention_jnp(q, k, v, **kwargs)
    elif attn_impl == "naive":
        o = L.naive_attention(q, k, v, **kwargs)
    elif attn_impl == "pallas":
        from repro.kernels import ops as KOPS
        o = KOPS.flash_attention(q, k, v, **kwargs)
    else:
        raise ValueError(attn_impl)
    out = jnp.einsum("bhsk,hkd->bsd", o, p["wo"])
    if return_kv:
        return out, (k, v)
    return out


def attn_decode_block(p: Params, x: jax.Array, cfg: ArchConfig, *, pos,
                      kcache, vcache, window: int, ring: bool,
                      kscale=None, vscale=None):
    """One-token attention. x: [B, 1, d]; caches: [B, Hkv, Smax, D].

    ``pos`` is a scalar (whole batch at one sequence position) or a [B]
    vector (continuous batching: every resident row at its own position —
    the scalar path keeps the cheap contiguous dynamic_update_slice, the
    vector path scatters one slot per row through a one-hot mask).

    When ``kscale``/``vscale`` are given the cache is int8 with
    per-(position, head) scales (cfg.kv_cache_dtype == "int8"). Returns
    (attn_out, updated-cache tuple) — (kc, vc) or (kc, vc, ks, vs).
    """
    q, k, v = _project_qkv(p, x)  # [B,H,1,hd]
    per_row = jnp.ndim(pos) >= 1
    b = x.shape[0]
    posv = jnp.broadcast_to(jnp.asarray(pos, jnp.int32).reshape(-1), (b,))
    q = L.apply_rope(q, posv[:, None, None], cfg.rope_theta)
    k = L.apply_rope(k, posv[:, None, None], cfg.rope_theta)
    smax = kcache.shape[2]
    slot = (posv % smax) if ring else jnp.minimum(posv, smax - 1)  # [B]
    cache_len = jnp.minimum((posv if per_row else pos) + 1, smax)
    win = 0 if ring else window  # ring enforces the window by overwrite

    if per_row:
        oh = jnp.arange(smax)[None, :] == slot[:, None]  # [B, Smax]

        def write(cache, new):  # new: [B, H, 1, D] or [B, H, 1] (scales)
            mask = oh[:, None, :, None] if cache.ndim == 4 else oh[:, None, :]
            return jnp.where(mask, new.astype(cache.dtype), cache)
    else:
        def write(cache, new):
            return jax.lax.dynamic_update_slice_in_dim(
                cache, new.astype(cache.dtype), slot[0], axis=2)

    if kscale is not None:
        k_q, k_s = L.quantize_kv(k, kscale.dtype)
        v_q, v_s = L.quantize_kv(v, vscale.dtype)
        k_q = jax.lax.optimization_barrier(k_q)
        v_q = jax.lax.optimization_barrier(v_q)
        kcache = write(kcache, k_q)
        vcache = write(vcache, v_q)
        kscale = write(kscale, k_s)
        vscale = write(vscale, v_s)
        o = L.decode_attention_q8(q, kcache, kscale, vcache, vscale,
                                  cache_len, window=win,
                                  logit_softcap=cfg.attn_logit_softcap)
        return jnp.einsum("bhsk,hkd->bsd", o, p["wo"]), \
            (kcache, vcache, kscale, vscale)
    # cast + barrier BEFORE the cache write: without the barrier XLA fuses
    # the rope's f32->bf16 convert by converting the ENTIRE cache to f32 for
    # the update instead (observed +20 GB/device at qwen decode_32k)
    k = jax.lax.optimization_barrier(k.astype(kcache.dtype))
    v = jax.lax.optimization_barrier(v.astype(vcache.dtype))
    kcache = write(kcache, k)
    vcache = write(vcache, v)
    o = L.decode_attention(q, kcache, vcache, cache_len, window=win,
                           logit_softcap=cfg.attn_logit_softcap)
    return jnp.einsum("bhsk,hkd->bsd", o, p["wo"]), (kcache, vcache)


def _layer_window(cfg: ArchConfig, layer_idx) -> Any:
    """Per-layer sliding window (gemma2 alternates local/global)."""
    if not cfg.sliding_window:
        return 0
    if cfg.local_global_alternate:
        return jnp.where(layer_idx % 2 == 0, cfg.sliding_window, 0)
    return cfg.sliding_window


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

@jax.custom_vjp
def _barrier(x):
    """optimization_barrier on the scan carry: without it XLA hoists the
    rms_norm f32 convert of the ENTIRE stacked saved-residual buffer out of
    the backward loop (observed +39 GB/device at gemma2 train_4k).

    custom_vjp because optimization_barrier itself has no differentiation
    rule (jax <= 0.4.37); the cotangent gets the same barrier so the
    backward scan carry is protected from the identical hoist."""
    return jax.lax.optimization_barrier(x)


def _barrier_fwd(x):
    return _barrier(x), None


def _barrier_bwd(_, ct):
    return (jax.lax.optimization_barrier(ct),)


_barrier.defvjp(_barrier_fwd, _barrier_bwd)


def _remat(fn, policy: str):
    if policy == "nothing":
        return fn
    if policy == "dots":
        return jax.checkpoint(
            fn, prevent_cse=False,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    if policy == "full":
        # prevent_cse=False is safe (and documented) under lax.scan; the
        # default True wraps saves in barriers that force an extra f32 copy of
        # the whole residual stack (observed +39 GB/device at gemma2 train_4k)
        return jax.checkpoint(fn, prevent_cse=False,
                              policy=jax.checkpoint_policies.nothing_saveable)
    raise ValueError(policy)


def embed_tokens(cfg: ArchConfig, params: Params, batch: Dict[str, jax.Array]):
    if cfg.embedding_frontend_stub and "embeds" in batch:
        x = batch["embeds"]  # modality frontend stub: precomputed embeddings
    else:
        x = params["embed"][batch["tokens"]]
    if cfg.name.startswith("gemma2"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    return x


def logits_from_hidden(cfg: ArchConfig, params: Params, x: jax.Array):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = x @ head
    return L.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)


def forward(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array], *,
            attn_impl: str = "flash", collect_cache: bool = False):
    """Full-sequence forward. Returns (hidden [B,S,d], moe_aux_loss) — plus the
    decode cache (KV stacks / SSM states) when ``collect_cache`` (prefill)."""
    x = embed_tokens(cfg, params, batch)
    x = constrain(x, "batch", None, None)  # pin batch->data in the residual
    bsz, s, d = x.shape
    positions = jnp.arange(s)
    aux0 = jnp.zeros((), jnp.float32)
    cache = None

    if cfg.family == "ssm":
        seq_ax = "model" if cfg.seq_shard_activations else None

        def body(carry, lp):
            h = constrain(_barrier(carry), "batch", seq_ax, None)
            y, st = SSM.mamba1_apply(lp["mamba"], L.rms_norm(h, lp["norm"]),
                                     cfg.ssm, chunk=cfg.ssm.chunk,
                                     return_state=True)
            return h + y, st
        x, states = jax.lax.scan(_remat(body, cfg.remat_policy), x,
                                 params["layers"])
        if collect_cache:
            cache = {"conv": states["conv"], "ssm": states["ssm"]}
        aux = aux0
    elif cfg.family == "hybrid":
        shared = params["shared"]

        seq_ax = "model" if cfg.seq_shard_activations else None

        def group_body(carry, gp):
            h = constrain(_barrier(carry), "batch", seq_ax, None)

            def mamba_body(hh, mp):
                y, st = SSM.mamba2_apply(mp["mamba"],
                                         L.rms_norm(hh, mp["norm_m"]),
                                         cfg.ssm, return_state=True)
                return hh + y, st
            h, mstates = jax.lax.scan(
                mamba_body, h,
                {"mamba": gp["mamba"], "norm_m": gp["norm_m"]})
            a, (k, v) = attn_block(shared["attn"],
                                   L.rms_norm(h, gp["norm_attn"]), cfg,
                                   positions=positions,
                                   window=cfg.sliding_window,
                                   attn_impl=attn_impl, return_kv=True)
            h = h + a
            m = L.mlp_apply(shared["mlp"], L.rms_norm(h, gp["norm_mlp"]),
                            cfg.mlp_act)
            return h + m, (mstates, k, v)
        x, (mstates, ks, vs) = jax.lax.scan(
            _remat(group_body, cfg.remat_policy), x, params["groups"])
        if collect_cache:
            cache = {"m_conv": mstates["conv"], "m_ssm": mstates["ssm"],
                     "k": ks, "v": vs}
        aux = aux0
    else:
        nl = cfg.n_layers
        layer_idx = jnp.arange(nl)

        seq_ax = "model" if cfg.seq_shard_activations else None

        def body(carry, xs):
            h, aux = carry
            h = constrain(_barrier(h), "batch", seq_ax, None)
            lp, idx = xs
            window = _layer_window(cfg, idx)
            a, (k, v) = attn_block(lp["attn"], L.rms_norm(h, lp["norm1"]), cfg,
                                   positions=positions, window=window,
                                   attn_impl=attn_impl, return_kv=True)
            h = h + a
            hn = L.rms_norm(h, lp["norm2"])
            if cfg.moe is not None:
                m, aux_l = MOE.moe_apply(lp["moe"], hn, cfg.moe, cfg.mlp_act)
                aux = aux + aux_l
            else:
                m = L.mlp_apply(lp["mlp"], hn, cfg.mlp_act)
            # barrier on the OUTPUT carry as well: without it XLA saves the
            # next iteration's rms_norm f32 upcast of this carry instead of
            # the bf16 value (a 2x f32 stacked-residual buffer — observed
            # 7.9 GB/device at llama3 train_4k)
            return (_barrier(h + m), aux), (k, v) if collect_cache else None
        (x, aux), kv = jax.lax.scan(_remat(body, cfg.remat_policy), (x, aux0),
                                    (params["layers"], layer_idx))
        if collect_cache:
            cache = {"k": kv[0], "v": kv[1]}

    x = L.rms_norm(x, params["final_norm"])
    if collect_cache:
        return x, aux, cache
    return x, aux


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def chunked_softmax_xent(cfg: ArchConfig, params: Params, hidden: jax.Array,
                         labels: jax.Array, chunk: int = 512) -> jax.Array:
    """Next-token CE without materialising [B, S, V] logits (scan over S-chunks).

    For 128k–256k vocabs at 1M tokens the full logits tensor is the single
    largest allocation in the step; chunking removes it (beyond-paper memory
    optimization, see EXPERIMENTS.md §Perf).
    """
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    assert s % chunk == 0
    hs = jnp.moveaxis(hidden.reshape(b, s // chunk, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, s // chunk, chunk), 1, 0)

    def body(tot, xs):
        h, y = xs
        logits = logits_from_hidden(cfg, params, h)  # [B, chunk, V] f32
        logits = constrain(logits, "batch", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[..., None], axis=-1)[..., 0]
        return tot + jnp.sum(lse - gold), None

    # checkpoint: recompute each chunk's logits in backward instead of saving
    # [B, chunk, V] f32 per chunk (8 x 524 MB/device at 256k vocab)
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hs, ls))
    return tot / (b * s)


def loss_fn(params: Params, cfg: ArchConfig, batch: Dict[str, jax.Array], *,
            attn_impl: str = "flash", aux_weight: float = 0.01) -> jax.Array:
    hidden, aux = forward(params, cfg, batch, attn_impl=attn_impl)
    ce = chunked_softmax_xent(cfg, params, hidden, batch["labels"])
    return ce + aux_weight * aux
