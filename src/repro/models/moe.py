"""Mixture-of-Experts layer: top-k router + GShard-style capacity dispatch.

The dispatch/combine are expressed as einsums so they lower to MXU matmuls on
TPU and shard cleanly (experts on the ``model`` mesh axis = expert parallelism
when E divides it). Tokens are split into GROUPS of ``group_size``: per-group
capacity C = cf * group_size * k / E, so total dispatch-tensor memory is
LINEAR in sequence length (T * k * cf * group_size elements), not quadratic.

The combine tensor is built WITHOUT the naive [g,s,k,E,C] one-hot intermediate:
positions are gathered for the chosen expert per slot, and the [g,s,E,C] tensor
comes from a single dot_general contracting the k slots — this is the
difference between an 86 GB and a ~200 MB per-device intermediate at train_4k.

``repro.kernels.moe_gmm`` provides the Pallas grouped-matmul for the expert FFN
hot loop; this module is the composable pure-jnp path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig


def capacity(cfg: MoEConfig, group_tokens: int) -> int:
    c = int(cfg.capacity_factor * group_tokens * cfg.top_k / cfg.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8 lanes


def router_topk(logits: jax.Array, top_k: int):
    """logits: [g, s, E] -> (weights [g,s,k], indices [g,s,k], probs [g,s,E])."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    weights, indices = jax.lax.top_k(probs, top_k)
    weights = weights / jnp.sum(weights, axis=-1, keepdims=True)
    return weights, indices, probs


def combine_tensor(indices: jax.Array, weights: jax.Array, num_experts: int,
                   cap: int) -> jax.Array:
    """[g,s,k] indices/weights -> combine [g, s, E, C] (drop over capacity)."""
    g, s, k = indices.shape
    onehot_e = jax.nn.one_hot(indices, num_experts, dtype=jnp.float32)
    # position of each (token, slot) within its expert queue, over (s, k)
    flat = onehot_e.reshape(g, s * k, num_experts)
    pos_all = (jnp.cumsum(flat, axis=1) - flat).reshape(g, s, k, num_experts)
    pos = jnp.sum(pos_all * onehot_e, axis=-1)            # [g,s,k] chosen pos
    within = pos < cap
    onehot_c = jax.nn.one_hot(
        jnp.where(within, pos, cap), cap, dtype=jnp.float32)  # [g,s,k,C]
    we = weights[..., None] * onehot_e * within[..., None]    # [g,s,k,E]
    # contract k: [g,s,k,E] x [g,s,k,C] -> [g,s,E,C]; no 5-D intermediate
    return jax.lax.dot_general(
        we, onehot_c, (((2,), (2,)), ((0, 1), (0, 1))))


def moe_apply(p: dict, x: jax.Array, cfg: MoEConfig, act: str,
              group_size: int = 512):
    """x: [B, S, d]. p: {'router': [d,E], 'wi': [E,d,f], 'wg'?, 'wo': [E,f,d]}.

    Returns (out [B,S,d], aux_loss scalar).
    """
    b, s, d = x.shape
    e = cfg.num_experts
    gs = min(group_size, s)
    assert s % gs == 0, (s, gs)
    xg = x.reshape(b * (s // gs), gs, d)
    cap = capacity(cfg, gs)
    logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(x.dtype))
    weights, indices, probs = router_topk(logits, cfg.top_k)
    combine = combine_tensor(indices, weights, e, cap)    # [g,s,E,C] f32
    dispatch = (combine > 0).astype(x.dtype)
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, xg)
    # expert parallelism: all-to-all tokens onto the expert (model) axis when
    # E divides it; groups stay batch-sharded (no-op otherwise)
    from repro.dist.sharding import constrain
    expert_in = constrain(expert_in, "model", "batch", None, None)
    # expert FFN (batched over E) — the grouped-matmul hot spot
    if act.endswith("gated"):
        actfn = jax.nn.silu if act == "silu_gated" else jax.nn.gelu
        h = actfn(jnp.einsum("egcd,edf->egcf", expert_in, p["wi"])) \
            * jnp.einsum("egcd,edf->egcf", expert_in, p["wg"])
    else:
        h = jnp.square(jax.nn.relu(jnp.einsum("egcd,edf->egcf", expert_in,
                                              p["wi"])))
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["wo"])
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), expert_out)
    # load-balancing aux loss (Switch/GShard)
    frac_tokens = jnp.mean(
        jax.nn.one_hot(indices[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    frac_probs = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return out.reshape(b, s, d), aux
