"""State-space blocks: Mamba-1 (selective scan) and Mamba-2 (SSD).

TPU adaptation notes (DESIGN.md §2): the CUDA reference implementations are
hand-fused recurrent kernels; here the train path uses (a) a *chunked* scan —
``lax.scan`` over sequence chunks carrying the SSM state, with an associative scan
inside each chunk — so peak live memory is O(chunk) not O(S·log S), and (b) for
Mamba-2, the SSD *matmul form*: intra-chunk work becomes [Lc, Lc] einsums that map
onto the MXU, with only the inter-chunk state recurrence left sequential. The Pallas
kernel in ``repro.kernels.mamba_scan`` fuses the Mamba-1 chunk loop.

Decode is a one-token recurrent update over (conv_state, ssm_state).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.layers import rms_norm


def causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv. x: [B, S, C]; w: [C, W]; b: [C]."""
    width = w.shape[-1]
    out = jnp.zeros_like(x)
    for i in range(width):
        shift = width - 1 - i
        xi = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :x.shape[1]]
        out = out + xi * w[:, i]
    return out + b


def conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
                b: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """One decode step. x_t: [B, C]; conv_state: [B, W-1, C]."""
    window = jnp.concatenate([conv_state, x_t[:, None]], axis=1)  # [B, W, C]
    out = jnp.einsum("bwc,cw->bc", window, w) + b
    return out, window[:, 1:]


# ---------------------------------------------------------------------------
# Mamba-1
# ---------------------------------------------------------------------------

def _scan_chunked(a: jax.Array, b: jax.Array, h0: jax.Array, chunk: int):
    """Linear recurrence h_t = a_t h_{t-1} + b_t, chunked.

    a, b: [B, S, ...]; h0: [B, ...]. Returns (h_all [B,S,...], h_last).
    """
    bsz, s = a.shape[:2]
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    ar = a.reshape((bsz, nc, chunk) + a.shape[2:])
    br = b.reshape((bsz, nc, chunk) + b.shape[2:])

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2

    def body(h, xs):
        ac, bc = xs  # [B, chunk, ...]
        pa, pb = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = pa * h[:, None] + pb
        return h_all[:, -1], h_all

    ar_t = jnp.moveaxis(ar, 1, 0)
    br_t = jnp.moveaxis(br, 1, 0)
    h_last, h_chunks = jax.lax.scan(body, h0, (ar_t, br_t))
    h_all = jnp.moveaxis(h_chunks, 0, 1).reshape((bsz, s) + a.shape[2:])
    return h_all, h_last


def mamba1_apply(p: dict, x: jax.Array, cfg: SSMConfig, *, chunk: int = 256,
                 return_state: bool = False):
    """Mamba-1 block. x: [B, S, d] -> [B, S, d] (+ final decode state)."""
    bsz, s, d = x.shape
    e = p["A_log"].shape[0]
    n = cfg.state_dim
    xz = x @ p["in_proj"]  # [B,S,2e]
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_tail = xs[:, -(cfg.conv_width - 1):]  # [B, W-1, e] pre-activation
    xs = jax.nn.silu(causal_conv1d(xs, p["conv_w"], p["conv_b"]))
    dt_rank = p["dt_proj_w"].shape[0]
    proj = xs @ p["x_proj"]  # [B,S,dt_rank+2n]
    dt_low, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj_w"] + p["dt_proj_b"])  # [B,S,e]
    a_cont = -jnp.exp(p["A_log"].astype(jnp.float32))  # [e,n]
    a = jnp.exp(dt[..., None].astype(jnp.float32) * a_cont)        # [B,S,e,n]
    b = (dt * xs)[..., None].astype(jnp.float32) * bmat[..., None, :].astype(jnp.float32)
    h, h_last = _scan_chunked(a, b, jnp.zeros((bsz, e, n), jnp.float32), chunk)
    y = jnp.einsum("bsen,bsn->bse", h, cmat.astype(jnp.float32))
    y = y + xs.astype(jnp.float32) * p["D"]
    y = y * jax.nn.silu(z).astype(jnp.float32)
    # cast BEFORE out_proj so bf16 params keep the residual stream bf16
    out = y.astype(x.dtype) @ p["out_proj"]
    if return_state:
        return out, {"conv": conv_tail, "ssm": h_last}
    return out


def mamba1_decode_step(p: dict, x_t: jax.Array, state: dict, cfg: SSMConfig):
    """x_t: [B, d]. state: {'conv': [B, W-1, e], 'ssm': [B, e, n]}."""
    n = cfg.state_dim
    xz = x_t @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    xs, conv_state = conv1d_step(xs, state["conv"], p["conv_w"], p["conv_b"])
    xs = jax.nn.silu(xs)
    dt_rank = p["dt_proj_w"].shape[0]
    proj = xs @ p["x_proj"]
    dt_low, bmat, cmat = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = jax.nn.softplus(dt_low @ p["dt_proj_w"] + p["dt_proj_b"])  # [B,e]
    a_cont = -jnp.exp(p["A_log"].astype(jnp.float32))
    a = jnp.exp(dt[..., None].astype(jnp.float32) * a_cont)  # [B,e,n]
    b = (dt * xs)[..., None].astype(jnp.float32) * bmat[:, None, :].astype(jnp.float32)
    h = a * state["ssm"] + b
    y = jnp.einsum("ben,bn->be", h, cmat.astype(jnp.float32))
    y = (y + xs.astype(jnp.float32) * p["D"]) \
        * jax.nn.silu(z).astype(jnp.float32)
    return y.astype(x_t.dtype) @ p["out_proj"], \
        {"conv": conv_state, "ssm": h}


# ---------------------------------------------------------------------------
# Mamba-2 (SSD, chunked matmul form)
# ---------------------------------------------------------------------------

def _split_m2(p: dict, x: jax.Array, cfg: SSMConfig):
    e = p["out_proj"].shape[0]
    n = cfg.state_dim
    nh = e // cfg.headdim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [e, e + e + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt + p["dt_bias"])  # [..., nh]
    return z, xbc, dt, e, n, nh


def mamba2_apply(p: dict, x: jax.Array, cfg: SSMConfig, *,
                 return_state: bool = False):
    """Mamba-2 (SSD) block, chunked. x: [B, S, d]."""
    bsz, s, d = x.shape
    z, xbc, dt, e, n, nh = _split_m2(p, x, cfg)
    conv_tail = xbc[:, -(cfg.conv_width - 1):]  # [B, W-1, e+2n]
    xbc = jax.nn.silu(causal_conv1d(xbc, p["conv_w"], p["conv_b"]))
    xs, bmat, cmat = jnp.split(xbc, [e, e + n], axis=-1)
    ph = cfg.headdim
    xh = xs.reshape(bsz, s, nh, ph)
    log_a = (-jnp.exp(p["A_log"].astype(jnp.float32)) * dt.astype(jnp.float32))

    lc = min(cfg.chunk, s)
    assert s % lc == 0, (s, lc)
    nc = s // lc
    xh_c = xh.reshape(bsz, nc, lc, nh, ph)
    dt_c = dt.reshape(bsz, nc, lc, nh).astype(jnp.float32)
    b_c = bmat.reshape(bsz, nc, lc, n).astype(jnp.float32)
    c_c = cmat.reshape(bsz, nc, lc, n).astype(jnp.float32)
    la_c = log_a.reshape(bsz, nc, lc, nh)
    cum = jnp.cumsum(la_c, axis=2)                      # [B,nc,Lc,nh]
    dtx = (dt_c[..., None] * xh_c.astype(jnp.float32))  # [B,nc,Lc,nh,P]

    # intra-chunk (attention-like, MXU-friendly)
    g = jnp.einsum("bcln,bcsn->bcls", c_c, b_c)         # [B,nc,Lc,Lc]
    seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # [B,nc,Lc,Lc,nh]
    causal = jnp.tril(jnp.ones((lc, lc), bool))
    att = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0) \
        * g[..., None]
    y_intra = jnp.einsum("bclsh,bcshp->bclhp", att, dtx)

    # chunk state contributions and inter-chunk recurrence
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)     # [B,nc,Lc,nh]
    s_c = jnp.einsum("bcsn,bcsh,bcshp->bchpn", b_c, decay_to_end, dtx)
    a_chunk = jnp.exp(cum[:, :, -1, :])                 # [B,nc,nh]

    def body(h, xs_):
        a_k, s_k = xs_  # [B,nh], [B,nh,P,N]
        h_new = h * a_k[..., None, None] + s_k
        return h_new, h  # emit state *entering* the chunk

    h0 = jnp.zeros((bsz, nh, ph, n), jnp.float32)
    h_last, h_prev = jax.lax.scan(body, h0, (jnp.moveaxis(a_chunk, 1, 0),
                                             jnp.moveaxis(s_c, 1, 0)))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                 # [B,nc,nh,P,N]
    y_inter = jnp.einsum("bcln,bclh,bchpn->bclhp", c_c, jnp.exp(cum), h_prev)

    y = (y_intra + y_inter).reshape(bsz, s, nh, ph)
    y = y + p["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(bsz, s, e).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    out = y @ p["out_proj"]
    if return_state:
        return out, {"conv": conv_tail, "ssm": h_last}
    return out


def mamba2_decode_step(p: dict, x_t: jax.Array, state: dict, cfg: SSMConfig):
    """x_t: [B, d]. state: {'conv': [B, W-1, e+2n], 'ssm': [B, nh, P, N]}."""
    bsz, d = x_t.shape
    z, xbc, dt, e, n, nh = _split_m2(p, x_t, cfg)
    xbc, conv_state = conv1d_step(xbc, state["conv"], p["conv_w"], p["conv_b"])
    xbc = jax.nn.silu(xbc)
    xs, bmat, cmat = jnp.split(xbc, [e, e + n], axis=-1)
    ph = cfg.headdim
    xh = xs.reshape(bsz, nh, ph).astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    a = jnp.exp(-jnp.exp(p["A_log"].astype(jnp.float32)) * dt32)  # [B,nh]
    dtx = dt32[..., None] * xh                                    # [B,nh,P]
    h = state["ssm"] * a[..., None, None] \
        + dtx[..., None] * bmat.astype(jnp.float32)[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h, cmat.astype(jnp.float32))
    y = y + p["D"][:, None] * xh
    y = y.reshape(bsz, e).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm"])
    return y @ p["out_proj"], {"conv": conv_state, "ssm": h}
