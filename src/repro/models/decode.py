"""One-token decode over model caches, for every architecture family.

Caches:
  * ATTN stacks: KV tensors stacked over layers ``[L, B, Hkv, Smax, hd]``.
    Pure-SWA archs (mixtral) get a ring buffer of size ``min(Smax, window)`` —
    the window is enforced by overwrite, so a 500k-token context costs O(window)
    HBM (this is what makes mixtral long_500k runnable, DESIGN.md §4).
  * SSM (falcon-mamba): conv + SSM recurrent states per layer, O(1) in context.
  * hybrid (zamba2): grouped Mamba-2 states + per-group shared-attention KV.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import ssm as SSM
from repro.models.model import (
    Params, attn_decode_block, logits_from_hidden, _layer_window,
)
from repro.models.moe import moe_apply

Cache = Dict[str, Any]


def uses_ring(cfg: ArchConfig) -> bool:
    return cfg.sliding_window > 0 and not cfg.local_global_alternate


def cache_seq_len(cfg: ArchConfig, max_seq: int) -> int:
    return min(max_seq, cfg.sliding_window) if uses_ring(cfg) else max_seq


def init_cache(cfg: ArchConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16) -> Cache:
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    if cfg.family == "ssm":
        e = cfg.ssm.expand * cfg.d_model
        return {
            "conv": jnp.zeros((cfg.n_layers, batch, cfg.ssm.conv_width - 1, e),
                              dtype),
            "ssm": jnp.zeros((cfg.n_layers, batch, e, cfg.ssm.state_dim),
                             jnp.float32),
        }
    if cfg.family == "hybrid":
        k = cfg.hybrid_shared_every
        g = cfg.n_layers // k
        e = cfg.ssm.expand * cfg.d_model
        n = cfg.ssm.state_dim
        nh = e // cfg.ssm.headdim
        smax = cache_seq_len(cfg, max_seq)
        return {
            "m_conv": jnp.zeros((g, k - 1, batch, cfg.ssm.conv_width - 1,
                                 e + 2 * n), dtype),
            "m_ssm": jnp.zeros((g, k - 1, batch, nh, cfg.ssm.headdim, n),
                               jnp.float32),
            "k": jnp.zeros((g, batch, cfg.n_kv_heads, smax, hd), dtype),
            "v": jnp.zeros((g, batch, cfg.n_kv_heads, smax, hd), dtype),
        }
    smax = cache_seq_len(cfg, max_seq)
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, smax, hd)
    if cfg.kv_cache_dtype == "int8":
        return {
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_s": jnp.zeros(shape[:-1], dtype),
            "v_s": jnp.zeros(shape[:-1], dtype),
        }
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def decode_step(params: Params, cfg: ArchConfig, cache: Cache,
                tokens: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Cache]:
    """tokens: [B] int32; pos: scalar int32 (current position, 0-based) or a
    [B] vector when rows decode at independent positions (continuous
    batching — see serve.engine).

    Returns (logits [B, V] f32, updated cache).
    """
    from repro.dist.sharding import constrain
    x = params["embed"][tokens]  # [B, d]
    x = constrain(x, "batch", None)
    if cfg.name.startswith("gemma2"):
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    ring = uses_ring(cfg)

    if cfg.family == "ssm":
        def body(h, xs):
            lp, conv, ssm_state = xs
            conv = jax.lax.optimization_barrier(conv)
            ssm_state = jax.lax.optimization_barrier(ssm_state)
            y, new = SSM.mamba1_decode_step(
                lp["mamba"], L.rms_norm(h, lp["norm"]),
                {"conv": conv, "ssm": ssm_state}, cfg.ssm)
            return h + y, (new["conv"], new["ssm"])
        x, (conv, ssm_state) = jax.lax.scan(
            body, x, (params["layers"], cache["conv"], cache["ssm"]))
        new_cache = {"conv": conv, "ssm": ssm_state}
    elif cfg.family == "hybrid":
        shared = params["shared"]

        def group_body(h, xs):
            gp, mconv, mssm, kc, vc = xs
            kc = jax.lax.optimization_barrier(kc)
            vc = jax.lax.optimization_barrier(vc)

            def mamba_body(hh, ys):
                mp, conv, st = ys
                y, new = SSM.mamba2_decode_step(
                    mp["mamba"], L.rms_norm(hh, mp["norm_m"]),
                    {"conv": conv, "ssm": st}, cfg.ssm)
                return hh + y, (new["conv"], new["ssm"])
            h, (mconv, mssm) = jax.lax.scan(
                mamba_body, h,
                ({"mamba": gp["mamba"], "norm_m": gp["norm_m"]}, mconv, mssm))
            a, (kc, vc) = attn_decode_block(
                shared["attn"], L.rms_norm(h, gp["norm_attn"])[:, None], cfg,
                pos=pos, kcache=kc, vcache=vc, window=cfg.sliding_window,
                ring=ring)
            h = h + a[:, 0]
            m = L.mlp_apply(shared["mlp"], L.rms_norm(h, gp["norm_mlp"]),
                            cfg.mlp_act)
            return h + m, (mconv, mssm, kc, vc)
        x, (mconv, mssm, kc, vc) = jax.lax.scan(
            group_body, x,
            (params["groups"], cache["m_conv"], cache["m_ssm"],
             cache["k"], cache["v"]))
        new_cache = {"m_conv": mconv, "m_ssm": mssm, "k": kc, "v": vc}
    else:
        layer_idx = jnp.arange(cfg.n_layers)
        q8 = cfg.kv_cache_dtype == "int8"

        def body(h, xs):
            if q8:
                lp, idx, kc, vc, ks, vs = xs
            else:
                lp, idx, kc, vc = xs
                ks = vs = None
            # barrier: the attention einsums read the cache with f32
            # accumulation; without the barrier XLA hoists that convert out
            # of the layer loop and materializes the WHOLE stacked cache in
            # f32 (observed +20 GB/device at qwen decode_32k)
            kc = jax.lax.optimization_barrier(kc)
            vc = jax.lax.optimization_barrier(vc)
            window = _layer_window(cfg, idx)
            a, kv = attn_decode_block(
                lp["attn"], L.rms_norm(h, lp["norm1"])[:, None], cfg,
                pos=pos, kcache=kc, vcache=vc, kscale=ks, vscale=vs,
                window=window, ring=ring)
            h = h + a[:, 0]
            hn = L.rms_norm(h, lp["norm2"])[:, None]
            if cfg.moe is not None:
                m, _ = moe_apply(lp["moe"], hn, cfg.moe, cfg.mlp_act)
            else:
                m = L.mlp_apply(lp["mlp"], hn, cfg.mlp_act)
            return h + m[:, 0], kv
        if q8:
            x, (kc, vc, ks, vs) = jax.lax.scan(
                body, x, (params["layers"], layer_idx, cache["k"],
                          cache["v"], cache["k_s"], cache["v_s"]))
            new_cache = {"k": kc, "v": vc, "k_s": ks, "v_s": vs}
        else:
            x, (kc, vc) = jax.lax.scan(
                body, x,
                (params["layers"], layer_idx, cache["k"], cache["v"]))
            new_cache = {"k": kc, "v": vc}

    x = L.rms_norm(x, params["final_norm"])
    logits = logits_from_hidden(cfg, params, x[:, None])[:, 0]
    return logits, new_cache


# ---------------------------------------------------------------------------
# Slot-wise cache surgery (continuous batching)
#
# A running decode batch adopts a prefilled request's single-row cache and
# retires finished rows in place: extract slices one row out, insert writes a
# row back (right-padding the sequence axis so a short prefill cache drops
# into a longer resident buffer; slots past the row's cache_len are masked by
# decode_attention, so the zero padding is never attended).
# ---------------------------------------------------------------------------

# per-key (batch_axis, seq_axis or None) for every cache layout produced by
# init_cache across the attn / ssm / hybrid families
CACHE_AXES: Dict[str, Tuple[int, Any]] = {
    "k": (1, 3), "v": (1, 3), "k_s": (1, 3), "v_s": (1, 3),
    "conv": (1, None), "ssm": (1, None),
    "m_conv": (2, None), "m_ssm": (2, None),
}


def cache_rows(cache: Cache) -> int:
    """Batch capacity (number of resident rows) of a decode cache."""
    key = next(iter(cache))
    return cache[key].shape[CACHE_AXES[key][0]]


def cache_extract(cache: Cache, row) -> Cache:
    """Slice out one resident row as a batch-1 cache. ``row`` may be a
    static int or a traced scalar."""
    return {key: jax.lax.dynamic_slice_in_dim(t, row, 1,
                                              axis=CACHE_AXES[key][0])
            for key, t in cache.items()}


def cache_insert(cache: Cache, row_cache: Cache, row) -> Cache:
    """Write a batch-1 ``row_cache`` into resident slot ``row``.

    The row cache's sequence axis may be SHORTER than the resident buffer's
    (e.g. a prompt-length prefill cache joining a max_seq batch, or a
    short-prompt ring): it is right-padded with zeros, which stay masked
    until decode writes them. A LONGER sequence axis is an error — the
    resident buffer cannot hold it.
    """
    out = {}
    for key, t in cache.items():
        bax, sax = CACHE_AXES[key]
        rt = row_cache[key]
        if sax is not None and rt.shape[sax] != t.shape[sax]:
            if rt.shape[sax] > t.shape[sax]:
                raise ValueError(
                    f"cache_insert: row cache {key} seq {rt.shape[sax]} "
                    f"exceeds resident buffer seq {t.shape[sax]}")
            pad = [(0, 0)] * rt.ndim
            pad[sax] = (0, t.shape[sax] - rt.shape[sax])
            rt = jnp.pad(rt, pad)
        out[key] = jax.lax.dynamic_update_slice_in_dim(
            t, rt.astype(t.dtype), row, axis=bax)
    return out


def cache_clear_row(cache: Cache, row) -> Cache:
    """Zero a retired row so stale KV bytes can't leak into a later adopt
    (cheap hygiene; correctness never reads a masked slot)."""
    zeros = {key: jnp.zeros_like(t) for key, t in cache_extract(
        cache, 0 if isinstance(row, int) else row).items()}
    return cache_insert(cache, zeros, row)
