"""Pallas grouped matmul (MoE expert FFN hot loop).

Rows of x are sorted by expert; ``group_sizes[e]`` rows belong to expert e.
The dense-dispatch einsum in repro.models.moe pads every expert to capacity C
and multiplies zeros; the grouped matmul walks [block_t, D] row tiles and
selects the right expert weight tile per program — compute is O(real tokens),
not O(E * C).

TPU adaptation: CUDA grouped GEMMs schedule one threadblock per (group,
tile); here the grid is (t_blocks, f_blocks) and the expert id of each row
tile comes from a prefix-sum lookup computed on the host side (rows are
capacity-grouped so a tile never straddles two experts when block_t divides
the capacity — asserted). Weight tiles stream through VMEM per program;
accumulation is f32 on the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(expert_of_ref, x_ref, w_ref, o_ref):
    """One (t_block, f_block) program. x_ref: [block_t, D];
    w_ref: [E, D, block_f] (full expert stack for this f block)."""
    e_idx = expert_of_ref[0]
    x = x_ref[...].astype(jnp.float32)
    w = pl.load(w_ref, (e_idx, slice(None), slice(None))).astype(jnp.float32)
    o_ref[...] = (x @ w).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_t", "block_f", "interpret"))
def moe_gmm(x, w, group_sizes, *, block_t: int = 128, block_f: int = 128,
            interpret: bool = True):
    """x: [T, D] rows sorted by expert; w: [E, D, F]; group_sizes: [E] ints
    summing to T, each a multiple of block_t. Returns [T, F].
    """
    t, d = x.shape
    e, _, f = w.shape
    block_t = min(block_t, t)
    block_f = min(block_f, f)
    assert t % block_t == 0 and f % block_f == 0, (t, block_t, f, block_f)
    nt, nf = t // block_t, f // block_f
    # expert of each row tile (host-side prefix sum; group_sizes is static
    # per (E, capacity) config in the capacity-padded layout)
    bounds = jnp.cumsum(group_sizes)
    tile_starts = jnp.arange(nt) * block_t
    expert_of_tile = jnp.searchsorted(bounds, tile_starts, side="right"
                                      ).astype(jnp.int32)

    return pl.pallas_call(
        _gmm_kernel,
        grid=(nt, nf),
        in_specs=[
            pl.BlockSpec((1,), lambda ti, fi: (ti,)),
            pl.BlockSpec((block_t, d), lambda ti, fi: (ti, 0)),
            pl.BlockSpec((e, d, block_f), lambda ti, fi: (0, 0, fi)),
        ],
        out_specs=pl.BlockSpec((block_t, block_f), lambda ti, fi: (ti, fi)),
        out_shape=jax.ShapeDtypeStruct((t, f), x.dtype),
        interpret=interpret,
    )(expert_of_tile, x, w)
