"""Pallas TPU flash attention: blocked online-softmax with GQA, sliding
window, and logit softcap.

TPU adaptation (DESIGN.md §2): the CUDA FlashAttention tiles over shared
memory per SM; here BlockSpec stages a [block_q, d] query tile and the
[seq_k, d] KV stream of one KV head through VMEM, and the K loop runs INSIDE
the kernel body as a ``fori_loop`` carrying the online-softmax state in
registers. Block sizes are MXU-aligned (128 multiples). Causal pruning skips
whole K blocks past the diagonal, and the sliding window skips blocks left of
the window — the loop bounds are computed per q-block, so the work per
program is O(touched blocks), not O(seq_k).

Layout: the grid is (batch*kv_head, group, q_block) with q blocks innermost,
so consecutive programs of one (b, kv_head) reuse the VMEM-resident KV
stream; GQA never reshapes the head dim (the group rides the grid).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "logit_softcap", "block_q",
                              "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    logit_softcap: float = 0.0, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Sk, D] -> [B, Hq, Sq, D]."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)

    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0, (sq, block_q, sk, block_k)
    nblocks = sk // block_k
    grid = (b * hkv, g, sq // block_q)

    def q_index(bh, gi, qi):
        return (bh // hkv, (bh % hkv) * g + gi, qi, 0)

    def kv_index(bh, gi, qi):
        return (bh // hkv, bh % hkv, 0, 0)

    def kernel(q_ref, k_ref, v_ref, o_ref):
        qi = pl.program_id(2)
        q_start = qi * block_q
        qf = q_ref[...].astype(jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, 1), 0)

        def body(j, carry):
            acc, m_prev, l_prev = carry
            kb = pl.load(k_ref, (pl.ds(j * block_k, block_k), slice(None)))
            vb = pl.load(v_ref, (pl.ds(j * block_k, block_k), slice(None)))
            s = qf @ kb.astype(jnp.float32).T               # [bq, bk] MXU
            if logit_softcap:
                s = logit_softcap * jnp.tanh(s / logit_softcap)
            k_pos = j * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (1, block_k), 1)
            mask = jnp.ones((block_q, block_k), jnp.bool_)
            if causal:
                mask &= q_pos >= k_pos
            if window:
                mask &= (q_pos - k_pos) < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
            p = jnp.exp(s - m_new)
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + jnp.sum(p, axis=-1, keepdims=True)
            acc = acc * alpha + p @ vb.astype(jnp.float32)  # [bq, d] MXU
            return acc, m_new, l_new

        # block pruning: causal upper bound at the diagonal; window lower
        # bound left of the oldest visible key
        hi = (jnp.minimum((q_start + block_q + block_k - 1) // block_k,
                          nblocks) if causal else nblocks)
        lo = (jnp.maximum((q_start - window) // block_k, 0) if window else 0)
        acc0 = jnp.zeros((block_q, d), jnp.float32)
        m0 = jnp.full((block_q, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((block_q, 1), jnp.float32)
        acc, _, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
        o_ref[...] = (acc / jnp.maximum(l, 1e-30)).astype(o_ref.dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, block_q, d), q_index),
            pl.BlockSpec((None, None, sk, d), kv_index),
            pl.BlockSpec((None, None, sk, d), kv_index),
        ],
        out_specs=pl.BlockSpec((None, None, block_q, d), q_index),
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, d), q.dtype),
        interpret=interpret,
    )(q, k, v)
