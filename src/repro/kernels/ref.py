"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L


def flash_attention_ref(q, k, v, *, causal=True, window=0, logit_softcap=0.0):
    """q: [B, Hq, Sq, D]; k, v: [B, Hkv, Sk, D] -> [B, Hq, Sq, D]."""
    return L.naive_attention(q, k, v, causal=causal, window=window,
                             logit_softcap=logit_softcap)


def rmsnorm_ref(x, scale, eps=1e-5):
    return L.rms_norm(x, scale, eps)


def mamba_scan_ref(a, b, h0):
    """h_t = a_t * h_{t-1} + b_t. a, b: [B, S, E, N]; h0: [B, E, N].

    Returns (h_all [B,S,E,N], h_last [B,E,N])."""
    def step(h, xs):
        at, bt = xs
        h = at * h + bt
        return h, h
    aT = jnp.moveaxis(a, 1, 0)
    bT = jnp.moveaxis(b, 1, 0)
    h_last, hs = jax.lax.scan(step, h0, (aT, bT))
    return jnp.moveaxis(hs, 0, 1), h_last


def moe_gmm_ref(x, w, group_sizes):
    """Grouped matmul: rows of x belong to expert g per group_sizes.

    x: [T, D] (rows sorted by expert), w: [E, D, F], group_sizes: [E] summing
    to T. Returns [T, F] where row t is x[t] @ w[expert_of(t)].
    """
    t = x.shape[0]
    bounds = jnp.cumsum(group_sizes)
    expert_of = jnp.searchsorted(bounds, jnp.arange(t), side="right")
    return jnp.einsum("td,tdf->tf", x, w[expert_of])
