"""Jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU (this container) and False on TPU —
the kernels are written for TPU BlockSpec tiling and validated against the
ref.py oracles in interpret mode.
"""
from __future__ import annotations

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import mamba_scan as _ms
from repro.kernels import moe_gmm as _gmm
from repro.kernels import rmsnorm as _rn


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, logit_softcap=0.0,
                    q_offset=0, block_q=128, block_k=128, interpret=None):
    assert q_offset == 0, "pallas path is train/prefill only (q_offset=0)"
    window = int(window) if not hasattr(window, "aval") else window
    if hasattr(window, "aval"):
        raise ValueError("pallas flash attention needs a static window; "
                         "use attn_impl='flash' for traced windows (gemma2)")
    return _fa.flash_attention(
        q, k, v, causal=causal, window=int(window or 0),
        logit_softcap=float(logit_softcap),
        block_q=block_q, block_k=block_k,
        interpret=_default_interpret() if interpret is None else interpret)


def rmsnorm(x, scale, *, eps=1e-5, interpret=None):
    return _rn.rmsnorm(
        x, scale, eps=eps,
        interpret=_default_interpret() if interpret is None else interpret)


def mamba_scan(a, b, *, chunk=64, interpret=None):
    return _ms.mamba_scan(
        a, b, chunk=chunk,
        interpret=_default_interpret() if interpret is None else interpret)


def moe_gmm(x, w, group_sizes, *, interpret=None):
    return _gmm.moe_gmm(
        x, w, group_sizes,
        interpret=_default_interpret() if interpret is None else interpret)
