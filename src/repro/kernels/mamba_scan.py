"""Pallas chunked selective-scan (the Mamba-1 recurrence hot loop).

h_t = a_t * h_{t-1} + b_t over the sequence, per (batch, channel, state).

TPU adaptation (DESIGN.md §2): the CUDA kernel is a warp-level parallel scan
in shared memory. TPUs have no warp shuffles; the VMEM-native formulation is
a CHUNKED sequential scan — grid over (batch, channel blocks), each program
walks the sequence in [chunk, block_e, n] VMEM tiles with the running state
[block_e, n] carried in registers. Within a tile the recurrence unrolls along
the chunk, which the VPU pipelines; HBM traffic is read-once/write-once
(the pure-XLA associative scan materializes log(S) intermediate sweeps).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(a_ref, b_ref, h_all_ref, h_last_ref, *, chunk):
    """One (batch, e-block) program. a/b_ref: [S, block_e, N]."""
    s = a_ref.shape[0]
    block_e, n = a_ref.shape[1], a_ref.shape[2]
    nchunks = s // chunk

    def outer(c, h):
        base = c * chunk
        a_tile = pl.load(a_ref, (pl.ds(base, chunk), slice(None), slice(None)))
        b_tile = pl.load(b_ref, (pl.ds(base, chunk), slice(None), slice(None)))

        def inner(t, carry):
            h_in, out_tile = carry
            h_new = a_tile[t] * h_in + b_tile[t]
            out_tile = jax.lax.dynamic_update_index_in_dim(
                out_tile, h_new, t, axis=0)
            return h_new, out_tile

        h, out_tile = jax.lax.fori_loop(
            0, chunk, inner, (h, jnp.zeros((chunk, block_e, n), h.dtype)))
        pl.store(h_all_ref, (pl.ds(base, chunk), slice(None), slice(None)),
                 out_tile)
        return h

    h = jnp.zeros((block_e, n), jnp.float32)
    h = jax.lax.fori_loop(0, nchunks, outer, h)
    h_last_ref[...] = h


@functools.partial(jax.jit,
                   static_argnames=("chunk", "block_e", "interpret"))
def mamba_scan(a, b, *, chunk: int = 64, block_e: int = 128,
               interpret: bool = True):
    """a, b: [B, S, E, N] f32 -> (h_all [B,S,E,N], h_last [B,E,N]).

    Zero initial state (matches the training path; decode uses the one-step
    recurrent update instead).
    """
    bsz, s, e, n = a.shape
    block_e = min(block_e, e)
    assert e % block_e == 0, (e, block_e)
    chunk = min(chunk, s)
    assert s % chunk == 0, (s, chunk)
    grid = (bsz, e // block_e)

    def idx(bi, ei):
        return (bi, 0, ei, 0)

    def idx_last(bi, ei):
        return (bi, ei, 0)

    h_all, h_last = pl.pallas_call(
        functools.partial(_scan_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, s, block_e, n), idx),
            pl.BlockSpec((None, s, block_e, n), idx),
        ],
        out_specs=[
            pl.BlockSpec((None, s, block_e, n), idx),
            pl.BlockSpec((None, block_e, n), idx_last),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, e, n), jnp.float32),
            jax.ShapeDtypeStruct((bsz, e, n), jnp.float32),
        ],
        interpret=interpret,
    )(a, b)
    return h_all, h_last
