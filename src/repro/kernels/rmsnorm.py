"""Pallas fused RMSNorm(+scale): one VMEM pass instead of XLA's
square/mean/rsqrt/mul chain (4 HBM round-trips for large rows).

Grid walks row blocks; each program reduces its [block_rows, d] tile in f32
and writes the normalized tile — HBM traffic is exactly read-once/write-once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                  # [rows, d]
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    scale = 1.0 + scale_ref[...].astype(jnp.float32)    # [1, d]
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * scale).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("eps", "block_rows", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = True):
    """x: [..., d]; scale: [d]. Matches repro.models.layers.rms_norm."""
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block_rows = min(block_rows, rows)
    # pad rows to a block multiple (tail block handled by padding, cheaper
    # than a masked epilogue for the shapes we use)
    pad = (-rows) % block_rows
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(x2.shape[0] // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, scale.reshape(1, d))
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
