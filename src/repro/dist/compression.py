"""Blockwise int8 gradient compression with error feedback.

``compress_decompress`` is the quantize->dequantize round trip a
``grad_compressor`` applies to gradients before the optimizer
(repro.train.train_step). Under FSDP the compression runs before the
data-axis all-reduce GSPMD inserts, so the wire format of the gradient
all-reduce is the quantized tensor.

Scaling is per-block absmax: within each block of ``BLOCK`` elements the
dequantization error is at most ``absmax(block) / 254`` per element (half a
quantization step of scale ``absmax / 127``), so blocks isolate outliers and
the global error bound tested in tests/test_substrate.py holds with margin.

Error feedback (``apply_with_error_feedback``) carries the per-step residual
forward so the APPLIED gradient stream telescopes: after any number of steps,
sum(applied) + residual == sum(true gradients) exactly (in f32), which is
what keeps compressed training unbiased over time.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

BLOCK = 256


def compress_decompress(g: jax.Array, block: int = BLOCK) -> jax.Array:
    """Blockwise int8 quantize + dequantize (jit-safe, shape/dtype preserving)."""
    flat = g.reshape(-1).astype(jnp.float32)
    n = flat.shape[0]
    pad = (-n) % block
    blocks = jnp.pad(flat, (0, pad)).reshape(-1, block)
    amax = jnp.max(jnp.abs(blocks), axis=1, keepdims=True)
    scale = jnp.maximum(amax, 1e-30) / 127.0
    codes = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    deq = codes.astype(jnp.float32) * scale
    return deq.reshape(-1)[:n].reshape(g.shape).astype(g.dtype)


def init_error_state(grads: Any) -> Any:
    """Zero residual tree matching ``grads`` (f32: residuals must accumulate
    exactly for the telescoping invariant)."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def apply_with_error_feedback(grads: Any, err_state: Any) -> Tuple[Any, Any]:
    """(grads, residual) -> (compressed grads to apply, new residual).

    q_t = Q(g_t + e_{t-1});  e_t = (g_t + e_{t-1}) - q_t
    => sum_t q_t + e_T == sum_t g_t  (telescopes, exactly in f32).
    """
    corrected = jax.tree_util.tree_map(
        lambda g, e: g.astype(jnp.float32) + e, grads, err_state)
    q = jax.tree_util.tree_map(compress_decompress, corrected)
    new_err = jax.tree_util.tree_map(lambda c, qq: c - qq, corrected, q)
    return q, new_err
