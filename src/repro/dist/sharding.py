"""Sharding rules: logical-axis activation constraints plus divisibility-aware
parameter / batch / cache PartitionSpecs.

Two logical activation axes cover every model in this repo:

  * ``batch`` — the mesh's data axes (``("pod", "data")`` when a DCN pod axis
    is present, else ``("data",)``): batch / FSDP parallelism.
  * ``model`` — the ``model`` mesh axis: tensor / expert / sequence
    parallelism.

``constrain`` is the one entry point model code uses to pin activation
shardings (each call site documents the memory pathology it prevents). It is
a no-op unless an ``activation_mesh`` context is active, so the same model
code runs unsharded in single-device tests.

Every rule is DIVISIBILITY-AWARE: a mesh axis whose size does not divide the
corresponding dim is dropped (that dim stays replicated) instead of erroring.
One rule table therefore covers both a 2-kv-head reduced config and a
128-head production config on the same 16x16 mesh
(tests/test_integration.py::test_param_specs_divisibility_all_archs).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional, Sequence, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

_ACTIVE = threading.local()


def current_mesh():
    """The mesh installed by ``activation_mesh`` (None outside any context)."""
    return getattr(_ACTIVE, "mesh", None)


@contextlib.contextmanager
def activation_mesh(mesh):
    """Install ``mesh`` as the target of ``constrain`` for the dynamic extent.

    The launch drivers wrap init + jit tracing in this context; model code
    stays mesh-agnostic and calls ``constrain`` unconditionally.
    """
    prev = getattr(_ACTIVE, "mesh", None)
    _ACTIVE.mesh = mesh
    try:
        yield mesh
    finally:
        _ACTIVE.mesh = prev


# ---------------------------------------------------------------------------
# logical -> mesh axis resolution
# ---------------------------------------------------------------------------

def _axis_group(mesh, logical: Optional[str]) -> Optional[Tuple[str, ...]]:
    """Resolve a logical axis name to a tuple of mesh axes (None = replicate)."""
    if logical is None:
        return None
    names = mesh.axis_names
    if logical == "batch":
        group = tuple(a for a in ("pod", "data") if a in names)
        return group or None
    if logical in names:
        return (logical,)
    return None


def _group_size(mesh, group: Tuple[str, ...]) -> int:
    size = 1
    for a in group:
        size *= mesh.shape[a]
    return size


def _entry(mesh, dim: int, logical) -> Any:
    """One PartitionSpec entry for a dim of size ``dim``, or None if the axis
    group's size does not divide it (replicate rather than error)."""
    group = _axis_group(mesh, logical)
    if group is None or dim % _group_size(mesh, group):
        return None
    return group[0] if len(group) == 1 else group


def _spec_for(mesh, shape: Sequence[int], logical_axes: Sequence) -> P:
    entries = [_entry(mesh, d, ax) for d, ax in zip(shape, logical_axes)]
    entries += [None] * (len(shape) - len(entries))
    return P(*entries)


def constrain(x: jax.Array, *logical_axes) -> jax.Array:
    """``with_sharding_constraint`` under the active activation mesh.

    ``logical_axes`` has one entry per dim of ``x``: "batch", "model", any
    literal mesh axis name, or None. Outside an ``activation_mesh`` context
    (or on a trivial 1-device mesh) this is the identity, so model code can
    pin shardings unconditionally.
    """
    mesh = current_mesh()
    if mesh is None or mesh.size == 1:
        return x
    spec = _spec_for(mesh, x.shape, logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------

# Rules are written for the UNSTACKED param rank and aligned to the trailing
# dims; leading layer/group stack dims stay replicated. "data" = FSDP axis,
# "model" = tensor/expert-parallel axis.
_PARAM_RULES = {
    # top level
    ("", "embed"): ("model", "data"),          # [V, d]: vocab-parallel
    ("", "lm_head"): ("data", "model"),        # [d, V]
    # attention (Megatron TP: heads on model, d_model FSDP on data)
    ("attn", "wq"): ("data", "model", None),   # [d, H, hd]
    ("attn", "wk"): ("data", "model", None),   # [d, Hkv, hd]
    ("attn", "wv"): ("data", "model", None),
    ("attn", "wo"): ("model", None, "data"),   # [H, hd, d]
    ("attn", "bq"): ("model", None),
    ("attn", "bk"): ("model", None),
    ("attn", "bv"): ("model", None),
    # dense MLP (column- then row-parallel)
    ("mlp", "wi"): ("data", "model"),          # [d, f]
    ("mlp", "wg"): ("data", "model"),
    ("mlp", "wo"): ("model", "data"),          # [f, d]
    # MoE (expert-parallel on model when E divides it; FSDP on d)
    ("moe", "router"): ("data", None),         # [d, E]
    ("moe", "wi"): ("model", "data", None),    # [E, d, f]
    ("moe", "wg"): ("model", "data", None),
    ("moe", "wo"): ("model", None, "data"),    # [E, f, d]
    # Mamba blocks: the expanded channel dim e plays the TP role
    ("mamba", "in_proj"): ("data", "model"),   # [d, 2e(+...)]
    ("mamba", "conv_w"): ("model", None),      # [e(+2n), W]
    ("mamba", "conv_b"): ("model",),
    ("mamba", "x_proj"): ("model", None),      # [e, r+2n]
    ("mamba", "dt_proj_w"): (None, "model"),   # [r, e]
    ("mamba", "dt_proj_b"): ("model",),
    ("mamba", "out_proj"): ("model", "data"),  # [e, d]
    # A_log / D / dt_bias / norm: small state tensors, replicated
}

_PARENTS = frozenset(p for p, _ in _PARAM_RULES if p)


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        names.append(getattr(k, "key", getattr(k, "name", str(k))))
    return tuple(names)


def _param_rule(path) -> Optional[Tuple]:
    names = _path_names(path)
    name = names[-1]
    parent = next((n for n in reversed(names[:-1]) if n in _PARENTS), "")
    return _PARAM_RULES.get((parent, name)) or _PARAM_RULES.get(("", name))


def param_specs(cfg: ArchConfig, params, mesh):
    """PartitionSpec tree (FSDP + TP) for a param tree of arrays or
    ShapeDtypeStructs. Optimizer moments reuse these specs unchanged."""

    def leaf_spec(path, leaf):
        rule = _param_rule(path)
        if rule is None or leaf.ndim < len(rule):
            return P()
        lead = leaf.ndim - len(rule)
        logical = (None,) * lead + tuple(rule)
        return _spec_for(mesh, leaf.shape, logical)

    return jax.tree_util.tree_map_with_path(leaf_spec, params)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------

def batch_specs(cfg: ArchConfig, batch, mesh):
    """Shard every input's leading (batch) dim over the data axes; scalars
    (e.g. decode ``pos``) stay replicated."""

    def leaf_spec(leaf):
        if leaf.ndim == 0:
            return P()
        logical = ("batch",) + (None,) * (leaf.ndim - 1)
        return _spec_for(mesh, leaf.shape, logical)

    return jax.tree_util.tree_map(leaf_spec, batch)


# Cache layouts (repro.models.decode.init_cache), keyed by leaf name:
#   k/v     [L|G, B, Hkv, S, hd]     k_s/v_s [L, B, Hkv, S]
#   conv    [L, B, W-1, e]           ssm     [L, B, e, N]
#   m_conv  [G, k-1, B, W-1, e+2n]   m_ssm   [G, k-1, B, nh, hd, N]
# ``context_parallel`` moves the data axes onto the sequence dim for
# small-batch long-context decode (global_batch < data-axis size).
_CACHE_RULES = {
    "k": (None, "batch", "model", None, None),
    "v": (None, "batch", "model", None, None),
    "k_s": (None, "batch", "model", None),
    "v_s": (None, "batch", "model", None),
    "conv": (None, "batch", None, "model"),
    "ssm": (None, "batch", "model", None),
    "m_conv": (None, None, "batch", None, "model"),
    "m_ssm": (None, None, "batch", "model", None, None),
}
_CACHE_SEQ_DIM = {"k": 3, "v": 3, "k_s": 3, "v_s": 3}


def cache_specs(cfg: ArchConfig, cache, mesh, *, context_parallel: bool = False):
    """PartitionSpecs for a decode/prefill cache tree."""

    def leaf_spec(path, leaf):
        name = _path_names(path)[-1]
        rule = _CACHE_RULES.get(name)
        if rule is None or leaf.ndim != len(rule):
            return P()
        logical = list(rule)
        if context_parallel and name in _CACHE_SEQ_DIM:
            # batch too small to shard: put the data axes on the sequence dim
            logical[1] = None
            logical[_CACHE_SEQ_DIM[name]] = "batch"
        return _spec_for(mesh, leaf.shape, logical)

    return jax.tree_util.tree_map_with_path(leaf_spec, cache)


def to_named(specs, mesh):
    """PartitionSpec tree -> NamedSharding tree (P is a tuple: need is_leaf)."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
