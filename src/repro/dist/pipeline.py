"""Pipeline parallelism: GPipe-style microbatch schedule over a ``stage``
mesh axis.

Layers are split contiguously over stages (``stack_stage_params``); each
device runs its stage's layer slice and passes activations to the next stage
with ``ppermute``. The schedule is the classic fill/drain loop: with M
microbatches and S stages it runs M + S - 1 ticks, every stage computing on
every tick (warm-up/drain ticks produce garbage that is masked out by tick
index, which keeps the loop body branch-free and scan-able).

This is the third parallelism axis next to data (batch) and model (tensor):
a pipeline task spans ``S`` devices with per-device memory ~1/S of the layer
stack — exactly the multi-chip ``ResourceVector.chips > 1`` workloads the MGB
schedulers place.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def stack_stage_params(params: Any, n_stages: int) -> Any:
    """Reshape each leaf's leading layer dim [L, ...] -> [S, L // S, ...]
    (stage s gets the contiguous layer slice [s * L/S, (s+1) * L/S))."""

    def split(w):
        L = w.shape[0]
        assert L % n_stages == 0, (L, n_stages)
        return w.reshape((n_stages, L // n_stages) + w.shape[1:])

    return jax.tree_util.tree_map(split, params)


def make_pipeline_forward(layer_fn: Callable, mesh, *, n_micro: int,
                          axis: str = None):
    """Build ``pipe(stage_params, x) -> y`` running ``layer_fn`` over a
    pipeline of ``mesh.shape[axis]`` stages with ``n_micro`` microbatches.

    ``layer_fn(stage_params_slice, x)`` applies one stage's layer slice to a
    microbatch and must be shape-preserving in ``x``. ``stage_params`` is the
    output of ``stack_stage_params``; ``x`` is [B, ...] with B % n_micro == 0.
    """
    axis = axis or mesh.axis_names[0]
    n_stages = mesh.shape[axis]

    def pipe(stage_params, x):
        batch = x.shape[0]
        assert batch % n_micro == 0, (batch, n_micro)
        mb = batch // n_micro
        xs = x.reshape((n_micro, mb) + x.shape[1:])

        @functools.partial(
            shard_map, mesh=mesh,
            in_specs=(P(axis), P()),
            out_specs=P(),
            check_rep=False)
        def _run(sp, xs):
            sp = jax.tree_util.tree_map(lambda w: w[0], sp)  # local slice
            stage = jax.lax.axis_index(axis)
            shift = [(i, (i + 1) % n_stages) for i in range(n_stages)]

            def tick(carry, t):
                recv, outs = carry
                feed = jax.lax.dynamic_index_in_dim(
                    xs, jnp.clip(t, 0, n_micro - 1), keepdims=False)
                inp = jnp.where(stage == 0, feed, recv)
                y = layer_fn(sp, inp)
                # the last stage finishes microbatch t - (S - 1) on tick t
                o_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
                emit = (stage == n_stages - 1) & (t >= n_stages - 1)
                cur = jax.lax.dynamic_index_in_dim(outs, o_idx,
                                                   keepdims=False)
                outs = jax.lax.dynamic_update_index_in_dim(
                    outs, jnp.where(emit, y, cur), o_idx, 0)
                recv = jax.lax.ppermute(y, axis, shift)
                return (recv, outs), None

            carry0 = (jnp.zeros_like(xs[0]), jnp.zeros_like(xs))
            (_, outs), _ = jax.lax.scan(
                tick, carry0, jnp.arange(n_micro + n_stages - 1))
            # results live on the last stage only; replicate them
            outs = jnp.where(stage == n_stages - 1, outs, 0.0)
            return jax.lax.psum(outs, axis)

        ys = _run(stage_params, xs)
        return ys.reshape((batch,) + x.shape[1:])

    return pipe
