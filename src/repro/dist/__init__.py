"""Distribution substrate: sharding rules, gradient compression, pipeline
parallelism.

The model/train/launch layers import these to turn single-device step
functions into multi-device GSPMD programs — the genuinely multi-chip tasks
(``ResourceVector.chips > 1``) the paper's schedulers place.

  * ``repro.dist.sharding``    — logical-axis activation constraints and
    divisibility-aware parameter/batch/cache PartitionSpecs.
  * ``repro.dist.compression`` — blockwise int8 gradient compression with
    error feedback.
  * ``repro.dist.pipeline``    — GPipe-style microbatch pipeline over a
    ``stage`` mesh axis.
"""
from repro.dist import compression, pipeline, sharding  # noqa: F401
