"""The paper's primary contribution: compiler-guided GPU-task scheduling.

Pipeline (paper Fig. 2): task construction (taskgraph, Alg. 1) -> probes
(probe: resource vectors from XLA compiled artifacts) -> lazy runtime (lazy:
device-independent buffers) -> scheduler (scheduler.*: SA / CG / schedGPU
baselines, MGB Alg. 2 + Alg. 3; gang/slice placement over the pod/mesh
topology model in ``topology`` — contiguous device groups with ICI/DCN link
accounting) -> execution (cluster: the open-arrival submission front-end;
executor: live event-driven engine; simulator: discrete-event virtual-clock
engine for W1-W8-scale studies).
"""
from repro.core.task import Job, ResourceVector, Task, UnitTask  # noqa: F401
from repro.core.taskgraph import build_gpu_tasks  # noqa: F401

# Cluster/JobHandle/JobStatus are re-exported lazily (PEP 562): cluster.py
# pulls in the live executor and therefore jax, which simulator-only and
# task-only consumers must not pay for at import time.
_CLUSTER_EXPORTS = ("Cluster", "JobHandle", "JobStatus")


def __getattr__(name):
    if name in _CLUSTER_EXPORTS:
        from repro.core import cluster
        return getattr(cluster, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
