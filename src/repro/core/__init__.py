"""The paper's primary contribution: compiler-guided GPU-task scheduling.

Pipeline (paper Fig. 2): task construction (taskgraph, Alg. 1) -> probes
(probe: resource vectors from XLA compiled artifacts) -> lazy runtime (lazy:
device-independent buffers) -> scheduler (scheduler.*: SA / CG / schedGPU
baselines, MGB Alg. 2 + Alg. 3, slice-level) -> execution (executor: live
worker pool; simulator: discrete-event engine for W1-W8-scale studies).
"""
from repro.core.task import Job, ResourceVector, Task, UnitTask  # noqa: F401
from repro.core.taskgraph import build_gpu_tasks  # noqa: F401
