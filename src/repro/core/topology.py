"""Pod/mesh topology model: the device-group substrate for gang placement.

The paper schedules single-GPU tasks inside one node. At pod scale the
schedulable unit for a multi-chip task is a *device group*: a contiguous,
ICI-connected block of a (rows x cols) chip grid inside one pod, or — for
tasks larger than a pod — a window of whole pods bridged by DCN. This module
owns ALL of the grid math that ``scheduler/slice.py`` used to carry privately,
plus the piece the schedulers never had: **per-link bandwidth accounting**.

Model (TPU v5e-like, DESIGN.md §2):

  * a chip is a ``DeviceState`` cell at ``(pod, row, col)``; flat device
    index ``(pod * rows + row) * cols + col`` matches the executor's device
    table;
  * **ICI links** connect orthogonally adjacent cells within a pod (a mesh;
    wraparound torus links are deliberately not modelled — contiguous slices
    never need them);
  * **DCN edges** connect consecutive pods (one aggregate edge per pod pair,
    ~4x slower than an ICI link);
  * a multi-chip task with ``collective_bytes`` puts a steady per-link load
    on every link *internal* to its group: ring collectives move ~the full
    payload through each link of the ring once per pass, so the per-link
    share is ``collective_bytes / est_seconds / link_bw`` — the fraction of
    that link's bandwidth the task occupies per wall-second while running.
    ``reserve``/``release`` maintain the aggregate share per link so a
    scheduler can check headroom at admission and a simulator can dilate
    co-resident gangs that oversubscribe a shared link.

Candidate enumeration is shape-aligned (a k-chip task considers near-square
factorizations of k tiled at multiples of the shape), which keeps the search
cheap and the torus unfragmented — the same policy the old slice scheduler
used, now shared by every topology client.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Sequence, Tuple

from repro.core.scheduler.base import DEFAULT_HBM, DeviceState
from repro.core.task import ResourceVector

# bandwidth constants (match repro.core.probe's roofline): one ICI link of a
# v5e-class chip, and one aggregate DCN edge between two pods
ICI_BW = 50e9
DCN_BW = 12.5e9

Cell = Tuple[int, int, int]            # (pod, row, col)
# ("ici", cell_a, cell_b) with cell_a < cell_b, or ("dcn", pod_a, pod_b)
Link = Tuple


@dataclasses.dataclass(frozen=True)
class SliceRect:
    """A contiguous rectangle of chips on one pod's (rows x cols) grid."""
    pod: int
    r0: int
    c0: int
    rows: int
    cols: int

    @property
    def chips(self) -> int:
        return self.rows * self.cols

    def cells(self) -> Iterator[Cell]:
        for r in range(self.r0, self.r0 + self.rows):
            for c in range(self.c0, self.c0 + self.cols):
                yield (self.pod, r, c)


@dataclasses.dataclass(frozen=True)
class GangReservation:
    """An atomically-held device group: one rect (intra-pod gang) or a window
    of whole-pod rects bridged by DCN. Duck-compatible with the old bare
    ``SliceRect`` placement (``chips``, ``cells()``), plus the flat
    ``device_indices`` the executor's device table and the simulator's busy
    accounting consume."""
    rects: Tuple[SliceRect, ...]
    device_indices: Tuple[int, ...]

    @property
    def chips(self) -> int:
        return len(self.device_indices)

    @property
    def lead(self) -> int:
        """Flat index of the group's first cell — the placement an audit log
        or a single-device consumer reports."""
        return self.device_indices[0]

    def cells(self) -> Iterator[Cell]:
        for rect in self.rects:
            yield from rect.cells()


def placement_devices(placement) -> Tuple[int, ...]:
    """Normalize a scheduler placement to flat device indices: an int from
    the flat schedulers becomes a 1-tuple, a ``GangReservation`` contributes
    its whole group."""
    idx = getattr(placement, "device_indices", None)
    if idx is not None:
        return tuple(idx)
    return (placement,)


def slice_shapes(chips: int, rows: int, cols: int) -> List[Tuple[int, int]]:
    """Near-square factorizations of ``chips`` that fit the grid (preferred
    first: square slices minimize ring hop count for both mesh axes)."""
    shapes = []
    for r in range(1, chips + 1):
        if chips % r:
            continue
        c = chips // r
        if r <= rows and c <= cols:
            shapes.append((r, c))
    shapes.sort(key=lambda rc: abs(rc[0] - rc[1]))
    return shapes


class Topology:
    """A multi-pod chip grid with per-chip state and per-link bandwidth
    accounting. Schedulers are clients: they decide *policy* (which candidate
    group to take, what counts as feasible); the topology owns *structure*
    (cells, shapes, links) and the link ledger."""

    def __init__(self, pods: int = 1, rows: int = 4, cols: int = 4,
                 hbm_per_chip: int = DEFAULT_HBM,
                 ici_bw: float = ICI_BW, dcn_bw: float = DCN_BW):
        self.pods, self.rows, self.cols = pods, rows, cols
        self.ici_bw, self.dcn_bw = ici_bw, dcn_bw
        self.cells: Dict[Cell, DeviceState] = {
            (p, r, c): DeviceState(index=self.flat_index((p, r, c)),
                                   total_hbm=hbm_per_chip)
            for p in range(pods) for r in range(rows) for c in range(cols)}
        # link -> aggregate bandwidth share ([0, n) — may exceed 1 when a
        # soft-link policy oversubscribes; the simulator dilates then)
        self.link_used: Dict[Link, float] = {}
        # task uid -> {link: share} charged at reserve time, so release is
        # exact even if the task's resources object is rebuilt meanwhile
        self._charges: Dict[int, Dict[Link, float]] = {}

    # -- indexing -----------------------------------------------------------
    @property
    def pod_size(self) -> int:
        return self.rows * self.cols

    @property
    def total_chips(self) -> int:
        return self.pods * self.pod_size

    def flat_index(self, cell: Cell) -> int:
        p, r, c = cell
        return (p * self.rows + r) * self.cols + c

    def cell_of(self, flat: int) -> Cell:
        c = flat % self.cols
        pr = flat // self.cols
        return (pr // self.rows, pr % self.rows, c)

    def device_list(self) -> List[DeviceState]:
        """Cells in flat-index order — the executor's device table view."""
        return list(self.cells.values())

    # -- candidate enumeration ----------------------------------------------
    def _reservation(self, rects: Sequence[SliceRect]) -> GangReservation:
        idx = tuple(self.flat_index(c) for rect in rects
                    for c in rect.cells())
        return GangReservation(tuple(rects), idx)

    def candidate_groups(self, chips: int) -> Iterator[GangReservation]:
        """Every device group a ``chips``-sized gang could hold: contiguous
        rects inside one pod (shape-aligned tiling, near-square shapes
        first), or — past one pod's capacity — windows of whole pods. The
        caller filters by its own feasibility policy."""
        if chips <= self.pod_size:
            for (sr, sc) in slice_shapes(chips, self.rows, self.cols):
                for pod in range(self.pods):
                    for r0 in range(0, self.rows - sr + 1, sr):
                        for c0 in range(0, self.cols - sc + 1, sc):
                            yield self._reservation(
                                [SliceRect(pod, r0, c0, sr, sc)])
            return
        if chips % self.pod_size:
            return  # pod-spanning gangs are whole-pod multiples only
        m = chips // self.pod_size
        for p0 in range(0, self.pods - m + 1):
            yield self._reservation(
                [SliceRect(p, 0, 0, self.rows, self.cols)
                 for p in range(p0, p0 + m)])

    def has_feasible_shape(self, chips: int) -> bool:
        """Does ANY candidate group of this size exist on the grid at all
        (alive or not)? False means the gang shape itself is impossible —
        e.g. 5 chips on a 4x4 pod (no 1x5 fits), or a non-pod-multiple
        spanning request — and a scheduler should fail it fast rather than
        park it forever."""
        return next(iter(self.candidate_groups(chips)), None) is not None

    # -- link model ----------------------------------------------------------
    @staticmethod
    def _ici_link(a: Cell, b: Cell) -> Link:
        return ("ici", a, b) if a < b else ("ici", b, a)

    def internal_links(self, res: GangReservation) -> List[Link]:
        """Links a gang's collectives traverse: every ICI link between
        adjacent cells inside each rect, plus the DCN edge between each
        consecutive pod pair of a spanning reservation."""
        links: List[Link] = []
        for rect in res.rects:
            for (p, r, c) in rect.cells():
                if r + 1 < rect.r0 + rect.rows:
                    links.append(self._ici_link((p, r, c), (p, r + 1, c)))
                if c + 1 < rect.c0 + rect.cols:
                    links.append(self._ici_link((p, r, c), (p, r, c + 1)))
        pods_used = sorted(rect.pod for rect in res.rects)
        for pa, pb in zip(pods_used, pods_used[1:]):
            links.append(("dcn", pa, pb))
        return links

    def link_share(self, resources: ResourceVector,
                   dcn: bool = False) -> float:
        """Steady-state fraction of one link's bandwidth the task's
        collectives occupy while it runs (ring model: the full payload
        crosses each ring link once per pass). Clamped to 1.0 — a task
        cannot use more than a link."""
        if resources.chips <= 1 or resources.collective_bytes <= 0:
            return 0.0
        est = max(resources.est_seconds, 1e-12)
        bw = self.dcn_bw if dcn else self.ici_bw
        return min(resources.collective_bytes / est / bw, 1.0)

    def link_charges(self, res: GangReservation,
                     resources: ResourceVector) -> Dict[Link, float]:
        """Per-link share this gang would add: ICI share on internal mesh
        links, DCN share on pod-bridging edges."""
        ici = self.link_share(resources)
        dcn = self.link_share(resources, dcn=True)
        return {link: (dcn if link[0] == "dcn" else ici)
                for link in self.internal_links(res)
                if (dcn if link[0] == "dcn" else ici) > 0.0}

    def link_headroom_ok(self, res: GangReservation,
                         resources: ResourceVector,
                         tolerance: float = 1e-9) -> bool:
        """Would reserving this group keep every affected link within its
        bandwidth? (The hard-link admission check.)"""
        for link, share in self.link_charges(res, resources).items():
            if self.link_used.get(link, 0.0) + share > 1.0 + tolerance:
                return False
        return True

    def max_link_load(self, res: GangReservation) -> float:
        """Highest aggregate share on any link of the group — the soft-link
        policy's tie-break input and the simulator's dilation input."""
        return max((self.link_used.get(link, 0.0)
                    for link in self.internal_links(res)), default=0.0)

    def reserve_links(self, uid: int, res: GangReservation,
                      resources: ResourceVector) -> None:
        charges = self.link_charges(res, resources)
        for link, share in charges.items():
            self.link_used[link] = self.link_used.get(link, 0.0) + share
        if charges:
            self._charges[uid] = charges

    def task_link_loads(self, uid: int) -> List[float]:
        """Current aggregate share on each link task ``uid`` is charged on —
        the simulator's ICI-dilation input (empty for link-free tasks)."""
        return [self.link_used.get(link, 0.0)
                for link in self._charges.get(uid, ())]

    def release_links(self, uid: int) -> None:
        for link, share in self._charges.pop(uid, {}).items():
            left = self.link_used.get(link, 0.0) - share
            if left <= 1e-12:
                self.link_used.pop(link, None)
            else:
                self.link_used[link] = left

    # -- liveness ------------------------------------------------------------
    def alive_count(self) -> int:
        return sum(1 for d in self.cells.values() if d.alive)
