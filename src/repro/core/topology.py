"""Pod/mesh topology model: the device-group substrate for gang placement.

The paper schedules single-GPU tasks inside one node. At pod scale the
schedulable unit for a multi-chip task is a *device group*: a contiguous,
ICI-connected block of a (rows x cols) chip grid inside one pod, or — for
tasks larger than a pod — a window of whole pods bridged by DCN. This module
owns ALL of the grid math that ``scheduler/slice.py`` used to carry privately,
plus the piece the schedulers never had: **per-link bandwidth accounting**.

Model (TPU v5e-like, DESIGN.md §2):

  * a chip is a ``DeviceState`` cell at ``(pod, row, col)``; flat device
    index ``(pod * rows + row) * cols + col`` matches the executor's device
    table;
  * **ICI links** connect orthogonally adjacent cells within a pod (a mesh;
    wraparound torus links are deliberately not modelled — contiguous slices
    never need them);
  * **DCN edges** connect consecutive pods (one aggregate edge per pod pair,
    ~4x slower than an ICI link);
  * a multi-chip task with ``collective_bytes`` puts a steady per-link load
    on every link *internal* to its group: ring collectives move ~the full
    payload through each link of the ring once per pass, so the per-link
    share is ``collective_bytes / est_seconds / link_bw`` — the fraction of
    that link's bandwidth the task occupies per wall-second while running.
    ``reserve``/``release`` maintain the aggregate share per link so a
    scheduler can check headroom at admission and a simulator can dilate
    co-resident gangs that oversubscribe a shared link.

Candidate enumeration is shape-aligned (a k-chip task considers near-square
factorizations of k tiled at multiples of the shape), which keeps the search
cheap and the torus unfragmented — the same policy the old slice scheduler
used, now shared by every topology client.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

from repro.core.scheduler.base import DEFAULT_HBM, DeviceState
from repro.core.task import ResourceVector

# bandwidth constants (match repro.core.probe's roofline): one ICI link of a
# v5e-class chip, and one aggregate DCN edge between two pods
ICI_BW = 50e9
DCN_BW = 12.5e9

Cell = Tuple[int, int, int]            # (pod, row, col)
# ("ici", cell_a, cell_b) with cell_a < cell_b, or ("dcn", pod_a, pod_b)
Link = Tuple


@dataclasses.dataclass(frozen=True)
class SliceRect:
    """A contiguous rectangle of chips on one pod's (rows x cols) grid."""
    pod: int
    r0: int
    c0: int
    rows: int
    cols: int

    @property
    def chips(self) -> int:
        return self.rows * self.cols

    def cells(self) -> Iterator[Cell]:
        for r in range(self.r0, self.r0 + self.rows):
            for c in range(self.c0, self.c0 + self.cols):
                yield (self.pod, r, c)


@dataclasses.dataclass(frozen=True)
class GangReservation:
    """An atomically-held device group: one rect (intra-pod gang) or a window
    of whole-pod rects bridged by DCN. Duck-compatible with the old bare
    ``SliceRect`` placement (``chips``, ``cells()``), plus the flat
    ``device_indices`` the executor's device table and the simulator's busy
    accounting consume."""
    rects: Tuple[SliceRect, ...]
    device_indices: Tuple[int, ...]

    @property
    def chips(self) -> int:
        return len(self.device_indices)

    @property
    def lead(self) -> int:
        """Flat index of the group's first cell — the placement an audit log
        or a single-device consumer reports."""
        return self.device_indices[0]

    def cells(self) -> Iterator[Cell]:
        for rect in self.rects:
            yield from rect.cells()


def placement_devices(placement) -> Tuple[int, ...]:
    """Normalize a scheduler placement to flat device indices: an int from
    the flat schedulers becomes a 1-tuple, a ``GangReservation`` contributes
    its whole group."""
    idx = getattr(placement, "device_indices", None)
    if idx is not None:
        return tuple(idx)
    return (placement,)


def slice_shapes(chips: int, rows: int, cols: int) -> List[Tuple[int, int]]:
    """Near-square factorizations of ``chips`` that fit the grid (preferred
    first: square slices minimize ring hop count for both mesh axes)."""
    shapes = []
    for r in range(1, chips + 1):
        if chips % r:
            continue
        c = chips // r
        if r <= rows and c <= cols:
            shapes.append((r, c))
    shapes.sort(key=lambda rc: abs(rc[0] - rc[1]))
    return shapes


TilePos = Tuple[int, int, int]         # (pod, r0, c0) of an aligned tile


class _ShapeIndex:
    """Incremental per-shape tile index (the sub-linear placement substrate).

    Aligned tiles of one (sr x sc) shape are DISJOINT — the tiling steps by
    the shape itself — so every cell belongs to at most one tile per shape
    and a cell-state flip updates exactly one tile's counters. Maintains,
    per tile position (enumeration order = ``candidate_groups`` order):

      * ``busy``  — member cells that are dead or hold residents; 0 means
        the tile is a completely free group;
      * ``dead``  — member cells marked dead; ``alive_tiles`` counts tiles
        at dead == 0 (the O(1) ``can_ever_fit`` input);
      * ``free_heap`` — a lazy min-heap of tile positions that became fully
        free (the ISSUE's per-shape free list; stale entries are skimmed on
        peek);
      * ``agg``  — cached (min_free_hbm, max_used_slots, sum_demand) per
        tile, EVICTED whenever a member cell changes and recomputed on
        demand in the same cell order the full enumeration used, so float
        tie-breaks match the historical scan bit-for-bit.
    """

    __slots__ = ("sr", "sc", "rows", "cols", "positions", "busy", "dead",
                 "agg", "alive_tiles", "free_heap")

    def __init__(self, topo: "Topology", sr: int, sc: int):
        self.sr, self.sc = sr, sc
        self.rows, self.cols = topo.rows, topo.cols
        self.positions: List[TilePos] = [
            (p, r0, c0)
            for p in range(topo.pods)
            for r0 in range(0, topo.rows - sr + 1, sr)
            for c0 in range(0, topo.cols - sc + 1, sc)]
        self.busy: Dict[TilePos, int] = {}
        self.dead: Dict[TilePos, int] = {}
        self.agg: Dict[TilePos, Tuple[int, int, float]] = {}
        for pos in self.positions:
            b = d = 0
            for cell in self.tile_cells(pos):
                dev = topo.cells[cell]
                if not dev.alive:
                    d += 1
                if not dev.alive or dev.residents:
                    b += 1
            self.busy[pos] = b
            self.dead[pos] = d
        self.alive_tiles = sum(1 for pos in self.positions
                               if not self.dead[pos])
        self.free_heap: List[TilePos] = [pos for pos in self.positions
                                         if not self.busy[pos]]
        heapq.heapify(self.free_heap)

    def tile_cells(self, pos: TilePos) -> Iterator[Cell]:
        p, r0, c0 = pos
        for r in range(r0, r0 + self.sr):
            for c in range(c0, c0 + self.sc):
                yield (p, r, c)

    def tile_of(self, cell: Cell) -> Optional[TilePos]:
        """The unique tile containing ``cell`` (None for remainder cells
        beyond the last aligned tile of an axis)."""
        p, r, c = cell
        r0 = r - r % self.sr
        c0 = c - c % self.sc
        if r0 + self.sr > self.rows or c0 + self.sc > self.cols:
            return None
        return (p, r0, c0)

    def peek_free(self) -> Optional[TilePos]:
        """Earliest-enumeration fully-free tile, or None (lazy heap skim)."""
        h = self.free_heap
        while h and self.busy[h[0]]:
            heapq.heappop(h)
        return h[0] if h else None


class Topology:
    """A multi-pod chip grid with per-chip state and per-link bandwidth
    accounting. Schedulers are clients: they decide *policy* (which candidate
    group to take, what counts as feasible); the topology owns *structure*
    (cells, shapes, links) and the link ledger.

    **Placement index.** Beyond enumeration (``candidate_groups``), the
    topology maintains incremental per-shape tile indexes (built lazily on
    first query for a shape, then updated on every occupancy/liveness change
    via ``note_cells`` / ``set_alive``) so a placement pass costs O(1) per
    candidate tile instead of O(tile size), ``can_ever_fit``-style checks
    are O(shapes), and completely-free groups come off a maintained free
    list. Contract: all cell-state mutation after the first indexed query
    must go through the owning scheduler's reserve/release paths (which call
    ``note_cells``) or ``set_alive`` — out-of-band mutation should call
    ``invalidate_index()``. Cells are uniform-HBM (``hbm_per_chip``), which
    the O(1) feasibility shortcuts rely on."""

    def __init__(self, pods: int = 1, rows: int = 4, cols: int = 4,
                 hbm_per_chip: int = DEFAULT_HBM,
                 ici_bw: float = ICI_BW, dcn_bw: float = DCN_BW):
        self.pods, self.rows, self.cols = pods, rows, cols
        self.ici_bw, self.dcn_bw = ici_bw, dcn_bw
        self.cells: Dict[Cell, DeviceState] = {
            (p, r, c): DeviceState(index=self.flat_index((p, r, c)),
                                   total_hbm=hbm_per_chip)
            for p in range(pods) for r in range(rows) for c in range(cols)}
        self.hbm_per_chip = hbm_per_chip
        # link -> aggregate bandwidth share ([0, n) — may exceed 1 when a
        # soft-link policy oversubscribes; the simulator dilates then)
        self.link_used: Dict[Link, float] = {}
        # task uid -> {link: share} charged at reserve time, so release is
        # exact even if the task's resources object is rebuilt meanwhile
        self._charges: Dict[int, Dict[Link, float]] = {}
        # placement index state (see class docstring): per-shape tile
        # indexes built lazily, plus per-cell busy/dead snapshots so a
        # note_cells call can turn "cell changed" into exact tile deltas
        self._shape_indexes: Dict[Tuple[int, int], _ShapeIndex] = {}
        self._shape_cache: Dict[int, List[Tuple[int, int]]] = {}
        self._cell_busy: Dict[Cell, bool] = {c: False for c in self.cells}
        self._cell_dead: Dict[Cell, bool] = {c: False for c in self.cells}
        self._pod_dead: List[int] = [0] * pods

    # -- indexing -----------------------------------------------------------
    @property
    def pod_size(self) -> int:
        return self.rows * self.cols

    @property
    def total_chips(self) -> int:
        return self.pods * self.pod_size

    def flat_index(self, cell: Cell) -> int:
        p, r, c = cell
        return (p * self.rows + r) * self.cols + c

    def cell_of(self, flat: int) -> Cell:
        c = flat % self.cols
        pr = flat // self.cols
        return (pr // self.rows, pr % self.rows, c)

    def device_list(self) -> List[DeviceState]:
        """Cells in flat-index order — the executor's device table view."""
        return list(self.cells.values())

    # -- candidate enumeration ----------------------------------------------
    def _reservation(self, rects: Sequence[SliceRect]) -> GangReservation:
        idx = tuple(self.flat_index(c) for rect in rects
                    for c in rect.cells())
        return GangReservation(tuple(rects), idx)

    def candidate_groups(self, chips: int) -> Iterator[GangReservation]:
        """Every device group a ``chips``-sized gang could hold: contiguous
        rects inside one pod (shape-aligned tiling, near-square shapes
        first), or — past one pod's capacity — windows of whole pods. The
        caller filters by its own feasibility policy."""
        if chips <= self.pod_size:
            for (sr, sc) in slice_shapes(chips, self.rows, self.cols):
                for pod in range(self.pods):
                    for r0 in range(0, self.rows - sr + 1, sr):
                        for c0 in range(0, self.cols - sc + 1, sc):
                            yield self._reservation(
                                [SliceRect(pod, r0, c0, sr, sc)])
            return
        if chips % self.pod_size:
            return  # pod-spanning gangs are whole-pod multiples only
        m = chips // self.pod_size
        for p0 in range(0, self.pods - m + 1):
            yield self._reservation(
                [SliceRect(p, 0, 0, self.rows, self.cols)
                 for p in range(p0, p0 + m)])

    def has_feasible_shape(self, chips: int) -> bool:
        """Does ANY candidate group of this size exist on the grid at all
        (alive or not)? False means the gang shape itself is impossible —
        e.g. 5 chips on a 4x4 pod (no 1x5 fits), or a non-pod-multiple
        spanning request — and a scheduler should fail it fast rather than
        park it forever."""
        return next(iter(self.candidate_groups(chips)), None) is not None

    # -- incremental placement index -----------------------------------------
    def shapes_for(self, chips: int) -> List[Tuple[int, int]]:
        """``slice_shapes`` memoized per gang size (the list is a pure
        function of the static grid)."""
        s = self._shape_cache.get(chips)
        if s is None:
            s = slice_shapes(chips, self.rows, self.cols)
            self._shape_cache[chips] = s
        return s

    def shape_index(self, sr: int, sc: int) -> _ShapeIndex:
        idx = self._shape_indexes.get((sr, sc))
        if idx is None:
            idx = _ShapeIndex(self, sr, sc)
            self._shape_indexes[(sr, sc)] = idx
        return idx

    def tile_group(self, sr: int, sc: int, pos: TilePos) -> GangReservation:
        p, r0, c0 = pos
        return self._reservation([SliceRect(p, r0, c0, sr, sc)])

    def tile_agg(self, idx: _ShapeIndex,
                 pos: TilePos) -> Tuple[int, int, float]:
        """Cached per-tile (min free HBM, max used slots, sum of in-use
        demand). Recomputed on demand after eviction; the demand sum walks
        cells in rect order — the exact float-add sequence of the historical
        per-candidate scan — so placement tie-breaks cannot drift."""
        a = idx.agg.get(pos)
        if a is None:
            min_free: Optional[int] = None
            max_slots = 0
            sum_demand = 0.0
            for cell in idx.tile_cells(pos):
                d = self.cells[cell]
                free = d.free_hbm
                if min_free is None or free < min_free:
                    min_free = free
                if d.used_slots > max_slots:
                    max_slots = d.used_slots
                sum_demand += d.in_use_demand
            a = (min_free if min_free is not None else 0,
                 max_slots, sum_demand)
            idx.agg[pos] = a
        return a

    def note_cells(self, cells_changed: Iterable[Cell]) -> None:
        """Occupancy/liveness of these cells may have changed: update every
        built shape index incrementally. O(changed cells x built shapes) —
        tiles are disjoint per shape, so each cell touches exactly one tile
        per shape. Reserve/release paths call this; see the class docstring
        for the out-of-band-mutation contract."""
        for cell in cells_changed:
            d = self.cells[cell]
            dead = not d.alive
            busy = dead or bool(d.residents)
            old_dead = self._cell_dead[cell]
            old_busy = self._cell_busy[cell]
            if dead != old_dead:
                self._cell_dead[cell] = dead
                self._pod_dead[cell[0]] += 1 if dead else -1
            if busy != old_busy:
                self._cell_busy[cell] = busy
            for idx in self._shape_indexes.values():
                pos = idx.tile_of(cell)
                if pos is None:
                    continue
                idx.agg.pop(pos, None)
                if busy != old_busy:
                    n = idx.busy[pos] + (1 if busy else -1)
                    idx.busy[pos] = n
                    if n == 0:
                        heapq.heappush(idx.free_heap, pos)
                if dead != old_dead:
                    n = idx.dead[pos] + (1 if dead else -1)
                    idx.dead[pos] = n
                    if dead and n == 1:
                        idx.alive_tiles -= 1
                    elif not dead and n == 0:
                        idx.alive_tiles += 1

    def set_alive(self, cell: Cell, alive: bool) -> None:
        """Liveness flips route through here so the index stays exact."""
        self.cells[cell].alive = alive
        self.note_cells((cell,))

    def invalidate_index(self) -> None:
        """Drop all built shape indexes (rebuilt lazily from true cell
        state). Escape hatch for callers that mutated cells out-of-band."""
        self._shape_indexes.clear()
        for cell, d in self.cells.items():
            self._cell_dead[cell] = not d.alive
            self._cell_busy[cell] = not d.alive or bool(d.residents)
        self._pod_dead = [0] * self.pods
        for (p, _, _), dead in self._cell_dead.items():
            if dead:
                self._pod_dead[p] += 1

    def any_alive_group(self, chips: int, per_chip: int) -> bool:
        """O(shapes) ``can_ever_fit`` input: does a candidate group exist
        whose members are ALL alive and could each hold ``per_chip`` bytes
        when empty? (Uniform ``hbm_per_chip`` makes the memory test
        group-independent.)"""
        if per_chip > self.hbm_per_chip:
            return False
        if chips <= self.pod_size:
            return any(self.shape_index(sr, sc).alive_tiles > 0
                       for (sr, sc) in self.shapes_for(chips))
        if chips % self.pod_size:
            return False
        m = chips // self.pod_size
        return any(all(self._pod_dead[p] == 0 for p in range(p0, p0 + m))
                   for p0 in range(self.pods - m + 1))

    def free_groups(self, chips: int) -> Iterator[GangReservation]:
        """Completely-free candidate groups straight off the maintained
        free lists (preferred shapes first, enumeration order within a
        shape) — no grid re-enumeration. Spanning sizes fall back to the
        enumerated path (pod windows are few)."""
        if chips <= self.pod_size:
            for (sr, sc) in self.shapes_for(chips):
                idx = self.shape_index(sr, sc)
                for pos in sorted(p for p in set(idx.free_heap)
                                  if not idx.busy[p]):
                    yield self.tile_group(sr, sc, pos)
            return
        for group in self.candidate_groups(chips):
            if all(not self._cell_busy[c] for c in group.cells()):
                yield group

    # -- link model ----------------------------------------------------------
    @staticmethod
    def _ici_link(a: Cell, b: Cell) -> Link:
        return ("ici", a, b) if a < b else ("ici", b, a)

    def internal_links(self, res: GangReservation) -> List[Link]:
        """Links a gang's collectives traverse: every ICI link between
        adjacent cells inside each rect, plus the DCN edge between each
        consecutive pod pair of a spanning reservation."""
        links: List[Link] = []
        for rect in res.rects:
            for (p, r, c) in rect.cells():
                if r + 1 < rect.r0 + rect.rows:
                    links.append(self._ici_link((p, r, c), (p, r + 1, c)))
                if c + 1 < rect.c0 + rect.cols:
                    links.append(self._ici_link((p, r, c), (p, r, c + 1)))
        pods_used = sorted(rect.pod for rect in res.rects)
        for pa, pb in zip(pods_used, pods_used[1:]):
            links.append(("dcn", pa, pb))
        return links

    def link_share(self, resources: ResourceVector,
                   dcn: bool = False) -> float:
        """Steady-state fraction of one link's bandwidth the task's
        collectives occupy while it runs (ring model: the full payload
        crosses each ring link once per pass). Clamped to 1.0 — a task
        cannot use more than a link."""
        if resources.chips <= 1 or resources.collective_bytes <= 0:
            return 0.0
        est = max(resources.est_seconds, 1e-12)
        bw = self.dcn_bw if dcn else self.ici_bw
        return min(resources.collective_bytes / est / bw, 1.0)

    def link_charges(self, res: GangReservation,
                     resources: ResourceVector) -> Dict[Link, float]:
        """Per-link share this gang would add: ICI share on internal mesh
        links, DCN share on pod-bridging edges."""
        ici = self.link_share(resources)
        dcn = self.link_share(resources, dcn=True)
        return {link: (dcn if link[0] == "dcn" else ici)
                for link in self.internal_links(res)
                if (dcn if link[0] == "dcn" else ici) > 0.0}

    def link_headroom_ok(self, res: GangReservation,
                         resources: ResourceVector,
                         tolerance: float = 1e-9) -> bool:
        """Would reserving this group keep every affected link within its
        bandwidth? (The hard-link admission check.)"""
        for link, share in self.link_charges(res, resources).items():
            if self.link_used.get(link, 0.0) + share > 1.0 + tolerance:
                return False
        return True

    def max_link_load(self, res: GangReservation) -> float:
        """Highest aggregate share on any link of the group — the soft-link
        policy's tie-break input and the simulator's dilation input."""
        return max((self.link_used.get(link, 0.0)
                    for link in self.internal_links(res)), default=0.0)

    def reserve_links(self, uid: int, res: GangReservation,
                      resources: ResourceVector) -> None:
        charges = self.link_charges(res, resources)
        for link, share in charges.items():
            self.link_used[link] = self.link_used.get(link, 0.0) + share
        if charges:
            self._charges[uid] = charges

    def task_link_loads(self, uid: int) -> List[float]:
        """Current aggregate share on each link task ``uid`` is charged on —
        the simulator's ICI-dilation input (empty for link-free tasks)."""
        return [self.link_used.get(link, 0.0)
                for link in self._charges.get(uid, ())]

    def release_links(self, uid: int) -> None:
        for link, share in self._charges.pop(uid, {}).items():
            left = self.link_used.get(link, 0.0) - share
            if left <= 1e-12:
                self.link_used.pop(link, None)
            else:
                self.link_used[link] = left

    # -- liveness ------------------------------------------------------------
    def alive_count(self) -> int:
        return sum(1 for d in self.cells.values() if d.alive)
