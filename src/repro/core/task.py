"""GPU tasks and resource vectors — the paper's basic scheduling unit.

Paper §III-A: a *GPU task* is a kernel launch bundled with the memory
operations (alloc / h2d copy / free) required to execute it correctly, so the
whole unit can be bound to ANY device. Here the "kernel launch" is a jitted
JAX computation; the bundled memory objects are the task's input/state buffers
(``repro.core.lazy.LazyBuffer``), and the resource vector is derived from the
XLA compiled artifact (``repro.core.probe``) instead of interpreting
instrumented symbols — strictly better information than the paper's probes.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, FrozenSet, List, Optional, Sequence, Tuple

_task_ids = itertools.count()


@dataclasses.dataclass(frozen=True)
class ResourceVector:
    """The probe payload: everything the scheduler knows about a task.

    Paper: (global-memory bytes, thread blocks, warps/SMs). TPU adaptation
    (DESIGN.md §2): thread-block/warp demand becomes ``core_demand`` — the
    roofline-estimated fraction of one chip's TensorCore-seconds the task
    needs per wall-second while running.
    """
    hbm_bytes: int                 # peak device memory while resident
    flops: float                   # compute work (global)
    bytes_accessed: float          # HBM traffic
    collective_bytes: float = 0.0  # ICI traffic (multi-chip tasks)
    est_seconds: float = 0.0       # roofline duration estimate, solo
    core_demand: float = 1.0       # in (0, 1]: compute-seconds per second
    bw_demand: float = 1.0         # in (0, 1]: HBM-bandwidth-seconds per second
    chips: int = 1                 # devices the task spans (1 = single chip)

    @property
    def demand(self) -> float:
        """Scalar load metric for schedulers — the dominant resource share
        (the paper's 'warps in use' rolled compute and issue slots into one
        number the same way)."""
        return max(self.core_demand, self.bw_demand)

    def scaled(self, work_scale: float) -> "ResourceVector":
        """Same kernel shape, ``work_scale``x the iterations (duration only)."""
        return dataclasses.replace(
            self, flops=self.flops * work_scale,
            bytes_accessed=self.bytes_accessed * work_scale,
            collective_bytes=self.collective_bytes * work_scale,
            est_seconds=self.est_seconds * work_scale)


@dataclasses.dataclass
class UnitTask:
    """One kernel launch + the memory objects it touches (paper Alg. 1 input)."""
    fn: Optional[Callable]            # jitted computation (None in simulation)
    memobjs: FrozenSet[str]           # buffer names (pseudo-addresses)
    resources: ResourceVector
    name: str = ""
    uid: int = dataclasses.field(default_factory=lambda: next(_task_ids))


@dataclasses.dataclass
class Task:
    """A schedulable GPU task: >=1 unit tasks merged over shared memobjs.

    The merge (paper Alg. 1) guarantees every computation touching a given
    buffer lands on the same device, so no cross-device moves are ever paid.
    """
    units: List[UnitTask]
    name: str = ""
    uid: int = dataclasses.field(default_factory=lambda: next(_task_ids))
    # admission class (read by the scheduler's waiter queue): higher priority
    # is admitted first; within a priority class, earlier absolute deadline
    # first (EDF), then submission order. Stamped job-wide by Cluster.submit.
    priority: int = 0
    deadline_t: Optional[float] = None
    # gang identity: multi-chip tasks (resources.chips > 1) carry a label
    # naming the gang they belong to, propagated job -> task -> ExecRecord
    # so a trace can be grouped by gang end to end. None for solo tasks
    # (the executor backfills the job's gang_id at submit).
    gang_id: Optional[str] = None
    # resident-growth binding (continuous batching, serve.engine): when set,
    # this task is a resource DELTA against an already-admitted resident —
    # a decode slot joining a running batch. Admission then only considers
    # the devices currently hosting one of these host tasks (the slot's KV
    # bytes must land next to its batch), still memory/slot-checked, so the
    # memory-hard guarantee covers batch GROWTH, not just task admission.
    grow_hosts: Optional[Tuple["Task", ...]] = None
    # host-side row budget: max concurrent grow-slots this resident can hold
    # (a decode loop has exactly max_batch physical cache rows). Checked by
    # the scheduler's grow admission against `grown_now`, which it maintains
    # (incremented on grow-admit, decremented by DeviceState.release via the
    # slot's `placed_host` back-pointer — so evictions settle it too). None
    # means no per-host cap beyond the device-wide compute-slot ledger.
    slot_budget: Optional[int] = None
    grown_now: int = 0
    placed_host: Optional["Task"] = None
    # preemption bookkeeping: times this task was evicted by the preemptive
    # scheduler layer, counted against PreemptionPolicy.budget (a task at
    # budget is immune to further eviction). Each eviction also adds
    # aging_step to age_boost — an ADMISSION-rank bonus (the waiter queue
    # ranks by priority + age_boost) so a repeatedly-bumped job eventually
    # outranks the arrivals displacing it. Deliberately NOT folded into
    # `priority`: the eviction decision rule compares raw priorities, and an
    # aged victim must never start preempting its own original class.
    preempt_count: int = 0
    age_boost: int = 0
    # runtime bookkeeping (filled by scheduler/executor)
    device: Optional[int] = None
    arrival_t: float = 0.0
    start_t: float = -1.0
    finish_t: float = -1.0
    # -- observed-vs-predicted calibration (obs.calibrate) -------------------
    # probe_vec: the probe's ORIGINAL prediction, stamped by the calibration
    # layer at the task's first admission probe, BEFORE any correction — it
    # is both the ground truth for prediction-error accounting and the
    # calibration store's class key (corrected vectors must not mint new
    # waiter classes or feed back into their own statistics).
    probe_vec: Optional[ResourceVector] = None
    # calibrated_vec: the corrected vector admission actually uses when a
    # CalibrationStore is attached (EWMA-scaled est_seconds, safety-margin
    # memory). When set, `resources` returns it — every reservation,
    # release, and feasibility check then sees the same corrected footprint.
    calibrated_vec: Optional[ResourceVector] = None
    # true_vec: ground truth for studies — the simulator runs the task for
    # true_vec.est_seconds (not the possibly-stale probe estimate) and the
    # profiler reads true_vec.hbm_bytes as the observed memory high-water.
    # None outside synthetic drift workloads (live tasks ARE ground truth).
    true_vec: Optional[ResourceVector] = None

    @property
    def memobjs(self) -> FrozenSet[str]:
        out: FrozenSet[str] = frozenset()
        for u in self.units:
            out |= u.memobjs
        return out

    @property
    def resources(self) -> ResourceVector:
        """Aggregate vector: memory is the UNION footprint (buffers shared),
        work is the sum; core_demand is the duration-weighted mean. A
        calibration-corrected vector (``calibrated_vec``) takes precedence —
        admission, release, and feasibility all see the same correction."""
        if self.calibrated_vec is not None:
            return self.calibrated_vec
        if len(self.units) == 1:
            return self.units[0].resources
        rs = [u.resources for u in self.units]
        tot_s = sum(r.est_seconds for r in rs)
        mem = _union_hbm(self.units)
        return ResourceVector(
            hbm_bytes=mem,
            flops=sum(r.flops for r in rs),
            bytes_accessed=sum(r.bytes_accessed for r in rs),
            collective_bytes=sum(r.collective_bytes for r in rs),
            est_seconds=tot_s,
            core_demand=(sum(r.core_demand * r.est_seconds for r in rs) / tot_s
                         if tot_s else max(r.core_demand for r in rs)),
            bw_demand=(sum(r.bw_demand * r.est_seconds for r in rs) / tot_s
                       if tot_s else max(r.bw_demand for r in rs)),
            chips=max(r.chips for r in rs),
        )

    def __repr__(self) -> str:
        r = self.resources
        return (f"Task({self.name or self.uid}, mem={r.hbm_bytes / 1e9:.2f}GB, "
                f"demand={r.demand:.2f}, est={r.est_seconds:.3f}s, "
                f"units={len(self.units)})")


def _union_hbm(units: Sequence[UnitTask]) -> int:
    """Union footprint: shared buffers counted once. Without per-buffer sizes
    we take max(unit footprints) + sum of each unit's private excess estimate;
    conservatively: max when all buffers shared, sum when disjoint. We use the
    fraction of shared memobjs as the interpolation weight."""
    if not units:
        return 0
    mems = [u.resources.hbm_bytes for u in units]
    all_objs = set().union(*(u.memobjs for u in units))
    if not all_objs:
        return sum(mems)
    counts = sum(len(u.memobjs) for u in units)
    shared_frac = 1.0 - len(all_objs) / max(counts, 1)
    return int(max(mems) + (1.0 - shared_frac) * (sum(mems) - max(mems)))


@dataclasses.dataclass
class Job:
    """A queued batch job = an ordered sequence of GPU tasks from one process.

    In the paper's evaluation a job is one Rodinia/Darknet process; its tasks
    all run on the device the scheduler picks for the first task-begin (the
    lazy runtime re-binds buffers there).
    """
    tasks: List[Task]
    name: str = ""
    uid: int = dataclasses.field(default_factory=lambda: next(_task_ids))
    arrival_t: float = 0.0
    finish_t: float = -1.0
    crashed: bool = False
    # why the job crashed, when the scheduler can say (e.g. the
    # infeasible-placement fast-fail); empty for runner exceptions/OOMs
    error: str = ""
    # admission class for every task in the job (see Task.priority)
    priority: int = 0
    deadline_t: Optional[float] = None
    # gang label stamped onto every task lacking one (see Task.gang_id)
    gang_id: Optional[str] = None

    @property
    def total_seconds(self) -> float:
        return sum(t.resources.est_seconds for t in self.tasks)

    @property
    def peak_hbm(self) -> int:
        return max((t.resources.hbm_bytes for t in self.tasks), default=0)


def true_work_seconds(task: Task) -> float:
    """Ground-truth solo work for ``task`` — what the simulator should RUN,
    as opposed to what admission PREDICTS. Precedence: an explicit
    ``true_vec`` (synthetic drift workloads), then the stamped original
    probe estimate, then the current vector. Keeping this separate from
    ``task.resources.est_seconds`` is what lets calibration correct the
    prediction without changing the simulated physics."""
    tv = task.true_vec
    if tv is not None:
        return tv.est_seconds
    pv = task.probe_vec
    if pv is not None:
        return pv.est_seconds
    return task.resources.est_seconds


def observed_highwater(task: Task) -> int:
    """Observed peak device memory for ``task``: the ground-truth vector's
    footprint when one exists, else the original probe's (a probe-exact
    prediction — the compiled artifact's actual buffer plan — IS the
    observation for live runs)."""
    tv = task.true_vec
    if tv is not None:
        return tv.hbm_bytes
    pv = task.probe_vec
    if pv is not None:
        return pv.hbm_bytes
    return task.resources.hbm_bytes
