"""Lazy runtime: device-independent buffers with deferred binding.

Paper §III-A2: statically-unbound memory ops are replaced by lazy ops that
record into a per-buffer queue under a *pseudo-address*; just before a kernel
launch, ``kernelLaunchPrepare`` replays the queues on the device the scheduler
picked and patches the real addresses in.

JAX analogue: arrays are device-bound at creation, so a task that pre-created
its inputs could never be moved. ``LazyBuffer`` records (alloc / h2d / fill)
ops against host-side state; ``bind(device)`` replays them via
``jax.device_put`` onto the scheduler-chosen device. ``kernel_launch_prepare``
binds every buffer of a task and returns the real arrays for the launch.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_pseudo_addr = itertools.count(0x1000)


@dataclasses.dataclass
class _Op:
    kind: str                     # alloc | h2d | fill
    payload: Any = None


class LazyBuffer:
    """A memory object with a pseudo-address and a recorded op queue."""

    def __init__(self, name: str = ""):
        self.pseudo = next(_pseudo_addr)
        self.name = name or f"buf@{self.pseudo:#x}"
        self.ops: List[_Op] = []
        self.shape: Optional[Tuple[int, ...]] = None
        self.dtype: Any = None
        self.device: Optional[Any] = None
        self._real: Optional[jax.Array] = None

    # -- recorded (lazy) operations --------------------------------------
    def alloc(self, shape: Sequence[int], dtype=jnp.float32) -> "LazyBuffer":
        """lazyMalloc: record the allocation; nothing touches a device."""
        self.shape, self.dtype = tuple(shape), jnp.dtype(dtype)
        self.ops.append(_Op("alloc"))
        return self

    def h2d(self, host_array: np.ndarray) -> "LazyBuffer":
        """lazy cudaMemcpyHostToDevice."""
        if self.shape is None:
            self.alloc(host_array.shape, host_array.dtype)
        self.ops.append(_Op("h2d", np.asarray(host_array)))
        return self

    def fill(self, value) -> "LazyBuffer":
        """lazy cudaMemset."""
        self.ops.append(_Op("fill", value))
        return self

    @property
    def nbytes(self) -> int:
        if self.shape is None:
            return 0
        return int(np.prod(self.shape, dtype=np.int64)) * self.dtype.itemsize

    # -- replay -----------------------------------------------------------
    def bind(self, device) -> jax.Array:
        """Replay the recorded queue on ``device`` and return the real array."""
        if self._real is not None and self.device == device:
            return self._real
        assert self.shape is not None, f"{self.name}: bind before alloc"
        arr: Optional[jax.Array] = None
        for op in self.ops:
            if op.kind == "alloc":
                arr = None  # allocation is realised by the first write below
            elif op.kind == "h2d":
                arr = jax.device_put(op.payload.astype(self.dtype), device)
            elif op.kind == "fill":
                arr = jax.device_put(
                    jnp.full(self.shape, op.payload, self.dtype), device)
        if arr is None:  # bare alloc: zeros (deterministic, like cudaMalloc+memset)
            arr = jax.device_put(jnp.zeros(self.shape, self.dtype), device)
        self._real = arr
        self.device = device
        return arr

    def free(self):
        """cudaFree: drop the device reference (post-dominator of the task)."""
        self._real = None
        self.device = None

    def d2h(self) -> np.ndarray:
        assert self._real is not None, f"{self.name}: d2h before bind"
        return np.asarray(self._real)

    def __repr__(self):
        return (f"LazyBuffer({self.name}, {self.shape}, {self.dtype}, "
                f"bound={self._real is not None})")


def kernel_launch_prepare(buffers: Dict[str, LazyBuffer], device
                          ) -> Dict[str, jax.Array]:
    """Paper's ``kernelLaunchPrepare``: replay every buffer queue on the
    scheduler-chosen device, returning pseudo-address -> real array."""
    return {name: buf.bind(device) for name, buf in buffers.items()}


def free_all(buffers: Dict[str, LazyBuffer]) -> None:
    for buf in buffers.values():
        buf.free()
