"""Co-execution interference model for tasks sharing a chip.

TPUs serialize programs per core, so "sharing a chip" means queued
co-execution — the TPU analogue of MPS timeslicing (DESIGN.md §2). Two
contended resources per chip:

  * compute (TensorCore-seconds): resident i needs ``core_demand`` d_i;
  * HBM bandwidth: resident i needs ``bw_demand`` b_i.

If sum(d) <= 1 and sum(b) <= 1 the chip interleaves memory-stalled tasks
behind compute with no slowdown; past either roof every resident dilates by
the larger oversubscription (processor sharing on the bottleneck resource).
An extra ``eta`` per co-resident models cache/queue overhead — calibrated so
the paper's observed kernel slowdowns (<=2.5% at typical Alg. 3 packing) are
reproduced at total demand ~1 with 2-4 residents.

This is deliberately simple: the paper's schedulers only need a monotone
"overload hurts, modestly" model, and §V-F shows slowdowns stay in single
digits under both algorithms.
"""
from __future__ import annotations

from typing import Sequence, Tuple

ETA_PER_RESIDENT = 0.008   # calibrated: 4 residents -> ~2.4% overhead

# Checkpoint/restore penalty a preempted task pays when it resumes (the
# simulator charges it before new progress; a live training task pays it
# inside train/checkpoint.py's restore path). Calibrated to the repo's
# AsyncCheckpointer scale: a snapshot+restore round trip of a few-GB train
# state is sub-second, small against the 8-40 s benchmark jobs it protects.
CHECKPOINT_PENALTY_S = 0.5

Demand = Tuple[float, float]   # (core_demand, bw_demand)


def slowdown(demands: Sequence[Demand]) -> float:
    """Dilation factor applied to every resident task's progress rate."""
    n = len(demands)
    if n <= 1:
        return 1.0
    core = sum(d for d, _ in demands)
    bw = sum(b for _, b in demands)
    overhead = 1.0 + ETA_PER_RESIDENT * (n - 1)
    return max(core, bw, 1.0) * overhead


def rate(demands: Sequence[Demand]) -> float:
    """Progress rate (fraction of solo speed) for each resident."""
    return 1.0 / slowdown(demands)


def ici_slowdown(link_loads: Sequence[float]) -> float:
    """ICI-contention dilation for a multi-chip task whose collectives share
    mesh links with co-resident gangs.

    ``link_loads`` are the aggregate bandwidth shares (own + neighbours') on
    each link the task is charged on (``Topology.task_link_loads``). Like the
    per-chip model above, this is processor sharing on the bottleneck
    resource: as long as every shared link has headroom (sum <= 1) the
    collectives interleave with no slowdown; past the roof on ANY link the
    whole gang dilates by the worst oversubscription — a synchronized
    collective advances at its slowest link's pace. A link-free task (no
    collectives, or chips == 1) is never dilated."""
    if not link_loads:
        return 1.0
    return max(max(link_loads), 1.0)
