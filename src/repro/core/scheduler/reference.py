"""Reference admission queue: the historical sorted-list implementation.

Before the fleet-scale refactor the waiter queue was a flat list kept sorted
by ``_Waiter.key`` via ``bisect.insort``, and every drain was a full rank-
order scan. That implementation is preserved here VERBATIM (modulo the class
name) as a test-only oracle and benchmark foil:

  * the property battery (``tests/test_sched_scale.py``) replays seeded
    priority/EDF/aging/restart traces through both queues and asserts
    identical admission sequences — the indexed queue in ``base.py`` must be
    bit-for-bit order-equivalent;
  * ``benchmarks/bench_sched_scale.py`` measures the pre-refactor engine's
    admissions/sec against the indexed engine at depth 1e2→1e5.

Do NOT use these classes in production paths: enqueue is O(n) memmove and
every wakeup is O(queue). They exist so the old behaviour stays executable.
"""
from __future__ import annotations

import bisect
import math
from typing import Any, List, Tuple

from repro.core.scheduler.base import (
    DEADLINE_SHED, AdmitCallback, WaiterQueueMixin, _Waiter,
)
from repro.core.scheduler.mgb import MGBAlg2Scheduler, MGBAlg3Scheduler
from repro.core.task import Task


class SortedListWaiterQueueMixin(WaiterQueueMixin):
    """The pre-refactor queue: a bisect-sorted list + full-scan drain.

    Overrides exactly the methods whose implementation moved to the index;
    everything else (epoch fencing, deferred notifications, the admission
    entry points) is shared with the production mixin, so a divergence in a
    parity test localises to the queue representation itself."""

    def _init_waiters(self) -> None:
        super()._init_waiters()
        # kept sorted by _Waiter.key; the drain scans it in rank order
        self._waiters: List[_Waiter] = []

    def _enqueue_locked(self, task: Task, callback: AdmitCallback, *,
                        restart: bool = False) -> _Waiter:
        if restart:
            self._restart_seq -= 1
            seq = self._restart_seq
        else:
            self._seq += 1
            seq = self._seq
        w = _Waiter(task,
                    callback,
                    getattr(task, "priority", 0)
                    + getattr(task, "age_boost", 0),
                    getattr(task, "deadline_t", None), restart, seq,
                    vec=task.resources)
        w.sort_key = w.key
        bisect.insort(self._waiters, w, key=lambda x: x.key)
        return w

    def _drain_locked(self, freed: Any = None
                      ) -> List[Tuple[_Waiter, Any, int]]:
        """The historical rank-order scan (deadline shed, freed-capacity
        hint, bounded failed-vector memo, preemption dominance memo)."""
        fired: List[Tuple[_Waiter, Any, int]] = []
        still: List[_Waiter] = []
        failed: List[Any] = []    # ResourceVectors infeasible this pass
        pfailed: List[Tuple[Any, int, float]] = []
        now = self._clock() if self.shed_expired else None
        # scan a snapshot: a mid-scan preemption re-enqueues its victims into
        # self._waiters (emptied here), so they survive the final merge
        pending, self._waiters = self._waiters, []
        for w in pending:  # already sorted by rank
            if (now is not None and w.deadline_t is not None
                    and now > w.deadline_t):
                self._admit_cbs.pop(w.task.uid, None)
                self._forget_task_locked(w.task)
                fired.append((w, DEADLINE_SHED,
                              self._epochs.get(w.task.uid, 0)))
                continue
            placement = None
            if freed is not None and not self._hint_may_fit(w.task, freed):
                self.hint_skips += 1
            elif any(f == w.task.resources for f in failed):
                pass  # identical vector already failed this pass
            else:
                placement = self._admit_locked(w.task)
                if placement is None and len(failed) < self._DRAIN_MEMO:
                    failed.append(w.task.resources)
            if placement is None and self.preempt_enabled:
                tprio = getattr(w.task, "priority", 0)
                tdl = w.task.deadline_t if w.task.deadline_t is not None \
                    else math.inf
                dominated = any(
                    res == w.task.resources
                    and (prio > tprio or (prio == tprio and dl <= tdl))
                    for res, prio, dl in pfailed)
                if dominated:
                    placement = None
                else:
                    placement = self._preempt_admit_locked(w.task)
                if placement is None:
                    if not dominated and len(pfailed) < self._DRAIN_MEMO:
                        pfailed.append((w.task.resources, tprio, tdl))
                else:
                    failed.clear()
                    pfailed.clear()
                    freed = None
            if placement is None:
                still.append(w)
            else:
                self._admit_cbs[w.task.uid] = w.callback
                fired.append((w, placement,
                              self._epochs.get(w.task.uid, 0)))
        if self._waiters:
            # preemption victims were re-enqueued mid-scan: merge survivors
            for w in still:
                bisect.insort(self._waiters, w, key=lambda x: x.key)
        else:
            self._waiters = still
        return fired

    # -- introspection / cancellation (all O(n) scans, as before) ----------
    def waiting_count(self) -> int:
        with self._lock:
            return len(self._waiters)

    def queue_stats(self) -> dict:
        # recomputed by scan — the very behaviour the satellite replaces;
        # shape-compatible with the production counters for parity tests
        with self._lock:
            per_class: dict = {}
            for w in self._waiters:
                per_class[w.priority] = per_class.get(w.priority, 0) + 1
            return {
                "depth": len(self._waiters),
                "per_class": per_class,
                "classes": len({w.vec for w in self._waiters}),
                "hint_skips": self.hint_skips,
            }

    def waiting_tasks(self) -> List[Task]:
        with self._lock:
            return [w.task for w in self._waiters]

    def cancel_wait(self, task: Task) -> bool:
        with self._lock:
            for w in self._waiters:
                if w.task.uid == task.uid:
                    self._waiters.remove(w)
                    self._admit_cbs.pop(task.uid, None)
                    return True
        return False

    def cancel_all_waiters(self) -> List[Task]:
        with self._lock:
            out = [w.task for w in self._waiters]
            for w in self._waiters:
                self._admit_cbs.pop(w.task.uid, None)
            self._waiters.clear()
            return out

    def _fail_impossible_locked(self) -> List[Tuple[_Waiter, Any, int]]:
        failed: List[Tuple[_Waiter, Any, int]] = []
        still: List[_Waiter] = []
        for w in self._waiters:
            if self.can_ever_fit(w.task):
                still.append(w)
            else:
                self._forget_task_locked(w.task)
                failed.append((w, None, self._epochs.get(w.task.uid, 0)))
        self._waiters = still
        return failed


class ReferenceAlg2Scheduler(SortedListWaiterQueueMixin, MGBAlg2Scheduler):
    """MGB Alg. 2 over the pre-refactor sorted-list queue (oracle)."""

    name = "MGB-Alg2-reference"


class ReferenceAlg3Scheduler(SortedListWaiterQueueMixin, MGBAlg3Scheduler):
    """MGB Alg. 3 over the pre-refactor sorted-list queue (oracle)."""

    name = "MGB-Alg3-reference"
