"""Sharded control plane: one admission engine per pod, stitched into a
single scheduler surface with cross-pod work stealing.

A single global scheduler serializes every probe, wakeup and drain on one
lock; at fleet scale (tens of pods, 1e4+ chips, 1e5 parked waiters) that lock
is the control plane's bottleneck even with the indexed queue. The paper's
daemon shards naturally along the hardware: placement is intra-pod (ICI),
only *data* movement crosses pods (DCN), so admission state factors into
per-pod engines that never need each other's locks on the hot path.

``ShardedScheduler`` owns N shard engines (by default one single-pod
``GangScheduler`` each) and presents the standard scheduler surface —
``admit_or_enqueue`` / ``task_end`` / ``mark_dead`` / ``cancel_wait`` / the
waiter-queue introspection — to the executor, simulator and ``Cluster``:

  * **routing**: a task is owned by exactly one shard at a time
    (``_owner``); every lifecycle call (``task_end``, ``cancel_wait``,
    ``admission_epoch``, ``link_pressure``) goes straight to the owner and
    takes only that shard's lock. Shard locks are NEVER nested;
  * **placement translation**: shards speak shard-local device indices;
    the wrapper translates placements (ints and ``GangReservation``
    device_indices/rect pods) by the shard's flat-index offset, so callers
    index the concatenated ``devices`` table exactly as with a global
    scheduler. ``task.device`` stays shard-local — only the owner shard
    ever dereferences it;
  * **work stealing**: when a ``task_end`` frees capacity on a shard whose
    own queue is empty, the shard steals the best-ranked *portable* waiter
    from the most-loaded shard (portable = single-chip, or a gang whose
    collective stream would fit a DCN edge — a cheap proxy for "its inputs
    can migrate across pods without drowning the interconnect"). The steal
    carries the waiter object whole (rank, seq, callback), transfers the
    task's admission-epoch history via ``adopt_epoch`` — so a superseded
    run's stale ``task_end`` stays fenced after the move — and is
    admit-or-nothing on the target (``try_admit``): a refused waiter is
    restored to its exact source position, so no task is ever lost or
    reordered by a failed steal;
  * **re-homing**: a shard that shrinks (``mark_dead``) until a parked
    waiter can never run there sweeps it with ``placement=None``; the
    wrapper intercepts that verdict and re-parks the waiter on a shard that
    still fits it, only reporting infeasibility to the caller when NO shard
    can ever take it — shard-local death is not fleet-local death.

Pod-spanning gangs (``chips`` beyond one shard) are rejected fast via
``can_ever_fit``/``infeasible_reason``: spanning placement needs the global
``GangScheduler``. Preemptive shards are likewise out of scope (``Cluster``
already requires a ``PreemptionMixin`` host for ``preempt=True``).
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

from repro.core.scheduler.base import (
    DEADLINE_SHED, DEFAULT_HBM, AdmitCallback, DeviceState,
)
from repro.core.scheduler.gang import GangScheduler
from repro.core.task import Task
from repro.core.topology import DCN_BW, ICI_BW, Cell, GangReservation
from repro.obs import events as obs
from repro.obs import explain as obsx

DeviceRef = Union[int, Cell]


class ShardedScheduler:
    """Per-pod sharded admission: N independent engines behind one surface.

    ``shard_factory(shard_index)`` builds each engine (default: a single-pod
    ``GangScheduler`` with the given grid/policy). Shards must expose the
    ``WaiterQueueMixin`` surface and a uniform ``devices`` length — the
    global flat device index is ``shard_index * shard_devices + local``."""

    preempt_enabled = False

    def __init__(self, pods: int = 2, rows: int = 4, cols: int = 4, *,
                 policy: str = "alg3", hbm_per_chip: int = DEFAULT_HBM,
                 ici_bw: float = ICI_BW, dcn_bw: float = DCN_BW,
                 shard_factory: Optional[Callable[[int], Any]] = None):
        if shard_factory is None:
            def shard_factory(si: int, *, _rows=rows, _cols=cols):
                return GangScheduler(1, _rows, _cols, policy=policy,
                                     hbm_per_chip=hbm_per_chip,
                                     ici_bw=ici_bw, dcn_bw=dcn_bw)
        self.shards: List[Any] = [shard_factory(si) for si in range(pods)]
        if not self.shards:
            raise ValueError("ShardedScheduler needs at least one shard")
        counts = {len(sh.devices) for sh in self.shards}
        if len(counts) != 1:
            raise ValueError(f"shards must be uniform, got device counts "
                             f"{sorted(counts)}")
        self._shard_devs = counts.pop()
        # pods per shard (for re-podding gang rects into the global grid);
        # flat shards have no topology and never emit rect placements
        self._shard_pods = {
            si: getattr(getattr(sh, "topo", None), "pods", 1)
            for si, sh in enumerate(self.shards)}
        self.dcn_bw = dcn_bw
        self.name = f"MGB-sharded-{policy}x{pods}"
        # global device table: shard-major concatenation; executor/simulator
        # index it positionally (DeviceState.index stays shard-local — flat
        # shards use it as their placement value, so it must not be rewritten)
        self._devices: List[DeviceState] = [
            d for sh in self.shards for d in sh.devices]
        # task uid -> owning shard index; guards under _lock, read lock-free
        # on hot paths (a task's owner only moves while it is PARKED, and
        # stale task_ends that race a move are epoch-fenced on either shard)
        self._owner: Dict[int, int] = {}
        self._lock = threading.Lock()
        self.steals = 0          # waiters successfully re-homed by stealing
        self.steal_attempts = 0  # steal probes (including refused ones)
        self.rehomes = 0         # waiters migrated off a shrunken shard
        # wrapper-level tracer (steal/restore events); obs.events.
        # attach_tracer also fans the tracer out to every shard with its
        # global device-index offset; explain.attach_explainer does the
        # same for the verdict rings
        self._trace = None
        self._explain = None

    # -- global views ---------------------------------------------------------
    @property
    def devices(self) -> List[DeviceState]:
        return self._devices

    @property
    def begin_attempts(self) -> int:
        return sum(sh.begin_attempts for sh in self.shards)

    @property
    def hint_skips(self) -> int:
        return sum(sh.hint_skips for sh in self.shards)

    @property
    def placements(self) -> List[tuple]:
        out: List[tuple] = []
        for si, sh in enumerate(self.shards):
            off = si * self._shard_devs
            out.extend((uid, lead + off) for uid, lead in sh.placements)
        return out

    @property
    def shed_expired(self) -> bool:
        return self.shards[0].shed_expired

    @shed_expired.setter
    def shed_expired(self, value: bool) -> None:
        for sh in self.shards:
            sh.shed_expired = value

    @property
    def _clock(self) -> Callable[[], float]:
        return self.shards[0]._clock

    @_clock.setter
    def _clock(self, fn: Callable[[], float]) -> None:
        # the simulator repoints the scheduler clock at its virtual time;
        # every shard sheds deadlines on the same timeline
        for sh in self.shards:
            sh._clock = fn

    def alive_devices(self) -> List[DeviceState]:
        return [d for d in self._devices if d.alive]

    def utilization(self) -> float:
        busy = sum(1 for d in self._devices if d.residents)
        return busy / max(len(self._devices), 1)

    # -- routing helpers ------------------------------------------------------
    def _route_device(self, device: DeviceRef) -> Tuple[int, DeviceRef]:
        """Global device reference -> (shard index, shard-local reference)."""
        if isinstance(device, int):
            return device // self._shard_devs, device % self._shard_devs
        p, r, c = device
        sp = self._shard_pods[0]
        return p // sp, (p % sp, r, c)

    def _translate(self, si: int, placement: Any) -> Any:
        """Shard-local placement -> global (flat indices + re-podded rects)."""
        if placement is None or placement is DEADLINE_SHED:
            return placement
        off = si * self._shard_devs
        if isinstance(placement, GangReservation):
            pod_off = si * self._shard_pods[si]
            rects = tuple(dataclasses.replace(rc, pod=rc.pod + pod_off)
                          for rc in placement.rects)
            return GangReservation(
                rects, tuple(d + off for d in placement.device_indices))
        return placement + off

    def _portable(self, task: Task) -> bool:
        """May this waiter be stolen across pods? Single-chip tasks always;
        a gang only when its steady collective stream would fit one DCN edge
        (a proxy for 'migrating its inputs will not drown the interconnect').
        Depends only on the task's resource vector, as ``steal_best_waiter``
        requires — and takes no locks (it runs under the source's)."""
        r = task.resources
        if r.chips <= 1 or r.collective_bytes <= 0:
            return True
        return r.collective_bytes / max(r.est_seconds, 1e-12) <= self.dcn_bw

    def _make_cb(self, user_cb: AdmitCallback) -> AdmitCallback:
        """Wrap an admission callback with owner-relative placement
        translation. The owner is resolved at FIRE time, not capture time,
        so the same wrapper stays correct when a steal moves the waiter."""
        def wrapped(t: Task, placement: Any, epoch: int) -> None:
            si = self._owner.get(t.uid, 0)
            if placement is None:
                # the owning shard shrank until t can never run THERE; that
                # is not a fleet verdict — re-park on a shard that still
                # fits it, carrying the epoch history for the fence
                for tsi, sh in enumerate(self.shards):
                    if tsi == si or not sh.can_ever_fit(t):
                        continue
                    sh.adopt_epoch(t, epoch)
                    with self._lock:
                        self._owner[t.uid] = tsi
                        self.rehomes += 1
                    ex = self._explain
                    if ex is not None:
                        ex.record(t.uid, t.name, obsx.REHOMED,
                                  data={"src": si, "dst": tsi})
                    sh.admit_or_enqueue(t, wrapped)
                    return
                user_cb(t, None, epoch)
                return
            user_cb(t, self._translate(si, placement), epoch)
        return wrapped

    # -- admission ------------------------------------------------------------
    def admit_or_enqueue(self, task: Task, callback: AdmitCallback) -> bool:
        """Probe every shard for immediate capacity (shard order — the same
        first-fit determinism a global scheduler's enumeration gives); park
        on the least-loaded shard that could ever run the task otherwise.
        Returns True iff admitted immediately."""
        wrapped = self._make_cb(callback)
        for si, sh in enumerate(self.shards):
            with self._lock:
                self._owner[task.uid] = si
            if sh.try_admit(task, wrapped) is not None:
                return True
        eligible = [si for si, sh in enumerate(self.shards)
                    if sh.can_ever_fit(task)]
        pool = eligible or list(range(len(self.shards)))
        si = min(pool, key=lambda s: self.shards[s].waiting_count())
        with self._lock:
            self._owner[task.uid] = si
        return self.shards[si].admit_or_enqueue(task, wrapped)

    def try_admit(self, task: Task, callback: AdmitCallback) -> Any:
        """Admit-or-nothing across the shards (never parks)."""
        wrapped = self._make_cb(callback)
        for si, sh in enumerate(self.shards):
            with self._lock:
                self._owner[task.uid] = si
            p = sh.try_admit(task, wrapped)
            if p is not None:
                return self._translate(si, p)
        return None

    def task_begin(self, task: Task) -> Any:
        """Legacy probe API: first shard that takes it (placement is
        translated; ``task_end`` routes by the recorded owner)."""
        for si, sh in enumerate(self.shards):
            p = sh.task_begin(task)
            if p is not None:
                with self._lock:
                    self._owner[task.uid] = si
                return self._translate(si, p)
        return None

    def task_end(self, task: Task, *, epoch: Optional[int] = None) -> bool:
        si = self._owner.get(task.uid)
        if si is None:
            return False
        ok = self.shards[si].task_end(task, epoch=epoch)
        if ok:
            # freed capacity + an empty local queue = steal opportunity
            self._steal_into(si)
        return ok

    # -- feasibility -----------------------------------------------------------
    def can_ever_fit(self, task: Task) -> bool:
        return any(sh.can_ever_fit(task) for sh in self.shards)

    def infeasible_reason(self, task: Task) -> str:
        r = task.resources
        k = max(r.chips, 1)
        if k > self._shard_devs:
            return (f"infeasible placement: gang {task.name or task.uid!r} "
                    f"needs {k} chips but the sharded control plane places "
                    f"each gang within ONE pod shard ({self._shard_devs} "
                    f"chips); pod-spanning gangs need the global "
                    f"GangScheduler")
        return self.shards[0].infeasible_reason(task)

    # -- work stealing ---------------------------------------------------------
    def _steal_into(self, target_si: int) -> None:
        """Pull portable waiters from the most-loaded shard into
        ``target_si`` while its own queue is empty and the steals land.
        Admit-or-nothing: a refused waiter goes back to its exact source
        position. No shard lock is ever held across a cross-shard call."""
        target = self.shards[target_si]
        while not target.waiting_count():
            src_si = max(
                (s for s in range(len(self.shards)) if s != target_si),
                key=lambda s: self.shards[s].waiting_count(), default=None)
            if src_si is None or not self.shards[src_si].waiting_count():
                return
            source = self.shards[src_si]
            w = source.steal_best_waiter(
                lambda t: self._portable(t) and target.can_ever_fit(t))
            if w is None:
                return
            self.steal_attempts += 1
            tr = self._trace
            if tr is not None:
                # STEAL precedes the target's ADMIT (emitted inside its
                # try_admit) so the lifecycle reads park -> steal -> admit
                tr.emit(obs.STEAL, w.task.uid, w.task.name,
                        data={"src": src_si, "dst": target_si})
            # fence transfer BEFORE the admit: the waiter may be an eviction
            # restart whose superseded run is still in flight — its stale
            # task_end must keep failing on the new owner too
            target.adopt_epoch(w.task, source.admission_epoch(w.task))
            with self._lock:
                self._owner[w.task.uid] = target_si
            if target.try_admit(w.task, w.callback) is None:
                with self._lock:
                    self._owner[w.task.uid] = src_si
                source.adopt_epoch(w.task, target.admission_epoch(w.task))
                source.restore_waiter(w)
                if tr is not None:
                    tr.emit(obs.RESTORE, w.task.uid, w.task.name,
                            data={"src": src_si, "dst": target_si})
                ex = self._explain
                if ex is not None:
                    ex.record(w.task.uid, w.task.name, obsx.STEAL_REFUSED,
                              reasons=({"reason": "target_refused",
                                        "src": src_si,
                                        "dst": target_si},),
                              data={"src": src_si, "dst": target_si},
                              collapse=True)
                return
            self.steals += 1
            ex = self._explain
            if ex is not None:
                ex.record(w.task.uid, w.task.name, obsx.STOLEN,
                          data={"src": src_si, "dst": target_si})

    # -- fault tolerance -------------------------------------------------------
    def mark_dead(self, device: DeviceRef) -> List[Task]:
        si, local = self._route_device(device)
        evicted = self.shards[si].mark_dead(local)
        # the shrunken shard's survivors were re-queued locally; idle shards
        # with capacity should pick up its (portable) backlog now rather
        # than at their next task_end
        for tsi in range(len(self.shards)):
            if tsi != si:
                self._steal_into(tsi)
        return evicted

    def revive(self, device: DeviceRef) -> None:
        si, local = self._route_device(device)
        self.shards[si].revive(local)
        self._steal_into(si)

    # -- waiter queue surface --------------------------------------------------
    def notify(self) -> int:
        fired = sum(sh.notify() for sh in self.shards)
        for si in range(len(self.shards)):
            self._steal_into(si)
        return fired

    def waiting_count(self) -> int:
        return sum(sh.waiting_count() for sh in self.shards)

    def queue_stats(self) -> Dict[str, Any]:
        """O(shards) merge of the per-shard O(1) counters, plus the
        per-shard depth vector (the balance the stealing works against)."""
        depth = 0
        classes = 0
        per_class: Dict[int, int] = {}
        per_shard: List[int] = []
        gang_front = None
        for sh in self.shards:
            s = sh.queue_stats()
            depth += s["depth"]
            classes += s["classes"]
            per_shard.append(s["depth"])
            for k, v in s["per_class"].items():
                per_class[k] = per_class.get(k, 0) + v
            if gang_front is None:
                gang_front = s.get("gang_front")
        return {"depth": depth, "per_class": per_class, "classes": classes,
                "hint_skips": self.hint_skips, "per_shard": per_shard,
                "steals": self.steals, "gang_front": gang_front}

    def waiting_tasks(self) -> List[Task]:
        # shard-major snapshot (rank-ordered within each shard)
        return [t for sh in self.shards for t in sh.waiting_tasks()]

    def explain_queue(self, task: Task) -> Optional[Tuple[dict, ...]]:
        """Live rejection probe routed to the owner shard (None when the
        task is not parked anywhere in the fleet)."""
        si = self._owner.get(task.uid)
        if si is None:
            return None
        eq = getattr(self.shards[si], "explain_queue", None)
        return eq(task) if eq is not None else None

    def cancel_wait(self, task: Task) -> bool:
        si = self._owner.get(task.uid)
        if si is None:
            return False
        return self.shards[si].cancel_wait(task)

    def cancel_all_waiters(self) -> List[Task]:
        return [t for sh in self.shards for t in sh.cancel_all_waiters()]

    def admission_epoch(self, task: Task) -> int:
        si = self._owner.get(task.uid)
        if si is None:
            return 0
        return self.shards[si].admission_epoch(task)

    def adopt_epoch(self, task: Task, epoch: int) -> None:
        si = self._owner.get(task.uid)
        if si is not None:
            self.shards[si].adopt_epoch(task, epoch)

    # -- runtime contention (simulator dilation input) -------------------------
    def link_pressure(self, task: Task) -> float:
        si = self._owner.get(task.uid)
        if si is None:
            return 1.0
        lp = getattr(self.shards[si], "link_pressure", None)
        return lp(task) if lp is not None else 1.0
