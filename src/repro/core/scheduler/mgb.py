"""MGB schedulers — the paper's contribution (Algorithms 2 and 3).

Alg. 2 (exact): emulates the hardware dispatcher. On a GPU that means walking
SMs and placing thread blocks; the TPU analogue (DESIGN.md §2) divides each
chip's compute-seconds into ``SLOTS`` equal slots and requires the task's
``ceil(core_demand * SLOTS)`` slots to be free — memory AND compute are hard
constraints, so a task waits until a chip can run it without dilation.

Alg. 3 (fast): memory is hard, compute is soft — among memory-feasible
devices pick the one with the least aggregate in-use core demand (the paper's
"fewest in-use warps"). Optimistic: it will oversubscribe compute to exploit
fast completions, which §V-B shows wins ~1.21x throughput over Alg. 2 at the
cost of <1% extra kernel slowdown.

Both policies are admission-only; their preemptive upgrades (evict running
lower-ranked work for an urgent arrival) live in ``scheduler.preempt`` as
``PreemptiveAlg2Scheduler`` / ``PreemptiveAlg3Scheduler`` — same
``device_feasible`` predicates, reused verbatim by the victim planner.
"""
from __future__ import annotations

from typing import Optional

from repro.core.scheduler.base import (
    SLOTS, DeviceState, Scheduler, slots_needed,
)
from repro.core.task import Task
from repro.obs import explain as obsx


class MGBAlg2Scheduler(Scheduler):
    """Exact slot accounting: memory and compute both hard constraints."""

    name = "MGB-Alg2"

    def device_feasible(self, task: Task, dev: DeviceState) -> bool:
        if not dev.alive:
            return False
        if task.resources.hbm_bytes > dev.free_hbm:
            return False  # memory: hard
        # dev.used_slots is maintained on admit/release: O(1) per device
        return dev.used_slots + slots_needed(task) <= SLOTS  # compute: hard

    def device_verdict(self, task: Task, dev: DeviceState) -> Optional[dict]:
        if not dev.alive:
            return {"device": dev.index + self._trace_dev_off,
                    "reason": obsx.R_DEVICE_DEAD}
        if task.resources.hbm_bytes > dev.free_hbm:
            return {"device": dev.index + self._trace_dev_off,
                    "reason": obsx.R_MEMORY_SHORT,
                    "short_bytes": task.resources.hbm_bytes - dev.free_hbm}
        need = slots_needed(task)
        if dev.used_slots + need > SLOTS:
            return {"device": dev.index + self._trace_dev_off,
                    "reason": obsx.R_SLOTS_FULL,
                    "short_slots": dev.used_slots + need - SLOTS}
        return None

    def select_device(self, task: Task) -> Optional[DeviceState]:
        for dev in self.devices:
            if self.device_feasible(task, dev):
                return dev
        return None


class MGBAlg3Scheduler(Scheduler):
    """Memory-hard / compute-soft: min in-use demand among feasible devices."""

    name = "MGB-Alg3"

    def __init__(self, num_devices: int, max_residents: int = 0, **kw):
        super().__init__(num_devices, **kw)
        # optional resident cap (0 = none). The paper relies on the worker-pool
        # size for backpressure; the executor passes 0.
        self.max_residents = max_residents

    def device_feasible(self, task: Task, dev: DeviceState) -> bool:
        if not dev.alive:
            return False
        if task.resources.hbm_bytes > dev.free_hbm:
            return False  # memory: hard — never an OOM (paper's guarantee)
        return not (self.max_residents
                    and len(dev.residents) >= self.max_residents)

    def device_verdict(self, task: Task, dev: DeviceState) -> Optional[dict]:
        if not dev.alive:
            return {"device": dev.index + self._trace_dev_off,
                    "reason": obsx.R_DEVICE_DEAD}
        if task.resources.hbm_bytes > dev.free_hbm:
            return {"device": dev.index + self._trace_dev_off,
                    "reason": obsx.R_MEMORY_SHORT,
                    "short_bytes": task.resources.hbm_bytes - dev.free_hbm}
        if self.max_residents and len(dev.residents) >= self.max_residents:
            return {"device": dev.index + self._trace_dev_off,
                    "reason": obsx.R_MAX_RESIDENTS,
                    "cap": self.max_residents}
        return None

    def select_device(self, task: Task) -> Optional[DeviceState]:
        best: Optional[DeviceState] = None
        for dev in self.devices:
            if not self.device_feasible(task, dev):
                continue
            if best is None or dev.in_use_demand < best.in_use_demand:
                best = dev
        return best
