from repro.core.scheduler.base import (  # noqa: F401
    DEADLINE_SHED, DeviceState, Scheduler,
)
from repro.core.scheduler.baselines import (  # noqa: F401
    CGScheduler, MemOnlyScheduler, SAScheduler,
)
from repro.core.scheduler.gang import GangScheduler  # noqa: F401
from repro.core.scheduler.mgb import (  # noqa: F401
    MGBAlg2Scheduler, MGBAlg3Scheduler,
)
from repro.core.scheduler.preempt import (  # noqa: F401
    PreemptionMixin, PreemptiveAlg2Scheduler, PreemptiveAlg3Scheduler,
    PreemptiveGangScheduler,
)
from repro.core.scheduler.reference import (  # noqa: F401
    ReferenceAlg2Scheduler, ReferenceAlg3Scheduler,
)
from repro.core.scheduler.sharded import ShardedScheduler  # noqa: F401
from repro.core.scheduler.slice import SliceScheduler  # noqa: F401
