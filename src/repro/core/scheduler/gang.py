"""Gang placement: topology-aware atomic reservation of device groups.

The paper's schedulers place one task on one device. The flagship multi-chip
workloads (sharded train steps, pipeline stages) declare ``chips > 1`` and
need a *gang*: a contiguous, ICI-connected device group reserved **all at
once**. ``GangScheduler`` is that layer, built on the pod/mesh model in
``repro.core.topology`` and the waiter queue in ``scheduler.base``:

  * a gang either gets ALL its chips or parks as ONE waiter — partial
    reservations never exist, so two half-admitted gangs can never deadlock
    against each other holding pieces the other needs;
  * per member chip, memory is checked HARD (the MGB guarantee extends to
    every device a job touches — Reaño et al.'s intra-node memory-safety
    condition, at pod scale) and compute follows the paper's policy split:
    ``policy="alg2"`` requires free slots on every member (exact),
    ``policy="alg3"`` is optimistic — min aggregate demand over candidate
    groups (fewest in-use warps, summed over the group);
  * ICI/DCN **link headroom** is part of admission: a gang's collectives put
    ``collective_bytes / est_seconds / link_bw`` of steady load on every
    link internal to its group (ring model). Under alg2 a group whose links
    would oversubscribe is rejected (links hard); under alg3 link pressure
    is the placement tie-break and oversubscription is tolerated — the
    simulator then dilates the sharing gangs (``interference.ici_slowdown``),
    mirroring how alg3 treats compute;
  * ``task_end`` / ``cancel`` / ``mark_dead`` release the WHOLE reservation
    (chips + links) under the existing epoch fence, and ``task_end`` hints
    the waiter-queue drain with the freed cells so heterogeneous queues skip
    waiters those cells cannot satisfy;
  * a gang whose shape can never exist (more chips than the fleet, or no
    feasible slice factorization, e.g. 5 chips on a 4x4 pod) fails fast via
    ``can_ever_fit`` + ``infeasible_reason`` instead of parking forever.

Single-chip tasks ride the same path as 1x1 groups, so one scheduler serves
a mixed single-chip / multi-chip open-arrival stream.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Tuple, Union

from repro.core import interference
from repro.core.scheduler.base import (
    DEFAULT_HBM, SLOTS, DeviceState, WaiterQueueMixin, slots_needed,
)
from repro.core.task import Task, observed_highwater
from repro.core.topology import (
    DCN_BW, ICI_BW, Cell, GangReservation, Topology,
)
from repro.obs import events as obs
from repro.obs import explain as obsx

CellOrIndex = Union[Cell, int]


class GangScheduler(WaiterQueueMixin):
    """Atomic gang reservation over a ``Topology``, through the shared
    priority/deadline waiter queue. The admission callback receives a
    ``GangReservation`` (``device_indices`` has the whole group, ``lead`` is
    the audit-log index); single-chip tasks get a 1-cell group."""

    def __init__(self, pods: int = 1, rows: int = 4, cols: int = 4, *,
                 policy: str = "alg3", hbm_per_chip: int = DEFAULT_HBM,
                 ici_bw: float = ICI_BW, dcn_bw: float = DCN_BW,
                 topology: Optional[Topology] = None):
        if policy not in ("alg2", "alg3"):
            raise ValueError(f"unknown gang policy {policy!r} "
                             "(expected 'alg2' or 'alg3')")
        if topology is None:
            topology = Topology(pods, rows, cols, hbm_per_chip,
                                ici_bw=ici_bw, dcn_bw=dcn_bw)
        self.topo = topology
        self.pods, self.rows, self.cols = \
            topology.pods, topology.rows, topology.cols
        self.policy = policy
        self.name = f"MGB-gang-{policy}"
        # legacy slice-scheduler surface: cell -> DeviceState (the same dict
        # the topology owns, not a copy)
        self.chips: Dict[Cell, DeviceState] = topology.cells
        # flat-index device-table view, built once (the cell set is fixed
        # after construction); executor/simulator hot paths index this per
        # gang member, so it must not be rebuilt per access
        self._device_list: List[DeviceState] = topology.device_list()
        self.bound: Dict[int, GangReservation] = {}   # task uid -> group
        self._lock = threading.Lock()
        self.begin_attempts = 0
        self.placements: List[tuple] = []   # (task uid, lead device) audit
        self._init_waiters()

    # -- device-table view (what the executor/simulator index) ---------------
    @property
    def devices(self) -> List[DeviceState]:
        return self._device_list

    def _as_cell(self, cell: CellOrIndex) -> Cell:
        return self.topo.cell_of(cell) if isinstance(cell, int) else cell

    # -- feasibility ---------------------------------------------------------
    def _member_ok(self, cell: Cell, per_chip: int, need: int) -> bool:
        """Is this cell admissible as a gang member RIGHT NOW? Memory hard
        always; compute slots hard only under alg2."""
        d = self.topo.cells[cell]
        if not d.alive or per_chip > d.free_hbm:
            return False
        if self.policy == "alg2" and d.used_slots + need > SLOTS:
            return False
        return True

    def _member_ever_ok(self, cell: Cell, per_chip: int, need: int) -> bool:
        """Same predicate against an EMPTY cell (the can_ever_fit check)."""
        d = self.topo.cells[cell]
        if not d.alive or per_chip > d.total_hbm:
            return False
        if self.policy == "alg2" and need > SLOTS:
            return False
        return True

    def _find_group(self, task: Task) -> Optional[GangReservation]:
        """Best feasible group for ``task``, evaluating candidates in the
        same enumeration order (and with the same tie-breaks) as the
        historical full scan, but against the topology's incremental tile
        index: infeasible tiles cost O(1) via cached aggregates instead of
        O(tile size) member walks, and a completely-free tile returns
        immediately — its key is provably the unbeatable (0.0, 0.0), since
        every link internal to a free group has both endpoints resident-free
        and therefore carries no charge."""
        r = task.resources
        k = max(r.chips, 1)
        per_chip = r.hbm_bytes // k
        need = slots_needed(task)
        best: Optional[GangReservation] = None
        best_key: Tuple[float, float] = (float("inf"), float("inf"))
        if k > self.topo.pod_size:
            # whole-pod windows: candidates are O(pods), keep the direct walk
            for group in self.topo.candidate_groups(k):
                if not all(self._member_ok(c, per_chip, need)
                           for c in group.cells()):
                    continue
                if self.policy == "alg2" \
                        and not self.topo.link_headroom_ok(group, r):
                    continue
                key = (sum(self.topo.cells[c].in_use_demand
                           for c in group.cells()),
                       self.topo.max_link_load(group))
                if key < best_key:
                    best, best_key = group, key
                if key == (0.0, 0.0):
                    return group
            return best
        for (sr, sc) in self.topo.shapes_for(k):
            idx = self.topo.shape_index(sr, sc)
            for pos in idx.positions:
                if idx.dead[pos]:
                    continue
                min_free, max_slots, sum_demand = self.topo.tile_agg(idx, pos)
                if per_chip > min_free:
                    continue
                if self.policy == "alg2" and max_slots + need > SLOTS:
                    continue
                group = self.topo.tile_group(sr, sc, pos)
                if not idx.busy[pos]:
                    # free group on idle links: cannot do better (and the
                    # alg2 link-headroom check passes trivially — per-task
                    # share is clamped to one link)
                    return group
                if self.policy == "alg2" \
                        and not self.topo.link_headroom_ok(group, r):
                    continue  # links hard: collectives must not oversubscribe
                # Alg. 3 tie-break, summed over the group: fewest in-use
                # warps first, then least-contended links (soft pressure)
                key = (sum_demand, self.topo.max_link_load(group))
                if key < best_key:
                    best, best_key = group, key
                if key == (0.0, 0.0):
                    return group  # idle group on idle links
        return best

    def can_ever_fit(self, task: Task) -> bool:
        # O(shapes) against the maintained alive-tile counters instead of a
        # full candidate enumeration per submission
        r = task.resources
        k = max(r.chips, 1)
        per_chip = r.hbm_bytes // k
        if self.policy == "alg2" and slots_needed(task) > SLOTS:
            return False
        return self.topo.any_alive_group(k, per_chip)

    def infeasible_reason(self, task: Task) -> str:
        r = task.resources
        k = max(r.chips, 1)
        topo = (f"{self.topo.pods} pod(s) x {self.topo.rows}x"
                f"{self.topo.cols}")
        if not self.topo.has_feasible_shape(k):
            return (f"infeasible placement: gang {task.name or task.uid!r} "
                    f"needs {k} chips but no {k}-chip contiguous group "
                    f"shape exists on the {topo} topology "
                    f"({self.topo.total_chips} chips total)")
        alive = self.topo.alive_count()
        if k > alive:
            return (f"infeasible placement: gang {task.name or task.uid!r} "
                    f"needs {k} chips but only {alive} of "
                    f"{self.topo.total_chips} are alive on the {topo} "
                    f"topology")
        return (f"infeasible placement: gang {task.name or task.uid!r} "
                f"needs {r.hbm_bytes / max(k, 1) / 1e9:.2f} GB HBM per chip "
                f"across {k} chips, beyond every feasible group on the "
                f"{topo} topology ({alive} alive chips)")

    # -- admission / release --------------------------------------------------
    def _admit_locked(self, task: Task) -> Optional[GangReservation]:
        # calibration correction at the first admission probe (idempotent —
        # apply() stamps probe_vec), mirroring Scheduler._admit_locked
        calib = self._calib
        if calib is not None and task.probe_vec is None:
            calib.apply(task)
        self.begin_attempts += 1
        group = self._find_group(task)
        if group is None:
            ex = self._explain
            if ex is not None:
                ex.reject(task.uid, task.name,
                          lambda: self._reject_reasons_locked(task))
            return None
        self._reserve_group_locked(task, group)
        self.placements.append((task.uid, group.lead))
        tr = self._trace
        if tr is not None:
            off = self._trace_dev_off
            tr.emit(obs.ADMIT, task.uid, task.name, group.lead + off,
                    self._epochs.get(task.uid, 0))
            if max(task.resources.chips, 1) > 1:
                tr.emit(obs.GANG_RESERVE, task.uid, task.name,
                        group.lead + off, self._epochs.get(task.uid, 0),
                        data={"devices": tuple(
                            d + off for d in group.device_indices)})
        ex = self._explain
        if ex is not None:
            off = self._trace_dev_off
            data = None
            if max(task.resources.chips, 1) > 1:
                data = {"devices": tuple(
                    d + off for d in group.device_indices)}
            ex.record(task.uid, task.name, obsx.ADMITTED,
                      device=group.lead + off, data=data)
        return group

    def _reject_reasons_locked(self, task: Task) -> Tuple[dict, ...]:
        """Why no group was feasible: one entry per refusing member cell
        (dead / memory-short / alg2 slots-full, mirroring ``_member_ok``),
        plus — when every member of some candidate group passes yet the
        group is still rejected under alg2 — a ``link_headroom`` entry
        naming the first such group. Falls back to ``no_feasible_group``
        when every cell passes individually but no contiguous tile exists."""
        r = task.resources
        k = max(r.chips, 1)
        per_chip = r.hbm_bytes // k
        need = slots_needed(task)
        off = self._trace_dev_off
        out: List[dict] = []
        omitted = 0
        cap = self._REASONS_CAP
        for cell, d in self.topo.cells.items():
            reason = None
            if not d.alive:
                reason = {"device": d.index + off,
                          "reason": obsx.R_DEVICE_DEAD}
            elif per_chip > d.free_hbm:
                reason = {"device": d.index + off,
                          "reason": obsx.R_MEMORY_SHORT,
                          "short_bytes": per_chip - d.free_hbm}
            elif self.policy == "alg2" and d.used_slots + need > SLOTS:
                reason = {"device": d.index + off,
                          "reason": obsx.R_SLOTS_FULL,
                          "short_slots": d.used_slots + need - SLOTS}
            if reason is None:
                continue
            if len(out) < cap:
                out.append(reason)
            else:
                omitted += 1
        if omitted:
            out.append({"reason": "truncated", "omitted": omitted})
        if self.policy == "alg2":
            # a group whose members all fit can still lose on link headroom
            for group in self.topo.candidate_groups(k):
                if all(self._member_ok(c, per_chip, need)
                       for c in group.cells()) \
                        and not self.topo.link_headroom_ok(group, r):
                    out.append({"device": group.lead + off,
                                "reason": obsx.R_LINK_HEADROOM,
                                "devices": tuple(
                                    d + off for d in group.device_indices)})
                    break
        if not out:
            out.append({"reason": obsx.R_NO_FEASIBLE_GROUP, "chips": k})
        return tuple(out)

    def _reserve_group_locked(self, task: Task,
                              group: GangReservation) -> None:
        """Apply the reservation bookkeeping for a KNOWN group: per-chip
        memory/slot charges, link charges, the bound map. Shared by
        admission and by the preemption layer's exact rollback (restoring a
        trial-evicted victim to the group it held)."""
        r = task.resources
        per_chip = r.hbm_bytes // max(r.chips, 1)
        need = slots_needed(task)
        for cell in group.cells():
            d = self.topo.cells[cell]
            # not DeviceState.admit(): a gang charges each member its
            # per-chip share, not the whole-gang footprint
            d.used_hbm += per_chip
            d.used_slots += need
            d.residents[task.uid] = task
        self.topo.reserve_links(task.uid, group, r)
        self.topo.note_cells(group.cells())  # keep the tile index exact
        self.bound[task.uid] = group
        task.device = group.lead

    def _release_locked(self, task: Task) -> Optional[GangReservation]:
        group = self.bound.pop(task.uid, None)
        if group is None:
            return None
        r = task.resources
        per_chip = r.hbm_bytes // max(r.chips, 1)
        need = slots_needed(task)
        for cell in group.cells():
            d = self.topo.cells[cell]
            if task.uid in d.residents:
                del d.residents[task.uid]
                d.used_hbm -= per_chip
                d.used_slots -= need
        self.topo.release_links(task.uid)
        self.topo.note_cells(group.cells())  # keep the tile index exact
        return group

    # -- paper API at gang granularity ----------------------------------------
    def task_begin(self, task: Task) -> Optional[GangReservation]:
        with self._lock:
            return self._admit_locked(task)

    def task_end(self, task: Task, *, epoch: Optional[int] = None) -> bool:
        """Release the WHOLE reservation (chips + links) and re-drive the
        waiter queue, hinting the drain with the freed cells so waiters no
        freed cell can satisfy are skipped without a probe."""
        with self._lock:
            if self._stale_locked(task, epoch):
                return False
            group = self._release_locked(task)
            self._admit_cbs.pop(task.uid, None)
            calib = self._calib
            if calib is not None and group is not None:
                calib.note_end(task, self._clock())
            tr = self._trace
            if tr is not None and group is not None:
                off = self._trace_dev_off
                epoch = self._epochs.get(task.uid, 0)
                if max(task.resources.chips, 1) > 1:
                    tr.emit(obs.GANG_RELEASE, task.uid, task.name,
                            group.lead + off, epoch)
                tr.emit(obs.END, task.uid, task.name,
                        group.lead + off, epoch,
                        data={"hw": observed_highwater(task)}
                        if calib is not None else None)
            freed = tuple(group.cells()) if group is not None else None
            fired = self._drain_locked(freed=freed)
        self._fire(fired)
        return True

    def _hint_may_fit(self, task: Task, freed: Tuple[Cell, ...]) -> bool:
        # sound: a newly feasible group must contain at least one freed cell
        # (all other cells — and all links, whose endpoints are freed cells —
        # are unchanged since the waiter parked), and that cell must itself
        # pass the member check
        r = task.resources
        per_chip = r.hbm_bytes // max(r.chips, 1)
        need = slots_needed(task)
        return any(self._member_ok(c, per_chip, need) for c in freed)

    # -- fault tolerance ------------------------------------------------------
    def mark_dead(self, cell: CellOrIndex) -> List[Task]:
        """Fail one chip: every gang overlapping it is evicted WHOLE (its
        entire reservation — all member chips and link charges — is
        released under the epoch fence, then it re-enters the waiter queue
        at the front of its priority class)."""
        cell = self._as_cell(cell)
        with self._lock:
            self.topo.set_alive(cell, False)
            tr = self._trace
            off = self._trace_dev_off
            if tr is not None:
                tr.emit(obs.MARK_DEAD,
                        device=self.topo.cells[cell].index + off)
            evicted: List[Task] = []
            for uid, group in list(self.bound.items()):
                if cell not in set(group.cells()):
                    continue
                task = None
                for c2 in group.cells():
                    task = self.topo.cells[c2].residents.get(uid)
                    if task is not None:
                        break
                if tr is not None:
                    tr.emit(obs.EVICT, task.uid, task.name,
                            group.lead + off,
                            self._epochs.get(task.uid, 0),
                            data={"cause": "device_dead"})
                    if max(task.resources.chips, 1) > 1:
                        # whole-gang eviction releases the reservation too:
                        # reserve/release must pair across every exit path
                        tr.emit(obs.GANG_RELEASE, task.uid, task.name,
                                group.lead + off,
                                self._epochs.get(task.uid, 0))
                ex = self._explain
                if ex is not None:
                    ex.record(task.uid, task.name, obsx.EVICTED,
                              device=group.lead + off,
                              reasons=({"reason": obsx.R_DEVICE_DEAD,
                                        "device":
                                            self.topo.cells[cell].index
                                            + off},))
                self._release_locked(task)
                task.device = None
                evicted.append(task)
            self._requeue_evicted_locked(evicted)
            fired = self._drain_locked()  # waiters may fit on survivors
            fired += self._fail_impossible_locked()
        self._fire(fired)
        return evicted

    def revive(self, cell: CellOrIndex) -> None:
        cell = self._as_cell(cell)
        with self._lock:
            self.topo.set_alive(cell, True)
            tr = self._trace
            if tr is not None:
                tr.emit(obs.REVIVE, device=self.topo.cells[cell].index
                        + self._trace_dev_off)
            fired = self._drain_locked(freed=(cell,))
        self._fire(fired)

    # -- runtime contention (the simulator's dilation inputs) -----------------
    def link_pressure(self, task: Task) -> float:
        """ICI-contention dilation factor for a RESIDENT task: processor
        sharing on the busiest link its collectives traverse (1.0 when its
        links have headroom or it runs no collectives)."""
        with self._lock:
            loads = self.topo.task_link_loads(task.uid)
        return interference.ici_slowdown(loads)

    # -- introspection --------------------------------------------------------
    def utilization(self) -> float:
        busy = sum(1 for d in self.topo.cells.values() if d.residents)
        return busy / len(self.topo.cells)
