"""Scheduler substrate: per-device state + the task_begin/task_end API.

The paper's scheduler is a user-level daemon; probes talk to it over shared
memory. Here it is an in-process object with the same two-call contract:

    dev = sched.task_begin(task)   # None => no feasible device, caller waits
    sched.task_end(task)           # frees the task's resources

``DeviceState`` tracks free HBM and the aggregate core demand ("in-use warps")
of resident tasks; death marking supports the fault-tolerance tests (a dead
device is never selected and its residents re-enter the queue).
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional, Sequence

from repro.core.task import Task

# 16 GB v5e HBM per chip (the paper's P100/V100 also had 16 GB)
DEFAULT_HBM = 16 * 1024**3

# Per-chip compute slots (Alg. 2's per-SM TB/warp table analogue). Lives here
# rather than in mgb.py so DeviceState can maintain the in-use slot count
# incrementally on admit/release.
SLOTS = 16


def slots_needed(task: Task) -> int:
    """Compute slots a task occupies while resident (>= 1 even at demand 0:
    a resident kernel always holds an issue slot)."""
    return max(1, math.ceil(task.resources.demand * SLOTS))


@dataclasses.dataclass
class DeviceState:
    index: int
    total_hbm: int = DEFAULT_HBM
    used_hbm: int = 0
    alive: bool = True
    residents: Dict[int, Task] = dataclasses.field(default_factory=dict)
    # in-use compute slots, maintained incrementally on admit/release so the
    # MGB Alg. 2 feasibility check is O(1) per candidate device instead of
    # O(residents) (it runs once per device per placement attempt)
    used_slots: int = 0

    @property
    def free_hbm(self) -> int:
        return self.total_hbm - self.used_hbm

    @property
    def in_use_demand(self) -> float:
        """Aggregate dominant-resource demand — the paper's 'active warps'."""
        return sum(t.resources.demand for t in self.residents.values())

    def demands(self) -> List[tuple]:
        return [(t.resources.core_demand, t.resources.bw_demand)
                for t in self.residents.values()]

    def admit(self, task: Task) -> None:
        self.used_hbm += task.resources.hbm_bytes
        self.used_slots += slots_needed(task)
        self.residents[task.uid] = task

    def release(self, task: Task) -> None:
        if task.uid in self.residents:
            del self.residents[task.uid]
            self.used_hbm -= task.resources.hbm_bytes
            self.used_slots -= slots_needed(task)

    def oom(self) -> bool:
        return self.used_hbm > self.total_hbm


class Scheduler:
    """Base scheduler: subclasses implement ``select_device``."""

    name = "base"

    def __init__(self, num_devices: int, hbm_per_device: int = DEFAULT_HBM):
        self.devices = [DeviceState(i, total_hbm=hbm_per_device)
                        for i in range(num_devices)]
        self._lock = threading.Lock()
        self.placements: List[tuple] = []  # (task_uid, device) audit log

    # -- policy hook -------------------------------------------------------
    def select_device(self, task: Task) -> Optional[DeviceState]:
        raise NotImplementedError

    # -- paper API -----------------------------------------------------------
    def task_begin(self, task: Task) -> Optional[int]:
        """Probe entry point: returns the device index or None (caller queues)."""
        with self._lock:
            dev = self.select_device(task)
            if dev is None:
                return None
            dev.admit(task)
            task.device = dev.index
            self.placements.append((task.uid, dev.index))
            return dev.index

    def task_end(self, task: Task) -> None:
        with self._lock:
            if task.device is not None:
                self.devices[task.device].release(task)

    # -- fault tolerance -----------------------------------------------------
    def mark_dead(self, device_index: int) -> List[Task]:
        """Fail a device: evict residents (they re-enter the queue)."""
        with self._lock:
            dev = self.devices[device_index]
            dev.alive = False
            evicted = list(dev.residents.values())
            for t in evicted:
                dev.release(t)
                t.device = None
            return evicted

    def revive(self, device_index: int) -> None:
        with self._lock:
            self.devices[device_index].alive = True

    def alive_devices(self) -> List[DeviceState]:
        return [d for d in self.devices if d.alive]
