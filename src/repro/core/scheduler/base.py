"""Scheduler substrate: per-device state + the task_begin/task_end API,
plus the waiter/notification machinery behind the event-driven executor.

The paper's scheduler is a user-level daemon; probes talk to it over shared
memory and a blocked ``task_begin`` sleeps on *notify* until ``task_end``
frees capacity. Here it is an in-process object with the same contract in
three flavours:

    dev = sched.task_begin(task)        # None => no feasible device
    sched.admit_or_enqueue(task, cb)    # non-blocking: cb fires on admission
    dev = sched.task_begin_blocking(t)  # condition-variable wait, no spinning
    sched.task_end(task)                # frees resources, re-drives waiters

``admit_or_enqueue`` is the serving-scale path: a blocked task holds **no**
thread — it sits in an *admission queue* ordered by (priority desc, deadline
EDF, arrival FIFO) and every ``task_end`` (or ``revive``) re-drives admission
in that order, firing the stored callback with the placement. A ``task_end``
drain is *hinted* with the freed capacity so waiters that provably cannot
use it are skipped without a probe, and (opt-in, ``shed_expired``) waiters
whose deadline already passed are failed with ``DEADLINE_SHED`` instead of
admitted late. The ordering is
enforced here, in the queue itself: callers just stamp ``task.priority`` /
``task.deadline_t`` (``Cluster.submit`` does this per job) and park. Within
one priority class arrival order is stable; tasks with deadlines rank by
earliest absolute deadline ahead of deadline-less peers of the same priority.
``mark_dead`` evicts residents; evicted tasks that were admitted through the
waiter path are re-enqueued at the *front of their priority class* (eviction
restart) and their callback fires again when they land on a surviving device.

Stale completions (a task evicted mid-run whose old incarnation later calls
``task_end``) are fenced with a per-task *epoch*: eviction bumps the epoch, so
a ``task_end(task, epoch=old)`` from the superseded run is a no-op and cannot
release the re-admitted incarnation's resources.

**Queue representation (fleet scale).** The admission queue is an indexed
structure (``_WaiterIndex``), not a sorted list: waiters live in per-
resource-class lazy-deletion heaps keyed by ``_Waiter.key``, alongside a
deadline min-heap for O(log n) shedding and maintained depth counters so
stats never scan the queue under the lock. Enqueue/cancel are O(log n) /
O(1) instead of the old ``bisect.insort`` O(n) memmove, and the
non-preemptive drain visits *resource classes* rather than waiters: within
one drain pass feasibility depends only on the resource vector (admissions
only consume capacity), so one failed probe retires the whole class for the
pass. This produces the exact admission sequence of the historical sorted-
list scan (kept verbatim in ``scheduler.reference`` as the test oracle) —
only the ``begin_attempts`` probe count can differ when more than
``_DRAIN_MEMO`` distinct vectors fail in a single pass, because the class
skip is effectively an unbounded memo. Preemption-enabled hosts take the
full rank-order scan path (eviction invalidates the class-skip premise),
also against the index.

``DeviceState`` tracks free HBM and the aggregate core demand ("in-use warps")
of resident tasks; death marking supports the fault-tolerance tests (a dead
device is never selected and its residents re-enter the queue).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.task import Task, observed_highwater
from repro.obs import events as obs
from repro.obs import explain as obsx

# 16 GB v5e HBM per chip (the paper's P100/V100 also had 16 GB)
DEFAULT_HBM = 16 * 1024**3

# Per-chip compute slots (Alg. 2's per-SM TB/warp table analogue). Lives here
# rather than in mgb.py so DeviceState can maintain the in-use slot count
# incrementally on admit/release.
SLOTS = 16

# callback(task, placement, epoch) — placement is a device index for the flat
# schedulers and a GangReservation for the gang/slice schedulers
AdmitCallback = Callable[[Task, Any, int], None]


class _DeadlineShed:
    """Sentinel placement: the waiter's deadline passed while it was parked
    and the scheduler's ``shed_expired`` policy failed it at the drain
    instead of admitting it late. Distinct from ``None`` (permanently
    infeasible — give up) so callers can report shed work separately."""

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return "DEADLINE_SHED"


DEADLINE_SHED = _DeadlineShed()

# preallocated skip-verdict reasons (obs.explain): collapse-recorded on the
# drain's probe-avoidance paths, so the tuples must not be rebuilt per skip
_HINT_SKIP_REASONS = ({"reason": obsx.R_HINT_SKIP},)
_CLASS_MEMO_REASONS = ({"reason": obsx.R_CLASS_MEMO},)
_PREEMPT_MEMO_REASONS = ({"reason": "preempt_memo_skip"},)


def slots_needed(task: Task) -> int:
    """Compute slots a task occupies while resident (>= 1 even at demand 0:
    a resident kernel always holds an issue slot)."""
    return max(1, math.ceil(task.resources.demand * SLOTS))


@dataclasses.dataclass
class DeviceState:
    index: int
    total_hbm: int = DEFAULT_HBM
    used_hbm: int = 0
    alive: bool = True
    residents: Dict[int, Task] = dataclasses.field(default_factory=dict)
    # in-use compute slots, maintained incrementally on admit/release so the
    # MGB Alg. 2 feasibility check is O(1) per candidate device instead of
    # O(residents) (it runs once per device per placement attempt)
    used_slots: int = 0

    @property
    def free_hbm(self) -> int:
        return self.total_hbm - self.used_hbm

    @property
    def in_use_demand(self) -> float:
        """Aggregate dominant-resource demand — the paper's 'active warps'."""
        return sum(t.resources.demand for t in self.residents.values())

    def demands(self) -> List[tuple]:
        return [(t.resources.core_demand, t.resources.bw_demand)
                for t in self.residents.values()]

    def admit(self, task: Task) -> None:
        self.used_hbm += task.resources.hbm_bytes
        self.used_slots += slots_needed(task)
        self.residents[task.uid] = task

    def release(self, task: Task) -> None:
        if task.uid in self.residents:
            del self.residents[task.uid]
            self.used_hbm -= task.resources.hbm_bytes
            self.used_slots -= slots_needed(task)
            if task.placed_host is not None:
                # settle the host's row budget on EVERY release path —
                # normal shrink, eviction, preemption alike
                task.placed_host.grown_now -= 1
                task.placed_host = None

    def oom(self) -> bool:
        return self.used_hbm > self.total_hbm


@dataclasses.dataclass
class _Waiter:
    task: Task
    callback: AdmitCallback
    priority: int = 0
    deadline_t: Optional[float] = None
    restart: bool = False       # evicted resident re-entering its class front
    seq: int = 0                # arrival order (negative for restarts)
    # resource vector cached at enqueue: Task.resources REBUILDS the vector
    # per access for multi-unit tasks, and the index buckets by it
    vec: Any = None
    # key cached at enqueue: heap pushes compare it many times
    sort_key: Tuple[int, int, float, int] = (0, 1, math.inf, 0)

    @property
    def key(self) -> Tuple[int, int, float, int]:
        """Admission rank: priority class desc, eviction-restarts at the
        front of their class, then EDF (no deadline sorts last), then stable
        arrival order."""
        return (-self.priority, 0 if self.restart else 1,
                self.deadline_t if self.deadline_t is not None else math.inf,
                self.seq)


class _WaiterIndex:
    """Indexed admission queue: per-resource-class heaps + lazy deletion.

    Waiters are bucketed by their (hashable, frozen) ``ResourceVector`` —
    feasibility within one drain pass depends only on that vector, so the
    drain works class-at-a-time. Each bucket is a min-heap of
    ``(sort_key, waiter)``; ``sort_key`` is globally unique (the seq field
    breaks every tie), so the waiter itself is never compared. Removal is
    O(1): drop the uid from ``_live`` and let stale heap entries evaporate
    when they surface at a bucket head. A parallel deadline min-heap serves
    expiry shedding without scanning, and depth counters (total, per
    priority class, per vector class) are maintained on add/discard so the
    stats paths never walk the queue."""

    __slots__ = ("_buckets", "_live", "_class_depth", "_vec_depth",
                 "_deadlines", "_dl_seq")

    def __init__(self) -> None:
        self._buckets: Dict[Any, List[Tuple[tuple, _Waiter]]] = {}
        self._live: Dict[int, _Waiter] = {}
        self._class_depth: Dict[int, int] = {}   # priority class -> depth
        self._vec_depth: Dict[Any, int] = {}     # resource class -> depth
        self._deadlines: List[Tuple[float, int, _Waiter]] = []
        self._dl_seq = 0

    def __len__(self) -> int:
        return len(self._live)

    def add(self, w: _Waiter) -> None:
        self._live[w.task.uid] = w
        heapq.heappush(self._buckets.setdefault(w.vec, []), (w.sort_key, w))
        self._class_depth[w.priority] = \
            self._class_depth.get(w.priority, 0) + 1
        self._vec_depth[w.vec] = self._vec_depth.get(w.vec, 0) + 1
        if w.deadline_t is not None:
            self._dl_seq += 1
            heapq.heappush(self._deadlines, (w.deadline_t, self._dl_seq, w))

    def discard(self, uid: int) -> Optional[_Waiter]:
        """O(1) removal by task uid (heap entries die lazily)."""
        w = self._live.pop(uid, None)
        if w is None:
            return None
        c = self._class_depth[w.priority] - 1
        if c:
            self._class_depth[w.priority] = c
        else:
            del self._class_depth[w.priority]
        v = self._vec_depth[w.vec] - 1
        if v:
            self._vec_depth[w.vec] = v
        else:
            del self._vec_depth[w.vec]
        return w

    def get(self, uid: int) -> Optional[_Waiter]:
        return self._live.get(uid)

    def classes(self) -> List[Any]:
        """Snapshot of the distinct resource-vector classes currently live."""
        return list(self._vec_depth.keys())

    def class_size(self, vec: Any) -> int:
        return self._vec_depth.get(vec, 0)

    def class_depth_snapshot(self) -> Dict[int, int]:
        return dict(self._class_depth)

    def peek_class(self, vec: Any) -> Optional[Tuple[tuple, _Waiter]]:
        """Best-ranked live waiter of a class (popping stale entries)."""
        h = self._buckets.get(vec)
        if h is None:
            return None
        while h:
            key, w = h[0]
            if self._live.get(w.task.uid) is w:
                return key, w
            heapq.heappop(h)
        del self._buckets[vec]
        return None

    def pop_expired(self, now: float) -> List[_Waiter]:
        """Remove + return every live waiter whose deadline is strictly past
        (``now > deadline``), best-deadline first. O(shed · log n)."""
        out: List[_Waiter] = []
        dl = self._deadlines
        while dl and dl[0][0] < now:
            _, _, w = heapq.heappop(dl)
            if self._live.get(w.task.uid) is w:
                self.discard(w.task.uid)
                out.append(w)
        return out

    def sorted_waiters(self) -> List[_Waiter]:
        """Rank-ordered snapshot (introspection / the preemptive scan path —
        NOT the indexed hot path)."""
        return sorted(self._live.values(), key=lambda w: w.sort_key)

    def take_all_sorted(self) -> List[_Waiter]:
        """Empty the index, returning the waiters in rank order."""
        out = self.sorted_waiters()
        self._buckets.clear()
        self._live.clear()
        self._class_depth.clear()
        self._vec_depth.clear()
        self._deadlines.clear()
        return out


class WaiterQueueMixin:
    """Admission queue + wakeup machinery shared by ``Scheduler`` and
    ``SliceScheduler`` (the paper's notify path), ordered by priority /
    deadline / arrival (see ``_Waiter.key``).

    Host class contract: ``self._lock`` (a ``threading.Lock``) and
    ``self._admit_locked(task) -> Optional[placement]`` (admission under the
    lock). Callbacks always fire OUTSIDE the lock, so a callback may call back
    into the scheduler without deadlocking.
    """

    def _init_waiters(self) -> None:
        # the indexed admission queue (see _WaiterIndex): rank order is
        # recovered per class via bucket heaps, never by keeping a flat
        # sorted list
        self._queue = _WaiterIndex()
        self._seq = 0           # arrival counter (FIFO within a class)
        self._restart_seq = 0   # decreasing: newest restart leads its class
        # preemption (off unless a PreemptionMixin host enables it): when a
        # waiter cannot be admitted from free capacity, the admission paths
        # offer it to _preempt_admit_locked, which may evict lower-ranked
        # residents to make room (the hook is a no-op here)
        self.preempt_enabled = False
        # notifications (e.g. preemption notices to the executor/simulator)
        # buffered under the lock and delivered by _fire_deferred OUTSIDE it,
        # strictly before any admission callback fired afterwards
        self._deferred: List[Callable[[], None]] = []
        # uid -> callback for tasks admitted through the waiter path; consulted
        # by mark_dead to re-enqueue evicted tasks
        self._admit_cbs: Dict[int, AdmitCallback] = {}
        # uid -> admission epoch; bumped on eviction to fence stale task_ends
        self._epochs: Dict[int, int] = {}
        # deadline shedding (off by default — a deadline is an EDF ordering
        # hint unless the operator opts in): when True, a parked waiter whose
        # ``deadline_t`` has already passed is failed with DEADLINE_SHED at
        # the next drain instead of being admitted late. ``_clock`` supplies
        # "now" on the same timeline the deadlines were stamped with — wall
        # monotonic by default; the simulator repoints it at its virtual
        # clock.
        self.shed_expired = False
        self._clock: Callable[[], float] = time.monotonic
        # waiters skipped without a probe because the freed-device drain hint
        # proved the freed capacity cannot satisfy them (observability for
        # the heterogeneous-queue benchmarks/tests)
        self.hint_skips = 0
        # lifecycle event tracer (obs.events.attach_tracer sets it): None
        # keeps every emission site a single attribute load, so the traced-
        # off hot path pays nothing. _trace_dev_off maps shard-local device
        # indices to fleet-global ones in emitted events (sharded control
        # plane stamps each shard's base; 0 everywhere else).
        self._trace: Optional[obs.Tracer] = None
        self._trace_dev_off = 0
        # decision explainer (obs.explain.attach_explainer sets it): same
        # None-guard contract as _trace — every verdict site costs one
        # attribute load when explanation is off
        self._explain: Optional[obsx.Explainer] = None
        # online calibration store (obs.calibrate.attach_calibrator sets it):
        # same None-guard contract again — admission applies corrected
        # vectors and completions feed observations only when attached
        self._calib = None

    @staticmethod
    def _class_key(task: Task) -> Any:
        """Resource-class key for the waiter index. Feasibility-within-a-pass
        normally depends only on the resource vector; for a GROW task (a
        decode-slot delta bound to specific host residents, see
        ``Task.grow_hosts``) it also depends on WHERE the hosts live, so two
        same-vector slots with different host sets must not share a class —
        one failing its probe must not retire the other for the pass."""
        hosts = getattr(task, "grow_hosts", None)
        if hosts:
            return (task.resources, tuple(h.uid for h in hosts))
        return task.resources

    def _enqueue_locked(self, task: Task, callback: AdmitCallback, *,
                        restart: bool = False) -> _Waiter:
        if restart:
            self._restart_seq -= 1
            seq = self._restart_seq
        else:
            self._seq += 1
            seq = self._seq
        # admission rank = declared class + anti-starvation aging (the
        # preemptive layer adds age_boost per eviction); the boost is kept
        # out of task.priority so eviction decisions stay on raw classes
        w = _Waiter(task,
                    callback,
                    getattr(task, "priority", 0)
                    + getattr(task, "age_boost", 0),
                    getattr(task, "deadline_t", None), restart, seq,
                    vec=self._class_key(task))
        w.sort_key = w.key
        self._queue.add(w)
        tr = self._trace
        if tr is not None:
            tr.emit(obs.REQUEUE if restart else obs.PARK,
                    task.uid, task.name,
                    epoch=self._epochs.get(task.uid, 0))
        return w

    def _restore_waiter_locked(self, w: _Waiter) -> None:
        """Re-add a previously-popped waiter object unchanged — same seq,
        same rank, so it lands back in its exact queue position (the sharded
        control plane's steal path puts a waiter back when the target shard
        turns it down)."""
        self._queue.add(w)

    # -- host hooks ---------------------------------------------------------
    def _admit_locked(self, task: Task):  # pragma: no cover - abstract
        raise NotImplementedError

    def can_ever_fit(self, task: Task) -> bool:
        """Would ``task`` be admissible on an *empty* alive device (or, for a
        gang scheduler, an empty alive device group)? Callers use this to
        fail fast instead of waiting forever (a 20 GB task on a 16 GB fleet
        — or a 5-chip gang on a 4x4 pod with no 5-chip shape — never becomes
        feasible)."""
        return True

    def infeasible_reason(self, task: Task) -> str:
        """Human-readable explanation for a ``can_ever_fit`` failure, stamped
        on the crashed job so the submitter sees *why* instead of a bare
        crash flag."""
        return (f"infeasible placement: task {task.name or task.uid!r} can "
                f"never be admitted on the current fleet")

    def _hint_may_fit(self, task: Task, freed: Any) -> bool:
        """Drain-scan hint: could ``task`` POSSIBLY be admitted given that
        only ``freed`` (a device index, or a cell tuple for topology
        schedulers) gained capacity since the task parked? Hosts override
        with an exact-or-conservative check — returning True merely probes,
        returning False MUST be sound (a parked waiter is infeasible on
        every unchanged device, so feasibility can only arrive via the freed
        one)."""
        return True

    def _preempt_admit_locked(self, task: Task):
        """Preemption hook (no-op unless a PreemptionMixin host overrides):
        called under the lock when ``task`` cannot be admitted from free
        capacity. May evict strictly lower-ranked residents (re-enqueueing
        them via ``_requeue_evicted_locked``) and return the placement the
        eviction made possible, or None to leave the waiter parked."""
        return None

    def _forget_task_locked(self, task: Task) -> None:
        """Terminal-exit hook: ``task`` is leaving the queue for good
        without a current-epoch ``task_end`` (deadline shed, or the
        impossible-after-shrink give-up). Hosts carrying per-task
        bookkeeping (the preemption layer's ledger) drop it here."""

    # -- admission ----------------------------------------------------------
    def admit_or_enqueue(self, task: Task, callback: AdmitCallback) -> bool:
        """Try to admit ``task``; on success fire ``callback`` immediately,
        otherwise park it in the admission queue (no thread is held), ranked
        by the task's ``priority`` / ``deadline_t`` stamps. The callback fires
        exactly once per admission, possibly again after an eviction +
        re-admission. If the fleet later shrinks (``mark_dead``) to where the
        task can NEVER be admitted, the callback fires once with
        ``placement=None`` — the caller must give up, not retry. Returns True
        iff admitted immediately."""
        fired: List[Tuple[_Waiter, Any, int]] = []
        with self._lock:
            placement = self._admit_locked(task)
            if placement is None and self.preempt_enabled \
                    and not getattr(task, "grow_hosts", None):
                # (grow tasks never preempt: a slot delta is batch growth,
                # not an independent arrival — evicting a resident could
                # evict the very host batch the slot wants to join)
                # an urgent arrival may evict strictly lower-ranked residents
                # instead of parking behind them (preemptive deadline/priority
                # enforcement); evicted victims re-enter the queue at the
                # front of their class carrying their progress credit
                placement = self._preempt_admit_locked(task)
                if placement is not None:
                    # the eviction may have freed capacity beyond what this
                    # arrival consumed (a whole-gang victim's other cells,
                    # or a victim bigger than the preemptor): offer it to
                    # parked waiters NOW, like every other freeing path
                    fired = self._drain_locked()
            if placement is None:
                self._enqueue_locked(task, callback)
                return False
            self._admit_cbs[task.uid] = callback
            epoch = self._epochs.get(task.uid, 0)
        self._fire_deferred()
        callback(task, placement, epoch)
        self._fire(fired)
        return True

    def try_admit(self, task: Task, callback: AdmitCallback):
        """Admit-or-nothing: like ``admit_or_enqueue`` but never parks the
        task on failure (and never attempts preemption). Returns the
        placement on success (callback fired), None otherwise (no state
        changed). The sharded control plane uses this to probe shards for
        immediate capacity before choosing where to park."""
        with self._lock:
            placement = self._admit_locked(task)
            if placement is None:
                return None
            self._admit_cbs[task.uid] = callback
            epoch = self._epochs.get(task.uid, 0)
        self._fire_deferred()
        callback(task, placement, epoch)
        return placement

    def task_begin_blocking(self, task: Task,
                            timeout: Optional[float] = None):
        """Blocking flavour for synchronous callers (serve loop): waits on an
        event — not a sleep/retry spin — until the wakeup path admits the
        task. Returns the placement, or None on timeout (the waiter is then
        cancelled)."""
        admitted = threading.Event()
        box: Dict[str, Any] = {}

        def cb(t: Task, placement, epoch: int) -> None:
            box["placement"] = placement  # None if permanently infeasible
            admitted.set()

        self.admit_or_enqueue(task, cb)
        if not admitted.wait(timeout):
            if self.cancel_wait(task):
                return None
            admitted.wait()  # admission raced the timeout: take the device
        return box["placement"]

    # -- wakeups ------------------------------------------------------------
    # distinct failed resource vectors memoized per PREEMPTIVE drain pass;
    # beyond this many, later waiters are probed unconditionally (bounds
    # memo-compare cost on the scan path). The indexed drain needs no cap:
    # its class skip is a dict-keyed memo with O(1) lookups.
    _DRAIN_MEMO = 32

    def _drain_locked(self, freed: Any = None
                      ) -> List[Tuple[_Waiter, Any, int]]:
        """Admit every now-feasible waiter in admission-rank order (priority
        desc, EDF, arrival), keeping still-infeasible ones queued. Higher-
        ranked tasks always get first claim on freed capacity, but a too-big
        head does not block smaller tasks behind it — smaller classes are
        probed in turn, which avoids head-of-line deadlock.

        Two implementations behind one contract, selected by
        ``preempt_enabled``:

          * **indexed drain** (non-preemptive hosts): class-at-a-time over
            the waiter index — O(classes·log + admitted·log) per wakeup
            instead of O(queue). Identical admission sequence to the scan
            (see the module docstring's equivalence argument).
          * **rank-order scan** (preemptive hosts): the historical full
            scan, kept because a committed eviction changes resident state
            mid-pass and invalidates the class-skip premise. Mid-scan
            victim requeues land in the (emptied) index and survive the
            final merge.

        Both share the probe-avoidance layers: deadline shedding
        (``shed_expired``), the freed-capacity hint (``_hint_may_fit``),
        and the failed-vector memo (a failed resource class is never
        re-probed within a pass)."""
        if self.preempt_enabled:
            return self._drain_scan_locked(freed)
        return self._drain_indexed_locked(freed)

    def _drain_indexed_locked(self, freed: Any = None
                              ) -> List[Tuple[_Waiter, Any, int]]:
        fired: List[Tuple[_Waiter, Any, int]] = []
        q = self._queue
        if self.shed_expired:
            # all expired waiters shed via the deadline heap — the same set
            # the scan would shed (every live waiter with deadline < now),
            # without touching the unexpired ones; re-sorted by queue rank
            # so the shed callbacks fire in the scan's order, not the
            # heap's deadline order
            for w in sorted(q.pop_expired(self._clock()),
                            key=lambda w: w.sort_key):
                self._admit_cbs.pop(w.task.uid, None)
                self._forget_task_locked(w.task)
                tr = self._trace
                if tr is not None:
                    tr.emit(obs.SHED, w.task.uid, w.task.name,
                            epoch=self._epochs.get(w.task.uid, 0))
                ex = self._explain
                if ex is not None:
                    ex.record(w.task.uid, w.task.name, obsx.SHED,
                              reasons=({"reason": "deadline_expired",
                                        "deadline_t": w.deadline_t},))
                fired.append((w, DEADLINE_SHED,
                              self._epochs.get(w.task.uid, 0)))
        if not len(q):
            return fired
        # one entry per resource class, keyed by the class's best waiter:
        # popping the heap yields the globally best-ranked un-skipped waiter
        top: List[Tuple[tuple, Any]] = []
        for vec in q.classes():
            peek = q.peek_class(vec)
            if peek is not None:
                top.append((peek[0], vec))
        heapq.heapify(top)
        while top:
            key, vec = heapq.heappop(top)
            peek = q.peek_class(vec)
            if peek is None:
                continue
            ckey, w = peek
            if ckey != key:
                # the entry was staled by an out-of-band removal; re-rank
                heapq.heappush(top, (ckey, vec))
                continue
            if freed is not None and not self._hint_may_fit(w.task, freed):
                # the freed capacity provably cannot serve this vector, so
                # it cannot serve ANY member: the whole class is skipped
                # (each member counts as a hint skip, as in the scan)
                self.hint_skips += q.class_size(vec)
                ex = self._explain
                if ex is not None:
                    ex.skip(w.task.uid, w.task.name, _HINT_SKIP_REASONS)
                continue
            placement = self._admit_locked(w.task)
            if placement is None:
                # failed-vector memo: admissions only consume capacity, so
                # this class stays infeasible for the rest of the pass
                ex = self._explain
                if ex is not None:
                    # the class head carries the probe's rejection verdict
                    # (recorded in _admit_locked); note how many classmates
                    # were retired for the pass on its strength
                    n = q.class_size(vec) - 1
                    if n > 0:
                        ex.annotate_last(w.task.uid, "class_memo_skip", n)
                continue
            q.discard(w.task.uid)
            self._admit_cbs[w.task.uid] = w.callback
            fired.append((w, placement, self._epochs.get(w.task.uid, 0)))
            nxt = q.peek_class(vec)
            if nxt is not None:
                heapq.heappush(top, (nxt[0], vec))
        return fired

    def _drain_scan_locked(self, freed: Any = None
                           ) -> List[Tuple[_Waiter, Any, int]]:
        """Preemptive-path drain: the full rank-order scan (see
        ``_drain_locked``), run against a drained snapshot of the index."""
        fired: List[Tuple[_Waiter, Any, int]] = []
        still: List[_Waiter] = []
        failed: List[Any] = []    # ResourceVectors infeasible this pass
        # (vector, raw priority, deadline) of waiters whose PREEMPTION
        # attempt failed this pass. A later waiter is skipped only when a
        # failed entry DOMINATES it on raw eviction power — same vector and
        # (higher raw priority, or equal priority and no-later deadline) —
        # because only then is its eligible victim set provably a subset.
        # Scan order alone is NOT enough: admission rank includes age_boost
        # and restart-front-of-class, which outranks() ignores, so a
        # later-scanned waiter can hold strictly more eviction rights.
        # Keeps a deep homogeneous queue at O(1) plans per wakeup.
        pfailed: List[Tuple[Any, int, float]] = []
        now = self._clock() if self.shed_expired else None
        # scan a snapshot: a mid-scan preemption re-enqueues its victims into
        # the index (emptied here), so they survive the final merge instead
        # of being overwritten by the survivor list
        pending = self._queue.take_all_sorted()
        for w in pending:  # already sorted by rank
            if (now is not None and w.deadline_t is not None
                    and now > w.deadline_t):
                # too late to be worth running: shed instead of admitting
                self._admit_cbs.pop(w.task.uid, None)
                self._forget_task_locked(w.task)
                tr = self._trace
                if tr is not None:
                    tr.emit(obs.SHED, w.task.uid, w.task.name,
                            epoch=self._epochs.get(w.task.uid, 0))
                ex = self._explain
                if ex is not None:
                    ex.record(w.task.uid, w.task.name, obsx.SHED,
                              reasons=({"reason": "deadline_expired",
                                        "deadline_t": w.deadline_t},))
                fired.append((w, DEADLINE_SHED,
                              self._epochs.get(w.task.uid, 0)))
                continue
            placement = None
            ckey = self._class_key(w.task)
            if freed is not None and not self._hint_may_fit(w.task, freed):
                self.hint_skips += 1
                ex = self._explain
                if ex is not None:
                    ex.skip(w.task.uid, w.task.name, _HINT_SKIP_REASONS)
            elif any(f == ckey for f in failed):
                # identical resource class already failed this pass
                ex = self._explain
                if ex is not None:
                    ex.skip(w.task.uid, w.task.name, _CLASS_MEMO_REASONS)
            else:
                placement = self._admit_locked(w.task)
                if placement is None and len(failed) < self._DRAIN_MEMO:
                    failed.append(ckey)
            if placement is None and self.preempt_enabled \
                    and not getattr(w.task, "grow_hosts", None):
                tprio = getattr(w.task, "priority", 0)
                tdl = w.task.deadline_t if w.task.deadline_t is not None \
                    else math.inf
                dominated = any(
                    res == w.task.resources
                    and (prio > tprio or (prio == tprio and dl <= tdl))
                    for res, prio, dl in pfailed)
                if dominated:
                    placement = None
                    ex = self._explain
                    if ex is not None:
                        ex.skip(w.task.uid, w.task.name,
                                _PREEMPT_MEMO_REASONS)
                else:
                    # free capacity (even hinted/memoized as insufficient)
                    # cannot take this waiter — but eviction of strictly
                    # lower-ranked residents might; min-runtime maturing
                    # between drains is why this retries even when no
                    # capacity was freed
                    placement = self._preempt_admit_locked(w.task)
                if placement is None:
                    if not dominated and len(pfailed) < self._DRAIN_MEMO:
                        pfailed.append((w.task.resources, tprio, tdl))
                else:
                    # a committed eviction changes resident state (and can
                    # free net capacity beyond what the preemptor took, e.g.
                    # a whole-gang victim): the memos AND the freed-capacity
                    # hint are stale — reset them so the rest of the pass
                    # probes against reality (the hint's soundness premise,
                    # "only the freed device improved", no longer holds)
                    failed.clear()
                    pfailed.clear()
                    freed = None
            if placement is None:
                still.append(w)
            else:
                self._admit_cbs[w.task.uid] = w.callback
                fired.append((w, placement,
                              self._epochs.get(w.task.uid, 0)))
        # preemption victims re-enqueued mid-scan are already back in the
        # index; merging the survivors is an insert, not a list rebuild
        for w in still:
            self._queue.add(w)
        return fired

    def _fire_deferred(self) -> None:
        """Deliver buffered out-of-band notifications (preemption notices)
        outside the lock, before any admission callback queued after them —
        a backend always learns a task was evicted before it sees the
        re-admission."""
        with self._lock:
            pending, self._deferred = self._deferred, []
        for fn in pending:
            fn()

    def _fire(self, fired: Sequence[Tuple[_Waiter, Any, int]]) -> None:
        self._fire_deferred()
        for w, placement, epoch in fired:
            w.callback(w.task, placement, epoch)

    def notify(self) -> int:
        """Re-drive the waiter queue now (used after ``revive``; harmless any
        time). Returns the number of waiters admitted."""
        with self._lock:
            fired = self._drain_locked()
        self._fire(fired)
        return len(fired)

    # -- waiter-queue introspection / cancellation --------------------------
    def waiting_count(self) -> int:
        """Queue depth — an O(1) maintained counter, never a scan."""
        with self._lock:
            return len(self._queue)

    def queue_stats(self) -> Dict[str, Any]:
        """Waiter-queue snapshot from maintained counters — safe to
        poll at depth 1e5 without stalling admission under the lock:
        ``depth`` (total waiters), ``per_class`` (waiters per admission
        priority class, aging included), ``classes`` (distinct resource
        vectors parked), ``hint_skips`` (probe-free skips to date), and
        ``gang_front`` — the best-ranked parked multi-chip waiter as
        ``(chips, per_chip_hbm)`` or None. Everything but gang_front is
        O(1); gang_front is O(classes · log) via per-class heap peeks —
        never a sort over the waiters (the ``waiting_tasks`` trap)."""
        with self._lock:
            gang_front = None
            best = None
            for vec in self._queue.classes():
                # grow-task classes key as (vector, host uids): unwrap
                r = vec[0] if isinstance(vec, tuple) else vec
                chips = getattr(r, "chips", 1)
                if chips <= 1:
                    continue
                peek = self._queue.peek_class(vec)
                if peek is not None and (best is None or peek[0] < best):
                    best = peek[0]
                    gang_front = (chips, r.hbm_bytes // chips)
            return {
                "depth": len(self._queue),
                "per_class": self._queue.class_depth_snapshot(),
                "classes": len(self._queue.classes()),
                "hint_skips": self.hint_skips,
                "gang_front": gang_front,
            }

    def waiting_tasks(self) -> List[Task]:
        """Rank-ordered snapshot of parked tasks. Debug/test helper — this
        sorts (O(n log n)); production telemetry should use
        ``queue_stats``."""
        with self._lock:
            return [w.task for w in self._queue.sorted_waiters()]

    def cancel_wait(self, task: Task) -> bool:
        """Remove ``task`` from the admission queue, dropping its stored
        callback so a cancelled waiter leaks no wakeup state. True iff it
        was waiting (then its callback is guaranteed never to fire again).
        O(1) against the index.

        The ``_epochs`` entry is deliberately KEPT: if the waiter is an
        eviction restart, the superseded run may still be mid-kernel, and
        deleting the bumped epoch would let its late ``task_end(epoch=old)``
        pass the staleness fence. Epoch entries persist after normal
        completion too, so this leaks nothing new."""
        with self._lock:
            if self._queue.discard(task.uid) is None:
                return False
            self._admit_cbs.pop(task.uid, None)
            return True

    def cancel_all_waiters(self) -> List[Task]:
        """Drop every waiter (caller decides their fate — e.g. the simulator
        counts never-feasible ones as crashed-at-submit). Epochs are kept,
        as in ``cancel_wait``."""
        with self._lock:
            waiters = self._queue.take_all_sorted()
            for w in waiters:
                self._admit_cbs.pop(w.task.uid, None)
            return [w.task for w in waiters]

    # -- epoch fencing ------------------------------------------------------
    def admission_epoch(self, task: Task) -> int:
        with self._lock:
            return self._epochs.get(task.uid, 0)

    def adopt_epoch(self, task: Task, epoch: int) -> None:
        """Carry a task's admission epoch in from another engine (the
        sharded control plane migrating a waiter across shards): the fence
        must keep rejecting the superseded run's ``task_end`` after the
        move, so the target engine takes the max of both histories."""
        with self._lock:
            cur = self._epochs.get(task.uid, 0)
            if epoch > cur:
                self._epochs[task.uid] = epoch

    def _stale_locked(self, task: Task, epoch: Optional[int]) -> bool:
        return (epoch is not None
                and epoch != self._epochs.get(task.uid, 0))

    def _fail_impossible_locked(self) -> List[Tuple[_Waiter, Any, int]]:
        """After capacity shrinks (mark_dead), sweep out waiters that can
        never be admitted again — without this they would wait forever once
        the last task_end wakeup has fired. Returns (waiter, None, epoch)
        tuples for ``_fire``: placement None tells the caller to give up.

        Feasibility-forever depends only on the resource vector, so the
        check runs once per class, not once per waiter."""
        failed: List[Tuple[_Waiter, Any, int]] = []
        q = self._queue
        for vec in q.classes():
            peek = q.peek_class(vec)
            if peek is None or self.can_ever_fit(peek[1].task):
                continue
            while True:
                peek = q.peek_class(vec)
                if peek is None:
                    break
                w = peek[1]
                q.discard(w.task.uid)
                self._admit_cbs.pop(w.task.uid, None)
                self._forget_task_locked(w.task)
                tr = self._trace
                if tr is not None:
                    tr.emit(obs.CRASH, w.task.uid, w.task.name,
                            epoch=self._epochs.get(w.task.uid, 0),
                            data={"reason": "infeasible"})
                ex = self._explain
                if ex is not None:
                    ex.record(w.task.uid, w.task.name, obsx.CRASHED,
                              reasons=({"reason": "infeasible"},))
                failed.append((w, None, self._epochs.get(w.task.uid, 0)))
        failed.sort(key=lambda e: e[0].sort_key)  # fire in rank order
        return failed

    def _requeue_evicted_locked(self, evicted: Sequence[Task]) -> None:
        """Re-enqueue evicted waiter-path tasks at the FRONT of their
        priority class (eviction restart), bumping their epoch so the
        superseded run's ``task_end`` becomes a fenced no-op. A restart never
        jumps a *higher* priority class — it only leads its own."""
        # reversed + decreasing restart seq keeps the evicted tasks' order
        for t in reversed(evicted):
            cb = self._admit_cbs.pop(t.uid, None)
            if cb is None:
                continue  # legacy task_begin admission: caller re-drives
            self._epochs[t.uid] = self._epochs.get(t.uid, 0) + 1
            self._enqueue_locked(t, cb, restart=True)

    # -- cross-shard handoff (used by scheduler.sharded) --------------------
    def steal_best_waiter(self, pred: Callable[[Task], bool]
                          ) -> Optional[_Waiter]:
        """Pop the best-ranked waiter whose task satisfies ``pred``.
        ``pred`` must depend only on the task's resource vector (it is
        evaluated once per class, on the class's best member). Returns the
        popped ``_Waiter`` (callback and rank intact) or None. The caller
        either re-homes the waiter on another engine or hands it back via
        ``_restore_waiter_locked``/``restore_waiter``."""
        with self._lock:
            best: Optional[Tuple[tuple, _Waiter]] = None
            for vec in self._queue.classes():
                peek = self._queue.peek_class(vec)
                if peek is None or not pred(peek[1].task):
                    continue
                if best is None or peek[0] < best[0]:
                    best = peek
            if best is None:
                return None
            w = best[1]
            self._queue.discard(w.task.uid)
            self._admit_cbs.pop(w.task.uid, None)
            return w

    def restore_waiter(self, w: _Waiter) -> None:
        """Put a stolen waiter back exactly where it was (same seq/rank)."""
        with self._lock:
            self._restore_waiter_locked(w)

    # -- decision explainability (obs.explain) -------------------------------
    # cap on per-verdict reason entries: a huge fleet's rejection verdict
    # must not allocate thousands of dicts under the lock
    _REASONS_CAP = 64

    def _reject_reasons_locked(self, task: Task) -> Tuple[dict, ...]:
        """Structured per-device/per-group rejection reasons for a failed
        admission probe (the payload of a REJECTED verdict). Hosts
        override with their policy's exact decomposition."""
        return ()

    def explain_queue(self, task: Task) -> Optional[Tuple[dict, ...]]:
        """Live rejection reasons for a currently-parked task — an
        on-demand probe under the lock, for waiters whose class was
        memo-skipped and therefore carry no recorded verdict of their own.
        None when the task is not parked here."""
        with self._lock:
            if self._queue.get(task.uid) is None:
                return None
            return self._reject_reasons_locked(task)


class Scheduler(WaiterQueueMixin):
    """Base scheduler: subclasses implement ``select_device``."""

    name = "base"

    def __init__(self, num_devices: int, hbm_per_device: int = DEFAULT_HBM):
        self.devices = [DeviceState(i, total_hbm=hbm_per_device)
                        for i in range(num_devices)]
        self._lock = threading.Lock()
        self.placements: List[tuple] = []  # (task_uid, device) audit log
        # admission attempts (successful or not) — the scheduler-overhead
        # metric benchmarks/bench_executor.py compares across executors
        self.begin_attempts = 0
        # largest alive device, maintained on mark_dead/revive so
        # can_ever_fit is O(1) per submission instead of O(devices)
        self._max_alive_hbm = max(
            (d.total_hbm for d in self.devices if d.alive), default=0)
        self._init_waiters()

    def _refresh_capacity_locked(self) -> None:
        self._max_alive_hbm = max(
            (d.total_hbm for d in self.devices if d.alive), default=0)

    # -- policy hooks ------------------------------------------------------
    def select_device(self, task: Task) -> Optional[DeviceState]:
        raise NotImplementedError

    def device_feasible(self, task: Task, dev: DeviceState) -> bool:
        """Would ``select_device`` consider ``dev`` for ``task`` right now?
        Each policy states its per-device admission predicate here;
        ``select_device`` ranges over it and the drain hint consults it to
        skip waiters a freed device cannot satisfy."""
        return dev.alive

    def _hint_may_fit(self, task: Task, freed: int) -> bool:
        # sound: a parked waiter was infeasible on EVERY device, and only
        # the freed device's state improved since — so it is admissible now
        # iff the freed device itself would take it. A grow task can only
        # land next to one of its hosts, so unless the freed device hosts
        # one, the probe is skipped.
        if task.grow_hosts:
            return any(h.device == freed for h in task.grow_hosts)
        return self.device_feasible(task, self.devices[freed])

    def _admit_locked(self, task: Task) -> Optional[int]:
        # calibration correction happens at the FIRST admission probe — before
        # the grow branch, so decode-slot deltas are corrected too. apply() is
        # idempotent (it stamps probe_vec), so re-probes of a parked waiter
        # and sharded re-routing never double-correct.
        calib = self._calib
        if calib is not None and task.probe_vec is None:
            calib.apply(task)
        if task.grow_hosts:
            return self._admit_grow_locked(task)
        self.begin_attempts += 1
        dev = self.select_device(task)
        if dev is None:
            ex = self._explain
            if ex is not None:
                # lazy: the O(devices) reason walk runs once per parked
                # episode — repeat probes just bump the verdict's repeats
                ex.reject(task.uid, task.name,
                          lambda: self._reject_reasons_locked(task))
            return None
        dev.admit(task)
        task.device = dev.index
        self.placements.append((task.uid, dev.index))
        tr = self._trace
        if tr is not None:
            # reservation payload only on calibrated runs: the profiler reads
            # it as "what admission actually granted"; uncalibrated traces
            # keep the zero-payload emission (bench_obs baseline unchanged)
            tr.emit(obs.ADMIT, task.uid, task.name,
                    dev.index + self._trace_dev_off,
                    self._epochs.get(task.uid, 0),
                    data={"hbm": task.resources.hbm_bytes}
                    if calib is not None else None)
        ex = self._explain
        if ex is not None:
            ex.record(task.uid, task.name, obsx.ADMITTED,
                      device=dev.index + self._trace_dev_off)
        return dev.index

    # -- decision explainability (obs.explain) -------------------------------
    def device_verdict(self, task: Task, dev: DeviceState) -> Optional[dict]:
        """Structured rejection reason for ``task`` on ``dev`` right now, or
        None when the device is feasible. Mirrors ``device_feasible``
        check-for-check; policy subclasses decompose their own predicate."""
        if not dev.alive:
            return {"device": dev.index + self._trace_dev_off,
                    "reason": obsx.R_DEVICE_DEAD}
        if not self.device_feasible(task, dev):
            return {"device": dev.index + self._trace_dev_off,
                    "reason": obsx.R_SLOTS_FULL}
        return None

    def _reject_reasons_locked(self, task: Task) -> Tuple[dict, ...]:
        """Per-device rejection reasons for a failed admission probe (the
        payload of a REJECTED verdict). One entry per refusing device, up
        to ``_REASONS_CAP`` + a truncation marker."""
        if getattr(task, "grow_hosts", None):
            return self._grow_reject_reasons_locked(task)
        out: List[dict] = []
        omitted = 0
        cap = self._REASONS_CAP
        for dev in self.devices:
            r = self.device_verdict(task, dev)
            if r is None:
                continue
            if len(out) < cap:
                out.append(r)
            else:
                omitted += 1
        if omitted:
            out.append({"reason": "truncated", "omitted": omitted})
        return tuple(out)

    def _grow_reject_reasons_locked(self, task: Task) -> Tuple[dict, ...]:
        """Why a decode-slot delta could not grow: one entry per candidate
        host, mirroring ``_grow_feasible_locked`` check-for-check."""
        out: List[dict] = []
        off = self._trace_dev_off
        need = slots_needed(task)
        for host in task.grow_hosts:
            if host.device is None:
                out.append({"host": host.uid, "reason": obsx.R_HOST_GONE})
                continue
            dev = self.devices[host.device]
            if not dev.alive:
                out.append({"host": host.uid, "device": dev.index + off,
                            "reason": obsx.R_DEVICE_DEAD})
            elif host.uid not in dev.residents:
                out.append({"host": host.uid, "device": dev.index + off,
                            "reason": obsx.R_HOST_GONE})
            elif task.resources.hbm_bytes > dev.free_hbm:
                out.append({"host": host.uid, "device": dev.index + off,
                            "reason": obsx.R_MEMORY_SHORT,
                            "short_bytes":
                                task.resources.hbm_bytes - dev.free_hbm})
            elif host.slot_budget is not None:
                if host.grown_now >= host.slot_budget:
                    out.append({"host": host.uid, "device": dev.index + off,
                                "reason": obsx.R_GROW_BUDGET,
                                "grown_now": host.grown_now,
                                "slot_budget": host.slot_budget})
            elif dev.used_slots + need > SLOTS:
                out.append({"host": host.uid, "device": dev.index + off,
                            "reason": obsx.R_SLOTS_FULL,
                            "short_slots": dev.used_slots + need - SLOTS})
        return tuple(out)


    def _grow_feasible_locked(self, task: Task,
                              dev: DeviceState, host: Task) -> bool:
        """Hard feasibility for a slot delta on a host's device, regardless
        of the policy subclass: the slot's KV bytes must physically fit, and
        the host's row budget (``slot_budget`` — a decode loop has exactly
        max_batch physical cache rows) must have a row free. Hosts with no
        budget fall back to the device-wide compute-slot ledger — but budget
        is the right cap for serving, where co-located prefill tasks may
        legitimately oversubscribe compute slots (Alg. 3) without that
        saying anything about cache-row availability."""
        if not (dev.alive and host.uid in dev.residents
                and task.resources.hbm_bytes <= dev.free_hbm):
            return False
        if host.slot_budget is not None:
            return host.grown_now < host.slot_budget
        return dev.used_slots + slots_needed(task) <= SLOTS

    def _admit_grow_locked(self, task: Task) -> Optional[int]:
        """Admission for a resident-growth delta (``task.grow_hosts``): only
        devices currently hosting one of the host tasks are candidates —
        the delta is batch growth, its bytes live next to its batch. Among
        feasible hosts, least-loaded (fewest used slots, then most free
        HBM) wins, balancing joins across decode loops."""
        self.begin_attempts += 1
        best: Optional[Tuple[DeviceState, Task]] = None

        def rank(dev: DeviceState, host: Task) -> tuple:
            return (host.grown_now, dev.used_slots, -dev.free_hbm)

        for host in task.grow_hosts:
            if host.device is None:
                continue
            dev = self.devices[host.device]
            if not self._grow_feasible_locked(task, dev, host):
                continue
            if best is None or rank(dev, host) < rank(*best):
                best = (dev, host)
        if best is None:
            ex = self._explain
            if ex is not None:
                ex.reject(task.uid, task.name,
                          lambda: self._grow_reject_reasons_locked(task))
            return None
        dev, host = best
        dev.admit(task)
        task.device = dev.index
        task.placed_host = host
        host.grown_now += 1
        self.placements.append((task.uid, dev.index))
        tr = self._trace
        if tr is not None:
            tr.emit(obs.GROW, task.uid, task.name,
                    dev.index + self._trace_dev_off,
                    self._epochs.get(task.uid, 0),
                    data={"host": host.uid})
        ex = self._explain
        if ex is not None:
            ex.record(task.uid, task.name, obsx.GROWN,
                      device=dev.index + self._trace_dev_off,
                      data={"host": host.uid})
        return dev.index

    def can_ever_fit(self, task: Task) -> bool:
        if task.grow_hosts:
            # a grow task is feasible-forever iff some host still lives on
            # an alive device big enough to EVER hold the delta (current
            # occupancy excluded — that can drain)
            return any(
                h.device is not None
                and self.devices[h.device].alive
                and h.uid in self.devices[h.device].residents
                and task.resources.hbm_bytes <= self.devices[h.device].total_hbm
                for h in task.grow_hosts)
        # O(1): against the maintained largest-alive-device capacity
        return task.resources.hbm_bytes <= self._max_alive_hbm

    def infeasible_reason(self, task: Task) -> str:
        alive = [d for d in self.devices if d.alive]
        biggest = max((d.total_hbm for d in alive), default=0)
        return (f"infeasible placement: task {task.name or task.uid!r} needs "
                f"{task.resources.hbm_bytes / 1e9:.2f} GB HBM but the "
                f"largest of {len(alive)} alive device(s) holds "
                f"{biggest / 1e9:.2f} GB")

    # -- paper API -----------------------------------------------------------
    def task_begin(self, task: Task) -> Optional[int]:
        """Probe entry point: returns the device index or None (caller queues)."""
        with self._lock:
            return self._admit_locked(task)

    def task_end(self, task: Task, *, epoch: Optional[int] = None) -> bool:
        """Free the task's resources and re-drive the waiter queue, passing
        the freed device as the drain hint so heterogeneous queues skip
        waiters that device can't satisfy. With ``epoch``, a completion from
        an evicted (superseded) run is fenced: nothing is released and False
        is returned."""
        with self._lock:
            if self._stale_locked(task, epoch):
                return False
            freed = task.device
            if freed is not None:
                self.devices[freed].release(task)
            self._admit_cbs.pop(task.uid, None)
            calib = self._calib
            if calib is not None and freed is not None:
                calib.note_end(task, self._clock())
            tr = self._trace
            if tr is not None and freed is not None:
                # freed None = a stale end for an already-evicted run (the
                # eviction cleared task.device): nothing was released, so
                # nothing is emitted — the fresh incarnation owns the task.
                # On calibrated runs the END carries the observed memory
                # high-water, closing the reserved-vs-observed join.
                tr.emit(obs.SHRINK if task.grow_hosts else obs.END,
                        task.uid, task.name,
                        freed + self._trace_dev_off,
                        self._epochs.get(task.uid, 0),
                        data={"hw": observed_highwater(task)}
                        if calib is not None else None)
            fired = self._drain_locked(freed=freed)
        self._fire(fired)
        return True

    # -- resident growth (continuous batching; see serve.engine) -------------
    def bind_resident(self, task: Task, device_index: int) -> bool:
        """Checked PINNED admission: admit ``task`` onto a specific device
        (memory + slot checked under the lock) or refuse without queueing.
        serve.engine uses this to plant one long-lived decode-loop resident
        per device; the loop's slot joins then grow against it via
        ``task_grow``. Release is a normal ``task_end``."""
        with self._lock:
            dev = self.devices[device_index]
            if not dev.alive \
                    or task.resources.hbm_bytes > dev.free_hbm \
                    or dev.used_slots + slots_needed(task) > SLOTS:
                return False
            self.begin_attempts += 1
            dev.admit(task)
            task.device = dev.index
            self.placements.append((task.uid, dev.index))
            tr = self._trace
            if tr is not None:
                tr.emit(obs.ADMIT, task.uid, task.name,
                        dev.index + self._trace_dev_off,
                        self._epochs.get(task.uid, 0),
                        data={"bind": True})
            ex = self._explain
            if ex is not None:
                ex.record(task.uid, task.name, obsx.ADMITTED,
                          device=dev.index + self._trace_dev_off,
                          data={"bind": True})
            return True

    def task_grow(self, slot_task: Task, hosts: Sequence[Task],
                  callback: AdmitCallback) -> bool:
        """Grow a resident batch by one probed delta: ``slot_task`` (its
        ResourceVector is the slot's KV-cache bytes + per-row compute share)
        is admitted onto a device hosting one of ``hosts``, or parked in the
        SAME admission queue as everything else — so a join that would OOM
        the device waits for a retire instead of growing the batch, and the
        memory-hard guarantee covers batch growth. Returns True iff grown
        immediately; otherwise ``callback`` fires on a later drain (or with
        DEADLINE_SHED / None, exactly like ``admit_or_enqueue``)."""
        slot_task.grow_hosts = tuple(hosts)
        return self.admit_or_enqueue(slot_task, callback)

    def task_shrink(self, slot_task: Task, *,
                    epoch: Optional[int] = None) -> bool:
        """Retire a slot admitted through ``task_grow``. Alias of
        ``task_end`` (same epoch fencing, same freed-capacity drain hint) —
        named so call sites read as batch shrink, and so the symmetry
        grow/shrink ↔ begin/end is explicit."""
        return self.task_end(slot_task, epoch=epoch)

    # -- fault tolerance -----------------------------------------------------
    def mark_dead(self, device_index: int) -> List[Task]:
        """Fail a device: evict residents. Waiter-path residents re-enter the
        waiter queue with restart priority (their callback fires again on a
        surviving device); legacy ``task_begin`` residents are only returned
        for the caller to re-drive."""
        with self._lock:
            dev = self.devices[device_index]
            dev.alive = False
            self._refresh_capacity_locked()
            evicted = list(dev.residents.values())
            tr = self._trace
            if tr is not None:
                off = self._trace_dev_off
                tr.emit(obs.MARK_DEAD, device=device_index + off)
                for t in evicted:
                    tr.emit(obs.EVICT, t.uid, t.name, device_index + off,
                            self._epochs.get(t.uid, 0),
                            data={"cause": "device_dead"})
            ex = self._explain
            if ex is not None:
                off = self._trace_dev_off
                for t in evicted:
                    ex.record(t.uid, t.name, obsx.EVICTED,
                              device=device_index + off,
                              reasons=({"reason": obsx.R_DEVICE_DEAD,
                                        "device": device_index + off},))
            for t in evicted:
                dev.release(t)
                t.device = None
            self._requeue_evicted_locked(evicted)
            fired = self._drain_locked()  # waiters may fit on survivors
            fired += self._fail_impossible_locked()
        self._fire(fired)
        return evicted

    def revive(self, device_index: int) -> None:
        with self._lock:
            self.devices[device_index].alive = True
            self._refresh_capacity_locked()
            tr = self._trace
            if tr is not None:
                tr.emit(obs.REVIVE,
                        device=device_index + self._trace_dev_off)
            # only the revived device changed: hint the drain at it
            fired = self._drain_locked(freed=device_index)
        self._fire(fired)

    def alive_devices(self) -> List[DeviceState]:
        return [d for d in self.devices if d.alive]
