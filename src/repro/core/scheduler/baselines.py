"""Baseline schedulers the paper evaluates against (§IV):

  * **SA** — single-assignment: one job per device, dedicated access for the
    job's lifetime (Slurm-style). Memory-safe, heavily under-utilized.
  * **CG** — core-to-GPU ratio packing under MPS: round-robin up to ``ratio``
    jobs per device with NO knowledge of memory or compute needs. Memory-
    UNSAFE: admitting a task that exceeds free HBM crashes the job (OOM), the
    behaviour Table II quantifies.
  * **MemOnly** — schedGPU [Reaño et al.]: memory is the only criterion and
    there is no device reassignment — a job is admitted to the FIRST device
    with enough free memory (so compute hot-spots pile up on device 0, the
    effect Fig. 6 shows).
"""
from __future__ import annotations

from typing import Optional

from repro.core.scheduler.base import DeviceState, Scheduler
from repro.core.task import Task


class SAScheduler(Scheduler):
    """Single-assignment: a device hosts at most one task/job at a time."""

    name = "SA"

    def device_feasible(self, task: Task, dev: DeviceState) -> bool:
        return dev.alive and not dev.residents

    def select_device(self, task: Task) -> Optional[DeviceState]:
        for dev in self.devices:
            if self.device_feasible(task, dev):
                return dev
        return None


class CGScheduler(Scheduler):
    """Ratio-based packing, memory-oblivious (the unsafe baseline).

    ``ratio`` = max co-resident jobs per device. Selection is round-robin over
    devices with a free slot; free HBM is NOT consulted — ``task_begin``
    succeeds even when the task's footprint exceeds the device, and the
    executor/simulator turns that into an OOM crash (paper Table II).
    """

    name = "CG"

    def __init__(self, num_devices: int, ratio: int = 4, **kw):
        super().__init__(num_devices, **kw)
        self.ratio = ratio
        self._rr = 0

    def can_ever_fit(self, task: Task) -> bool:
        # memory-oblivious: any alive device "fits" (and may then OOM)
        return any(d.alive for d in self.devices)

    def device_feasible(self, task: Task, dev: DeviceState) -> bool:
        # free HBM deliberately NOT consulted — the whole point of CG
        return dev.alive and len(dev.residents) < self.ratio

    def select_device(self, task: Task) -> Optional[DeviceState]:
        n = len(self.devices)
        for k in range(n):
            dev = self.devices[(self._rr + k) % n]
            if self.device_feasible(task, dev):
                self._rr = (self._rr + k + 1) % n
                return dev
        return None


class MemOnlyScheduler(Scheduler):
    """schedGPU: memory-safe but compute-blind and reassignment-free."""

    name = "schedGPU"

    def device_feasible(self, task: Task, dev: DeviceState) -> bool:
        return dev.alive and task.resources.hbm_bytes <= dev.free_hbm

    def select_device(self, task: Task) -> Optional[DeviceState]:
        for dev in self.devices:  # first fit — never balances
            if self.device_feasible(task, dev):
                return dev
        return None
