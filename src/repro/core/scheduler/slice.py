"""Beyond-paper: slice-level scheduling on a pod mesh.

The paper packs single-GPU tasks onto 2-4 devices in one node. At pod scale
the schedulable resource is a *mesh slice*: a task declares ``chips`` (1, 8,
16, 256, ...) and the scheduler places it on a contiguous, ICI-connected block
of a (rows x cols) chip grid — contiguity keeps the task's collectives on
intra-slice links. Memory stays a hard per-chip constraint (the MGB
guarantee); compute follows Alg. 3's min-aggregate-demand tie-break across
candidate slices.

This is the 1000+-node story: a 2-pod 512-chip system schedules a mix of
405B whole-slice training tasks and tiny SSM decode tasks without fragmenting
the torus.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from typing import Dict, List, Optional, Tuple

from repro.core.scheduler.base import (
    DEFAULT_HBM, DeviceState, WaiterQueueMixin, slots_needed,
)
from repro.core.task import Task


@dataclasses.dataclass(frozen=True)
class SliceRect:
    """A contiguous rectangle of chips on one pod's (rows x cols) grid."""
    pod: int
    r0: int
    c0: int
    rows: int
    cols: int

    @property
    def chips(self) -> int:
        return self.rows * self.cols

    def cells(self):
        for r in range(self.r0, self.r0 + self.rows):
            for c in range(self.c0, self.c0 + self.cols):
                yield (self.pod, r, c)


def _slice_shapes(chips: int, rows: int, cols: int) -> List[Tuple[int, int]]:
    """Near-square factorizations of ``chips`` that fit the grid (preferred
    first: square slices minimize ring hop count for both mesh axes)."""
    shapes = []
    for r in range(1, chips + 1):
        if chips % r:
            continue
        c = chips // r
        if r <= rows and c <= cols:
            shapes.append((r, c))
    shapes.sort(key=lambda rc: abs(rc[0] - rc[1]))
    return shapes


class SliceScheduler(WaiterQueueMixin):
    """Places k-chip tasks on contiguous slices of a multi-pod chip grid.

    Inherits the waiter/wakeup machinery from ``WaiterQueueMixin``, so the
    event-driven executor drives slice tasks through the exact same
    admit_or_enqueue / task_end-notify protocol as the flat schedulers — the
    admission callback just receives a ``SliceRect`` instead of an index.
    """

    name = "MGB-slice"

    def __init__(self, pods: int = 2, rows: int = 16, cols: int = 16,
                 hbm_per_chip: int = DEFAULT_HBM):
        self.pods, self.rows, self.cols = pods, rows, cols
        self.chips: Dict[Tuple[int, int, int], DeviceState] = {
            (p, r, c): DeviceState(index=(p * rows + r) * cols + c,
                                   total_hbm=hbm_per_chip)
            for p in range(pods) for r in range(rows) for c in range(cols)}
        self.bound: Dict[int, SliceRect] = {}   # task uid -> slice
        self._lock = threading.Lock()
        self.begin_attempts = 0
        self._init_waiters()

    # -- feasibility --------------------------------------------------------
    def _fits(self, rect: SliceRect, per_chip_bytes: int) -> bool:
        for cell in rect.cells():
            d = self.chips[cell]
            if not d.alive or per_chip_bytes > d.free_hbm:
                return False
        return True

    def _slice_demand(self, rect: SliceRect) -> float:
        return sum(self.chips[c].in_use_demand for c in rect.cells())

    def _find_slice(self, n_chips: int, per_chip_bytes: int
                    ) -> Optional[SliceRect]:
        best: Optional[SliceRect] = None
        best_demand = math.inf
        for pod in range(self.pods):
            for (sr, sc) in _slice_shapes(n_chips, self.rows, self.cols):
                for r0 in range(0, self.rows - sr + 1, sr):
                    for c0 in range(0, self.cols - sc + 1, sc):
                        rect = SliceRect(pod, r0, c0, sr, sc)
                        if not self._fits(rect, per_chip_bytes):
                            continue
                        d = self._slice_demand(rect)
                        if d < best_demand:
                            best, best_demand = rect, d
                        if d == 0.0:
                            return rect  # idle slice: cannot do better
        return best

    # -- paper API at slice granularity --------------------------------------
    def _admit_locked(self, task: Task) -> Optional[SliceRect]:
        self.begin_attempts += 1
        r = task.resources
        per_chip = r.hbm_bytes // max(r.chips, 1)
        rect = self._find_slice(r.chips, per_chip)
        if rect is None:
            return None
        for cell in rect.cells():
            dev = self.chips[cell]
            # not DeviceState.admit(): a slice task charges each chip its
            # per-chip share, not the whole-task footprint
            dev.used_hbm += per_chip
            dev.used_slots += slots_needed(task)
            dev.residents[task.uid] = task
        self.bound[task.uid] = rect
        task.device = rect.pod * self.rows * self.cols \
            + rect.r0 * self.cols + rect.c0
        return rect

    def can_ever_fit(self, task: Task) -> bool:
        r = task.resources
        per_chip = r.hbm_bytes // max(r.chips, 1)
        alive = sum(1 for d in self.chips.values()
                    if d.alive and per_chip <= d.total_hbm)
        return alive >= r.chips

    def task_begin(self, task: Task) -> Optional[SliceRect]:
        with self._lock:
            return self._admit_locked(task)

    def _release_locked(self, task: Task) -> None:
        rect = self.bound.pop(task.uid, None)
        if rect is None:
            return
        per_chip = task.resources.hbm_bytes // max(task.resources.chips, 1)
        for cell in rect.cells():
            dev = self.chips[cell]
            if task.uid in dev.residents:
                del dev.residents[task.uid]
                dev.used_hbm -= per_chip
                dev.used_slots -= slots_needed(task)

    def task_end(self, task: Task, *, epoch: Optional[int] = None) -> bool:
        with self._lock:
            if self._stale_locked(task, epoch):
                return False
            self._release_locked(task)
            self._admit_cbs.pop(task.uid, None)
            fired = self._drain_locked()
        self._fire(fired)
        return True

    def mark_dead(self, cell: Tuple[int, int, int]) -> List[Task]:
        """Fail one chip: every slice-task overlapping it is evicted whole."""
        with self._lock:
            self.chips[cell].alive = False
            evicted = []
            for uid, rect in list(self.bound.items()):
                if cell in set(rect.cells()):
                    task = None
                    for c2 in rect.cells():
                        task = self.chips[c2].residents.get(uid)
                        if task is not None:
                            break
                    self._release_locked(task)
                    task.device = None
                    evicted.append(task)
            self._requeue_evicted_locked(evicted)
            fired = self._drain_locked()  # waiters may fit on survivors
            fired += self._fail_impossible_locked()
        self._fire(fired)
        return evicted

    def revive(self, cell: Tuple[int, int, int]) -> None:
        with self._lock:
            self.chips[cell].alive = True
            fired = self._drain_locked()
        self._fire(fired)

    def utilization(self) -> float:
        busy = sum(1 for d in self.chips.values() if d.residents)
        return busy / len(self.chips)
