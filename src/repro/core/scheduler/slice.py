"""Beyond-paper: slice-level scheduling on a pod mesh — now a thin client of
the gang placement subsystem.

Historically this module owned its own grid math (rect enumeration, per-chip
fit checks). That all lives in ``repro.core.topology`` now, and the atomic
reservation + waiter-queue integration lives in
``repro.core.scheduler.gang.GangScheduler``; ``SliceScheduler`` survives as
the memory-hard / compute-soft (Alg. 3) configuration of that subsystem at
pod-fleet defaults — the 1000+-node story: a 2-pod 512-chip system schedules
a mix of 405B whole-slice training tasks and tiny SSM decode tasks without
fragmenting the torus, with ICI/DCN link accounting it never had before.
"""
from __future__ import annotations

from repro.core.scheduler.base import DEFAULT_HBM
from repro.core.scheduler.gang import GangScheduler
from repro.core.topology import SliceRect  # noqa: F401  (legacy re-export)


class SliceScheduler(GangScheduler):
    """Places k-chip tasks on contiguous slices of a multi-pod chip grid:
    ``GangScheduler`` with the Alg. 3 policy (memory hard per member chip,
    compute + links soft with min-demand / least-link-pressure tie-breaks)
    at pod-scale defaults."""

    def __init__(self, pods: int = 2, rows: int = 16, cols: int = 16,
                 hbm_per_chip: int = DEFAULT_HBM):
        super().__init__(pods, rows, cols, policy="alg3",
                         hbm_per_chip=hbm_per_chip)
        self.name = "MGB-slice"
