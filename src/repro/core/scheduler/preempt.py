"""Preemptive scheduling layer: checkpoint-based eviction of running work.

``PreemptionMixin`` upgrades any waiter-queue scheduler (the flat MGB
policies) — and ``GangPreemptionMixin`` the gang scheduler — from
admission-only to preemptive: when a waiter strictly outranks a resident
(priority class desc, EDF within a class — ``repro.core.preemption.outranks``)
and cannot be admitted from free capacity, the scheduler selects a
**min-cost victim set** (cost = remaining work x held memory), evicts it,
and admits the waiter in its place. The hook rides the existing admission
paths (``admit_or_enqueue`` for urgent arrivals, the ``_drain_locked`` scan
for parked waiters whose victims matured), so both backends replay identical
eviction decisions from one submission trace.

Eviction reuses the waiter-queue substrate end to end:

  * victims re-enter the admission queue at the **front of their priority
    class** (the eviction-restart path device failures already use) with
    their epoch bumped, so the superseded run's ``task_end`` is a fenced
    no-op;
  * each victim's **remaining work is banked** in the progress ledger —
    the simulator resumes it at remaining + checkpoint penalty (work
    conserving), and because re-admission goes through normal placement,
    a victim resuming on a *different* device IS live migration (counted
    in ``migrations``);
  * a **gang is evicted whole or not at all** — eviction releases its
    entire reservation (all member chips and link charges) through the
    gang scheduler's atomic-release path, so partial reservations never
    exist even mid-preemption;
  * guardrails (``PreemptionPolicy``): ``min_runtime_s`` residency before
    a task is preemptible, a per-job eviction ``budget`` after which it is
    immune, and ``aging_step`` priority escalation per eviction so
    repeatedly-bumped low-priority work eventually outranks its bullies.

Victim selection is greedy cheapest-first per device (per candidate group
for gangs): trial-evict in increasing cost order until the waiter's own
feasibility predicate passes, roll the trial back exactly, and commit the
cheapest feasible plan found. Trial + rollback run under the scheduler lock,
so concurrent admissions never observe a half-evicted fleet.
"""
from __future__ import annotations

import weakref
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.preemption import (
    PreemptionPolicy, ProgressLedger, outranks, preemption_cost,
    remaining_estimate,
)
from repro.core.scheduler.gang import GangScheduler
from repro.core.scheduler.mgb import MGBAlg2Scheduler, MGBAlg3Scheduler
from repro.core.scheduler.base import slots_needed
from repro.core.task import Task
from repro.obs import events as obs
from repro.obs import explain as obsx

# a preemption notice batch: (evicted task, its SUPERSEDED admission epoch)
# in eviction order. The epoch lets a backend reject a late-delivered notice
# whose victim has already been re-admitted and re-armed — without it, a
# stale notice could stop the fresh attempt and turn its early return into
# a current-epoch (i.e. real) completion.
PreemptListener = Callable[[List[Tuple[Task, int]]], None]


class PreemptionMixin:
    """Adds `_preempt_admit_locked` (the base-class hook) over any flat
    ``Scheduler`` host. Host contract: ``self.devices`` / ``device_feasible``
    (victim planning), ``self._lock`` / ``_clock`` / ``_admit_cbs`` /
    ``_requeue_evicted_locked`` (the waiter-queue substrate).

    ``preempt_policy=`` names the knob bundle (``preempt_`` prefix because
    the gang host already uses ``policy=`` for its alg2/alg3 compute
    policy). Constructing a preemptive class enables preemption;
    ``Cluster(preempt=...)`` can override either way.
    """

    def __init__(self, *args, preempt_policy: Optional[PreemptionPolicy] = None,
                 **kw):
        super().__init__(*args, **kw)
        self.preempt_policy = preempt_policy or PreemptionPolicy()
        self.ledger = ProgressLedger()
        self.preempt_enabled = True
        self.preemptions = 0          # committed evictions
        self.migrations = 0           # evicted tasks re-admitted elsewhere
        # (victim uid, preemptor uid) in decision order — the eviction-order
        # parity artifact the live/sim tests compare
        self.preempt_log: List[Tuple[int, int]] = []
        self._resident_since: Dict[int, float] = {}
        self._evicted_from: Dict[int, int] = {}   # uid -> lead device index
        # weak refs to backend observers (see add_preempt_listener): each
        # entry is a zero-arg resolver returning the listener or None
        self._preempt_listeners: List[
            Callable[[], Optional[PreemptListener]]] = []

    # -- backend notification -------------------------------------------------
    def add_preempt_listener(self, fn: PreemptListener) -> None:
        """Register an eviction observer (the executor signals the running
        task's cooperative checkpoint; the simulator banks exact remaining
        work). Notices are delivered outside the lock, always before the
        victim's re-admission callback can fire. Bound methods are held
        WEAKLY: a scheduler reused across backends must not keep every
        Executor/Simulator ever attached to it alive (dead refs are swept
        on the next register/notify)."""
        try:
            ref: Callable[[], Optional[PreemptListener]] = \
                weakref.WeakMethod(fn)
        except TypeError:
            ref = (lambda fn=fn: fn)   # plain callable: hold strongly
        with self._lock:
            self._preempt_listeners = [
                r for r in self._preempt_listeners if r() is not None]
            if not any(r() == fn for r in self._preempt_listeners):
                self._preempt_listeners.append(ref)

    # -- admission bookkeeping ------------------------------------------------
    def _admit_locked(self, task: Task):
        placement = super()._admit_locked(task)
        if placement is not None:
            self._resident_since[task.uid] = self._clock()
            prev = self._evicted_from.pop(task.uid, None)
            if prev is not None and prev != task.device:
                # the evicted task resumed on a DIFFERENT device: requeue +
                # placement just performed a live migration
                self.migrations += 1
        return placement

    def task_end(self, task: Task, *, epoch: Optional[int] = None) -> bool:
        ok = super().task_end(task, epoch=epoch)
        if ok:
            # current-epoch completion: drop the residency stamp and any
            # banked progress (GIL-atomic pops; stale completions keep both
            # for the live re-admitted incarnation)
            self._resident_since.pop(task.uid, None)
            self.ledger.clear(task.uid)
        return ok

    def _drop_preempt_state(self, task: Task) -> None:
        """A waiter leaving for good (cancelled, shed, impossible after the
        fleet shrank) never resumes: its banked progress and migration
        breadcrumb would otherwise leak forever (uids are never reused, so
        the entries are pure dead weight)."""
        self.ledger.clear(task.uid)
        self._evicted_from.pop(task.uid, None)
        self._resident_since.pop(task.uid, None)

    def _forget_task_locked(self, task: Task) -> None:
        self._drop_preempt_state(task)

    def cancel_wait(self, task: Task) -> bool:
        ok = super().cancel_wait(task)
        if ok:
            self._drop_preempt_state(task)
        return ok

    def cancel_all_waiters(self) -> List[Task]:
        out = super().cancel_all_waiters()
        for t in out:
            self._drop_preempt_state(t)
        return out

    # -- victim eligibility / cost --------------------------------------------
    def _victim_ok_locked(self, waiter: Task, resident: Task,
                          now: float) -> bool:
        if resident.uid not in self._admit_cbs:
            return False   # legacy task_begin resident: no requeue path
        if resident.preempt_count >= self.preempt_policy.budget:
            return False   # eviction budget spent: immune from here on
        since = self._resident_since.get(resident.uid, now)
        if now - since < self.preempt_policy.min_runtime_s:
            return False   # too fresh: anti-thrash residency guard
        return outranks(waiter, resident)

    def _victim_cost_locked(self, resident: Task, now: float) -> float:
        since = self._resident_since.get(resident.uid, now)
        return preemption_cost(
            resident, remaining_estimate(resident, self.ledger, now - since))

    # -- evict / restore primitives (flat host; gang mixin overrides) ---------
    def _evict_locked(self, victim: Task):
        tok = victim.device
        self.devices[tok].release(victim)
        victim.device = None
        return tok

    def _restore_locked(self, victim: Task, tok) -> None:
        self.devices[tok].admit(victim)
        victim.device = tok

    def _tok_lead(self, tok) -> int:
        return tok

    # -- victim planning ------------------------------------------------------
    def _greedy_plan_locked(self, cands: List[Task],
                            feasible: Callable[[], bool], now: float,
                            best_cost: float,
                            useful: Optional[Callable[[Task], bool]] = None
                            ) -> Optional[Tuple[List[Task], float]]:
        """Greedy min-cost victim cover against a feasibility predicate:
        trial-evict candidates cheapest-first until ``feasible()`` passes,
        then PRUNE — restore each taken victim in turn and keep only those
        whose restoration breaks feasibility (a cheap bystander evicted on
        the way to the resident that actually makes room is given back).
        Everything is restored before returning; the caller re-evicts the
        committed plan. Returns (victims, cost) or None."""
        cands = sorted(cands, key=lambda t: self._victim_cost_locked(t, now))
        taken: List[Task] = []
        toks: List[object] = []
        cost = 0.0
        ok = feasible()
        for v in cands:
            if ok or cost >= best_cost:
                break
            if useful is not None and not useful(v):
                continue  # evicting this victim frees nothing we need
            toks.append(self._evict_locked(v))
            taken.append(v)
            cost += self._victim_cost_locked(v, now)
            ok = feasible()
        plan: Optional[Tuple[List[Task], float]] = None
        if ok and taken:
            kept: List[Task] = []
            kept_toks: List[object] = []
            for v, tok in zip(taken, toks):
                self._restore_locked(v, tok)
                if not feasible():
                    self._evict_locked(v)
                    kept.append(v)
                    kept_toks.append(tok)
            taken, toks = kept, kept_toks
            cost = sum(self._victim_cost_locked(v, now) for v in taken)
            if taken and cost < best_cost:
                plan = (list(taken), cost)
        for v, tok in zip(reversed(taken), reversed(toks)):
            self._restore_locked(v, tok)
        return plan

    # bound on recorded considered-plan entries per preemption attempt (the
    # explain collector must not grow with fleet size)
    _PLANS_CAP = 16

    def _plan_victims_locked(self, task: Task,
                             explain_out: Optional[List[dict]] = None
                             ) -> Optional[List[Task]]:
        """Min-cost victim set on ONE device (flat host): per alive device,
        greedy-cover against that device's own ``device_feasible`` predicate,
        keep the cheapest feasible plan across devices. Greedy + prune, not
        optimal subset-sum — the cost model only has to rank victims.

        With ``explain_out`` (a list, explain enabled), every per-device
        planning outcome is appended: feasible plans with their victim uids
        and cost, infeasible/over-budget attempts with the eligible-victim
        count — the "considered and rejected" record of a preemption
        verdict."""
        now = self._clock()
        best: Optional[List[Task]] = None
        best_cost = float("inf")
        for dev in self.devices:
            if not dev.alive:
                continue
            cands = [t for t in dev.residents.values()
                     if self._victim_ok_locked(task, t, now)]
            if not cands:
                continue
            plan = self._greedy_plan_locked(
                cands, lambda d=dev: self.device_feasible(task, d),
                now, best_cost)
            if explain_out is not None and len(explain_out) < self._PLANS_CAP:
                if plan is not None:
                    explain_out.append(
                        {"device": dev.index + self._trace_dev_off,
                         "victims": [v.uid for v in plan[0]],
                         "cost_s": plan[1]})
                else:
                    explain_out.append(
                        {"device": dev.index + self._trace_dev_off,
                         "eligible": len(cands), "rejected": True})
            if plan is not None:
                best, best_cost = plan
        return best

    # -- the hook -------------------------------------------------------------
    def _preempt_admit_locked(self, task: Task):
        ex = self._explain
        considered: Optional[List[dict]] = [] if ex is not None else None
        plan = self._plan_victims_locked(task, explain_out=considered)
        if not plan:
            if ex is not None:
                # collapse: a parked waiter retrying every drain keeps ONE
                # no-plan verdict with a bumped repeat count (the first
                # attempt's considered-plan record is retained)
                ex.record(task.uid, task.name, obsx.PREEMPT_REJECTED,
                          reasons=({"reason": obsx.R_NO_VICTIM_PLAN},),
                          data={"considered": considered}
                          if considered else None,
                          collapse=True)
            return None
        toks = [self._evict_locked(v) for v in plan]
        placement = self._admit_locked(task)
        if placement is None:
            # the plan was feasibility-checked, so this should not happen;
            # restore exactly rather than trusting that it cannot
            for v, tok in zip(reversed(plan), reversed(toks)):
                self._restore_locked(v, tok)
            return None
        now = self._clock()
        if ex is not None:
            ex.record(task.uid, task.name, obsx.PREEMPT_PLANNED,
                      device=getattr(placement, "lead", placement)
                      + self._trace_dev_off,
                      data={"victims": [v.uid for v in plan],
                            "cost_s": sum(self._victim_cost_locked(v, now)
                                          for v in plan),
                            "considered": considered})
        for v, tok in zip(plan, toks):
            since = self._resident_since.pop(v.uid, now)
            # bank remaining work BEFORE mutating the ledger entry it reads;
            # an estimate from residency time — the simulator's listener
            # overwrites it with the exact value
            rem = remaining_estimate(v, self.ledger, now - since)
            if ex is not None:
                ex.record(v.uid, v.name, obsx.EVICTED,
                          device=self._tok_lead(tok) + self._trace_dev_off,
                          reasons=({"reason": "preempted", "by": task.uid,
                                    "by_name": task.name,
                                    "cost_s": preemption_cost(v, rem)},))
            self.ledger.set_remaining(v.uid, rem)
            v.preempt_count += 1
            if self.preempt_policy.aging_step:
                # anti-starvation aging: each eviction raises the victim's
                # ADMISSION rank, so a repeatedly-bumped job eventually
                # outranks the stream of arrivals displacing it (and, past
                # budget, is immune). An admission bonus only — raw
                # task.priority is what eviction decisions compare, so an
                # aged victim never starts bullying its own class
                v.age_boost += self.preempt_policy.aging_step
            self._evicted_from[v.uid] = self._tok_lead(tok)
            self.preemptions += 1
            self.preempt_log.append((v.uid, task.uid))
            tr = self._trace
            if tr is not None:
                # fires after the preemptor's ADMIT (emitted inside
                # _admit_locked above) — the same order on both backends,
                # and per-victim lifecycle legality is unaffected
                tr.emit(obs.EVICT, v.uid, v.name,
                        self._tok_lead(tok) + self._trace_dev_off,
                        self._epochs.get(v.uid, 0),
                        data={"by": task.uid, "cause": "preempt"})
        # capture each victim's pre-bump epoch BEFORE the requeue bumps it:
        # the notice is addressed to that superseded attempt only
        note = [(v, self._epochs.get(v.uid, 0)) for v in plan]
        self._requeue_evicted_locked(plan)
        if self._preempt_listeners:
            listeners = [fn for fn in
                         (r() for r in self._preempt_listeners)
                         if fn is not None]
            self._deferred.append(
                lambda: [fn(note) for fn in listeners])
        return placement


class GangPreemptionMixin(PreemptionMixin):
    """Preemption over the gang scheduler: victims are whole reservations.

    Planning ranges over the topology's candidate groups for the waiter's
    shape; a victim overlapping the chosen group is evicted WHOLE (its
    entire reservation — all member chips and link charges — through
    ``_release_locked``), so no partial reservation ever exists. Solo tasks
    hold 1-cell reservations and ride the same path.
    """

    def _evict_locked(self, victim: Task):
        group = self.bound[victim.uid]
        self._release_locked(victim)
        victim.device = None
        return group

    def _restore_locked(self, victim: Task, group) -> None:
        self._reserve_group_locked(victim, group)

    def _tok_lead(self, group) -> int:
        return group.lead

    def _group_admissible_locked(self, group, per_chip: int, need: int,
                                 resources) -> bool:
        if not all(self._member_ok(c, per_chip, need)
                   for c in group.cells()):
            return False
        # self.policy is the gang host's alg2/alg3 COMPUTE policy string
        return self.policy != "alg2" \
            or self.topo.link_headroom_ok(group, resources)

    def _plan_victims_locked(self, task: Task,
                             explain_out: Optional[List[dict]] = None
                             ) -> Optional[List[Task]]:
        r = task.resources
        k = max(r.chips, 1)
        per_chip = r.hbm_bytes // k
        need = slots_needed(task)
        now = self._clock()
        # cheap pre-gate: with no eligible victim anywhere on the fleet, no
        # candidate group can assemble one — skip the group enumeration
        # (groups x cells) that dominates the cost of a doomed plan
        if not any(self._victim_ok_locked(task, t, now)
                   for d in self.devices if d.alive
                   for t in d.residents.values()):
            if explain_out is not None:
                explain_out.append({"eligible": 0, "rejected": True})
            return None
        best: Optional[List[Task]] = None
        best_cost = float("inf")
        for group in self.topo.candidate_groups(k):
            cells = list(group.cells())
            if any(not self.topo.cells[c].alive for c in cells):
                continue
            cellset = set(cells)
            cands: List[Task] = []
            seen = set()
            for c in cells:
                for t in self.topo.cells[c].residents.values():
                    if t.uid not in seen:
                        seen.add(t.uid)
                        if self._victim_ok_locked(task, t, now):
                            cands.append(t)
            if not cands:
                continue

            def useful(v: Task, cellset=cellset) -> bool:
                # a victim helps iff it occupies a group cell that is not yet
                # member-feasible, or (alg2, links hard) holds link charges
                # whose release could restore headroom
                overlap = [c for c in self.bound[v.uid].cells()
                           if c in cellset]
                return any(not self._member_ok(c, per_chip, need)
                           for c in overlap) \
                    or (self.policy == "alg2"
                        and v.resources.collective_bytes > 0)

            plan = self._greedy_plan_locked(
                cands,
                lambda g=group: self._group_admissible_locked(
                    g, per_chip, need, r),
                now, best_cost, useful=useful)
            if explain_out is not None and len(explain_out) < self._PLANS_CAP:
                if plan is not None:
                    explain_out.append(
                        {"device": group.lead + self._trace_dev_off,
                         "victims": [v.uid for v in plan[0]],
                         "cost_s": plan[1]})
                else:
                    explain_out.append(
                        {"device": group.lead + self._trace_dev_off,
                         "eligible": len(cands), "rejected": True})
            if plan is not None:
                best, best_cost = plan
        return best


class PreemptiveAlg2Scheduler(PreemptionMixin, MGBAlg2Scheduler):
    """Alg. 2 (memory + compute slots hard) with preemptive admission."""
    name = "MGB-Alg2-preempt"


class PreemptiveAlg3Scheduler(PreemptionMixin, MGBAlg3Scheduler):
    """Alg. 3 (memory hard, compute soft) with preemptive admission."""
    name = "MGB-Alg3-preempt"


class PreemptiveGangScheduler(GangPreemptionMixin, GangScheduler):
    """Gang scheduler with whole-reservation preemptive admission."""

    def __init__(self, *args, **kw):
        super().__init__(*args, **kw)
        self.name += "-preempt"
