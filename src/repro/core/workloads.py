"""Workload generation for the paper's evaluation (§V-A).

Rodinia-analogue jobs: a library of kernel families with the same resource
personalities as the paper's picks (backprop, srad v1/v2, lavaMD, needle,
dwt2d, bfs) expressed as pure-JAX computations. Each job's ResourceVector is
obtained the compiler-guided way — ``jit(fn).lower(ShapeDtypeStruct...).
compile()`` and probing the artifact (no allocation, so we probe at FULL
multi-GB footprints even on this CPU container). Durations are the roofline
estimate scaled by an iteration count calibrated to the paper's 5-10-minute
workloads.

Mixes (Table I): large = >4 GB footprint, small = 1-4 GB; W1..W8 are
{16, 32} jobs x {1:1, 2:1, 3:1, 5:1} large:small, randomly drawn but seeded.

NN jobs (§V-E): predict / train / detect / generate personalities probed from
THIS repo's real model substrate (prefill / train_step / decode of reduced
archs) — each network 0.5-1.5 GB, detect deliberately low-utilization
(nvidia-smi reported <=25% for yolo with MULTIPLE jobs resident, i.e.
<=1/8 per job — demands are calibrated to the paper's own utilization data).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.probe import probe_fn
from repro.core.task import Job, ResourceVector, Task, UnitTask

GB = 1024**3


# ---------------------------------------------------------------------------
# Rodinia-analogue kernel library
# ---------------------------------------------------------------------------
# Each entry: (fn(n) kernel over an n-element working set, bytes-per-n,
# personality notes). All fns are jittable; probes run on ShapeDtypeStructs.

def _k_backprop(x, w1, w2):
    """2-layer MLP fwd+bwd over a chunk (pattern recognition)."""
    def loss(w1, w2):
        h = jnp.tanh(x @ w1)
        return jnp.sum(jnp.square(h @ w2))
    g1, g2 = jax.grad(loss, argnums=(0, 1))(w1, w2)
    return w1 - 1e-3 * g1, w2 - 1e-3 * g2


def _k_srad(img):
    """Anisotropic diffusion stencil sweep (image processing)."""
    def step(im, _):
        n = jnp.roll(im, 1, 0) + jnp.roll(im, -1, 0) \
            + jnp.roll(im, 1, 1) + jnp.roll(im, -1, 1) - 4 * im
        g = n / (im + 1e-6)
        c = 1.0 / (1.0 + jnp.square(g))
        return im + 0.1 * c * n, None
    out, _ = jax.lax.scan(step, img, None, length=8)
    return out


def _k_lavamd(pos, q):
    """All-pairs-in-neighborhood force kernel (molecular dynamics)."""
    def cell(p_block):
        d = p_block[:, None, :] - p_block[None, :, :]   # [c, c, 3]
        r2 = jnp.sum(d * d, axis=-1) + 1e-3
        f = q[:, None] * q[None, :] / r2
        return jnp.sum(f[..., None] * d, axis=1)
    return jax.vmap(cell)(pos)


def _k_needle(seq):
    """Wavefront DP over an alignment matrix (bioinformatics)."""
    def row(prev, s):
        cur = jnp.maximum(prev + s, jnp.roll(prev, 1) - 1.0)
        return cur, cur
    _, rows = jax.lax.scan(row, seq[0], seq)
    return rows


def _k_dwt2d(img):
    """Separable wavelet transform passes (image/video compression)."""
    lo = (img[:, ::2] + img[:, 1::2]) * 0.5
    hi = (img[:, ::2] - img[:, 1::2]) * 0.5
    lo2 = (lo[::2] + lo[1::2]) * 0.5
    hi2 = (lo[::2] - lo[1::2]) * 0.5
    return lo2, hi2, hi


def _k_bfs(adj, frontier):
    """Sparse frontier expansion as dense matvec rounds (graph)."""
    def step(f, _):
        nf = jnp.clip(adj @ f, 0.0, 1.0)
        return nf, jnp.sum(nf)
    out, sums = jax.lax.scan(step, frontier, None, length=4)
    return out, sums


# Achieved-efficiency profiles (core_eff, bw_eff): the fraction of peak
# compute / HBM bandwidth each family reaches while running solo. Dense
# matmuls run near the MXU roof; stencils reach ~half of stream bandwidth;
# wavefront DP and graph frontier expansion are latency-bound. Calibrated to
# the paper's motivating observation that a typical workload uses ~30% of a
# device (§I) — the mixes below average ~=0.35 dominant-resource share.
EFFICIENCY = {
    "backprop": (0.85, 0.60),
    "srad_v1": (0.50, 0.45),
    "srad_v2": (0.50, 0.45),
    "lavamd": (0.90, 0.50),
    "needle": (0.30, 0.25),
    "dwt2d": (0.40, 0.35),
    "bfs": (0.25, 0.20),
}


def _probe_at(family: str, n: int) -> ResourceVector:
    """Probe one kernel family at an n-element working set (no allocation)."""
    S = jax.ShapeDtypeStruct
    f32 = jnp.float32
    eff = EFFICIENCY[family]
    if family == "backprop":
        d = max(int((n / 6) ** 0.5) // 128 * 128, 256)
        return probe_fn(_k_backprop, S((d, d), f32), S((d, d), f32),
                        S((d, d), f32), efficiency=eff)
    if family in ("srad_v1", "srad_v2"):
        side = max(int((n / 2) ** 0.5) // 128 * 128, 256)
        return probe_fn(_k_srad, S((side, side), f32), efficiency=eff)
    if family == "lavamd":
        cells_ = max(n // (4 * 128), 64)
        return probe_fn(_k_lavamd, S((cells_, 128, 3), f32), S((128,), f32),
                        efficiency=eff)
    if family == "needle":
        side = max(int((n / 2) ** 0.5) // 128 * 128, 256)
        return probe_fn(_k_needle, S((side, side), f32), efficiency=eff)
    if family == "dwt2d":
        side = max(int((n / 2) ** 0.5) // 128 * 128, 256)
        return probe_fn(_k_dwt2d, S((side, side), f32), efficiency=eff)
    if family == "bfs":
        side = max(int(n ** 0.5) // 128 * 128, 256)
        return probe_fn(_k_bfs, S((side, side), f32), S((side,), f32),
                        efficiency=eff)
    raise KeyError(family)


@functools.lru_cache(maxsize=None)
def _probe_family(family: str, footprint_bytes: int) -> ResourceVector:
    """Probe a kernel family, CALIBRATING the working-set size until the
    compiled footprint (args + temps, which the nominal size underestimates)
    lands within 25% of the target. Footprint is ~linear in n, so 1-3
    fixed-point steps converge."""
    n = footprint_bytes // 4
    vec = _probe_at(family, n)
    for _ in range(3):
        ratio = vec.hbm_bytes / footprint_bytes
        if 0.75 <= ratio <= 1.25:
            break
        n = max(int(n / ratio), 1 << 16)
        vec = _probe_at(family, n)
    return vec


# paper: 7 combos at 1-4 GB (all but lavaMD), 10 combos > 4 GB (all but bfs)
SMALL_FAMILIES = ["backprop", "srad_v1", "srad_v2", "needle", "dwt2d", "bfs"]
LARGE_FAMILIES = ["backprop", "srad_v1", "srad_v2", "lavamd", "needle",
                  "dwt2d"]
SMALL_RANGE = (1.0 * GB, 4.0 * GB)
LARGE_RANGE = (4.5 * GB, 13.0 * GB)
# calibrate job durations to the paper's 5-10-minute workload scale
TARGET_JOB_SECONDS = (8.0, 40.0)


def make_rodinia_job(rng: np.random.Generator, *, large: bool,
                     name: str) -> Job:
    fam = rng.choice(LARGE_FAMILIES if large else SMALL_FAMILIES)
    lo, hi = LARGE_RANGE if large else SMALL_RANGE
    # snap footprints to a small grid so the probe cache hits
    foot = int(rng.uniform(lo, hi) / (0.5 * GB)) * int(0.5 * GB)
    base = _probe_family(str(fam), foot)
    tgt = rng.uniform(*TARGET_JOB_SECONDS)
    vec = base.scaled(tgt / max(base.est_seconds, 1e-9))
    unit = UnitTask(fn=None, memobjs=frozenset({f"{name}/ws"}),
                    resources=vec, name=f"{fam}-{foot // GB}G")
    return Job(tasks=[Task(units=[unit], name=unit.name)], name=name)


def make_mix(seed: int, n_jobs: int, ratio: Tuple[int, int]) -> List[Job]:
    """ratio = (large, small), e.g. (3, 1). Jobs randomly drawn, seeded."""
    rng = np.random.default_rng(seed)
    lg, sm = ratio
    jobs = []
    for i in range(n_jobs):
        large = (i % (lg + sm)) < lg
        jobs.append(make_rodinia_job(rng, large=large, name=f"job{i:03d}"))
    order = rng.permutation(len(jobs))
    return [jobs[i] for i in order]


# Table I: the eight Rodinia workloads
WORKLOADS: Dict[str, Tuple[int, Tuple[int, int]]] = {
    "W1": (16, (1, 1)), "W2": (16, (2, 1)), "W3": (16, (3, 1)),
    "W4": (16, (5, 1)), "W5": (32, (1, 1)), "W6": (32, (2, 1)),
    "W7": (32, (3, 1)), "W8": (32, (5, 1)),
}


def workload(name: str, seed: int = 0) -> List[Job]:
    n, ratio = WORKLOADS[name]
    # stable per-workload seed (python hash() is salted per process)
    tag = sum(ord(c) * 31 ** i for i, c in enumerate(name)) % 1000
    return make_mix(seed + tag, n, ratio)


# ---------------------------------------------------------------------------
# NN jobs (§V-E) — probed from this repo's real model substrate
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _nn_vector(kind: str) -> ResourceVector:
    from repro.configs.registry import get_arch
    from repro.launch.flops import forward_flops, step_flops
    from repro.configs.base import ShapeConfig
    from repro.optim.adamw import AdamWConfig
    from repro.serve.decode import make_prefill_step
    from repro.train.train_step import abstract_train_state, make_train_step

    if kind == "predict":   # darknet19/53 classification: prefill-like
        cfg = get_arch("qwen1.5-32b").reduced()
        shape = ShapeConfig("nn_predict", 1024, 8, "prefill")
        step = make_prefill_step(cfg, attn_impl="flash_jnp")
        params, _ = abstract_train_state(cfg, AdamWConfig())
        from repro.launch.specs import input_specs
        batch = input_specs(cfg, shape)
        compiled = jax.jit(step).lower(params, batch).compile()
        from repro.core.probe import vector_from_compiled
        return vector_from_compiled(
            compiled, flops_override=forward_flops(cfg, 8, 1024),
            work_scale=400.0, efficiency=(0.18, 0.15))
    if kind == "train":     # CIFAR-small training
        cfg = get_arch("gemma2-9b").reduced()
        shape = ShapeConfig("nn_train", 512, 16, "train")
        opt = AdamWConfig()
        step = make_train_step(cfg, opt, attn_impl="flash_jnp")
        params, opts = abstract_train_state(cfg, opt)
        from repro.launch.specs import input_specs
        batch = input_specs(cfg, shape)
        compiled = jax.jit(step).lower(params, opts, batch).compile()
        from repro.core.probe import vector_from_compiled
        return vector_from_compiled(
            compiled, flops_override=step_flops(cfg, shape),
            work_scale=250.0, efficiency=(0.39, 0.30))
    if kind == "detect":    # yolo real-time: tiny, low utilization (<=25%)
        import dataclasses as _dc
        base = _nn_vector("predict")
        return _dc.replace(base.scaled(0.5), core_demand=0.12,
                           bw_demand=0.10, hbm_bytes=int(0.6 * GB))
    if kind == "generate":  # RNN text generation: decode-step personality
        from repro.serve.decode import abstract_cache, make_serve_step
        cfg = get_arch("musicgen-large").reduced()
        serve = make_serve_step(cfg)
        params, _ = abstract_train_state(cfg, AdamWConfig())
        cache = abstract_cache(cfg, 8, 512)
        tok = jax.ShapeDtypeStruct((8,), jnp.int32)
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        compiled = jax.jit(serve).lower(params, cache, tok, pos).compile()
        from repro.core.probe import vector_from_compiled
        return vector_from_compiled(compiled, work_scale=20000.0,
                                    efficiency=(0.05, 0.275))
    raise KeyError(kind)


NN_KINDS = ("predict", "train", "detect", "generate")
# paper: each NN's device state is 0.5-1.5 GB
_NN_MEM = {"predict": int(1.1 * GB), "train": int(1.5 * GB),
           "detect": int(0.6 * GB), "generate": int(0.5 * GB)}


def make_nn_job(kind: str, idx: int) -> Job:
    import dataclasses as _dc
    vec = _dc.replace(_nn_vector(kind), hbm_bytes=_NN_MEM[kind])
    unit = UnitTask(fn=None, memobjs=frozenset({f"nn{idx}/{kind}"}),
                    resources=vec, name=f"{kind}{idx}")
    return Job(tasks=[Task(units=[unit], name=unit.name)], name=f"{kind}{idx}")


def nn_homogeneous(kind: str, n_jobs: int = 8) -> List[Job]:
    return [make_nn_job(kind, i) for i in range(n_jobs)]


def nn_mix(seed: int, n_jobs: int = 128) -> List[Job]:
    rng = np.random.default_rng(seed)
    return [make_nn_job(str(rng.choice(NN_KINDS)), i) for i in range(n_jobs)]


# ---------------------------------------------------------------------------
# Gang workloads — multi-chip tasks for the gang placement subsystem
# ---------------------------------------------------------------------------
# A gang job is one Task with resources.chips = k: a sharded train step (or
# pipeline stage group) whose k shards run in lockstep on a contiguous device
# group. Convention (matches GangScheduler): ``hbm_bytes`` is the TOTAL
# footprint (charged per chip as hbm_bytes / chips), ``core_demand`` /
# ``bw_demand`` are per-chip shares, ``collective_bytes`` is the per-link
# ring payload its collectives move over the group's ICI links, and
# ``est_seconds`` is the roofline max of compute and ICI-collective time.
# Vectors are synthetic (seeded) rather than probed: a gang has no single
# compiled artifact to probe yet — the per-shard executable exists, but the
# group personality (collective share, lockstep duration) is a property of
# the sharding, which these knobs model directly.

# v5e-class peaks, for internally-consistent synthetic flops/bytes numbers
_PEAK_FLOPS = 197e12
_HBM_BW = 819e9
_ICI_BW = 50e9


def make_gang_job(rng: np.random.Generator, *, chips: int, name: str,
                  per_chip_gb: Tuple[float, float] = (2.0, 6.0),
                  seconds: Tuple[float, float] = TARGET_JOB_SECONDS,
                  collective_share: Tuple[float, float] = (0.25, 0.6)) -> Job:
    """One k-chip gang job: seeded per-chip footprint/demand, a compute
    duration, and a collective payload sized so its steady ICI-link share
    lands in ``collective_share`` (the knob link contention studies turn)."""
    per_chip = rng.uniform(*per_chip_gb) * GB
    compute_s = rng.uniform(*seconds)
    share = rng.uniform(*collective_share)
    demand = rng.uniform(0.4, 0.9)
    # per-link ring payload that occupies `share` of a link for compute_s
    collective_bytes = share * compute_s * _ICI_BW
    est = max(compute_s, collective_bytes / _ICI_BW)  # = compute_s (share<=1)
    vec = ResourceVector(
        hbm_bytes=int(per_chip * chips),
        flops=demand * compute_s * _PEAK_FLOPS * chips,
        bytes_accessed=0.5 * demand * compute_s * _HBM_BW * chips,
        collective_bytes=collective_bytes,
        est_seconds=est, core_demand=demand, bw_demand=0.5 * demand,
        chips=chips)
    unit = UnitTask(fn=None, memobjs=frozenset({f"{name}/shards"}),
                    resources=vec, name=name)
    task = Task(units=[unit], name=name, gang_id=name)
    return Job(tasks=[task], name=name, gang_id=name)


def gang_mix(seed: int, *, n_singles: int = 12, n_gangs: int = 8,
             chip_choices: Sequence[int] = (2, 4),
             probe_singles: bool = True,
             single_large_frac: float = 0.25,
             per_chip_gb: Tuple[float, float] = (2.0, 6.0)) -> List[Job]:
    """The mixed single-chip / multi-chip open-arrival scenario: W-mix-style
    Rodinia jobs (``single_large_frac`` of them from the >4 GB families —
    large residents are what fragments a mesh) interleaved with seeded
    k-chip gangs, shuffled into one arrival order. ``probe_singles=False``
    swaps the compiler-probed singles for synthetic ones (same
    personalities, no XLA compiles) so smoke tests stay fast."""
    rng = np.random.default_rng(seed)
    jobs: List[Job] = []
    for i in range(n_singles):
        large = rng.random() < single_large_frac
        if probe_singles:
            jobs.append(make_rodinia_job(rng, large=large,
                                         name=f"single{i:03d}"))
        else:
            lo, hi = LARGE_RANGE if large else SMALL_RANGE
            vec = ResourceVector(
                hbm_bytes=int(rng.uniform(lo, hi)), flops=1e12,
                bytes_accessed=1e11,
                est_seconds=rng.uniform(*TARGET_JOB_SECONDS),
                core_demand=rng.uniform(0.2, 0.6),
                bw_demand=rng.uniform(0.2, 0.5))
            unit = UnitTask(fn=None, memobjs=frozenset({f"single{i}/ws"}),
                            resources=vec, name=f"single{i:03d}")
            jobs.append(Job(tasks=[Task(units=[unit], name=unit.name)],
                            name=unit.name))
    for i in range(n_gangs):
        chips = int(rng.choice(chip_choices))
        jobs.append(make_gang_job(rng, chips=chips,
                                  name=f"gang{i:03d}x{chips}",
                                  per_chip_gb=per_chip_gb))
    order = rng.permutation(len(jobs))
    return [jobs[i] for i in order]


# ---------------------------------------------------------------------------
# Overload workloads — the preemption subsystem's evaluation trace
# ---------------------------------------------------------------------------
# An OVERLOADED open-arrival scenario: long memory-heavy background jobs
# saturate the fleet, short urgent deadlined jobs arrive while they run, and
# small low-demand bystanders co-reside throughout. Memory is the binding
# constraint by construction (background + urgent footprints cannot share a
# 16 GB device), so an urgent arrival can only (a) wait out a background job
# many times its length, (b) be shed, or (c) preempt — the three systems
# benchmarks/bench_preempt.py compares. Bystanders are small enough to stay
# resident through the churn: their kernel slowdown is the "non-preempted
# degradation" the paper's <=2.5% envelope is checked against.

def _synthetic_job(rng: np.random.Generator, name: str, *,
                   gb: Tuple[float, float], seconds: Tuple[float, float],
                   core: float, bw: float, priority: int = 0) -> Job:
    vec = ResourceVector(
        hbm_bytes=int(rng.uniform(*gb) * GB), flops=1e12,
        bytes_accessed=1e11, est_seconds=float(rng.uniform(*seconds)),
        core_demand=core, bw_demand=bw)
    unit = UnitTask(fn=None, memobjs=frozenset({f"{name}/ws"}),
                    resources=vec, name=name)
    return Job(tasks=[Task(units=[unit], name=name)], name=name,
               priority=priority)


def overload_mix(seed: int, *, n_background: int = 8, n_bystander: int = 4,
                 n_urgent: int = 24, urgent_rate_hz: float = 1.2,
                 bg_gb: Tuple[float, float] = (9.5, 11.0),
                 bg_seconds: Tuple[float, float] = (16.0, 24.0),
                 urgent_gb: Tuple[float, float] = (8.5, 9.5),
                 urgent_seconds: Tuple[float, float] = (0.6, 1.4),
                 urgent_deadline_slack_s: float = 2.0,
                 urgent_priority: int = 5) -> List[Dict]:
    """Seeded overload trace as submission rows
    ``{"t", "job", "priority", "deadline_s", "kind"}`` sorted by arrival.

    Backgrounds (priority 0, no deadline, ~10 GB x ~20 s) and bystanders
    (~1 GB, low demand) arrive in the first two seconds and saturate the
    fleet; urgents (priority ``urgent_priority``, ~9 GB x ~1 s, deadline =
    est + slack) arrive Poisson at ``urgent_rate_hz`` from t=2 onwards.
    ``deadline_s`` is relative to the row's own ``t`` — callers pass it to
    ``Cluster.submit`` at that virtual time (or ignore it for the FIFO
    baseline and only measure against it)."""
    rng = np.random.default_rng(seed)
    rows: List[Dict] = []
    for i in range(n_background):
        rows.append({"t": float(rng.uniform(0.0, 1.0)),
                     "job": _synthetic_job(rng, f"bg{i:03d}", gb=bg_gb,
                                           seconds=bg_seconds,
                                           core=0.45, bw=0.30),
                     "priority": 0, "deadline_s": None, "kind": "background"})
    for i in range(n_bystander):
        rows.append({"t": float(rng.uniform(0.0, 2.0)),
                     "job": _synthetic_job(rng, f"by{i:03d}", gb=(0.8, 1.5),
                                           seconds=(8.0, 14.0),
                                           core=0.10, bw=0.08),
                     "priority": 0, "deadline_s": None, "kind": "bystander"})
    t = 2.0
    for i in range(n_urgent):
        t += float(rng.exponential(1.0 / urgent_rate_hz))
        job = _synthetic_job(rng, f"urgent{i:03d}", gb=urgent_gb,
                             seconds=urgent_seconds, core=0.50, bw=0.35,
                             priority=urgent_priority)
        rows.append({"t": t, "job": job, "priority": urgent_priority,
                     "deadline_s": job.total_seconds
                     + urgent_deadline_slack_s,
                     "kind": "urgent"})
    rows.sort(key=lambda r: r["t"])
    return rows


def drifting_mix(seed: int, *, n_jobs: int = 120, n_classes: int = 4,
                 rate_hz: float = 6.0, drift_start: float = 1.0,
                 drift_end: float = 2.5, mem_truth: float = 0.8,
                 est_range: Tuple[float, float] = (0.2, 0.8),
                 gb_range: Tuple[float, float] = (2.0, 6.0)) -> List[Dict]:
    """Seeded DRIFTING trace for the calibration plane (obs.calibrate):
    submission rows ``{"t", "job", "priority", "deadline_s", "kind"}``.

    ``n_classes`` resource classes each share ONE frozen predicted vector
    (so the calibration store's value-keyed class memos aggregate them),
    but every task carries a ``true_vec`` whose runtime is the prediction
    times a drift factor ramping linearly ``drift_start`` -> ``drift_end``
    over the trace — the probes grow steadily more wrong, the way a
    dataset-size or input-distribution shift degrades a stale estimate.
    Ground-truth memory is ``mem_truth`` x the predicted footprint
    (conservative probes), so inflate-only calibration yields ZERO memory
    violations — the acceptance-gate workload for bench_profile."""
    rng = np.random.default_rng(seed)
    classes = [ResourceVector(
        hbm_bytes=int(rng.uniform(*gb_range) * GB), flops=1e12,
        bytes_accessed=1e11, est_seconds=float(rng.uniform(*est_range)),
        core_demand=0.35, bw_demand=0.25) for _ in range(n_classes)]
    rows: List[Dict] = []
    t = 0.0
    for i in range(n_jobs):
        t += float(rng.exponential(1.0 / rate_hz))
        c = i % n_classes
        vec = classes[c]
        factor = drift_start + (drift_end - drift_start) \
            * (i / max(n_jobs - 1, 1))
        true_vec = dataclasses.replace(
            vec, est_seconds=vec.est_seconds * factor,
            hbm_bytes=int(vec.hbm_bytes * mem_truth))
        name = f"drift{i:03d}"
        unit = UnitTask(fn=None, memobjs=frozenset({f"{name}/ws"}),
                        resources=vec, name=name)
        job = Job(tasks=[Task(units=[unit], name=name, true_vec=true_vec)],
                  name=name)
        rows.append({"t": t, "job": job, "priority": 0,
                     "deadline_s": None, "kind": f"class{c}"})
    return rows


def split_gangs(jobs: Sequence[Job], *, dcn_bw: float = 12.5e9) -> List[Job]:
    """The chips-OBLIVIOUS view of a gang trace: every k-chip gang becomes k
    independent single-chip jobs, the way a flat scheduler sees today's
    sharded workloads. Scattered shards lose the contiguity guarantee, so
    their collectives cross slow inter-node paths: each shard's duration is
    re-roofed at ``collective_bytes / dcn_bw`` (vs the gang's intra-slice
    ICI time), and the logical job is only as fast as its LAST shard — the
    two effects ``bench_gang.py`` quantifies against gang-aware placement."""
    out: List[Job] = []
    for job in jobs:
        gangs = [t for t in job.tasks if t.resources.chips > 1]
        if not gangs:
            out.append(job)
            continue
        if len(job.tasks) > 1:
            # shattering a multi-task job into concurrent shard-jobs would
            # silently drop its sequential task ordering — refuse instead
            raise ValueError(
                f"split_gangs: job {job.name!r} has {len(job.tasks)} tasks; "
                "only single-task gang jobs have a faithful chips-oblivious "
                "split")
        for t in job.tasks:
            r = t.resources
            k = max(r.chips, 1)
            for j in range(k):
                shard_vec = dataclasses.replace(
                    r, hbm_bytes=r.hbm_bytes // k, chips=1,
                    flops=r.flops / k, bytes_accessed=r.bytes_accessed / k,
                    est_seconds=max(r.est_seconds,
                                    r.collective_bytes / dcn_bw))
                unit = UnitTask(fn=None,
                                memobjs=frozenset({f"{t.name}/shard{j}"}),
                                resources=shard_vec,
                                name=f"{t.name}/shard{j}")
                shard = Task(units=[unit], name=unit.name,
                             gang_id=t.gang_id or t.name)
                # the oblivious replay must keep the job's admission class
                out.append(Job(tasks=[shard], name=unit.name,
                               gang_id=t.gang_id or t.name,
                               priority=job.priority,
                               deadline_t=job.deadline_t))
    return out
