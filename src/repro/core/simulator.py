"""Discrete-event simulator for job mixes under a scheduler.

Reproduces the paper's evaluation protocol (§V-A) and doubles as the
virtual-clock backend of ``repro.core.cluster.Cluster``: jobs may be
submitted at any virtual time (``submit``), the clock advances event by
event (``step`` / ``drain``), and a pool of workers each dequeue a job, run
its GPU tasks under the scheduler, and pull the next. ``run(jobs)`` is the
closed-batch compatibility wrapper (everything arrives at t=0). Task progress
follows the processor-sharing interference model (repro.core.interference):
residents of an oversubscribed chip dilate by the total core demand. A gang
task (multi-chip reservation) occupies every member chip, advances at its
slowest member's rate, and is further dilated by ICI link contention when
co-resident gangs oversubscribe a shared link (``interference.ici_slowdown``
over the scheduler's link ledger).

Admission goes through the scheduler's OWN waiter queue — the same
priority/deadline-ordered wakeup path the live executor uses — so simulated
and live submissions of one trace produce the same admission order. Under a
preemptive scheduler (``repro.core.scheduler.preempt``) that extends to
EVICTION order: the scheduler's preemption notices interrupt the victim's
virtual-clock run, its exact remaining work is banked in the progress
ledger, and the resumed attempt (possibly on a different device — that is
migration) runs for remaining + checkpoint penalty instead of from scratch,
so preempted work is conserved.

Crash semantics (paper Table II): a memory-oblivious scheduler (CG) may admit
a task whose footprint exceeds the device's free HBM — the job then dies with
OOM, exactly like a failed cudaMalloc. Memory-safe schedulers (SA, MGB,
schedGPU) never trigger this path.

The simulator is deterministic given (submission trace, scheduler, workers)
and is the engine behind benchmarks/fig4, fig5, table2, table3, table4, fig6.
"""
from __future__ import annotations

import dataclasses
import time
import weakref
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import interference
from repro.core.executor import NEVER_STARTED, ExecRecord
from repro.core.scheduler.base import DEADLINE_SHED, Scheduler
from repro.core.task import Job, Task, true_work_seconds
from repro.core.topology import placement_devices
from repro.obs import events as obs

_EPS = 1e-12


@dataclasses.dataclass
class SimResult:
    makespan: float
    throughput: float              # completed jobs per second
    completed: int
    crashed: int
    turnaround: Dict[str, float]   # per-job turnaround seconds
    slowdowns: Dict[str, float]    # per-KERNEL execution dilation (Table IV)
    dilations: Dict[str, float]    # per-task wall dilation incl. sharing
    device_busy: List[float]       # per-device busy seconds
    utilization: float             # mean busy fraction over makespan
    cancelled: int = 0             # jobs ended by JobHandle.cancel()
    shed: int = 0                  # parked jobs failed past their deadline
    # True iff drain() hit its time_limit with work still pending — a capped
    # run must not masquerade as a completed one (callers check this instead
    # of trusting `completed`)
    truncated: bool = False

    @property
    def mean_turnaround(self) -> float:
        vals = list(self.turnaround.values())
        return sum(vals) / len(vals) if vals else 0.0

    @property
    def mean_slowdown_pct(self) -> float:
        vals = list(self.slowdowns.values())
        return (sum(vals) / len(vals) - 1.0) * 100 if vals else 0.0


@dataclasses.dataclass
class _Running:
    task: Task
    job: "_JobState"
    remaining: float       # seconds of solo work left
    # every device of the reservation (1 entry for single-chip tasks; a
    # gang's synchronized shards advance at the SLOWEST member's rate and
    # occupy every member chip for busy accounting)
    devices: Tuple[int, ...]
    # integral of per-kernel overhead d(work): MPS interleaves at kernel
    # granularity, so an individual kernel's execution dilates only by the
    # co-residency overhead (cache/queue, interference.ETA_PER_RESIDENT);
    # the sharing factor shows up as wait time between kernels instead.
    kwork: float = 0.0

    @property
    def lead(self) -> int:
        return self.devices[0]


@dataclasses.dataclass
class _JobState:
    job: Job
    next_task: int = 0
    t_queue: float = 0.0   # virtual time the current task entered admission
    started: bool = False
    done: bool = False
    cancelled: bool = False
    cancel_requested: bool = False
    shed: bool = False     # parked past its deadline and shed at a drain
    # resolution hook, fired exactly once when the job resolves (done,
    # crashed, cancelled or shed) — the Cluster front-end maintains its
    # aggregate stats counters here instead of re-scanning every handle
    on_done: Optional[Callable[["_JobState"], None]] = None
    records: List[ExecRecord] = dataclasses.field(default_factory=list)


class Simulator:
    """Event-driven processor-sharing simulation of the worker-pool protocol
    with an open-arrival front door (``submit`` / ``step`` / ``drain``)."""

    def __init__(self, scheduler: Scheduler, *, workers: int,
                 poll_interval: float = 0.05, crash_delay: float = 8.0):
        self.sched = scheduler
        self.workers = workers
        # preemptive scheduler: observe evictions so the victim's in-flight
        # virtual run is stopped and its EXACT remaining work banked (the
        # scheduler's own estimate is residency-based and ignores dilation)
        if hasattr(scheduler, "add_preempt_listener"):
            scheduler.add_preempt_listener(self._on_preempt)
        self.poll = poll_interval  # retry cadence when no device is feasible
        # a job that dies of OOM still burned startup time (process launch,
        # data load) before the failed alloc — without this, crash cascades
        # are instantaneous and the unsafe scheduler's crash rate is inflated
        self.crash_delay = crash_delay
        self.reset()

    # -- lifecycle ----------------------------------------------------------
    def reset(self) -> None:
        """Fresh virtual clock and empty state (``run`` calls this; open-
        arrival users call it to reuse the object across traces)."""
        self.now = 0.0
        # deadline shedding (if the scheduler opts in) must judge "now" on
        # the VIRTUAL clock the deadlines were stamped with. Bound weakly:
        # a scheduler that outlives this simulator must not pin it (and its
        # records) in memory through the clock closure
        ref = weakref.ref(self)
        self.sched._clock = \
            lambda: s.now if (s := ref()) is not None else time.monotonic()
        self.records: List[ExecRecord] = []
        self._queue: List[_JobState] = []   # jobs waiting for a sim worker
        # admissions fired by the scheduler's waiter queue (the SAME wakeup
        # path the live executor uses, so sim and executor agree on placement
        # sequence): callbacks append here with their admission epoch,
        # _try_start drains and drops entries a later eviction superseded
        self._admitted_buf: List[
            Tuple[_JobState, Task, Optional[int], int]] = []
        self._blocked: Dict[int, _JobState] = {}  # task uid -> parked job
        self._jobs_by_task: Dict[int, _JobState] = {}  # uid -> owning job
        self._running: Dict[int, _Running] = {}   # task uid -> running record
        self._idle_workers = self.workers
        self._busy: List[float] = [0.0] * len(self.sched.devices)
        self._slowdowns: Dict[str, float] = {}
        self._dilations: Dict[str, float] = {}
        self._solo: Dict[int, float] = {}
        self._started_at: Dict[int, float] = {}
        self._completed = 0
        self._crashed = 0
        self._cancelled = 0
        self._shed = 0
        self._crashing: List[Tuple[float, _JobState]] = []  # (free time, job)
        self._turnaround: Dict[str, float] = {}
        self._failure_pending: Optional[Tuple[float, int]] = None
        self._truncated = False

    # -- open-arrival API ----------------------------------------------------
    def submit(self, job: Job, *, priority: Optional[int] = None,
               deadline_t: Optional[float] = None,
               on_done: Optional[Callable[[_JobState], None]] = None
               ) -> _JobState:
        """Submit ``job`` at the CURRENT virtual time — legal at any point,
        including while earlier jobs are mid-flight (call ``step`` between
        submissions to advance the clock). ``deadline_t`` is an absolute
        virtual-clock deadline; the scheduler's admission queue enforces the
        priority/EDF ordering. ``on_done`` fires exactly once when the job
        resolves (done/crashed/cancelled/shed)."""
        if priority is not None:
            job.priority = priority
        if deadline_t is not None:
            job.deadline_t = deadline_t
        for t in job.tasks:
            t.priority = job.priority
            t.deadline_t = job.deadline_t
            if t.gang_id is None:
                t.gang_id = job.gang_id
        job.arrival_t = self.now
        js = _JobState(job, on_done=on_done)
        if not job.tasks:
            # empty job: completes instantly with a zeroed record, holding no
            # worker (mirrors the live executor's empty-tasks path)
            rec = ExecRecord(job.name, "", -1, self.now, self.now, self.now)
            js.records.append(rec)
            self.records.append(rec)
            js.done = True
            job.finish_t = self.now
            self._completed += 1
            self._turnaround[job.name or str(job.uid)] = 0.0
            if js.on_done is not None:
                js.on_done(js)
            return js
        self._queue.append(js)
        self._try_start()
        return js

    def cancel(self, js: _JobState) -> bool:
        """Cancel a submitted job: a job still waiting for a worker or parked
        in the admission queue ends immediately (no scheduler state leaks); a
        running task finishes its current kernel first. True iff the job will
        end (or ended) cancelled."""
        if js.done:
            return js.cancelled
        js.cancel_requested = True
        if js in self._queue:               # never reached a worker
            self._queue.remove(js)
            self._end_cancelled(js, held_worker=False)
            return True
        idx = js.next_task
        tasks = js.job.tasks
        if idx < len(tasks):
            t = tasks[idx]
            if t.uid in self._blocked and self.sched.cancel_wait(t):
                del self._blocked[t.uid]
                self._end_cancelled(js, held_worker=True)
                return True
        # running (or admitted): the completion path honours the flag
        return True

    def step(self, limit: Optional[float] = None) -> bool:
        """Advance the virtual clock to the next event (a task completion, a
        crash reap, an injected failure, or a poll tick when everything is
        parked). With ``limit``, never advance past that virtual time —
        running work makes partial progress instead (the open-arrival
        driver's tool: submissions between events land at exact times).
        Returns False when nothing is pending."""
        if not self.pending():
            return False
        if not self._running and self._crashing:
            reap_t = min(t for t, _ in self._crashing)
            if limit is not None and reap_t > limit:
                self.now = max(self.now, limit)
                return True
            self.now = reap_t
            self._reap_crashed()
            self._try_start()
            return True
        if not self._running:
            # nothing progresses: either a failure is pending or every
            # submitted task is parked in the admission queue
            prev = self.now
            if self._failure_pending is not None \
                    and self._failure_pending[0] <= self.now + self.poll:
                self.now = max(self.now, self._failure_pending[0])
            else:
                self.now += self.poll
            if limit is not None and limit >= prev:
                self.now = min(self.now, limit)
            self._maybe_fail()
            self._try_start()
            if not self._running and self._failure_pending is None \
                    and not self._queue and not self._admitted_buf \
                    and self._blocked:
                # waiting tasks can never start (nothing running holds the
                # capacity they need): count them as crashed-at-submit to
                # avoid livelock
                tr = getattr(self.sched, "_trace", None)
                for t in self.sched.cancel_all_waiters():
                    js = self._blocked.pop(t.uid, None)
                    if js is not None:
                        js.job.crashed = True
                        js.job.finish_t = self.now
                        if tr is not None:
                            tr.emit(obs.CRASH, t.uid, t.name,
                                    data={"reason": "stuck"})
                        self._finish_job(js, crashed_job=True)
                self._blocked.clear()
                return False
            return True
        rt = self._rates()
        # next event: earliest task completion at current rates (a
        # completion's task_end IS the wakeup that re-drives admission —
        # no poll tick needed for waiters), or the injected failure
        dt = min((r.remaining / rt[uid][0]
                  for uid, r in self._running.items()),
                 default=float("inf"))
        if self._crashing:
            dt = min(dt, max(min(t for t, _ in self._crashing) - self.now,
                             0.0))
        if self._failure_pending is not None:
            dt = min(dt, max(self._failure_pending[0] - self.now, 0.0))
        dt = max(dt, _EPS)
        if limit is not None:
            # bounded step: stop AT the limit, applying partial progress
            dt = min(dt, max(limit - self.now, _EPS))
        # advance; accumulate per-kernel overhead against work done
        for uid, r in self._running.items():
            rate_t, overhead_t = rt[uid]
            work = dt * rate_t
            r.remaining -= work
            r.kwork += work * overhead_t
        for d in {d for r in self._running.values() for d in r.devices}:
            self._busy[d] += dt
        self.now += dt
        self._reap_crashed()
        self._maybe_fail()
        self._complete_finished()
        self._try_start()
        return True

    def pending(self) -> bool:
        """True while any submitted work is unresolved."""
        return bool(self._running or self._queue or self._crashing
                    or self._blocked or self._admitted_buf)

    def run_until(self, t: float) -> None:
        """Advance the virtual clock to EXACTLY ``t``, processing every event
        on the way (events never overshoot it). The open-arrival driver:
        ``submit(a); run_until(t_b); submit(b); ...`` lands each submission
        at its intended arrival time, progress interleaving in between."""
        while self.now < t - 1e-9:
            if not self.step(limit=t):
                self.now = t  # idle: nothing to process, jump the clock
                return

    def drain(self, time_limit: float = 1e7) -> "SimResult":
        """Barrier: advance the clock until every submitted job resolved
        (or ``time_limit`` virtual seconds passed); returns the result so
        far. Parked waiters that can never start are crashed, mirroring the
        closed-batch protocol. Hitting the limit with work still pending
        marks the result ``truncated`` — capped runs must not masquerade as
        completed ones, so callers check the flag (Cluster.drain raises).
        Stepping is bounded, so the clock never overshoots the limit; the
        flag describes THIS drain (a later uncapped drain that finishes the
        work reports truncated=False)."""
        self._truncated = False
        while self.pending():
            if self.now >= time_limit:
                self._truncated = True
                break
            if not self.step(limit=time_limit):
                break
        return self.result()

    def result(self) -> SimResult:
        """Metrics snapshot at the current virtual time. Safe on an empty or
        partially-drained simulation: all means are guarded against empty
        completion sets."""
        makespan = self.now
        n_dev = max(len(self._busy), 1)
        util = (sum(self._busy) / (n_dev * makespan)) if makespan > 0 else 0.0
        return SimResult(
            makespan=makespan,
            throughput=self._completed / makespan if makespan > 0 else 0.0,
            completed=self._completed, crashed=self._crashed,
            turnaround=dict(self._turnaround),
            slowdowns=dict(self._slowdowns),
            dilations=dict(self._dilations),
            device_busy=list(self._busy), utilization=util,
            cancelled=self._cancelled, shed=self._shed,
            truncated=self._truncated)

    # -- compatibility wrapper ------------------------------------------------
    def run(self, jobs: Sequence[Job], *, time_limit: float = 1e7,
            failure_at: Optional[Tuple[float, int]] = None) -> SimResult:
        """Closed-batch protocol: every job arrives at t=0, drain to the end.
        ``failure_at``: (time, device) — kill a device mid-run; its resident
        jobs' tasks re-enter the queue (fault-tolerance path)."""
        self.reset()
        self._failure_pending = failure_at
        for j in jobs:
            self.submit(j)
        return self.drain(time_limit)

    # -- engine internals -----------------------------------------------------
    def _rates(self) -> Dict[int, Tuple[float, float]]:
        """task uid -> (progress rate, per-kernel overhead factor).

        A single-chip task progresses at its device's processor-sharing rate.
        A gang's shards are synchronized, so the gang advances at its
        SLOWEST member chip's rate, further dilated by ICI contention when a
        soft-link policy let co-resident gangs oversubscribe a shared link
        (``interference.ici_slowdown`` via the scheduler's link ledger)."""
        by_dev: Dict[int, List[tuple]] = {}
        for r in self._running.values():
            res = r.task.resources
            for d in r.devices:
                by_dev.setdefault(d, []).append(
                    (res.core_demand, res.bw_demand))
        dev_rate = {d: (interference.rate(ds),
                        1.0 + interference.ETA_PER_RESIDENT * (len(ds) - 1))
                    for d, ds in by_dev.items()}
        link_pressure = getattr(self.sched, "link_pressure", None)
        out: Dict[int, Tuple[float, float]] = {}
        for uid, r in self._running.items():
            rate = min(dev_rate[d][0] for d in r.devices)
            overhead = max(dev_rate[d][1] for d in r.devices)
            if link_pressure is not None and r.task.resources.chips > 1:
                rate /= link_pressure(r.task)
            out[uid] = (rate, overhead)
        return out

    def _submit_task(self, js: _JobState) -> None:
        """Hand the job's next task to the scheduler's admission path:
        admitted now (callback fires inline) or parked in the waiter
        queue — wakeups on task_end/mark_dead/revive re-drive it."""
        task = js.job.tasks[js.next_task]
        js.t_queue = self.now
        # read at emit time (attach_tracer may run after construction);
        # submission is per-task, not the hot admission inner loop
        tr = getattr(self.sched, "_trace", None)
        if tr is not None:
            tr.emit(obs.SUBMIT, task.uid, task.name,
                    data=obs.submit_data(task, js.job.name, js.job.uid))
        if not self.sched.can_ever_fit(task):
            # never feasible (oversized footprint, or a gang shape the
            # topology cannot hold): fail fast with the scheduler's
            # explanation instead of parking forever — mirrors the live
            # executor's crash-at-submit
            js.job.crashed = True
            js.job.error = self.sched.infeasible_reason(task)
            js.job.finish_t = self.now
            if tr is not None:
                tr.emit(obs.CRASH, task.uid, task.name,
                        data={"reason": "infeasible"})
            rec = ExecRecord(js.job.name, task.name, -1, self.now,
                             NEVER_STARTED, self.now, crashed=True)
            js.records.append(rec)
            self.records.append(rec)
            self._finish_job(js, crashed_job=True)
            return
        self._blocked[task.uid] = js
        self._jobs_by_task[task.uid] = js

        def cb(t: Task, placement: Optional[int], epoch: int,
               js=js) -> None:
            self._admitted_buf.append((js, t, placement, epoch))

        self.sched.admit_or_enqueue(task, cb)

    def _on_preempt(self, victims: Sequence[Tuple[Task, int]]) -> None:
        """Preemption notice from the scheduler: stop the victims' virtual
        runs, bank their EXACT remaining work (overwriting the scheduler's
        residency-based estimate), and re-park their jobs — the banked value
        is what the resumed attempt starts from, so no completed virtual
        work is ever re-run. (The notice's superseded-epoch tag matters only
        to the multi-threaded live backend; the sim is single-threaded, so
        delivery is always timely.)"""
        for t, _epoch in victims:
            rec = self._running.pop(t.uid, None)
            if rec is not None:
                self.sched.ledger.set_remaining(t.uid, max(rec.remaining, 0.0))
            # evicted while still in the admission buffer: the stale entry is
            # dropped by _try_start's epoch check; either way the job is
            # parked again until the re-admission callback fires
            js = rec.job if rec is not None else self._jobs_by_task.get(t.uid)
            if js is not None and not js.done:
                self._blocked[t.uid] = js

    def _try_start(self) -> None:
        # workers pick jobs from the queue while any are idle
        while self._idle_workers > 0 and self._queue:
            js = self._queue.pop(0)
            self._idle_workers -= 1
            self._submit_task(js)
        # drain admissions (task_end inside this loop can fire more)
        while self._admitted_buf:
            js, task, placement, epoch = self._admitted_buf.pop(0)
            if placement is not None and placement is not DEADLINE_SHED \
                    and self.sched.admission_epoch(task) != epoch:
                # superseded between admission and start (preempted or
                # mark_dead-evicted while buffered): the resources were
                # already released and the task re-enqueued — the fresh
                # incarnation's callback owns it now
                continue
            self._blocked.pop(task.uid, None)
            if js.cancel_requested and placement is not None \
                    and placement is not DEADLINE_SHED:
                # cancelled while parked-then-admitted: release the admission
                self.sched.task_end(task)
                self._end_cancelled(js, held_worker=True)
                continue
            if placement is DEADLINE_SHED:
                # parked past its deadline: the scheduler shed it at the
                # drain — the job fails with SHED status, not CRASHED. A
                # cancel that raced the shed wins (matches the live
                # backend's _finish, where cancel_requested beats shed)
                if js.cancel_requested:
                    self._end_cancelled(js, held_worker=True)
                else:
                    self._end_shed(js)
                continue
            if placement is None:
                # mark_dead shrank the fleet below this task's needs:
                # the scheduler gave up on it — crashed at submit
                js.job.crashed = True
                js.job.error = js.job.error \
                    or self.sched.infeasible_reason(task)
                js.job.finish_t = self.now
                self._finish_job(js, crashed_job=True)
                continue
            devs = placement_devices(placement)
            # memory-unsafe scheduler: admitted past capacity on any member
            # -> OOM crash after the startup delay (worker stays occupied)
            tr = getattr(self.sched, "_trace", None)
            if any(self.sched.devices[d].oom() for d in devs):
                self.sched.task_end(task)
                js.job.crashed = True
                if tr is not None:
                    tr.emit(obs.CRASH, task.uid, task.name, devs[0],
                            data={"reason": "oom"})
                self._crashing.append((self.now + self.crash_delay, js))
                continue
            task.start_t = self.now
            js.started = True
            if tr is not None:
                tr.emit(obs.BEGIN, task.uid, task.name, devs[0], epoch)
            self._started_at[task.uid] = self.now
            # the simulated PHYSICS run ground-truth work (true_vec when a
            # drift workload supplies one, else the original probe estimate)
            # — never the calibration-corrected prediction, which must only
            # change what admission RESERVES, not what the task DOES
            work = true_work_seconds(task)
            ledger = getattr(self.sched, "ledger", None)
            if ledger is not None:
                banked = ledger.remaining_or_none(task.uid)
                if banked is not None:
                    # work-conserving resume after preemption: remaining
                    # work plus the checkpoint/restore penalty, not a
                    # from-scratch restart — migration (a different device
                    # group than last time) costs the same penalty
                    work = banked + \
                        self.sched.preempt_policy.checkpoint_penalty_s
            self._solo[task.uid] = work
            self._running[task.uid] = _Running(task, js, work, devs)

    def _drop_job_maps(self, js: _JobState) -> None:
        # a resolved job's task entries are dead weight (uids never recur)
        for t in js.job.tasks:
            self._jobs_by_task.pop(t.uid, None)

    def _finish_job(self, js: _JobState, crashed_job: bool = False) -> None:
        js.done = True
        self._drop_job_maps(js)
        if crashed_job:
            self._crashed += 1
        else:
            self._completed += 1
            js.job.finish_t = self.now
            self._turnaround[js.job.name or str(js.job.uid)] = \
                self.now - js.job.arrival_t
        self._idle_workers += 1
        if js.on_done is not None:
            js.on_done(js)

    def _end_cancelled(self, js: _JobState, *, held_worker: bool) -> None:
        js.done = True
        self._drop_job_maps(js)
        js.cancelled = True
        js.job.finish_t = self.now
        self._cancelled += 1
        if held_worker:
            self._idle_workers += 1
        if js.on_done is not None:
            js.on_done(js)

    def _end_shed(self, js: _JobState) -> None:
        # a shed waiter was parked (holding a sim worker) but never admitted
        js.done = True
        self._drop_job_maps(js)
        js.shed = True
        js.job.finish_t = self.now
        self._shed += 1
        self._idle_workers += 1
        if js.on_done is not None:
            js.on_done(js)

    def _reap_crashed(self) -> None:
        done = [(t, js) for t, js in self._crashing if t <= self.now + _EPS]
        self._crashing = [(t, js) for t, js in self._crashing
                          if t > self.now + _EPS]
        for _, js in done:
            js.job.finish_t = self.now
            self._finish_job(js, crashed_job=True)

    def _maybe_fail(self) -> None:
        if self._failure_pending is None \
                or self.now < self._failure_pending[0] - _EPS:
            return
        _, dead = self._failure_pending
        self._failure_pending = None
        self._fail_device(dead)

    def _fail_device(self, dead) -> None:
        # mark_dead re-enqueues evicted tasks through the waiter queue with
        # eviction-restart priority; their admission callback may already
        # have fired onto a surviving device (admitted_buf)
        evicted = self.sched.mark_dead(dead)
        for t in evicted:
            rec = self._running.pop(t.uid, None)
            if rec is not None:
                # restart from scratch on another device (task-level
                # checkpoint/restart is the executor's job)
                self._blocked.setdefault(t.uid, rec.job)

    def inject_failure(self, device) -> None:
        """Kill ``device`` at the CURRENT virtual time — the external
        fault-injection hook (``obs.whatif`` replays recorded MARK_DEAD
        events through this; unlike ``_failure_pending`` it supports any
        number of deaths per run). Same semantics as the scheduled path:
        residents are evicted, stop progressing, and re-park."""
        self._fail_device(device)
        self._try_start()

    def revive_device(self, device) -> None:
        """Bring ``device`` back at the current virtual time (the REVIVE
        counterpart of ``inject_failure``)."""
        self.sched.revive(device)
        self._try_start()

    def _complete_finished(self) -> None:
        done = [uid for uid, r in self._running.items()
                if r.remaining <= 1e-9]
        for uid in done:
            # the FIRST completion's task_end re-drives admission, which can
            # preempt a co-completing resident before ITS task_end runs —
            # the eviction notice already removed it from _running and
            # re-parked it (it resumes for its ~zero banked remainder plus
            # the restore penalty), so it is simply no longer ours to end
            rec = self._running.pop(uid, None)
            if rec is None:
                continue
            self.sched.task_end(rec.task)
            rec.task.finish_t = self.now
            dur = self.now - self._started_at[uid]
            if self._solo[uid] > 0:
                key = rec.task.name or str(uid)
                self._dilations[key] = dur / self._solo[uid]
                self._slowdowns[key] = rec.kwork / self._solo[uid]
            js = rec.job
            record = ExecRecord(js.job.name, rec.task.name, rec.lead,
                                js.t_queue, self._started_at[uid], self.now,
                                gang_chips=len(rec.devices))
            js.records.append(record)
            self.records.append(record)
            if js.cancel_requested:
                self._end_cancelled(js, held_worker=True)
                continue
            js.next_task += 1
            if js.next_task >= len(js.job.tasks):
                self._finish_job(js)
            else:
                self._submit_task(js)
