"""Discrete-event simulator for batch job mixes under a scheduler.

Reproduces the paper's evaluation protocol (§V-A): a queue full of jobs at
t=0, a pool of workers that each dequeue a job, run its GPU tasks under the
scheduler, and pull the next. Task progress follows the processor-sharing
interference model (repro.core.interference): residents of an oversubscribed
chip dilate by the total core demand.

Crash semantics (paper Table II): a memory-oblivious scheduler (CG) may admit
a task whose footprint exceeds the device's free HBM — the job then dies with
OOM, exactly like a failed cudaMalloc. Memory-safe schedulers (SA, MGB,
schedGPU) never trigger this path.

The simulator is deterministic given (jobs, scheduler, workers) and is the
engine behind benchmarks/fig4, fig5, table2, table3, table4, fig6.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

from repro.core import interference
from repro.core.scheduler.base import Scheduler
from repro.core.task import Job, Task

_EPS = 1e-12


@dataclasses.dataclass
class SimResult:
    makespan: float
    throughput: float              # completed jobs per second
    completed: int
    crashed: int
    turnaround: Dict[str, float]   # per-job turnaround seconds
    slowdowns: Dict[str, float]    # per-KERNEL execution dilation (Table IV)
    dilations: Dict[str, float]    # per-task wall dilation incl. sharing
    device_busy: List[float]       # per-device busy seconds
    utilization: float             # mean busy fraction over makespan

    @property
    def mean_turnaround(self) -> float:
        vals = list(self.turnaround.values())
        return sum(vals) / len(vals) if vals else 0.0

    @property
    def mean_slowdown_pct(self) -> float:
        vals = list(self.slowdowns.values())
        return (sum(vals) / len(vals) - 1.0) * 100 if vals else 0.0


@dataclasses.dataclass
class _Running:
    task: Task
    job: "_JobState"
    remaining: float       # seconds of solo work left
    device: int
    # integral of per-kernel overhead d(work): MPS interleaves at kernel
    # granularity, so an individual kernel's execution dilates only by the
    # co-residency overhead (cache/queue, interference.ETA_PER_RESIDENT);
    # the sharing factor shows up as wait time between kernels instead.
    kwork: float = 0.0


@dataclasses.dataclass
class _JobState:
    job: Job
    next_task: int = 0
    worker: Optional[int] = None


class Simulator:
    """Event-driven processor-sharing simulation of the worker-pool protocol."""

    def __init__(self, scheduler: Scheduler, *, workers: int,
                 poll_interval: float = 0.05, crash_delay: float = 8.0):
        self.sched = scheduler
        self.workers = workers
        self.poll = poll_interval  # retry cadence when no device is feasible
        # a job that dies of OOM still burned startup time (process launch,
        # data load) before the failed alloc — without this, crash cascades
        # are instantaneous and the unsafe scheduler's crash rate is inflated
        self.crash_delay = crash_delay

    def run(self, jobs: Sequence[Job], *, time_limit: float = 1e7,
            failure_at: Optional[Tuple[float, int]] = None) -> SimResult:
        """``failure_at``: (time, device) — kill a device mid-run; its
        resident jobs' tasks re-enter the queue (fault-tolerance path)."""
        queue: List[_JobState] = [_JobState(j) for j in jobs]
        for js in queue:
            js.job.arrival_t = 0.0
        # admissions fired by the scheduler's waiter queue (the SAME wakeup
        # path the live executor uses, so sim and executor agree on placement
        # sequence): callbacks append here, try_start drains
        admitted_buf: List[Tuple[_JobState, Task, int]] = []
        blocked: Dict[int, _JobState] = {}  # task uid -> job waiting in queue
        running: Dict[int, _Running] = {}   # task uid -> running record
        idle_workers = self.workers
        now = 0.0
        busy: List[float] = [0.0] * len(self.sched.devices)
        slowdowns: Dict[str, float] = {}
        dilations: Dict[str, float] = {}
        solo: Dict[int, float] = {}
        started: Dict[int, float] = {}
        completed = crashed = 0
        crashing: List[Tuple[float, _JobState]] = []  # (worker-free time, job)
        turnaround: Dict[str, float] = {}
        failure_pending = failure_at

        def rates() -> Dict[int, Tuple[float, float]]:
            """device -> (progress rate, per-kernel overhead factor)."""
            by_dev: Dict[int, List[tuple]] = {}
            for r in running.values():
                res = r.task.resources
                by_dev.setdefault(r.device, []).append(
                    (res.core_demand, res.bw_demand))
            return {d: (interference.rate(ds),
                        1.0 + interference.ETA_PER_RESIDENT * (len(ds) - 1))
                    for d, ds in by_dev.items()}

        def submit(js: _JobState) -> None:
            """Hand the job's next task to the scheduler's admission path:
            admitted now (callback fires inline) or parked in the waiter
            queue — wakeups on task_end/mark_dead/revive re-drive it."""
            task = js.job.tasks[js.next_task]
            blocked[task.uid] = js

            def cb(t: Task, placement: int, epoch: int, js=js) -> None:
                admitted_buf.append((js, t, placement))

            self.sched.admit_or_enqueue(task, cb)

        def try_start() -> None:
            nonlocal idle_workers, crashed, completed
            # workers pick jobs from the queue while any are idle
            while idle_workers > 0 and queue:
                js = queue.pop(0)
                idle_workers -= 1
                submit(js)
            # drain admissions (task_end inside this loop can fire more)
            while admitted_buf:
                js, task, dev = admitted_buf.pop(0)
                blocked.pop(task.uid, None)
                if dev is None:
                    # mark_dead shrank the fleet below this task's needs:
                    # the scheduler gave up on it — crashed at submit
                    js.job.crashed = True
                    js.job.finish_t = now
                    _finish_job(js, crashed_job=True)
                    continue
                # memory-unsafe scheduler: admitted past capacity -> OOM
                # crash after the startup delay (worker stays occupied)
                if self.sched.devices[dev].oom():
                    self.sched.task_end(task)
                    js.job.crashed = True
                    crashing.append((now + self.crash_delay, js))
                    continue
                task.start_t = now
                started[task.uid] = now
                solo[task.uid] = task.resources.est_seconds
                running[task.uid] = _Running(task, js, task.resources.est_seconds,
                                             dev)

        def _finish_job(js: _JobState, crashed_job: bool = False) -> None:
            nonlocal idle_workers, crashed, completed
            if crashed_job:
                crashed += 1
            else:
                completed += 1
                js.job.finish_t = now
                turnaround[js.job.name or str(js.job.uid)] = \
                    now - js.job.arrival_t
            idle_workers += 1

        def reap_crashed() -> None:
            nonlocal crashing
            done = [(t, js) for t, js in crashing if t <= now + _EPS]
            crashing = [(t, js) for t, js in crashing if t > now + _EPS]
            for _, js in done:
                js.job.finish_t = now
                _finish_job(js, crashed_job=True)

        try_start()
        while running or queue or crashing or blocked or admitted_buf:
            if now > time_limit:
                break
            if not running and crashing:
                now = min(t for t, _ in crashing)
                reap_crashed()
                try_start()
                continue
            if not running:
                # nothing progresses: either a failure is pending or every
                # submitted task is parked in the waiter queue
                if failure_pending is not None and failure_pending[0] <= now + self.poll:
                    now = max(now, failure_pending[0])
                else:
                    now += self.poll
                try_start()
                if not running and not queue and not blocked \
                        and not admitted_buf:
                    break
                if not running and failure_pending is None and not queue:
                    # waiting tasks can never start (e.g. task > device HBM):
                    # count them as crashed-at-submit to avoid livelock
                    for t in self.sched.cancel_all_waiters():
                        js = blocked.pop(t.uid, None)
                        if js is not None:
                            js.job.crashed = True
                            _finish_job(js, crashed_job=True)
                    blocked.clear()
                    break
                if not running:
                    continue
            rt = rates()
            # next event: earliest task completion at current rates (a
            # completion's task_end IS the wakeup that re-drives admission —
            # no poll tick needed for waiters), or the injected failure
            dt_done = min((r.remaining / rt[r.device][0]
                           for r in running.values()),
                          default=float("inf"))
            dt = dt_done
            if crashing:
                dt = min(dt, max(min(t for t, _ in crashing) - now, 0.0))
            if failure_pending is not None:
                dt = min(dt, max(failure_pending[0] - now, 0.0))
            dt = max(dt, _EPS)
            # advance; accumulate per-kernel overhead against work done
            for r in running.values():
                rate_d, overhead_d = rt[r.device]
                work = dt * rate_d
                r.remaining -= work
                r.kwork += work * overhead_d
            for d, ds in _group_devices(running).items():
                busy[d] += dt
            now += dt
            reap_crashed()
            # failure injection
            if failure_pending is not None and now >= failure_pending[0] - _EPS:
                _, dead = failure_pending
                failure_pending = None
                # mark_dead re-enqueues evicted tasks through the waiter
                # queue with restart priority; their admission callback may
                # already have fired onto a surviving device (admitted_buf)
                evicted = self.sched.mark_dead(dead)
                for t in evicted:
                    rec = running.pop(t.uid, None)
                    if rec is not None:
                        # restart from scratch on another device (task-level
                        # checkpoint/restart is the executor's job)
                        blocked.setdefault(t.uid, rec.job)
            # completions
            done = [uid for uid, r in running.items() if r.remaining <= 1e-9]
            for uid in done:
                rec = running.pop(uid)
                self.sched.task_end(rec.task)
                rec.task.finish_t = now
                dur = now - started[uid]
                if solo[uid] > 0:
                    key = rec.task.name or str(uid)
                    dilations[key] = dur / solo[uid]
                    slowdowns[key] = rec.kwork / solo[uid]
                js = rec.job
                js.next_task += 1
                if js.next_task >= len(js.job.tasks):
                    _finish_job(js)
                else:
                    submit(js)
            try_start()

        makespan = now
        util = (sum(busy) / (len(busy) * makespan)) if makespan > 0 else 0.0
        return SimResult(
            makespan=makespan,
            throughput=completed / makespan if makespan > 0 else 0.0,
            completed=completed, crashed=crashed,
            turnaround=turnaround, slowdowns=slowdowns, dilations=dilations,
            device_busy=busy, utilization=util)


def _group_devices(running: Dict[int, _Running]) -> Dict[int, List[tuple]]:
    out: Dict[int, List[tuple]] = {}
    for r in running.values():
        res = r.task.resources
        out.setdefault(r.device, []).append((res.core_demand, res.bw_demand))
    return out
