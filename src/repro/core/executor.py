"""Live executor: a worker pool running REAL jitted JAX computations under a
scheduler — the end-to-end path probe -> task_begin -> lazy bind -> launch ->
task_end (paper §IV prototype, minus MPS which has no TPU analogue).

On this CPU-only container jax exposes one device, so the executor virtualizes
``num_devices`` logical devices over it: placement, memory accounting and
OOM/crash semantics are per *virtual* device (exactly the scheduler's view),
while the arithmetic runs wherever jax puts it. On real hardware
``jax.devices()`` replaces the virtual table and ``LazyBuffer.bind`` receives
the physical device — nothing else changes.
"""
from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax

from repro.core import lazy
from repro.core.scheduler.base import Scheduler
from repro.core.task import Job, Task


class OOMError(RuntimeError):
    """Raised when an admitted task exceeds its device's memory (CG path)."""


@dataclasses.dataclass
class ExecRecord:
    job: str
    task: str
    device: int
    t_queue: float
    t_start: float
    t_end: float
    crashed: bool = False


@dataclasses.dataclass
class ExecJob:
    """A live job: ordered (task, runner) pairs. ``runner(device)`` executes
    the task's computation after the lazy buffers are bound to ``device``."""
    job: Job
    runners: List[Callable[[object], None]]
    buffers: Dict[str, lazy.LazyBuffer] = dataclasses.field(default_factory=dict)


class Executor:
    """Worker-pool executor mirroring the paper's batch protocol."""

    def __init__(self, scheduler: Scheduler, *, workers: int,
                 devices: Optional[Sequence[object]] = None,
                 poll_interval: float = 0.002):
        self.sched = scheduler
        self.workers = workers
        self.poll = poll_interval
        n = len(scheduler.devices)
        real = list(devices) if devices is not None else list(jax.devices())
        # virtual device i -> a real jax device (round-robin over whatever
        # the platform exposes; 1 CPU device here, n TPUs in production)
        self.device_map = [real[i % len(real)] for i in range(n)]
        self.records: List[ExecRecord] = []
        self._rec_lock = threading.Lock()

    def run(self, jobs: Sequence[ExecJob]) -> Dict[str, float]:
        q: "queue_mod.Queue[ExecJob]" = queue_mod.Queue()
        for j in jobs:
            j.job.arrival_t = time.monotonic()
            q.put(j)
        stop = threading.Event()

        def worker(_wid: int) -> None:
            while not stop.is_set():
                try:
                    ej = q.get_nowait()
                except queue_mod.Empty:
                    return
                try:
                    self._run_job(ej)
                except OOMError:
                    ej.job.crashed = True
                ej.job.finish_t = time.monotonic()

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(self.workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        done = [j.job for j in jobs if not j.job.crashed]
        t0 = min(j.job.arrival_t for j in jobs)
        t1 = max(j.job.finish_t for j in jobs)
        makespan = max(t1 - t0, 1e-9)
        return {
            "makespan_s": makespan,
            "throughput_jobs_per_s": len(done) / makespan,
            "completed": len(done),
            "crashed": sum(1 for j in jobs if j.job.crashed),
            "mean_turnaround_s": sum(
                j.job.finish_t - j.job.arrival_t for j in jobs
                if not j.job.crashed) / max(len(done), 1),
        }

    def _run_job(self, ej: ExecJob) -> None:
        for task, runner in zip(ej.job.tasks, ej.runners):
            t_queue = time.monotonic()
            # probe -> scheduler (task_begin), retry while infeasible
            dev_idx = self.sched.task_begin(task)
            while dev_idx is None:
                time.sleep(self.poll)
                dev_idx = self.sched.task_begin(task)
            # memory-unsafe scheduler may have oversubscribed: OOM crash
            if self.sched.devices[dev_idx].oom():
                self.sched.task_end(task)
                with self._rec_lock:
                    self.records.append(ExecRecord(
                        ej.job.name, task.name, dev_idx, t_queue,
                        time.monotonic(), time.monotonic(), crashed=True))
                raise OOMError(
                    f"{task.name}: {task.resources.hbm_bytes} B exceeded "
                    f"device {dev_idx} capacity")
            t_start = time.monotonic()
            try:
                # lazy runtime: replay buffer queues on the chosen device,
                # then launch the real computation
                device = self.device_map[dev_idx]
                lazy.kernel_launch_prepare(ej.buffers, device)
                runner(device)
            finally:
                self.sched.task_end(task)
            with self._rec_lock:
                self.records.append(ExecRecord(
                    ej.job.name, task.name, dev_idx, t_queue, t_start,
                    time.monotonic()))
        lazy.free_all(ej.buffers)
