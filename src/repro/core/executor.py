"""Live executor: an event-driven engine running REAL jitted JAX computations
under a scheduler — the end-to-end path probe -> admit/enqueue -> wakeup ->
lazy bind -> launch -> release (paper §IV prototype, minus MPS which has no
TPU analogue).

Engine shape (the paper's daemon, in-process):

  * **open arrival**: ``submit(ej)`` may be called at ANY time — including
    while earlier jobs are mid-flight — exactly like probes arriving at the
    paper's daemon. ``run(jobs)`` survives as the closed-batch compatibility
    shim (submit everything, drain, report);
  * a single **dispatcher** owns the pending work: each job submits its next
    task via ``Scheduler.admit_or_enqueue`` — a blocked task holds NO thread,
    it sits in the scheduler's priority/deadline admission queue;
  * every ``task_end`` re-drives admission (the paper's *notify*), and the
    admission callback pushes the (task, placement) pair onto a **bounded
    execution pool** sized to the device count, not the job count. A gang
    placement (``GangReservation`` from the gang scheduler) dispatches the
    task as ONE bound group: its runner receives the ordered device list of
    the whole reservation;
  * completion callbacks advance the owning job to its next task (or finish
    it), so thousands of queued jobs need only ``workers`` threads;
  * ``drain()`` is the barrier (wait until every submitted job resolved),
    ``shutdown()`` tears the pool down. ``repro.core.cluster.Cluster`` is the
    user-facing front-end over this engine.

``PollingExecutor`` preserves the previous worker-pool protocol — one thread
per in-flight job spinning ``task_begin`` in a sleep(poll) loop — as the
baseline ``benchmarks/bench_executor.py`` measures the event-driven engine
against.

On this CPU-only container jax exposes one device, so the executor virtualizes
``num_devices`` logical devices over it: placement, memory accounting and
OOM/crash semantics are per *virtual* device (exactly the scheduler's view),
while the arithmetic runs wherever jax puts it. On real hardware
``jax.devices()`` replaces the virtual table and ``LazyBuffer.bind`` receives
the physical device — nothing else changes.
"""
from __future__ import annotations

import dataclasses
import queue as queue_mod
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax

from repro.core import lazy
from repro.core.scheduler.base import DEADLINE_SHED, Scheduler
from repro.core.task import Job, Task
from repro.core.topology import placement_devices
from repro.obs import events as obs


class OOMError(RuntimeError):
    """Raised when an admitted task exceeds its device's memory (CG path)."""


# ``ExecRecord.t_start`` sentinel: the task crashed BEFORE its kernel ever
# launched (infeasible-at-submit, fleet-shrank-while-parked, pre-dispatch
# OOM). Distinct from any real timestamp so latency consumers can exclude
# never-started records instead of folding a fake zero-length execution
# window into their means — check ``rec.started``, not ``rec.crashed``.
NEVER_STARTED = -1.0


@dataclasses.dataclass
class ExecRecord:
    job: str
    task: str
    device: int          # lead device of the placement (-1 = never placed)
    t_queue: float
    t_start: float       # NEVER_STARTED if the task crashed pre-launch
    t_end: float
    crashed: bool = False
    # size of the reserved device group (1 for single-chip tasks); the gang
    # bench groups queueing-delay percentiles by this
    gang_chips: int = 1

    @property
    def started(self) -> bool:
        """True iff the task's kernel actually began executing — only then
        do t_start/t_end bound a real execution window."""
        return self.t_start >= 0.0


@dataclasses.dataclass
class ExecJob:
    """A live job: ordered (task, runner) pairs. ``runner(device)`` executes
    the task's computation after the lazy buffers are bound to ``device``."""
    job: Job
    runners: List[Callable[[object], None]]
    buffers: Dict[str, lazy.LazyBuffer] = dataclasses.field(default_factory=dict)
    # cooperative preemption surface (set/observed only under a preemptive
    # scheduler): ``preempted`` is SET when the scheduler evicts this job's
    # in-flight task and CLEARED at each (re)dispatch — a cooperative runner
    # polls it between steps and returns early, since the eviction already
    # released the reservation and the epoch fence voids this attempt's
    # completion. ``on_preempt`` (optional) fires once per eviction with the
    # evicted Task: wire it to train/checkpoint.py's save for training tasks
    # so the resumed dispatch — possibly on a DIFFERENT device, which is how
    # migration falls out of requeue + placement — restores from the last
    # committed step instead of recomputing.
    preempted: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    on_preempt: Optional[Callable[[Task], None]] = None


def _empty_stats() -> Dict[str, float]:
    return {"makespan_s": 0.0, "throughput_jobs_per_s": 0.0,
            "completed": 0, "crashed": 0, "mean_turnaround_s": 0.0,
            "sched_attempts": 0}


@dataclasses.dataclass
class _JobRun:
    """Dispatcher-side job state: which task is next, when it was queued,
    plus the open-arrival lifecycle bits ``JobHandle`` observes."""
    ej: ExecJob
    next_task: int = 0
    t_queue: float = 0.0
    started: bool = False
    cancel_requested: bool = False
    cancelled: bool = False
    shed: bool = False      # parked past its deadline and shed at a drain
    on_done: Optional[Callable[["_JobRun"], None]] = None
    done: threading.Event = dataclasses.field(
        default_factory=threading.Event)
    records: List[ExecRecord] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class _Ready:
    """An admitted task waiting for an execution-pool thread. ``placement``
    is a device index (flat schedulers) or a ``GangReservation`` (gang
    scheduler — the task's unit group runs bound to the whole device set)."""
    jr: _JobRun
    task_idx: int
    placement: object
    epoch: int


class Executor:
    """Event-driven executor: open-arrival submission, admission wakeups,
    bounded execution pool."""

    def __init__(self, scheduler: Scheduler, *, workers: int,
                 devices: Optional[Sequence[object]] = None,
                 poll_interval: float = 0.002):
        self.sched = scheduler
        self.workers = workers
        self.poll = poll_interval  # kept for API compat (PollingExecutor uses it)
        n = len(scheduler.devices)
        real = list(devices) if devices is not None else list(jax.devices())
        # virtual device i -> a real jax device (round-robin over whatever
        # the platform exposes; 1 CPU device here, n TPUs in production)
        self.device_map = [real[i % len(real)] for i in range(n)]
        self.records: List[ExecRecord] = []
        self._rec_lock = threading.Lock()
        # preemptive scheduler: observe evictions so the victim's running
        # attempt is signalled to stop cooperatively (and its checkpoint
        # callback fires) — the re-admission callback then re-dispatches it
        self._jr_by_uid: Dict[int, "_JobRun"] = {}
        # per-task attempt serialization: a re-dispatched incarnation must
        # not run concurrently with a still-executing superseded attempt —
        # they share ExecJob.buffers and the single `preempted` event, so
        # attempt 2 waits for attempt 1's runner to exit (an evicted
        # cooperative runner exits promptly; a non-cooperative one finishes
        # its kernel, exactly the cost it would pay anyway)
        self._attempt_locks: Dict[int, threading.Lock] = {}
        # uid -> epoch of the attempt currently armed on ExecJob.preempted,
        # guarded by _signal_lock: an eviction notice is addressed to its
        # victim's superseded epoch, and delivery may lag (the delivering
        # thread holds no lock) — a notice older than the armed attempt must
        # be dropped, or it would stop the FRESH attempt and turn its early
        # return into a current-epoch completion (silent lost work)
        self._armed_epoch: Dict[int, int] = {}
        self._signal_lock = threading.Lock()
        if hasattr(scheduler, "add_preempt_listener"):
            scheduler.add_preempt_listener(self._on_preempt)
        # open-arrival engine state
        self._ready: Optional["queue_mod.Queue[Optional[_Ready]]"] = None
        self._threads: List[threading.Thread] = []
        self._running = False
        self._lifecycle = threading.Lock()     # guards start/shutdown
        self._state = threading.Condition()    # guards _inflight
        self._inflight = 0

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> None:
        """Spin up the execution pool; idempotent (``submit`` auto-starts)."""
        with self._lifecycle:
            self._start_locked()

    def _start_locked(self) -> None:
        if self._running:
            return
        self._ready = queue_mod.Queue()
        self._threads = [threading.Thread(target=self._pool_worker,
                                          daemon=True)
                         for _ in range(self.workers)]
        for t in self._threads:
            t.start()
        self._running = True

    def drain(self) -> None:
        """Barrier: block until every job submitted so far has resolved
        (done, crashed, or cancelled). Jobs submitted while draining extend
        the wait — the barrier is over the in-flight count, not a snapshot."""
        with self._state:
            while self._inflight:
                self._state.wait()

    def shutdown(self) -> None:
        """Drain, then stop the pool threads. ``submit`` restarts it. A
        ``submit`` racing shutdown either lands before the teardown (the
        re-drain below picks it up) or blocks on the lifecycle lock and
        restarts a fresh pool — never lost."""
        while True:
            self.drain()
            with self._lifecycle:
                if not self._running:
                    return
                with self._state:
                    if self._inflight:
                        continue  # a submit raced the drain: wait again
                for _ in self._threads:
                    self._ready.put(None)
                for t in self._threads:
                    t.join()
                self._threads = []
                self._running = False
                return

    # -- open-arrival API ----------------------------------------------------
    def submit(self, ej: ExecJob, *, priority: Optional[int] = None,
               deadline_t: Optional[float] = None,
               on_done: Optional[Callable[[_JobRun], None]] = None
               ) -> _JobRun:
        """Enter ``ej`` into the admission path NOW — legal at any time,
        including while earlier jobs are mid-flight. ``priority`` /
        ``deadline_t`` stamp every task of the job (None keeps stamps already
        on the job); the scheduler's admission queue enforces the ordering.
        Returns the job's ``_JobRun`` (wrap it in a ``cluster.JobHandle`` for
        the user-facing future API)."""
        job = ej.job
        if priority is not None:
            job.priority = priority
        if deadline_t is not None:
            job.deadline_t = deadline_t
        for t in job.tasks:
            t.priority = job.priority
            t.deadline_t = job.deadline_t
            if t.gang_id is None:
                t.gang_id = job.gang_id
        jr = _JobRun(ej, on_done=on_done)
        job.arrival_t = time.monotonic()
        with self._lifecycle:
            # pool-start + in-flight increment are atomic w.r.t. shutdown's
            # teardown check, so a racing submit is never stranded
            self._start_locked()
            with self._state:
                self._inflight += 1
        if not job.tasks:
            # empty job: nothing to place — finish immediately with a zeroed
            # record instead of indexing runners[0]
            now = time.monotonic()
            self._record(jr, ExecRecord(job.name, "", -1, now, now, now))
            self._finish(jr, crashed=False)
        else:
            self._submit_next(jr)
        return jr

    def cancel(self, jr: _JobRun) -> bool:
        """Cancel: a parked waiter is removed from the admission queue
        immediately (no scheduler state leaks); a running task finishes its
        current kernel, then the job stops advancing. Returns False iff the
        job had already finished (too late); True otherwise — the job then
        ends CANCELLED (or CRASHED, if its in-flight kernel crashes). The
        flag is raised under the finish lock, so a True return can never be
        contradicted by a DONE status."""
        with self._state:
            if jr.done.is_set():
                return jr.cancelled
            jr.cancel_requested = True
        idx = jr.next_task
        tasks = jr.ej.job.tasks
        if idx < len(tasks) and self.sched.cancel_wait(tasks[idx]):
            # it was parked: the admission callback can never fire now
            self._finish(jr, crashed=False, cancelled=True)
        # else admitted or mid-handoff: the execute/completion/finish path
        # sees the flag
        return True

    # -- compatibility shim ---------------------------------------------------
    def run(self, jobs: Sequence[ExecJob]) -> Dict[str, float]:
        """Closed-batch protocol: submit every job, drain, report. Kept as a
        thin shim over the open-arrival engine (metrics keys unchanged)."""
        if not jobs:
            return _empty_stats()
        attempts0 = getattr(self.sched, "begin_attempts", 0)
        self.start()
        # deterministic arrival order: jobs enter the admission path in the
        # order given, so queue-rank wakeups replay the submission sequence
        for ej in jobs:
            self.submit(ej)
        self.drain()
        self.shutdown()
        return self._stats(jobs, attempts0)

    # -- engine internals -----------------------------------------------------
    def _record(self, jr: _JobRun, rec: ExecRecord) -> None:
        with self._rec_lock:
            self.records.append(rec)
            jr.records.append(rec)

    def _finish(self, jr: _JobRun, *, crashed: bool,
                cancelled: bool = False, shed: bool = False) -> None:
        for t in jr.ej.job.tasks:
            self._jr_by_uid.pop(t.uid, None)
            self._attempt_locks.pop(t.uid, None)
            self._armed_epoch.pop(t.uid, None)
        with self._state:
            if jr.done.is_set():
                return  # double-finish guard (cancel raced a completion)
            # a cancel requested before this point wins over DONE (matching
            # the sim backend, where the completion path checks the flag
            # even on the job's last task); a crash stays a crash
            if jr.cancel_requested and not crashed:
                cancelled = True
            jr.ej.job.crashed = jr.ej.job.crashed or crashed
            jr.cancelled = cancelled
            jr.shed = shed and not cancelled
            jr.ej.job.finish_t = time.monotonic()
            jr.done.set()
            self._inflight -= 1
            if self._inflight == 0:
                self._state.notify_all()
        lazy.free_all(jr.ej.buffers)
        if jr.on_done is not None:
            jr.on_done(jr)

    def _on_preempt(self, victims) -> None:
        """Eviction notice from the scheduler: signal the running attempt to
        stop cooperatively and take the job's checkpoint. Each notice names
        the victim's SUPERSEDED epoch; if a fresh attempt has already armed
        itself with a newer epoch (late delivery — the delivering thread
        holds no lock), the notice is dropped: stopping the fresh attempt
        would count its early return as a real completion. The superseded
        attempt's eventual ``task_end`` is epoch-fenced either way."""
        for t, epoch in victims:
            jr = self._jr_by_uid.get(t.uid)
            if jr is None:
                continue
            with self._signal_lock:
                stale = self._armed_epoch.get(t.uid, -1) > epoch
                if not stale:
                    jr.ej.preempted.set()
            if not stale and jr.ej.on_preempt is not None:
                try:
                    jr.ej.on_preempt(t)
                except Exception:
                    # a failing checkpoint must not poison the scheduler's
                    # notify path; the task simply restarts from its last
                    # committed state
                    pass

    def _submit_next(self, jr: _JobRun) -> None:
        if jr.cancel_requested:
            self._finish(jr, crashed=False, cancelled=True)
            return
        idx = jr.next_task
        task = jr.ej.job.tasks[idx]
        self._jr_by_uid[task.uid] = jr
        jr.t_queue = time.monotonic()
        # read at emit time (attach_tracer may run after construction);
        # this path is per-task, not per-admission — not hot
        tr = getattr(self.sched, "_trace", None)
        if tr is not None:
            tr.emit(obs.SUBMIT, task.uid, task.name,
                    data=obs.submit_data(task, jr.ej.job.name,
                                         jr.ej.job.uid))
        if not self.sched.can_ever_fit(task):
            # never feasible on any alive device (or, for a gang, no
            # feasible device-group shape): crash-at-submit with the
            # scheduler's explanation instead of waiting forever
            jr.ej.job.error = self.sched.infeasible_reason(task)
            if tr is not None:
                tr.emit(obs.CRASH, task.uid, task.name,
                        data={"reason": "infeasible"})
            self._record(jr, ExecRecord(
                jr.ej.job.name, task.name, -1, jr.t_queue, NEVER_STARTED,
                time.monotonic(), crashed=True))
            self._finish(jr, crashed=True)
            return

        def on_admit(t: Task, placement, epoch: int,
                     jr=jr, idx=idx) -> None:
            # fires under task_end/notify of *another* task (or inline on
            # immediate admission): just hand off to the execution pool.
            # placement None = the fleet shrank to where this task can never
            # run (mark_dead sweep): crash the job instead of waiting;
            # DEADLINE_SHED = the scheduler shed the parked waiter past its
            # deadline: fail the job with SHED status, not CRASHED
            if placement is DEADLINE_SHED:
                # no record: the job consumed no device time (matches the
                # sim backend — a shed handle reports records == [])
                self._finish(jr, crashed=False, shed=True)
                return
            if placement is None:
                jr.ej.job.error = self.sched.infeasible_reason(t)
                self._record(jr, ExecRecord(
                    jr.ej.job.name, t.name, -1, jr.t_queue, NEVER_STARTED,
                    time.monotonic(), crashed=True))
                self._finish(jr, crashed=True)
                return
            self._ready.put(_Ready(jr, idx, placement, epoch))

        self.sched.admit_or_enqueue(task, on_admit)

    def _execute(self, item: _Ready) -> None:
        jr, task = item.jr, item.jr.ej.job.tasks[item.task_idx]
        # a gang placement binds the task to its WHOLE reserved device
        # group; the lead device carries the record/audit identity
        devs = placement_devices(item.placement)
        lead = devs[0]
        # evicted while queued for the pool (device died): the re-admitted
        # incarnation owns this task now — drop the stale work item
        if self.sched.admission_epoch(task) != item.epoch:
            return
        tr = getattr(self.sched, "_trace", None)
        if tr is not None:
            tr.emit(obs.DISPATCH, task.uid, task.name, lead, item.epoch,
                    data={"chips": len(devs)})
        if jr.cancel_requested:
            # cancelled between admission and execution: release the
            # admission (it holds the whole reservation) and end the job
            if self.sched.task_end(task, epoch=item.epoch):
                self._finish(jr, crashed=False, cancelled=True)
            return
        # memory-unsafe scheduler may have oversubscribed: OOM crash if ANY
        # member device of the group is past capacity (memory safety must
        # hold across every device a job touches)
        if any(self.sched.devices[d].oom() for d in devs):
            if not self.sched.task_end(task, epoch=item.epoch):
                return  # fenced: evicted + re-admitted elsewhere mid-check
            if tr is not None:
                # after task_end's END: the resources WERE released before
                # the crash was recorded (the tolerated DONE->DEAD arc)
                tr.emit(obs.CRASH, task.uid, task.name, lead, item.epoch,
                        data={"reason": "oom"})
            self._record(jr, ExecRecord(
                jr.ej.job.name, task.name, lead, jr.t_queue, NEVER_STARTED,
                time.monotonic(), crashed=True, gang_chips=len(devs)))
            self._finish(jr, crashed=True)
            return
        # serialize with any still-running superseded attempt of this task,
        # then arm the cooperative-preemption surface: clear FIRST, then
        # re-check the epoch. An eviction racing this dispatch lands on one
        # side or the other: before the re-check, its epoch bump voids this
        # attempt (the eaten event cannot be meant for a running attempt —
        # the lock guarantees none is); after it, the notice finds the
        # cleared event and stops the runner below.
        if task.uid not in self._jr_by_uid:
            return  # job already resolved: stale straggler dispatch
        lock = self._attempt_locks.setdefault(task.uid, threading.Lock())
        crashed = False
        t_start = None
        with lock:
            with self._signal_lock:
                # clear + arm atomically w.r.t. notice delivery: from here a
                # notice is delivered only if addressed to THIS epoch (or a
                # later one, which cannot exist yet)
                jr.ej.preempted.clear()
                self._armed_epoch[task.uid] = item.epoch
            if self.sched.admission_epoch(task) == item.epoch:
                # the execution window starts only once any superseded
                # attempt has exited — its tail must not be charged to
                # this attempt's record
                t_start = time.monotonic()
                # stamped for the calibration store: task_end reads start_t
                # to attribute wall-clock runtime against the probe estimate
                task.start_t = t_start
                jr.started = True
                if tr is not None:
                    tr.emit(obs.BEGIN, task.uid, task.name, lead,
                            item.epoch)
                try:
                    # lazy runtime: replay buffer queues on the gang's lead
                    # device, then launch the task's unit group as ONE bound
                    # dispatch — a single-chip runner receives its device, a
                    # gang runner receives the ordered device list of its
                    # reservation
                    lazy.kernel_launch_prepare(jr.ej.buffers,
                                               self.device_map[lead])
                    bound = (self.device_map[lead] if len(devs) == 1
                             else [self.device_map[d] for d in devs])
                    jr.ej.runners[item.task_idx](bound)
                except Exception:
                    crashed = True
        if t_start is None:
            # superseded between pool pickup and dispatch. If the fresh
            # incarnation meanwhile finished the whole job, _finish's
            # cleanup may have raced our setdefault — reap the entries it
            # can no longer see
            if jr.done.is_set():
                self._attempt_locks.pop(task.uid, None)
                self._armed_epoch.pop(task.uid, None)
            return
        # epoch fence: if the device died mid-run the task was evicted and
        # re-enqueued — this completion is stale, the fresh incarnation
        # owns the job's progress (and the resources were already freed)
        current = self.sched.task_end(task, epoch=item.epoch)
        if not current:
            return
        if crashed:
            if tr is not None:
                tr.emit(obs.CRASH, task.uid, task.name, lead, item.epoch,
                        data={"reason": "runner"})
            now = time.monotonic()
            self._record(jr, ExecRecord(
                jr.ej.job.name, task.name, lead, jr.t_queue,
                t_start, now, crashed=True, gang_chips=len(devs)))
            self._finish(jr, crashed=True)
            return
        self._record(jr, ExecRecord(
            jr.ej.job.name, task.name, lead, jr.t_queue, t_start,
            time.monotonic(), gang_chips=len(devs)))
        jr.next_task += 1
        if jr.next_task >= len(jr.ej.job.tasks):
            self._finish(jr, crashed=False)
        else:
            self._submit_next(jr)

    def _pool_worker(self) -> None:
        while True:
            item = self._ready.get()
            if item is None:
                return
            self._execute(item)

    def _stats(self, jobs: Sequence[ExecJob], attempts0: int
               ) -> Dict[str, float]:
        done = [j.job for j in jobs if not j.job.crashed]
        t0 = min(j.job.arrival_t for j in jobs)
        t1 = max(j.job.finish_t for j in jobs)
        makespan = max(t1 - t0, 1e-9)
        return {
            "makespan_s": makespan,
            "throughput_jobs_per_s": len(done) / makespan,
            "completed": len(done),
            "crashed": sum(1 for j in jobs if j.job.crashed),
            "mean_turnaround_s": sum(
                j.job.finish_t - j.job.arrival_t for j in jobs
                if not j.job.crashed) / max(len(done), 1),
            "sched_attempts":
                getattr(self.sched, "begin_attempts", 0) - attempts0,
        }


class PollingExecutor(Executor):
    """The previous protocol: one worker thread per in-flight job, each
    spinning ``task_begin`` in a sleep(poll) retry loop. Kept as the baseline
    the event-driven engine is benchmarked against — concurrency is capped at
    ``workers`` and blocked jobs burn a thread + poll attempts each."""

    def run(self, jobs: Sequence[ExecJob]) -> Dict[str, float]:
        if not jobs:
            return _empty_stats()
        attempts0 = getattr(self.sched, "begin_attempts", 0)
        q: "queue_mod.Queue[ExecJob]" = queue_mod.Queue()
        for j in jobs:
            j.job.arrival_t = time.monotonic()
            q.put(j)

        def worker(_wid: int) -> None:
            while True:
                try:
                    ej = q.get_nowait()
                except queue_mod.Empty:
                    return
                try:
                    self._run_job(ej)
                except OOMError:
                    ej.job.crashed = True
                finally:
                    lazy.free_all(ej.buffers)  # crash paths must free too
                ej.job.finish_t = time.monotonic()

        threads = [threading.Thread(target=worker, args=(i,))
                   for i in range(self.workers)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        return self._stats(jobs, attempts0)

    def _run_job(self, ej: ExecJob) -> None:
        for task, runner in zip(ej.job.tasks, ej.runners):
            t_queue = time.monotonic()
            # probe -> scheduler (task_begin), retry while infeasible
            placement = self.sched.task_begin(task)
            while placement is None:
                if not self.sched.can_ever_fit(task):
                    raise OOMError(f"{task.name}: never feasible")
                time.sleep(self.poll)
                placement = self.sched.task_begin(task)
            devs = placement_devices(placement)
            lead = devs[0]
            # memory-unsafe scheduler may have oversubscribed: OOM crash
            if any(self.sched.devices[d].oom() for d in devs):
                self.sched.task_end(task)
                with self._rec_lock:
                    self.records.append(ExecRecord(
                        ej.job.name, task.name, lead, t_queue,
                        NEVER_STARTED, time.monotonic(), crashed=True,
                        gang_chips=len(devs)))
                raise OOMError(
                    f"{task.name}: {task.resources.hbm_bytes} B exceeded "
                    f"device {lead} capacity")
            t_start = time.monotonic()
            try:
                lazy.kernel_launch_prepare(ej.buffers, self.device_map[lead])
                bound = (self.device_map[lead] if len(devs) == 1
                         else [self.device_map[d] for d in devs])
                runner(bound)
            finally:
                self.sched.task_end(task)
            with self._rec_lock:
                self.records.append(ExecRecord(
                    ej.job.name, task.name, lead, t_queue, t_start,
                    time.monotonic(), gang_chips=len(devs)))
