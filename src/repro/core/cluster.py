"""Open-arrival submission front-end: the ``Cluster`` object jobs arrive at.

The paper's scheduler is a daemon — probes submit tasks whenever a process
reaches a launch point, not as a pre-declared batch. ``Cluster`` is that
front door for this repo: ``submit`` may be called at ANY time (including
while earlier jobs are mid-flight) and returns a future-like ``JobHandle``;
``drain`` is the barrier; ``shutdown`` tears the engine down.

    cluster = Cluster(MGBAlg3Scheduler(4), workers=4)
    h = cluster.submit(ej, priority=5, deadline_s=2.0)
    ...                        # keep submitting while it runs
    recs = h.result(timeout=30)    # per-task ExecRecords
    cluster.drain()

Two interchangeable backends sit behind the same API:

  * ``backend="live"`` — the event-driven ``Executor``: real jitted JAX
    computations, wall-clock time, a bounded execution pool;
  * ``backend="sim"``  — the discrete-event ``Simulator``: virtual clock,
    processor-sharing interference model, no real execution. ``step()``
    advances the clock so submissions can interleave with simulated
    progress.

Both route admission through the scheduler's OWN priority/deadline waiter
queue, so the same submission trace produces the same admission order live
and simulated — the property that makes simulator studies predictive of the
serving path.

Priority/deadline semantics (enforced in the scheduler's admission queue,
not by this caller): higher ``priority`` admits first; within a priority
class, earliest ``deadline_s`` first (EDF — by default a deadline is an
ordering hint, not an enforcement: late tasks still run); no-deadline tasks
rank after deadlined peers of their class; arrival order breaks remaining
ties, and a task evicted by a device failure restarts at the front of its
class. With ``shed_late=True`` the deadline becomes (soft) enforcement: a
job still PARKED when its deadline passes is failed with ``JobStatus.SHED``
at the next admission drain instead of admitted late.
"""
from __future__ import annotations

import enum
import threading
import time
from typing import Callable, Dict, List, Optional, Sequence, Union

from repro.core.executor import ExecJob, ExecRecord, Executor, _JobRun
from repro.core.scheduler.base import Scheduler
from repro.core.scheduler.preempt import PreemptionMixin
from repro.core.simulator import Simulator, _JobState
from repro.core.task import Job
from repro.obs import explain as obsx
from repro.obs.calibrate import CalibrationStore, attach_calibrator
from repro.obs.events import Tracer, attach_tracer
from repro.obs.explain import Explainer, attach_explainer
from repro.obs.export import write_chrome_trace
from repro.obs.metrics import MetricsRegistry
from repro.obs.profile import Profiler, TaskProfile
from repro.obs.replay import FlightRecorder


class JobStatus(enum.Enum):
    QUEUED = "queued"        # submitted, not yet executing
    RUNNING = "running"      # at least one task started
    DONE = "done"            # all tasks completed
    CRASHED = "crashed"      # OOM / runner exception / never feasible
    CANCELLED = "cancelled"  # ended by JobHandle.cancel()
    SHED = "shed"            # parked past its deadline, failed at a drain
    #                          (only with shed_late=True deadline shedding)


class JobHandle:
    """Future-like view of one submitted job, valid on either backend.

    ``result(timeout)`` blocks (live: wall clock; sim: advances the virtual
    clock) until the job resolves and returns its per-task ``ExecRecord``
    list; check ``status`` to distinguish DONE from CRASHED/CANCELLED.
    """

    def __init__(self, cluster: "Cluster", job: Job,
                 state: Union[_JobRun, _JobState]):
        self._cluster = cluster
        self.job = job
        self._state = state

    # -- lifecycle ----------------------------------------------------------
    @property
    def status(self) -> JobStatus:
        s = self._state
        finished = s.done.is_set() if isinstance(s, _JobRun) else s.done
        if finished:
            if s.cancelled:
                return JobStatus.CANCELLED
            if s.shed:
                return JobStatus.SHED
            if self.job.crashed:
                return JobStatus.CRASHED
            return JobStatus.DONE
        return JobStatus.RUNNING if s.started else JobStatus.QUEUED

    @property
    def records(self) -> List[ExecRecord]:
        """Per-task execution records accumulated so far (live wall times or
        virtual-clock times, matching the backend)."""
        return list(self._state.records)

    def result(self, timeout: Optional[float] = None) -> List[ExecRecord]:
        """Wait until the job resolves; returns its ``ExecRecord`` list.
        Live backend: blocks up to ``timeout`` wall seconds (raises
        ``TimeoutError`` on expiry). Sim backend: advances the virtual clock
        until the job resolves (``timeout`` bounds virtual seconds)."""
        s = self._state
        if isinstance(s, _JobRun):
            if not s.done.wait(timeout):
                raise TimeoutError(f"job {self.job.name!r} still "
                                   f"{self.status.value} after {timeout}s")
        else:
            sim = self._cluster._sim
            limit = sim.now + timeout if timeout is not None else None
            while not s.done:
                if limit is not None and sim.now > limit:
                    raise TimeoutError(f"job {self.job.name!r} still "
                                       f"{self.status.value} at virtual "
                                       f"t={sim.now:.3f}")
                if not sim.step():
                    break  # simulation idle: job crashed-at-drain or stuck
            if not s.done:
                raise TimeoutError(
                    f"job {self.job.name!r} cannot make progress")
        return self.records

    def cancel(self) -> bool:
        """Cancel the job: a parked/queued job ends immediately (its waiter
        leaves the scheduler's admission queue with no state leaked); a
        running task finishes its current kernel first. Returns False iff
        the job had already finished; True otherwise — the job then reports
        CANCELLED (or CRASHED if its in-flight kernel crashes)."""
        return self._cluster._cancel(self._state)

    def explain(self) -> Dict[str, List]:
        """Per-task decision verdicts: why is this job still parked, who
        evicted it and at what cost, where did it land. Delegates to
        ``Cluster.explain`` (needs the cluster built with ``explain=`` or
        ``trace=``)."""
        return self._cluster.explain(self)

    def profile(self) -> Dict[str, TaskProfile]:
        """Per-task observed-vs-predicted attribution: runtime error against
        the probe estimate, memory reserved vs high-water, the parked /
        dispatch / execution delay decomposition. Delegates to
        ``Cluster.profile`` (needs the cluster built with ``trace=``)."""
        return self._cluster.profile(self)


class Cluster:
    """The open-arrival submission surface over a scheduler + backend."""

    def __init__(self, scheduler: Scheduler, *, workers: Optional[int] = None,
                 backend: str = "live",
                 devices: Optional[Sequence[object]] = None,
                 poll_interval: float = 0.05, crash_delay: float = 8.0,
                 shed_late: bool = False, preempt: Optional[bool] = None,
                 trace: Union[None, bool, Tracer] = None,
                 explain: Union[None, bool, Explainer] = None,
                 calibrate: Union[None, bool, CalibrationStore] = None,
                 metrics: Optional[MetricsRegistry] = None,
                 flight_path: Optional[str] = None):
        self.sched = scheduler
        self.backend = backend
        # deadline enforcement (the shedding half): a parked waiter whose
        # deadline already passed is failed with JobStatus.SHED at the next
        # admission drain instead of being admitted late. Off by default —
        # deadlines stay a pure EDF ordering hint unless the operator opts in
        scheduler.shed_expired = shed_late
        # deadline/priority enforcement (the eviction half): preempt=True
        # lets an arriving waiter that strictly outranks a resident evict it
        # (checkpoint-based, work-conserving — see scheduler.preempt); the
        # scheduler must be preemption-capable. preempt=False disables it on
        # a capable scheduler; None (default) keeps the scheduler's own
        # setting (preemptive classes enable themselves at construction).
        if preempt is not None:
            if preempt and not isinstance(scheduler, PreemptionMixin):
                raise ValueError(
                    f"preempt=True needs a preemption-capable scheduler, "
                    f"got {type(scheduler).__name__} — use "
                    f"PreemptiveAlg2Scheduler / PreemptiveAlg3Scheduler / "
                    f"PreemptiveGangScheduler from repro.core.scheduler")
            scheduler.preempt_enabled = bool(preempt)
        n_workers = workers if workers is not None \
            else len(scheduler.devices)
        self._ex: Optional[Executor] = None
        self._sim: Optional[Simulator] = None
        if backend == "live":
            # a scheduler previously driven by a Simulator has its _clock
            # bound to that sim's (now frozen) virtual time: restore wall
            # monotonic so deadline shedding judges live deadlines correctly
            scheduler._clock = time.monotonic
            self._ex = Executor(scheduler, workers=n_workers,
                                devices=devices)
        elif backend == "sim":
            self._sim = Simulator(scheduler, workers=n_workers,
                                  poll_interval=poll_interval,
                                  crash_delay=crash_delay)
        else:
            raise ValueError(f"unknown backend {backend!r} "
                             "(expected 'live' or 'sim')")
        # event-sourced telemetry (repro.obs): trace=True builds a default
        # Tracer, or pass a pre-sized one. Attached AFTER backend
        # construction — attach_tracer binds the tracer's clock to the
        # scheduler's _clock late, so it follows the sim's virtual-clock
        # repointing (and the live backend's wall-monotonic restore) above
        self.trace: Optional[Tracer] = None
        self.flight: Optional[FlightRecorder] = None
        self.metrics: Optional[MetricsRegistry] = metrics
        # NB: identity checks, not truthiness — Tracer/Explainer define
        # __len__, so a freshly-built (empty) instance is falsy and a bare
        # `if trace:` would silently skip attaching it
        want_trace = trace is not None and trace is not False
        if want_trace:
            self.trace = trace if isinstance(trace, Tracer) else Tracer()
            attach_tracer(scheduler, self.trace)
            if flight_path is not None:
                self.flight = FlightRecorder(self.trace, flight_path,
                                             registry=metrics)
        # decision explainability (repro.obs.explain): explain=True builds
        # a default Explainer, or pass a pre-sized one; explain=None follows
        # trace — a traced cluster answers "why" as well as "what". Attached
        # after the backend for the same late clock binding as the tracer.
        self.explainer: Optional[Explainer] = None
        if explain is None:
            explain = want_trace
        if explain is not False:
            self.explainer = explain if isinstance(explain, Explainer) \
                else Explainer()
            attach_explainer(scheduler, self.explainer)
        # online probe calibration (repro.obs.calibrate): calibrate=True
        # builds a default CalibrationStore, or pass a tuned one. Admission
        # then uses EWMA-corrected est_seconds and safety-margin memory;
        # completions feed the store. A scheduler pre-wrapped in
        # CalibratedScheduler is discovered instead of double-attached.
        self.calibration: Optional[CalibrationStore] = None
        if calibrate is not None and calibrate is not False:
            self.calibration = calibrate \
                if isinstance(calibrate, CalibrationStore) \
                else CalibrationStore()
            attach_calibrator(scheduler, self.calibration)
        else:
            self.calibration = getattr(scheduler, "_calib", None)
        self.handles: List[JobHandle] = []
        # scheduler counters are lifetime totals; snapshot them so a cluster
        # built over a reused scheduler reports only its own activity
        self._attempts0 = getattr(scheduler, "begin_attempts", 0)
        self._preempt0 = getattr(scheduler, "preemptions", 0)
        self._migr0 = getattr(scheduler, "migrations", 0)
        self._submit_lock = threading.Lock()
        # aggregate-stats counters, maintained at submit time and by each
        # job's resolution callback (the backend fires it exactly once per
        # job) so stats() is O(1) instead of re-scanning every handle —
        # polling it at 1e5 submitted jobs must not stall the control plane
        self._stats_lock = threading.Lock()
        self._n_jobs = 0
        self._t0 = float("inf")    # earliest arrival over ALL jobs
        self._t1 = float("-inf")   # latest finish over RESOLVED jobs
        self._n_done = 0
        self._n_crashed = 0
        self._n_cancelled = 0
        self._n_shed = 0
        self._turnaround_sum = 0.0  # over DONE jobs only

    # -- submission ----------------------------------------------------------
    def submit(self, job: Union[Job, ExecJob], *,
               runners: Optional[List[Callable]] = None,
               priority: Optional[int] = None,
               deadline_s: Optional[float] = None,
               on_done: Optional[Callable[["JobHandle"], None]] = None
               ) -> JobHandle:
        """Submit ``job`` NOW — at any time, including while earlier jobs are
        executing. ``priority`` (higher first) and ``deadline_s`` (seconds
        from submission; EDF within a priority class) rank the job in the
        scheduler's admission queue; leaving either None keeps any stamp
        already on the Job (default class 0, no deadline). Live backend
        wants an ``ExecJob`` (or a ``Job`` plus ``runners``); the sim
        backend takes a plain ``Job``. Returns a ``JobHandle``
        immediately.

        ``on_done(handle)`` (optional) fires exactly once when the job
        resolves (DONE/CRASHED/CANCELLED/SHED) — the streaming-completion
        hook serve.engine chains prefill→decode-slot joins on. Live backend:
        fires on a backend thread; keep it non-blocking. It may fire before
        ``submit`` returns (an instantly-resolving job)."""
        done_cb = self._on_job_resolved if on_done is None \
            else self._chain_on_done(on_done)
        with self._submit_lock:
            if self._ex is not None:
                ej = self._as_execjob(job, runners)
                deadline_t = (time.monotonic() + deadline_s
                              if deadline_s is not None else None)
                state: Union[_JobRun, _JobState] = self._ex.submit(
                    ej, priority=priority, deadline_t=deadline_t,
                    on_done=done_cb)
                handle = JobHandle(self, ej.job, state)
            else:
                plain = job.job if isinstance(job, ExecJob) else job
                deadline_t = (self._sim.now + deadline_s
                              if deadline_s is not None else None)
                state = self._sim.submit(plain, priority=priority,
                                         deadline_t=deadline_t,
                                         on_done=done_cb)
                handle = JobHandle(self, plain, state)
            with self._stats_lock:
                self._n_jobs += 1
                self._t0 = min(self._t0, handle.job.arrival_t)
            self.handles.append(handle)
            return handle

    def _chain_on_done(self, user_cb: Callable[["JobHandle"], None]
                       ) -> Callable[[Union[_JobRun, _JobState]], None]:
        """Wrap a user completion callback around the stats-folding backend
        callback. The backend may resolve an (e.g. empty) job INSIDE
        ``submit``, before the public handle exists — so the handle is built
        on demand from the backend state rather than captured."""
        def cb(state: Union[_JobRun, _JobState]) -> None:
            self._on_job_resolved(state)
            job = state.ej.job if isinstance(state, _JobRun) else state.job
            user_cb(JobHandle(self, job, state))
        return cb

    def _on_job_resolved(self, state: Union[_JobRun, _JobState]) -> None:
        """Backend resolution callback (fired exactly once per job): fold the
        job's terminal status into the maintained aggregate counters. The
        classification mirrors ``JobHandle.status`` — cancel beats shed
        beats crash beats done."""
        job = state.ej.job if isinstance(state, _JobRun) else state.job
        with self._stats_lock:
            if job.finish_t >= 0:
                self._t1 = max(self._t1, job.finish_t)
            if state.cancelled:
                self._n_cancelled += 1
            elif state.shed:
                self._n_shed += 1
            elif job.crashed:
                self._n_crashed += 1
            else:
                self._n_done += 1
                self._turnaround_sum += job.finish_t - job.arrival_t
        if self.flight is not None and job.crashed \
                and not state.cancelled and not state.shed:
            self.flight.dump("crash")

    @staticmethod
    def _as_execjob(job: Union[Job, ExecJob],
                    runners: Optional[List[Callable]]) -> ExecJob:
        if isinstance(job, ExecJob):
            return job
        if runners is None:
            # placement/ordering studies on the live engine: tasks place,
            # execute instantly, release
            runners = [(lambda device: None)] * len(job.tasks)
        if len(runners) != len(job.tasks):
            raise ValueError(f"{len(runners)} runners for "
                             f"{len(job.tasks)} tasks")
        return ExecJob(job=job, runners=list(runners))

    def _cancel(self, state: Union[_JobRun, _JobState]) -> bool:
        if isinstance(state, _JobRun):
            return self._ex.cancel(state)
        return self._sim.cancel(state)

    # -- barriers / clock ----------------------------------------------------
    def drain(self) -> None:
        """Barrier: block (live) or advance the virtual clock (sim) until
        every job submitted so far has resolved. New submissions remain legal
        afterwards — drain is a checkpoint, not a shutdown. A sim drain that
        hits its virtual time limit with work still pending raises instead
        of returning quietly: a capped run must not read as a completed one."""
        if self._ex is not None:
            self._ex.drain()
        else:
            self._sim_drain_checked()
        if self.flight is not None:
            self.flight.dump("drain", always=True)

    def _sim_drain_checked(self) -> None:
        res = self._sim.drain()
        if res.truncated:
            raise RuntimeError(
                f"simulation drain truncated at virtual t={self._sim.now:.0f}s "
                f"with work still pending ({res.completed} completed) — the "
                f"time limit was hit, not the end of the trace")

    def step(self) -> bool:
        """Sim backend: advance the virtual clock one event (False when
        idle). Live backend: no-op False — wall time advances on its own."""
        if self._sim is not None:
            return self._sim.step()
        return False

    def run_until(self, t: float) -> None:
        """Sim backend: advance the virtual clock to exactly ``t`` (the
        open-arrival driver — submit, run_until the next arrival, submit).
        Live backend: no-op; wall time advances on its own."""
        if self._sim is not None:
            self._sim.run_until(t)

    def inject_failure(self, device) -> None:
        """Declare ``device`` dead NOW on either backend (sim: residents'
        virtual runs stop and re-park; live: the scheduler's mark_dead
        path). ``obs.whatif`` replays recorded fleet faults through this."""
        if self._sim is not None:
            self._sim.inject_failure(device)
        else:
            self.sched.mark_dead(device)

    def revive(self, device) -> None:
        """Bring ``device`` back in service on either backend."""
        if self._sim is not None:
            self._sim.revive_device(device)
        else:
            self.sched.revive(device)

    @property
    def now(self) -> float:
        """Current time on the backend's clock (virtual for sim)."""
        return self._sim.now if self._sim is not None else time.monotonic()

    def shutdown(self) -> None:
        """Drain, then stop the live execution pool (sim: just drains).
        The cluster is reusable — the next ``submit`` restarts the pool."""
        if self._ex is not None:
            self._ex.shutdown()
        else:
            self._sim_drain_checked()

    def explain(self, handle: "JobHandle") -> Dict[str, List[obsx.Verdict]]:
        """Why is this job still parked / who evicted it, at what cost —
        answered in one call, per task name: the recorded verdict window
        (rejections with per-device reasons, skips, preemption plans,
        evictions naming the preemptor, the final placement) plus, for a
        task parked RIGHT NOW, a live rejection probe of the current
        queue state — so even a waiter the drain never individually
        probed (class-memo skip) reports at least one structured reason
        per attempted device. Requires ``explain=`` (on by default when
        the cluster is traced)."""
        if self.explainer is None:
            raise RuntimeError(
                "Cluster was built without explain= — pass explain=True "
                "(or an Explainer) to record decision verdicts")
        ex = self.explainer
        eq = getattr(self.sched, "explain_queue", None)
        out: Dict[str, List[obsx.Verdict]] = {}
        for task in handle.job.tasks:
            verdicts = ex.verdicts(task.uid)
            if eq is not None:
                live = eq(task)
                if live is not None:       # parked right now: probe live
                    verdicts.append(obsx.Verdict(
                        seq=-1, t=self.now, uid=task.uid, name=task.name,
                        action=obsx.REJECTED, reasons=tuple(live),
                        data={"live": True}))
            out[task.name or str(task.uid)] = verdicts
        return out

    def profile(self, handle: Optional["JobHandle"] = None):
        """Observed-vs-predicted attribution from the event stream (requires
        ``trace=``). With a handle: per-task ``TaskProfile`` records for that
        job, keyed by task name — runtime error vs the probe estimate,
        memory reserved vs observed high-water, parked/dispatch/execution
        delay decomposition, evictions. Without: the fleet summary —
        aggregate error stats, per-device occupancy, and (when the cluster
        is calibrated) the calibration store's accuracy report. Mirrors
        ``explain()``/``JobHandle.explain()``."""
        if self.trace is None:
            raise RuntimeError("Cluster was built without trace= — pass "
                               "trace=True (or a Tracer) to enable profiling")
        prof = Profiler(self.trace, self.calibration)
        if handle is None:
            return prof.summary()
        profs = prof.profiles()
        out: Dict[str, TaskProfile] = {}
        for task in handle.job.tasks:
            p = profs.get(task.uid)
            if p is None:          # never reached an emission site yet
                p = TaskProfile(task.uid)
                p.name = task.name
            out[task.name or str(task.uid)] = p
        return out

    def export_trace(self, path: str, *,
                     profile_counters: Optional[bool] = None) -> Dict:
        """Write the tracer's event window as a Chrome/Perfetto trace-event
        JSON (chrome://tracing or https://ui.perfetto.dev) and return the
        document. Requires the cluster to have been built with ``trace=``.

        On a sharded or multi-pod control plane the device tracks are
        named ``pod{p}/dev{d}`` (pod factoring derived from the
        scheduler) instead of flat ``device {i}``.

        ``profile_counters`` adds the profiling plane's counter tracks
        (per-device occupancy %, fleet prediction-error %); default: on
        exactly when the cluster is calibrated."""
        if self.trace is None:
            raise RuntimeError("Cluster was built without trace= — pass "
                               "trace=True (or a Tracer) to enable telemetry")
        if profile_counters is None:
            profile_counters = self.calibration is not None
        return write_chrome_trace(self.trace.events(), path,
                                  devices_per_pod=self._devices_per_pod(),
                                  profile_counters=profile_counters)

    def _devices_per_pod(self) -> Optional[int]:
        """Pod factoring for trace-track / dashboard labels: a sharded
        wrapper's uniform shard width, or a multi-pod gang topology's
        pod size; None for flat fleets (keeps ``device {i}`` labels)."""
        sched = self.sched
        dpp = getattr(sched, "_shard_devs", None)
        if dpp and len(getattr(sched, "shards", ())) > 1:
            return dpp
        topo = getattr(sched, "topo", None)
        if topo is not None and getattr(topo, "pods", 1) > 1:
            return topo.rows * topo.cols
        return None

    def __enter__(self) -> "Cluster":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()

    # -- metrics -------------------------------------------------------------
    def stats(self) -> Dict[str, float]:
        """Aggregate metrics over every job submitted so far, with the same
        keys ``Executor.run`` reports (plus ``cancelled``). Times are wall
        seconds (live) or virtual seconds (sim).

        O(1): read from counters maintained at submit time and by each
        job's resolution callback — never a scan over the handle list, so
        a dashboard may poll this at 1e5 submitted jobs without stalling
        submission. Unresolved jobs count toward nothing but the arrival
        front ``t0`` (exactly as the historical handle scan had it)."""
        preemptions = getattr(self.sched, "preemptions", 0) - self._preempt0
        migrations = getattr(self.sched, "migrations", 0) - self._migr0
        with self._stats_lock:
            if not self._n_jobs:
                return {"makespan_s": 0.0, "throughput_jobs_per_s": 0.0,
                        "completed": 0, "crashed": 0,
                        "mean_turnaround_s": 0.0, "sched_attempts": 0,
                        "cancelled": 0, "shed": 0,
                        "preemptions": preemptions,
                        "migrations": migrations}
            t0 = self._t0
            t1 = self._t1 if self._t1 > float("-inf") else t0
            makespan = max(t1 - t0, 1e-9)
            n_done = self._n_done
            return {
                "makespan_s": makespan,
                "throughput_jobs_per_s": n_done / makespan,
                "completed": n_done,
                "crashed": self._n_crashed,
                "cancelled": self._n_cancelled,
                "shed": self._n_shed,
                "preemptions": preemptions,
                "migrations": migrations,
                "mean_turnaround_s":
                    self._turnaround_sum / max(n_done, 1),
                "sched_attempts":
                    getattr(self.sched, "begin_attempts", 0)
                    - self._attempts0,
            }
