"""Preemption substrate: cost model, victim-eligibility policy, progress
ledger.

The paper's scheduler is admission-only — once a kernel is placed it runs to
completion, so under overload a late-arriving urgent job can only wait (or be
shed). This module supplies the pieces the preemptive scheduler layer
(``repro.core.scheduler.preempt``) builds on:

  * **decision rule** (``outranks``): an arriving waiter may evict a resident
    only if it STRICTLY outranks it on the same order the admission queue
    enforces — higher priority class first, then earlier absolute deadline
    (EDF) within a class. Strictness means preemption only ever moves
    resources up the rank order, so eviction chains terminate (a victim can
    never preempt its preemptor back);
  * **cost model** (``preemption_cost``): evicting a resident forfeits its
    in-flight state, so the victim set is chosen to minimize
    ``remaining work x held memory`` — the product of the compute we would
    re-run without a checkpoint and the state a checkpoint would have to
    move. ``remaining_estimate`` supplies the remaining-work term from the
    progress ledger minus time-in-residence (the simulator overwrites the
    estimate with its exact value at eviction);
  * **progress ledger** (``ProgressLedger``): uid -> remaining solo-work
    seconds, banked at eviction so resumed work is work-conserving — the
    simulator restarts the task at its remaining work (plus a configurable
    checkpoint/restore penalty) instead of from scratch, and the live
    executor's cost estimates stay honest across repeated evictions;
  * **guardrails** (``PreemptionPolicy``): ``min_runtime_s`` before a
    resident becomes preemptible (no thrash on fresh admissions),
    ``budget`` evictions per job after which it is immune, and
    ``aging_step`` priority escalation per eviction so a repeatedly-bumped
    low-priority job eventually outranks the stream that keeps displacing
    it (starvation freedom).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core import interference
from repro.core.task import Task

# floor on the remaining-work estimate: a task observed nearly done still
# costs SOMETHING to evict (checkpoint + restore round trip at minimum)
_REMAINING_FLOOR_S = 1e-3


@dataclasses.dataclass
class PreemptionPolicy:
    """Guardrail knobs for the preemptive scheduler layer.

    Defaults are calibrated for the repo's benchmark scales (jobs of seconds
    to tens of virtual seconds): a resident must survive ``min_runtime_s``
    before it is eligible as a victim, a job is evicted at most ``budget``
    times before becoming immune, and each eviction raises the victim's
    priority by ``aging_step`` classes so sustained high-priority arrivals
    cannot starve it forever. ``checkpoint_penalty_s`` is the restore cost a
    resumed task pays before making new progress (the simulator charges it
    explicitly; a live training task pays it inside its own
    checkpoint-restore path).
    """
    min_runtime_s: float = 0.25
    budget: int = 3
    aging_step: int = 1
    checkpoint_penalty_s: float = interference.CHECKPOINT_PENALTY_S


class ProgressLedger:
    """Remaining-work bank for preempted tasks, keyed by task uid.

    ``set_remaining`` is called at eviction (the scheduler estimates; the
    simulator overwrites with the exact value), ``remaining`` answers cost
    queries and the resume path, ``clear`` drops the entry on completion.
    Mutations happen under the owning scheduler's lock, so no lock here.
    """

    def __init__(self) -> None:
        self._remaining: Dict[int, float] = {}

    def set_remaining(self, uid: int, seconds: float) -> None:
        self._remaining[uid] = max(seconds, _REMAINING_FLOOR_S)

    def remaining(self, task: Task) -> float:
        """Remaining solo-work seconds: the banked value for a previously
        preempted task, the full estimate otherwise."""
        return self._remaining.get(task.uid, task.resources.est_seconds)

    def remaining_or_none(self, uid: int) -> Optional[float]:
        """Banked remaining work, or None if the task was never preempted
        (callers then start it from its full estimate)."""
        return self._remaining.get(uid)

    def clear(self, uid: int) -> None:
        self._remaining.pop(uid, None)

    def __len__(self) -> int:
        return len(self._remaining)


def outranks(waiter: Task, resident: Task) -> bool:
    """Strict rank order for the eviction decision — the admission queue's
    own order (priority class desc, then EDF within a class). A waiter that
    merely TIES a resident never preempts it: strictness is what makes
    eviction chains terminate and keeps equal-class work FIFO."""
    if waiter.priority != resident.priority:
        return waiter.priority > resident.priority
    if waiter.deadline_t is None:
        return False  # no deadline: cannot outrank within its own class
    return resident.deadline_t is None or waiter.deadline_t < resident.deadline_t


def remaining_estimate(task: Task, ledger: ProgressLedger,
                       elapsed_s: float) -> float:
    """Remaining-work estimate for a RESIDENT task: its banked (or full)
    remaining work minus time in residence this attempt. An estimate — wall
    residence overstates progress on a shared chip — but it only has to rank
    victims, and the simulator replaces it with the exact value at eviction."""
    return max(ledger.remaining(task) - max(elapsed_s, 0.0),
               _REMAINING_FLOOR_S)


def preemption_cost(task: Task, remaining_s: float) -> float:
    """Eviction cost of a resident: remaining work x held memory (GB·s).

    Both terms measure forfeited/moved state: the compute a checkpointless
    restart would redo, and the bytes a checkpoint must serialize + restore.
    A gang charges its WHOLE footprint — it is evicted whole or not at all."""
    return remaining_s * (task.resources.hbm_bytes / 1e9)
