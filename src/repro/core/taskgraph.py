"""Task construction — paper Algorithm 1.

Unit tasks that share memory objects are merged into one schedulable Task so
they always land on the same device (no cross-device data movement). The paper
does this over LLVM def-use chains; here the memobj sets come either from the
lazy runtime (buffer pseudo-addresses a computation reads/writes) or from
explicit declarations on ``UnitTask``.

The merge is transitive closure over the "shares a buffer" relation —
implemented with union-find (the paper's doubly-nested visited loop is the
same closure, O(n^2); union-find keeps large job graphs cheap).
"""
from __future__ import annotations

from typing import Dict, List, Sequence

from repro.core.task import Task, UnitTask


class _UnionFind:
    def __init__(self, n: int):
        self.parent = list(range(n))

    def find(self, i: int) -> int:
        while self.parent[i] != i:
            self.parent[i] = self.parent[self.parent[i]]
            i = self.parent[i]
        return i

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


def build_gpu_tasks(units: Sequence[UnitTask]) -> List[Task]:
    """Paper Alg. 1: group unit tasks whose memobj sets intersect."""
    n = len(units)
    uf = _UnionFind(n)
    owner: Dict[str, int] = {}  # memobj -> first unit index seen
    for i, u in enumerate(units):
        for obj in u.memobjs:
            if obj in owner:
                uf.union(owner[obj], i)
            else:
                owner[obj] = i
    groups: Dict[int, List[UnitTask]] = {}
    for i, u in enumerate(units):
        groups.setdefault(uf.find(i), []).append(u)
    tasks = []
    for members in groups.values():
        name = "+".join(m.name or str(m.uid) for m in members[:3])
        if len(members) > 3:
            name += f"+{len(members) - 3}more"
        tasks.append(Task(units=members, name=name))
    # deterministic order: by first unit uid (program order)
    tasks.sort(key=lambda t: min(u.uid for u in t.units))
    return tasks
