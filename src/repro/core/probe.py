"""Compiler-guided probes: derive a task's ResourceVector from the XLA
compiled artifact — the JAX analogue of the paper's instrumented
``task_begin(mem, threads, blocks)``.

Paper §III-A3: the LLVM pass interprets symbolic cudaMalloc sizes / grid dims
at runtime. Here the "compiler" is XLA itself: ``jit(fn).lower(args)`` +
``.compile()`` yield the exact HBM footprint (memory_analysis) and the
FLOP/byte work (cost_analysis) of the whole computation — the task is already
a closed, device-independent unit, so the analysis is exact rather than a
static over-approximation.

``probe_fn`` is cached by (fn, shapes): the paper amortizes its static
analysis at compile time; we amortize the AOT lowering the same way.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax

from repro.core.task import ResourceVector

# TPU v5e-class constants (same as launch.roofline; kept here so core/ has no
# circular dep on launch/)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def _mem_bytes(compiled) -> int:
    m = compiled.memory_analysis()
    return int(getattr(m, "argument_size_in_bytes", 0)
               + getattr(m, "output_size_in_bytes", 0)
               + getattr(m, "temp_size_in_bytes", 0)
               - getattr(m, "alias_size_in_bytes", 0))


def _cost(compiled) -> Dict[str, float]:
    c = compiled.cost_analysis()
    if isinstance(c, list):
        c = c[0]
    return c or {}


def vector_from_compiled(compiled, *, chips: int = 1,
                         flops_override: Optional[float] = None,
                         collective_bytes: float = 0.0,
                         work_scale: float = 1.0,
                         efficiency: Tuple[float, float] = (1.0, 1.0)
                         ) -> ResourceVector:
    """Build the probe payload from a compiled executable.

    ``flops_override`` replaces XLA's flops counter (which counts while-loop
    bodies once — see launch.flops) with an analytic model when available.
    ``work_scale`` multiplies duration terms (e.g. a job = N identical steps).

    ``efficiency`` = (core_eff, bw_eff): the fraction of peak compute / HBM
    bandwidth the kernel ACHIEVES while running solo. The roofline terms bound
    a perfect kernel; real ones sit below the roof (occupancy, latency,
    divergence — the paper's own motivation cites ~30% typical utilization),
    and the achieved fraction is exactly the resource share a co-resident
    consumes. Callers pass measured/calibrated profiles (workloads.py) or
    leave (1, 1) for ideal kernels.
    """
    cost = _cost(compiled)
    flops = float(flops_override if flops_override is not None
                  else cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    core_eff, bw_eff = efficiency
    compute_s = flops / (chips * PEAK_FLOPS * core_eff)
    memory_s = bytes_acc / (HBM_BW * bw_eff)
    collective_s = collective_bytes / ICI_BW
    est = max(compute_s, memory_s, collective_s, 1e-9)
    # demands: achieved share of the raw roof, per wall-second
    compute_share = (flops / (chips * PEAK_FLOPS)) / est
    memory_share = (bytes_acc / HBM_BW) / est
    return ResourceVector(
        hbm_bytes=_mem_bytes(compiled),
        flops=flops * work_scale,
        bytes_accessed=bytes_acc * work_scale,
        collective_bytes=collective_bytes * work_scale,
        est_seconds=est * work_scale,
        # fraction of the chip's compute-seconds (resp. HBM-bandwidth-seconds)
        # this task occupies per wall-second while running: a compute-bound
        # kernel at 85% MXU efficiency has core_demand 0.85
        core_demand=max(min(compute_share, 1.0), 0.01),
        bw_demand=max(min(memory_share, 1.0), 0.01),
        chips=chips,
    )


_probe_cache: Dict[Tuple, Any] = {}


def _abstractify(tree):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def probe_fn(fn: Callable, *args, chips: int = 1, work_scale: float = 1.0,
             flops_override: Optional[float] = None,
             efficiency: Tuple[float, float] = (1.0, 1.0)) -> ResourceVector:
    """Probe a python/jitted function with concrete or abstract args (any
    pytree of arrays/ShapeDtypeStructs).

    This is the instrumented ``task_begin`` of the paper: called right before
    launch, it conveys the resource needs to the scheduler. AOT compilation
    happens once per (fn, shape-signature).
    """
    sds = _abstractify(args)
    leaves, treedef = jax.tree_util.tree_flatten(sds)
    key = (id(fn), treedef,
           tuple((tuple(l.shape), str(l.dtype)) for l in leaves))
    compiled = _probe_cache.get(key)
    if compiled is None:
        compiled = jax.jit(fn).lower(*sds).compile()
        if len(_probe_cache) < 512:
            _probe_cache[key] = compiled
    return vector_from_compiled(compiled, chips=chips, work_scale=work_scale,
                                flops_override=flops_override,
                                efficiency=efficiency)
