"""End-to-end training driver: data pipeline -> sharded train loop with
checkpointing, straggler detection, and (optionally) the compiler-guided
scheduler wrapping the whole run as a GPU task.

Scales from this CPU container (reduced config, 1x1 mesh) to a production
pod (full config, 16x16 mesh) with no code change — only --mesh/--reduced.

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch gemma2-9b --reduced \
        --steps 50 --batch 8 --seq 128 [--ckpt-dir /tmp/ck] [--resume]
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS, get_arch
from repro.data.pipeline import Prefetcher, TokenPipeline, shard_batch
from repro.dist import sharding as SH
from repro.launch.mesh import data_axes, make_mesh
from repro.models.model import init_params
from repro.optim import adamw
from repro.train import checkpoint as CK
from repro.train.straggler import StragglerDetector
from repro.train.train_step import make_train_step


def train(arch: str, *, steps: int = 50, batch: int = 8, seq: int = 128,
          reduced: bool = True, mesh_shape=(1, 1), ckpt_dir: Optional[str] = None,
          ckpt_every: int = 20, resume: bool = False, seed: int = 0,
          attn_impl: str = "flash", log_every: int = 10,
          lr: float = 3e-4) -> dict:
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    mesh = make_mesh(mesh_shape, ("data", "model"))
    shape = ShapeConfig("driver", seq, batch, "train")
    opt_cfg = adamw.AdamWConfig(lr=lr, warmup_steps=min(20, steps // 5 + 1),
                                total_steps=steps,
                                moment_dtype=cfg.optimizer_moment_dtype)
    step_fn = make_train_step(cfg, opt_cfg, attn_impl=attn_impl)

    with SH.activation_mesh(mesh):
        params = init_params(cfg, jax.random.PRNGKey(seed))
        opt_state = adamw.init_state(opt_cfg, params)
        pspecs = SH.param_specs(cfg, params, mesh)
        psh = SH.to_named(pspecs, mesh)
        osh = {"mu": psh, "nu": psh, "step": NamedSharding(mesh, P())}
        params = jax.tree_util.tree_map(jax.device_put, params, psh)
        opt_state = {
            "mu": jax.tree_util.tree_map(jax.device_put, opt_state["mu"], psh),
            "nu": jax.tree_util.tree_map(jax.device_put, opt_state["nu"], psh),
            "step": jax.device_put(opt_state["step"], osh["step"]),
        }

        start_step = 0
        ckpt = CK.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        if resume and ckpt_dir and CK.latest_step(ckpt_dir) is not None:
            start_step, state = CK.restore(
                ckpt_dir, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            params = jax.tree_util.tree_map(jax.device_put, params, psh)
            print(f"[train] resumed from step {start_step}")

        pipe = TokenPipeline(cfg, shape, seed=seed, start_step=start_step,
                             batch_override=batch, seq_override=seq)
        prefetch = Prefetcher(pipe)
        bsh = SH.to_named(SH.batch_specs(
            cfg, jax.eval_shape(lambda: pipe.batch_at(0)), mesh), mesh)

        jstep = jax.jit(step_fn, donate_argnums=(0, 1))
        det = StragglerDetector(n_hosts=1)
        losses = []
        t_start = time.time()
        for step in range(start_step, steps):
            b = shard_batch(next(prefetch), bsh)
            t0 = time.time()
            params, opt_state, metrics = jstep(params, opt_state, b)
            loss = float(metrics["loss"])
            det.record_step(0, time.time() - t0)
            losses.append(loss)
            if step % log_every == 0 or step == steps - 1:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}", flush=True)
            if ckpt and (step + 1) % ckpt_every == 0:
                ckpt.save(step + 1, {"params": params, "opt": opt_state})
        if ckpt:
            ckpt.save(steps, {"params": params, "opt": opt_state})
            ckpt.wait()
        prefetch.close()
        wall = time.time() - t_start
    return {"losses": losses, "final_loss": losses[-1] if losses else None,
            "wall_s": wall, "steps": steps - start_step,
            "stragglers": det.stragglers()}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-9b", choices=sorted(ARCHS))
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="full config (needs a pod); default is reduced")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--attn-impl", default="flash",
                    choices=["flash", "flash_jnp", "naive", "pallas"])
    args = ap.parse_args()
    res = train(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
                reduced=not args.full, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, resume=args.resume,
                attn_impl=args.attn_impl)
    print(f"[train] done: final_loss={res['final_loss']:.4f} "
          f"wall={res['wall_s']:.1f}s "
          f"({res['steps'] / res['wall_s']:.2f} steps/s)")


if __name__ == "__main__":
    main()
