"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so that
importing this module never touches jax device state — the dry-run must set
XLA_FLAGS before first jax init.

Axes:
  * ``data``  — batch / FSDP axis (16-way per pod)
  * ``model`` — tensor/expert-parallel axis (16-way, intra-pod ICI)
  * ``pod``   — multi-pod data-parallel axis (DCN); gradients all-reduce across it
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Generic helper for tests/examples (e.g. 1x1 CPU mesh)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def data_axes(mesh) -> tuple:
    """Axes over which the batch is sharded (pod joins data when present)."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def fsdp_axis(mesh) -> str:
    return "data"


def model_axis(mesh) -> str:
    return "model"
