"""``repro-top``: an ASCII fleet dashboard over the scheduler surface.

Renders, from O(1)/O(devices) reads only (``queue_stats()``, the device
table, an ``SLOMonitor.status()``), the view an operator keeps open
while a fleet runs:

  * the admission queue — depth, class count, per-class depths, hint
    skips, the gang at the queue front, per-shard balance and steal
    count on a sharded control plane;
  * one row per device — ``pod{p}/dev{d}`` label on sharded/multi-pod
    fleets, an occupancy bar (OBSERVED occupancy % from the profiler's
    residency timeline on traced fleets, HBM fraction otherwise),
    used/total GB, compute slots, resident count, DEAD marker;
  * per-class prediction-accuracy rows when a calibration store is
    attached — raw vs corrected runtime error, learned EWMA ratio,
    observed memory high-water;
  * the SLO strip — per-stream burn rates (incl. the probe-drift
    stream) with a healthy/VIOLATING flag and the worst
    observed-vs-roofline slowdown against the paper's 2.5% envelope.

``Top`` wraps the renderer in a refresh loop for a live terminal;
``python -m repro.launch.top --demo`` drives a small simulated workload
through it and prints the final frame (CI-safe: no TTY tricks, no
timing dependence).
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Any, Dict, List, Optional

from repro.core.scheduler.base import SLOTS

_GB = 1e9


def _bar(frac: float, width: int = 20) -> str:
    frac = min(max(frac, 0.0), 1.0)
    n = int(round(frac * width))
    return "[" + "#" * n + "." * (width - n) + "]"


def _devices_per_pod(sched: Any) -> Optional[int]:
    """Pod factoring for device labels: a sharded wrapper's uniform
    shard width, or a multi-pod gang topology's pod size."""
    dpp = getattr(sched, "_shard_devs", None)
    if dpp and len(getattr(sched, "shards", ())) > 1:
        return dpp
    topo = getattr(sched, "topo", None)
    if topo is not None and getattr(topo, "pods", 1) > 1:
        return topo.rows * topo.cols
    return None


def _queue_lines(stats: Dict[str, Any]) -> List[str]:
    per_class = stats.get("per_class") or {}
    classes = ", ".join(f"p{k}:{v}" for k, v in
                        sorted(per_class.items(), reverse=True)) or "-"
    lines = [f"queue   depth={stats.get('depth', 0)} "
             f"classes={stats.get('classes', 0)} [{classes}] "
             f"hint_skips={stats.get('hint_skips', 0)}"]
    gf = stats.get("gang_front")
    if gf:
        lines.append(f"        gang_front={gf}")
    if "per_shard" in stats:
        shard = " ".join(f"s{i}:{d}" for i, d in
                         enumerate(stats["per_shard"]))
        lines.append(f"shards  {shard}  steals={stats.get('steals', 0)}")
    return lines


def _device_lines(sched: Any, width: int = 20,
                  occupancy: Optional[Dict[int, Dict[str, Any]]] = None
                  ) -> List[str]:
    """One row per device. The bar shows OBSERVED occupancy % (the
    profiler's demand-weighted residency timeline) when a traced window
    supplies one — what the chip is doing, not just what admission
    reserved; HBM stays the numeric used/total readout. Untraced fleets
    keep the historical HBM-fraction bar."""
    dpp = _devices_per_pod(sched)
    lines = []
    for i, d in enumerate(sched.devices):
        label = f"pod{i // dpp}/dev{i % dpp}" if dpp else f"dev {i}"
        used = d.used_hbm / _GB
        total = d.total_hbm / _GB
        occ = occupancy.get(i) if occupancy else None
        if occ is not None:
            frac = occ["last"]
            pct = f" occ {frac * 100:3.0f}%"
        else:
            frac = d.used_hbm / d.total_hbm if d.total_hbm else 0.0
            pct = ""
        dead = "  DEAD" if not d.alive else ""
        lines.append(
            f"{label:<12}{_bar(frac, width)}{pct} {used:5.1f}/{total:4.1f}GB "
            f"slots {d.used_slots:2d}/{SLOTS} residents "
            f"{len(d.residents)}{dead}")
    return lines


def _calib_lines(store: Any, limit: int = 4) -> List[str]:
    """Per-class prediction-accuracy rows from an attached
    ``CalibrationStore``: raw vs corrected mean absolute runtime error,
    the learned EWMA ratio, observed memory high-water."""
    rows = store.rows(limit=limit)
    if not rows:
        return []
    lines = [f"calib   classes={len(rows)} shown, "
             f"corrections={store.corrections} "
             f"violations={store.violations}"]
    for r in rows:
        ratio = f"{r['ratio']:.2f}" if r["n"] else "  - "
        lines.append(
            f"        est {r['est_s']:6.3f}s x{ratio} n={r['n']:<4d} "
            f"mae raw {r['mae_raw_s']:.3f}s -> used {r['mae_used_s']:.3f}s "
            f"hw {r['hw_gb']:.1f}/{r['hbm_gb']:.1f}GB")
    return lines


def _slo_lines(status: Dict[str, Any]) -> List[str]:
    parts = []
    for stream in ("deadline", "ttft", "tpot", "slowdown", "drift"):
        s = status.get(stream)
        if not s or not s["n"]:
            continue
        flag = "ok" if s["healthy"] else "VIOLATING"
        parts.append(f"{stream} burn={s['burn']:.2f} {flag}")
    lines = [f"slo     {'  '.join(parts) or '(no samples)'}"]
    worst = status.get("worst_slowdown")
    if worst:
        lines.append(f"        worst_slowdown {worst['name']} "
                     f"x{worst['factor']:.3f}")
    return lines


def render(sched: Any, *, slo: Optional[Any] = None,
           stats: Optional[Dict[str, Any]] = None,
           title: str = "repro-top", bar_width: int = 20) -> str:
    """One dashboard frame as a string. ``stats`` lets a caller pass
    ``Cluster.stats()`` for the footer; ``slo`` is an ``SLOMonitor``.
    On a traced scheduler the device bars switch to observed occupancy %
    (profiler residency timeline); an attached calibration store adds
    per-class prediction-accuracy rows."""
    occupancy = None
    tracer = getattr(sched, "_trace", None)
    if tracer is not None:
        from repro.obs.profile import device_occupancy
        occupancy = device_occupancy(tracer.events())
    lines = [title, "=" * max(len(title), 8)]
    lines += _queue_lines(sched.queue_stats())
    lines += _device_lines(sched, bar_width, occupancy)
    store = getattr(sched, "_calib", None)
    if store is not None:
        lines += _calib_lines(store)
    if slo is not None:
        lines += _slo_lines(slo.status())
    if stats:
        lines.append(
            f"jobs    done={stats.get('completed', 0)} "
            f"crashed={stats.get('crashed', 0)} "
            f"shed={stats.get('shed', 0)} "
            f"preempted={stats.get('preemptions', 0)} "
            f"makespan={stats.get('makespan_s', 0.0):.2f}s")
    return "\n".join(lines)


class Top:
    """Minimal live loop: clear screen, render, sleep, repeat."""

    def __init__(self, sched: Any, *, slo: Optional[Any] = None,
                 stats_fn: Optional[Any] = None,
                 interval_s: float = 1.0, out=sys.stdout):
        self.sched = sched
        self.slo = slo
        self.stats_fn = stats_fn
        self.interval_s = interval_s
        self.out = out

    def frame(self) -> str:
        stats = self.stats_fn() if self.stats_fn is not None else None
        return render(self.sched, slo=self.slo, stats=stats)

    def run(self, frames: Optional[int] = None) -> None:
        n = 0
        try:
            while frames is None or n < frames:
                self.out.write("\x1b[2J\x1b[H" + self.frame() + "\n")
                self.out.flush()
                n += 1
                if frames is None or n < frames:
                    time.sleep(self.interval_s)
        except KeyboardInterrupt:
            pass


def _demo() -> str:
    """Drive a small simulated overload through the dashboard (CI-safe:
    single final frame, no sleeps, deterministic)."""
    from repro.core.cluster import Cluster
    from repro.core.scheduler.preempt import PreemptiveAlg3Scheduler
    from repro.core.workloads import overload_mix
    from repro.obs.slo import SLOMonitor

    c = Cluster(PreemptiveAlg3Scheduler(4), workers=8, backend="sim",
                shed_late=True, trace=True, calibrate=True)
    # drift stream fed straight from the calibration store's observations
    slo = SLOMonitor.for_calibration(c.calibration, window=32)
    rows = overload_mix(11, n_urgent=8)
    for row in rows:
        c.run_until(row["t"])
        c.submit(row["job"], priority=row["priority"],
                 deadline_s=row["deadline_s"])
    c.run_until(rows[-1]["t"] + 1.0)   # mid-flight frame: queues populated
    mid = render(c.sched, slo=slo, stats=c.stats())
    c._sim.drain(1e7)
    for h in c.handles:
        if h.job.deadline_t is not None:
            slo.note_deadline(h.status.name == "DONE"
                              and h.job.finish_t <= h.job.deadline_t)
    for name, factor in c._sim.result().slowdowns.items():
        slo.note_slowdown_factor(name, factor)
    final = render(c.sched, slo=slo, stats=c.stats())
    return mid + "\n\n--- after drain ---\n\n" + final


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(description="ASCII scheduler dashboard")
    p.add_argument("--demo", action="store_true",
                   help="render a simulated workload and exit (CI-safe)")
    args = p.parse_args(argv)
    if args.demo:
        print(_demo())
        return 0
    p.error("repro-top needs --demo (live attach requires an embedding "
            "process: build a Top(sched, ...) around your cluster)")
    return 2


if __name__ == "__main__":
    raise SystemExit(main())
