"""End-to-end serving driver: batched prefill + decode under the
compiler-guided scheduler — every request batch is a GPU task whose resource
vector comes from the compiled prefill/decode executables (repro.core.probe),
streamed through the open-arrival ``Cluster`` front-end: each request is
``cluster.submit``-ed with a per-request deadline (EDF admission within its
priority class), blocked batches hold no thread (they park in the
scheduler's admission queue), and completions wake the next admission. The
execution pool is sized to the device count, so thousands of queued decode
tasks need only a handful of threads.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --requests 16 --batch 4 --prompt-len 64 --gen-len 32 --deadline-s 5
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_arch
from repro.core.cluster import Cluster, JobStatus
from repro.core.executor import ExecJob
from repro.core.probe import probe_fn
from repro.core.scheduler import MGBAlg3Scheduler, PreemptiveAlg3Scheduler
from repro.core.task import Job, Task, UnitTask
from repro.models.model import init_params
from repro.serve.decode import greedy_generate, make_prefill_step


def serve(arch: str, *, requests: int = 16, batch: int = 4,
          prompt_len: int = 64, gen_len: int = 32, seed: int = 0,
          num_devices: int = 2, workers: int = 0,
          deadline_s: float = 5.0, shed_late: bool = False,
          preempt: bool = False) -> dict:
    cfg = get_arch(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    prefill = jax.jit(make_prefill_step(cfg, attn_impl="flash_jnp"))
    # preempt turns the deadline into the ENFORCEMENT half shedding cannot
    # give: an arriving earlier-deadline request may evict a resident one
    # (same priority class, EDF outranking) instead of waiting behind it
    sched = (PreemptiveAlg3Scheduler(num_devices) if preempt
             else MGBAlg3Scheduler(num_devices))

    rng = np.random.default_rng(seed)
    n_batches = (requests + batch - 1) // batch
    # probe ONE representative batch (all batches share shapes, so they share
    # the compiled executable and the resource vector)
    first_prompts = jnp.asarray(rng.integers(
        0, cfg.vocab, (batch, prompt_len), dtype=np.int32))
    probe_batch = {"tokens": first_prompts}
    if cfg.embedding_frontend_stub:
        probe_batch["embeds"] = jnp.asarray(rng.standard_normal(
            (batch, prompt_len, cfg.d_model), dtype=np.float32))
    vec = probe_fn(prefill, params, probe_batch)

    # shed_late turns the deadline from an EDF ordering hint into (soft)
    # enforcement: a request still parked when its deadline passes is failed
    # with JobStatus.SHED at the next drain instead of served late
    cluster = Cluster(sched, workers=workers or num_devices,
                      shed_late=shed_late, preempt=preempt or None)
    handles = []
    t0 = time.time()
    # open arrival: each request batch is submitted as it "comes in", with
    # its own deadline — admission is EDF within the priority class, so
    # earlier-deadline requests claim freed capacity first
    for i in range(n_batches):
        b = dict(probe_batch) if i == 0 else {
            "tokens": jnp.asarray(rng.integers(
                0, cfg.vocab, (batch, prompt_len), dtype=np.int32))}
        if cfg.embedding_frontend_stub and "embeds" not in b:
            b["embeds"] = jnp.asarray(rng.standard_normal(
                (batch, prompt_len, cfg.d_model), dtype=np.float32))

        def runner(device, b=b):
            logits, cache = prefill(params, b)
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out, _ = greedy_generate(cfg, params, cache, first, prompt_len,
                                     gen_len - 1)
            jax.block_until_ready(out)

        task = Task(units=[UnitTask(fn=None, memobjs=frozenset({f"req{i}"}),
                                    resources=vec, name=f"req{i}")],
                    name=f"req{i}")
        handles.append(cluster.submit(
            ExecJob(job=Job(tasks=[task], name=f"req{i}"), runners=[runner]),
            deadline_s=deadline_s))

    cluster.drain()
    stats = cluster.stats()
    cluster.shutdown()
    wall = time.time() - t0
    toks = stats["completed"] * batch * gen_len
    lat = [r.t_end - r.t_start
           for h in handles for r in h.records if not r.crashed]
    met = [h for h in handles if h.status is JobStatus.DONE
           and h.records and h.records[-1].t_end
           <= h.job.deadline_t]
    # shed requests (deadline passed while parked) are reported SEPARATELY
    # from deadlines_met: they consumed no device time at all, vs completed
    # requests that merely finished late
    shed = [h for h in handles if h.status is JobStatus.SHED]
    return {"requests": requests, "batches": n_batches,
            "tokens_generated": toks, "wall_s": wall,
            "tokens_per_s": toks / wall,
            "mean_batch_latency_s": float(np.mean(lat)) if lat else 0.0,
            "completed": stats["completed"], "crashed": stats["crashed"],
            "deadlines_met": len(met),
            "deadline_met_rate": len(met) / max(n_batches, 1),
            "shed": len(shed),
            "preemptions": stats["preemptions"],
            "migrations": stats["migrations"],
            "sched_attempts": stats["sched_attempts"],
            "placements": sched.placements}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--num-devices", type=int, default=2)
    ap.add_argument("--workers", type=int, default=0,
                    help="execution-pool size (0 = one per device)")
    ap.add_argument("--deadline-s", type=float, default=5.0,
                    help="per-request admission deadline (EDF ordering)")
    ap.add_argument("--shed-late", action="store_true",
                    help="fail requests still parked past their deadline "
                         "(JobStatus.SHED) instead of serving them late")
    ap.add_argument("--preempt", action="store_true",
                    help="preemptive EDF: an arriving earlier-deadline "
                         "request may evict a resident one (checkpoint-"
                         "based, work-conserving) instead of queueing "
                         "behind it")
    args = ap.parse_args()
    res = serve(args.arch, requests=args.requests, batch=args.batch,
                prompt_len=args.prompt_len, gen_len=args.gen_len,
                num_devices=args.num_devices, workers=args.workers,
                deadline_s=args.deadline_s, shed_late=args.shed_late,
                preempt=args.preempt)
    print(f"[serve] {res['tokens_generated']} tokens in {res['wall_s']:.1f}s "
          f"({res['tokens_per_s']:.1f} tok/s, "
          f"batch latency {res['mean_batch_latency_s'] * 1e3:.0f} ms, "
          f"{res['deadlines_met']}/{res['batches']} deadlines met "
          f"({100 * res['deadline_met_rate']:.0f}%), "
          f"{res['shed']} shed, {res['preemptions']} preemption(s), "
          f"{res['migrations']} migration(s), "
          f"{res['sched_attempts']} admission attempts)")


if __name__ == "__main__":
    main()
