"""End-to-end serving driver: batched prefill + decode under the
compiler-guided scheduler — every request batch is a GPU task whose resource
vector comes from the compiled prefill/decode executables (repro.core.probe).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --requests 16 --batch 4 --prompt-len 64 --gen-len 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_arch
from repro.core.probe import probe_fn
from repro.core.scheduler import MGBAlg3Scheduler
from repro.core.task import Task, UnitTask
from repro.models import decode as D
from repro.models.model import init_params
from repro.serve.decode import greedy_generate, make_prefill_step


def serve(arch: str, *, requests: int = 16, batch: int = 4,
          prompt_len: int = 64, gen_len: int = 32, seed: int = 0,
          num_devices: int = 2) -> dict:
    cfg = get_arch(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    prefill = jax.jit(make_prefill_step(cfg, attn_impl="flash_jnp"))
    sched = MGBAlg3Scheduler(num_devices)

    rng = np.random.default_rng(seed)
    n_batches = (requests + batch - 1) // batch
    lat, toks = [], 0
    t0 = time.time()
    for i in range(n_batches):
        prompts = jnp.asarray(rng.integers(
            0, cfg.vocab, (batch, prompt_len), dtype=np.int32))
        b = {"tokens": prompts}
        if cfg.embedding_frontend_stub:
            b["embeds"] = jnp.asarray(rng.standard_normal(
                (batch, prompt_len, cfg.d_model), dtype=np.float32))
        # probe the batch as a GPU task and ask the scheduler for a device
        vec = probe_fn(prefill, params, b)
        task = Task(units=[UnitTask(fn=None, memobjs=frozenset({f"req{i}"}),
                                    resources=vec, name=f"req{i}")],
                    name=f"req{i}")
        while sched.task_begin(task) is None:
            time.sleep(0.001)
        t_req = time.time()
        try:
            logits, cache = prefill(params, b)
            first = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            out, _ = greedy_generate(cfg, params, cache, first, prompt_len,
                                     gen_len - 1)
            jax.block_until_ready(out)
        finally:
            sched.task_end(task)
        lat.append(time.time() - t_req)
        toks += batch * gen_len
    wall = time.time() - t0
    return {"requests": requests, "batches": n_batches,
            "tokens_generated": toks, "wall_s": wall,
            "tokens_per_s": toks / wall,
            "mean_batch_latency_s": float(np.mean(lat)),
            "placements": sched.placements}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    args = ap.parse_args()
    res = serve(args.arch, requests=args.requests, batch=args.batch,
                prompt_len=args.prompt_len, gen_len=args.gen_len)
    print(f"[serve] {res['tokens_generated']} tokens in {res['wall_s']:.1f}s "
          f"({res['tokens_per_s']:.1f} tok/s, "
          f"batch latency {res['mean_batch_latency_s'] * 1e3:.0f} ms)")


if __name__ == "__main__":
    main()
