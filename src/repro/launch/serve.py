"""End-to-end serving driver: prefill + decode under the compiler-guided
scheduler, with two serving disciplines over the same open-arrival
``Cluster`` front-end:

* **static** (default): every request batch is ONE GPU task whose resource
  vector comes from the compiled prefill/decode executables
  (repro.core.probe). Each batch is ``cluster.submit``-ed with a
  per-request deadline (EDF admission within its priority class), blocked
  batches hold no thread (they park in the scheduler's admission queue),
  and completions wake the next admission. Rows in the last batch beyond
  ``requests`` are shape padding — computed, but never counted as served
  tokens.
* **continuous** (``--continuous``): requests stream individually through
  ``repro.serve.engine.ServeEngine`` — per-device decode loops whose batch
  composition changes between steps; prefills are short high-priority
  tasks, each decode-slot join is a probed KV-delta admitted through the
  scheduler (memory-safe batch growth).

Both report per-request TTFT (arrival → first token) and TPOT (mean
inter-token time over the decode tail).

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x7b \
        --requests 16 --batch 4 --prompt-len 64 --gen-len 32 --deadline-s 5
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ARCHS, get_arch
from repro.core.cluster import Cluster, JobStatus
from repro.core.executor import ExecJob
from repro.core.probe import probe_fn
from repro.core.scheduler import MGBAlg3Scheduler, PreemptiveAlg3Scheduler
from repro.core.task import Job, Task, UnitTask
from repro.models.model import init_params
from repro.serve.decode import greedy_generate, make_prefill_step


def _pct(xs, p):
    if not xs:
        return 0.0
    xs = sorted(xs)
    i = min(int(p * (len(xs) - 1) + 0.5), len(xs) - 1)
    return xs[i]


def serve(arch: str, *, requests: int = 16, batch: int = 4,
          prompt_len: int = 64, gen_len: int = 32, seed: int = 0,
          num_devices: int = 2, workers: int = 0,
          deadline_s: float = 5.0, shed_late: bool = False,
          preempt: bool = False, trace_path: str = None) -> dict:
    cfg = get_arch(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    prefill = jax.jit(make_prefill_step(cfg, attn_impl="flash_jnp"))
    # preempt turns the deadline into the ENFORCEMENT half shedding cannot
    # give: an arriving earlier-deadline request may evict a resident one
    # (same priority class, EDF outranking) instead of waiting behind it
    sched = (PreemptiveAlg3Scheduler(num_devices) if preempt
             else MGBAlg3Scheduler(num_devices))

    rng = np.random.default_rng(seed)
    n_batches = (requests + batch - 1) // batch
    # real (non-padding) rows per batch: the final batch is shape-padded to
    # ``batch`` so every batch shares one compiled executable, but only
    # ``requests`` rows exist — padded rows must not count as served tokens
    rows = [min(batch, requests - i * batch) for i in range(n_batches)]
    # probe ONE representative batch (all batches share shapes, so they share
    # the compiled executable and the resource vector)
    first_prompts = jnp.asarray(rng.integers(
        0, cfg.vocab, (batch, prompt_len), dtype=np.int32))
    probe_batch = {"tokens": first_prompts}
    if cfg.embedding_frontend_stub:
        probe_batch["embeds"] = jnp.asarray(rng.standard_normal(
            (batch, prompt_len, cfg.d_model), dtype=np.float32))
    vec = probe_fn(prefill, params, probe_batch)

    # shed_late turns the deadline from an EDF ordering hint into (soft)
    # enforcement: a request still parked when its deadline passes is failed
    # with JobStatus.SHED at the next drain instead of served late
    cluster = Cluster(sched, workers=workers or num_devices,
                      shed_late=shed_late, preempt=preempt or None,
                      trace=bool(trace_path))
    handles = []
    # per-batch wall-clock marks filled by the runner: (submit, first-token,
    # last-token) — the per-request TTFT/TPOT instrumentation
    marks = [[0.0, -1.0, -1.0] for _ in range(n_batches)]
    t0 = time.time()
    # open arrival: each request batch is submitted as it "comes in", with
    # its own deadline — admission is EDF within the priority class, so
    # earlier-deadline requests claim freed capacity first
    for i in range(n_batches):
        b = dict(probe_batch) if i == 0 else {
            "tokens": jnp.asarray(rng.integers(
                0, cfg.vocab, (batch, prompt_len), dtype=np.int32))}
        if cfg.embedding_frontend_stub and "embeds" not in b:
            b["embeds"] = jnp.asarray(rng.standard_normal(
                (batch, prompt_len, cfg.d_model), dtype=np.float32))

        def runner(device, b=b, i=i):
            logits, cache = prefill(params, b)
            first = jax.block_until_ready(
                jnp.argmax(logits, axis=-1).astype(jnp.int32))
            marks[i][1] = time.time()
            out, _ = greedy_generate(cfg, params, cache, first, prompt_len,
                                     gen_len - 1)
            jax.block_until_ready(out)
            marks[i][2] = time.time()

        marks[i][0] = time.time()
        task = Task(units=[UnitTask(fn=None, memobjs=frozenset({f"req{i}"}),
                                    resources=vec, name=f"req{i}")],
                    name=f"req{i}")
        handles.append(cluster.submit(
            ExecJob(job=Job(tasks=[task], name=f"req{i}"), runners=[runner]),
            deadline_s=deadline_s))

    cluster.drain()
    stats = cluster.stats()
    cluster.shutdown()
    if trace_path:
        cluster.export_trace(trace_path)
    wall = time.time() - t0
    done = [i for i, h in enumerate(handles) if h.status is JobStatus.DONE]
    # only real rows of completed batches count — a padded row generated
    # tokens nobody asked for, and a crashed/shed batch served none
    toks = sum(rows[i] for i in done) * gen_len
    # never-started records (crashed pre-launch) carry the NEVER_STARTED
    # sentinel, not a fake timestamp — they must not enter latency stats
    lat = [r.t_end - r.t_start
           for h in handles for r in h.records
           if not r.crashed and r.started]
    ttfts = [marks[i][1] - marks[i][0]
             for i in done for _ in range(rows[i]) if marks[i][1] >= 0]
    tpots = ([(marks[i][2] - marks[i][1]) / (gen_len - 1)
              for i in done for _ in range(rows[i]) if marks[i][2] >= 0]
             if gen_len > 1 else [])
    met = [h for h in handles if h.status is JobStatus.DONE
           and h.records and h.records[-1].t_end
           <= h.job.deadline_t]
    # shed requests (deadline passed while parked) are reported SEPARATELY
    # from deadlines_met: they consumed no device time at all, vs completed
    # requests that merely finished late
    shed = [h for h in handles if h.status is JobStatus.SHED]
    return {"requests": requests, "batches": n_batches,
            "tokens_generated": toks, "wall_s": wall,
            "tokens_per_s": toks / wall,
            "mean_batch_latency_s": float(np.mean(lat)) if lat else 0.0,
            "p50_ttft_s": _pct(ttfts, 0.50), "p99_ttft_s": _pct(ttfts, 0.99),
            "p50_tpot_s": _pct(tpots, 0.50), "p99_tpot_s": _pct(tpots, 0.99),
            "completed": stats["completed"], "crashed": stats["crashed"],
            "deadlines_met": len(met),
            "deadline_met_rate": len(met) / max(n_batches, 1),
            "shed": len(shed),
            "preemptions": stats["preemptions"],
            "migrations": stats["migrations"],
            "sched_attempts": stats["sched_attempts"],
            "placements": sched.placements}


def serve_continuous(arch: str, *, requests: int = 16, batch: int = 4,
                     prompt_len: int = 64, gen_len: int = 32, seed: int = 0,
                     num_devices: int = 2, workers: int = 0,
                     ttft_slo_s: float = 5.0, tpot_slo_s: float = 1.0,
                     shed_late: bool = False,
                     trace_path: str = None) -> dict:
    """Continuous-batching counterpart: per-request streaming through
    ServeEngine; ``batch`` becomes each decode loop's max rows."""
    from repro.serve.engine import SLO, JaxModel, ServeEngine

    cfg = get_arch(arch).reduced()
    params = init_params(cfg, jax.random.PRNGKey(seed))
    model = JaxModel(cfg, params, max_batch=batch,
                     max_seq=prompt_len + gen_len, attn_impl="flash_jnp")
    cluster = Cluster(MGBAlg3Scheduler(num_devices),
                      workers=workers or num_devices, shed_late=shed_late,
                      trace=bool(trace_path))
    eng = ServeEngine(cluster, model, max_batch=batch,
                      slo=SLO(ttft_s=ttft_slo_s, tpot_s=tpot_slo_s))
    rng = np.random.default_rng(seed)
    t0 = time.time()
    for _ in range(requests):
        eng.submit(prompt=jnp.asarray(rng.integers(
            0, cfg.vocab, (1, prompt_len), dtype=np.int32)),
            gen_len=gen_len)
    eng.drain()
    wall = time.time() - t0
    m = eng.metrics()
    eng.shutdown()
    cluster.shutdown()
    if trace_path:
        cluster.export_trace(trace_path)
    m.update(wall_s=wall, tokens_per_s=m["tokens"] / wall,
             sched_attempts=cluster.stats()["sched_attempts"])
    return m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--num-devices", type=int, default=2)
    ap.add_argument("--workers", type=int, default=0,
                    help="execution-pool size (0 = one per device)")
    ap.add_argument("--deadline-s", type=float, default=5.0,
                    help="per-request admission deadline (EDF ordering); "
                         "continuous mode reads it as the TTFT SLO")
    ap.add_argument("--tpot-slo-s", type=float, default=1.0,
                    help="continuous mode: time-per-output-token SLO")
    ap.add_argument("--shed-late", action="store_true",
                    help="fail requests still parked past their deadline "
                         "(JobStatus.SHED) instead of serving them late")
    ap.add_argument("--preempt", action="store_true",
                    help="preemptive EDF: an arriving earlier-deadline "
                         "request may evict a resident one (checkpoint-"
                         "based, work-conserving) instead of queueing "
                         "behind it (static mode only)")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="record the scheduler's event stream and write a "
                         "Chrome/Perfetto trace-event JSON here at the end "
                         "(load in chrome://tracing or ui.perfetto.dev)")
    ap.add_argument("--continuous", action="store_true",
                    help="continuous batching via repro.serve.engine: "
                         "requests stream individually, decode batches "
                         "grow/shrink per step under scheduler admission")
    args = ap.parse_args()
    if args.continuous:
        res = serve_continuous(
            args.arch, requests=args.requests, batch=args.batch,
            prompt_len=args.prompt_len, gen_len=args.gen_len,
            num_devices=args.num_devices, workers=args.workers,
            ttft_slo_s=args.deadline_s, tpot_slo_s=args.tpot_slo_s,
            shed_late=args.shed_late, trace_path=args.trace)
        print(f"[serve --continuous] {res['done']}/{res['requests']} done, "
              f"{res['tokens']} tokens in {res['wall_s']:.1f}s "
              f"({res['tokens_per_s']:.1f} tok/s, "
              f"TTFT p50/p99 {res['p50_ttft_s'] * 1e3:.0f}/"
              f"{res['p99_ttft_s'] * 1e3:.0f} ms, "
              f"TPOT p50/p99 {res['p50_tpot_s'] * 1e3:.0f}/"
              f"{res['p99_tpot_s'] * 1e3:.0f} ms, "
              f"goodput {res['goodput_rps']:.2f} req/s, "
              f"{res['shed']} shed, {res['violations']} memory violations)")
        return
    res = serve(args.arch, requests=args.requests, batch=args.batch,
                prompt_len=args.prompt_len, gen_len=args.gen_len,
                num_devices=args.num_devices, workers=args.workers,
                deadline_s=args.deadline_s, shed_late=args.shed_late,
                preempt=args.preempt, trace_path=args.trace)
    print(f"[serve] {res['tokens_generated']} tokens in {res['wall_s']:.1f}s "
          f"({res['tokens_per_s']:.1f} tok/s, "
          f"batch latency {res['mean_batch_latency_s'] * 1e3:.0f} ms, "
          f"TTFT p99 {res['p99_ttft_s'] * 1e3:.0f} ms, "
          f"TPOT p99 {res['p99_tpot_s'] * 1e3:.0f} ms, "
          f"{res['deadlines_met']}/{res['batches']} deadlines met "
          f"({100 * res['deadline_met_rate']:.0f}%), "
          f"{res['shed']} shed, {res['preemptions']} preemption(s), "
          f"{res['migrations']} migration(s), "
          f"{res['sched_attempts']} admission attempts)")


if __name__ == "__main__":
    main()
