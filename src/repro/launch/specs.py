"""ShapeDtypeStruct stand-ins for every model input — shardable, weak-type
correct, zero allocation. Shared by the dry-run, the probe, and benchmarks."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig

SDS = jax.ShapeDtypeStruct


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, SDS]:
    """Inputs for the step function the given shape lowers.

    train/prefill: the full-sequence batch; decode: one token per sequence.
    [vlm]/[audio] archs get precomputed frontend embeddings per spec.
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind == "decode":
        return {"tokens": SDS((b,), jnp.int32),
                "pos": SDS((), jnp.int32)}
    specs = {"tokens": SDS((b, s), jnp.int32)}
    if shape.kind == "train":
        specs["labels"] = SDS((b, s), jnp.int32)
    if cfg.embedding_frontend_stub:
        specs["embeds"] = SDS((b, s, cfg.d_model), jnp.bfloat16)
    return specs
