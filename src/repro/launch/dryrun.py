import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and record memory/cost/roofline — deliverable (e)/(g).

The two lines above MUST run before any jax import: jax locks the device count
at first init, and the dry-run needs 512 placeholder host devices to build the
(pod=2, data=16, model=16) mesh. This flag is set ONLY here (smoke tests and
benchmarks see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
        --shape train_4k [--multi-pod] [--out results.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ShapeConfig
from repro.configs.registry import ARCHS, cells, get_arch, get_shape
from repro.dist import sharding as SH
from repro.launch import roofline as RL
from repro.launch.mesh import data_axes, make_production_mesh
from repro.launch.specs import input_specs
from repro.optim.adamw import AdamWConfig
from repro.serve.decode import abstract_cache, make_prefill_step, make_serve_step
from repro.train.train_step import abstract_train_state, make_train_step


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch_name: str, shape_name: str, *, multi_pod: bool = False,
               attn_impl: str = "flash", opt_overrides: dict = None,
               return_lowered: bool = False):
    """Lower + compile one (arch x shape) cell. Returns a result dict."""
    cfg = get_arch(arch_name)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    t0 = time.time()
    ctx = SH.activation_mesh(mesh)
    ctx.__enter__()

    params_sds, opt_sds = None, None
    if shape.kind == "train":
        opt_cfg = AdamWConfig(moment_dtype=cfg.optimizer_moment_dtype,
                              **(opt_overrides or {}))
        # each microbatch must still shard over the full data axis
        dax = 1
        for ax in data_axes(mesh):
            dax *= mesh.shape[ax]
        mb = max(1, min(cfg.num_microbatches, shape.global_batch // dax))
        step = make_train_step(cfg, opt_cfg, attn_impl=attn_impl,
                               num_microbatches=mb)
        params_sds, opt_sds = abstract_train_state(cfg, opt_cfg)
        psh = _named(mesh, SH.param_specs(cfg, params_sds, mesh))
        osh = {"mu": psh, "nu": psh,
               "step": NamedSharding(mesh, P())}
        batch_sds = input_specs(cfg, shape)
        bsh = _named(mesh, SH.batch_specs(cfg, batch_sds, mesh))
        jitted = jax.jit(step, in_shardings=(psh, osh, bsh),
                         out_shardings=(psh, osh, None),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        prefill = make_prefill_step(cfg, attn_impl=attn_impl)
        params_sds, _ = abstract_train_state(cfg, AdamWConfig())
        psh = _named(mesh, SH.param_specs(cfg, params_sds, mesh))
        batch_sds = input_specs(cfg, shape)
        bsh = _named(mesh, SH.batch_specs(cfg, batch_sds, mesh))
        # pin the OUTPUT cache sharding: left to the compiler it comes out
        # model-replicated (llama3 prefill_32k: +33.8 GB/device)
        out_sds = jax.eval_shape(prefill, params_sds, batch_sds)
        csh = _named(mesh, SH.cache_specs(cfg, out_sds[1], mesh))
        lowered = jax.jit(prefill, in_shardings=(psh, bsh),
                          out_shardings=(None, csh)).lower(
            params_sds, batch_sds)
    else:  # decode
        serve = make_serve_step(cfg)
        params_sds, _ = abstract_train_state(cfg, AdamWConfig())
        psh = _named(mesh, SH.param_specs(cfg, params_sds, mesh))
        cache_sds = abstract_cache(cfg, shape.global_batch, shape.seq_len)
        ctx_par = shape.global_batch < mesh.shape["data"]
        csh = _named(mesh, SH.cache_specs(cfg, cache_sds, mesh,
                                          context_parallel=ctx_par))
        io = input_specs(cfg, shape)
        tok_sh = _named(mesh, SH.batch_specs(
            cfg, {"tokens": io["tokens"]}, mesh))["tokens"]
        pos_sh = NamedSharding(mesh, P())
        lowered = jax.jit(serve, in_shardings=(psh, csh, tok_sh, pos_sh),
                          out_shardings=(None, csh),
                          donate_argnums=(1,)).lower(
            params_sds, cache_sds, io["tokens"], io["pos"])

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    ctx.__exit__(None, None, None)
    rl = RL.analyze(compiled, cfg, shape, chips)
    mem = compiled.memory_analysis()
    result = {
        "arch": arch_name, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "chips": chips,
        "status": "ok",
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", 0),
            "peak_per_device": rl.peak_mem_per_device,
            "fits_16GB": rl.peak_mem_per_device <= 16e9,
        },
        "roofline": rl.to_dict(),
        "params_total": cfg.param_count(),
        "params_active": cfg.active_param_count(),
    }
    if return_lowered:
        return result, lowered, compiled
    return result


def run_all(multi_pod: bool, out_dir: str, only_arch=None):
    os.makedirs(out_dir, exist_ok=True)
    summary = []
    for cfg, shape, skip in cells():
        if only_arch and cfg.name != only_arch:
            continue
        tag = f"{cfg.name}__{shape.name}__{'2x16x16' if multi_pod else '16x16'}"
        path = os.path.join(out_dir, tag + ".json")
        if skip:
            res = {"arch": cfg.name, "shape": shape.name, "status": "SKIP",
                   "reason": "long_500k needs sub-quadratic attention "
                             "(DESIGN.md long_500k applicability)"}
        elif os.path.exists(path):
            with open(path) as f:
                res = json.load(f)
            summary.append(res)
            print(f"[cached] {tag}")
            continue
        else:
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                res = lower_cell(cfg.name, shape.name, multi_pod=multi_pod)
                r = res["roofline"]
                print(f"  ok: compute={r['compute_s']:.3f}s "
                      f"memory={r['memory_s']:.3f}s "
                      f"collective={r['collective_s']:.3f}s "
                      f"dominant={r['dominant']} "
                      f"peak_mem={res['memory']['peak_per_device']/1e9:.2f}GB",
                      flush=True)
            except Exception as e:  # a failure here is a bug in our system
                res = {"arch": cfg.name, "shape": shape.name, "status": "FAIL",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                print(f"  FAIL: {e}", flush=True)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        summary.append(res)
    n_ok = sum(1 for r in summary if r.get("status") == "ok")
    n_skip = sum(1 for r in summary if r.get("status") == "SKIP")
    n_fail = sum(1 for r in summary if r.get("status") == "FAIL")
    print(f"\n=== dry-run: {n_ok} ok / {n_skip} skip / {n_fail} FAIL ===")
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=sorted(ARCHS))
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="benchmarks/results/dryrun")
    args = ap.parse_args()
    if args.all or (args.arch is None):
        run_all(args.multi_pod, args.out_dir, only_arch=args.arch)
        return
    res = lower_cell(args.arch, args.shape, multi_pod=args.multi_pod)
    print(json.dumps(res, indent=1))


if __name__ == "__main__":
    main()
