"""Analytic FLOPs model per (arch x shape).

XLA's ``cost_analysis()`` counts while-loop bodies ONCE, so its FLOPs number is
useless for scan-over-layers programs (observed: 18x undercount on gemma2). The
roofline compute term therefore uses this analytic model — every matmul in the
model code is accounted, including attention's quadratic term, MoE capacity
padding + dispatch einsums, SSD chunk matmuls, and the remat recompute factor.
HLO raw flops are still recorded for reference.
"""
from __future__ import annotations

from repro.configs.base import ArchConfig, ShapeConfig, ATTN, MAMBA1, MAMBA2, \
    SHARED_ATTN
from repro.models.moe import capacity

MOE_GROUP = 512


def _attn_layer_flops(cfg: ArchConfig, s_q: int, kv_len: float) -> float:
    """Forward FLOPs for one attention block over s_q query tokens, each
    attending to ``kv_len`` keys on average."""
    d = cfg.d_model
    hd = cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    proj = 2 * s_q * d * (hq + 2 * hkv) * hd + 2 * s_q * hq * hd * d
    attn = 2 * 2 * s_q * kv_len * hq * hd  # QK^T + PV
    return proj + attn


def _mlp_layer_flops(cfg: ArchConfig, tokens: int) -> float:
    mults = 3 if cfg.mlp_act.endswith("gated") else 2
    return 2.0 * tokens * cfg.d_model * cfg.d_ff * mults


def _moe_layer_flops(cfg: ArchConfig, tokens: int) -> float:
    m = cfg.moe
    d, f = cfg.d_model, cfg.d_ff
    mults = 3 if cfg.mlp_act.endswith("gated") else 2
    cap = capacity(m, MOE_GROUP)
    eff_tokens_per_group = m.num_experts * cap       # incl. capacity padding
    groups = tokens / MOE_GROUP
    expert = 2.0 * groups * eff_tokens_per_group * d * f * mults
    router = 2.0 * tokens * d * m.num_experts
    # dispatch + combine einsums: 2 * g * s * E * C * d each
    dispatch = 2 * 2.0 * groups * MOE_GROUP * m.num_experts * cap * d
    return expert + router + dispatch


def _mamba1_layer_flops(cfg: ArchConfig, tokens: int) -> float:
    d = cfg.d_model
    e = cfg.ssm.expand * d
    n = cfg.ssm.state_dim
    r = max(1, d // 16)
    per_tok = (2 * d * 2 * e + 2 * e * cfg.ssm.conv_width
               + 2 * e * (r + 2 * n) + 2 * r * e
               + 9 * e * n          # scan elementwise (assoc-scan ~3 passes)
               + 2 * e * n          # y = h . C
               + 2 * e * d)
    return float(tokens) * per_tok


def _mamba2_layer_flops(cfg: ArchConfig, tokens: int) -> float:
    d = cfg.d_model
    e = cfg.ssm.expand * d
    n = cfg.ssm.state_dim
    nh = e // cfg.ssm.headdim
    lc = cfg.ssm.chunk
    per_tok = (2 * d * (2 * e + 2 * n + nh)
               + 2 * (e + 2 * n) * cfg.ssm.conv_width
               + 2 * lc * n            # C B^T within chunk
               + 2 * lc * e            # att @ dtx
               + 2 * 2 * e * n         # chunk states + y_inter
               + 2 * e * d)
    return float(tokens) * per_tok


def forward_flops(cfg: ArchConfig, batch: int, seq: int, *,
                  kv_len: float = None) -> float:
    """One forward pass over batch x seq tokens (kv_len: avg keys/query)."""
    tokens = batch * seq
    total = 0.0
    for i, kind in enumerate(cfg.layer_kinds()):
        if kind in (ATTN, SHARED_ATTN):
            if kv_len is not None:
                kl = kv_len
            else:
                w = cfg.sliding_window
                local = bool(w) and (not cfg.local_global_alternate or i % 2 == 0)
                kl = min(seq / 2.0, w) if local else seq / 2.0  # causal avg
            total += batch * _attn_layer_flops(cfg, seq, kl)
            if kind == ATTN and cfg.moe is not None:
                total += _moe_layer_flops(cfg, tokens)
            else:
                total += _mlp_layer_flops(cfg, tokens)
        elif kind == MAMBA1:
            total += _mamba1_layer_flops(cfg, tokens)
        elif kind == MAMBA2:
            total += _mamba2_layer_flops(cfg, tokens)
    total += 2.0 * tokens * cfg.d_model * cfg.vocab  # lm head
    return total


REMAT_FACTOR = {"nothing": 3.0, "dots": 3.3, "full": 4.0}


def step_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Analytic FLOPs for the step the shape lowers (global, all chips)."""
    if shape.kind == "train":
        fwd = forward_flops(cfg, shape.global_batch, shape.seq_len)
        return fwd * REMAT_FACTOR.get(cfg.remat_policy, 4.0)
    if shape.kind == "prefill":
        return forward_flops(cfg, shape.global_batch, shape.seq_len)
    # decode: one token; attention reads the whole cache (ring: window)
    kv = cache_kv_len(cfg, shape.seq_len)
    return forward_flops(cfg, shape.global_batch, 1, kv_len=kv)


def cache_kv_len(cfg: ArchConfig, seq_len: int) -> float:
    if cfg.sliding_window and not cfg.local_global_alternate:
        return float(min(seq_len, cfg.sliding_window))
    return float(seq_len)
