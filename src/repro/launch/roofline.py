"""Three-term roofline analysis from compiled XLA artifacts (no hardware).

Per (arch x shape x mesh):
    compute_s    = per-device HLO FLOPs / peak_FLOPs_per_chip
    memory_s     = per-device HLO bytes / HBM bandwidth
    collective_s = per-device collective link bytes / ICI link bandwidth

``cost_analysis()`` of the SPMD-partitioned executable reports PER-DEVICE
flops/bytes (the module is the per-device program), so each term divides by a
single chip's peak — mathematically identical to global/(chips*peak).

collective bytes are parsed from ``compiled.as_text()``: for each collective
op we sum the shape literals on the defining line (operands + result) and
apply a traffic factor (all-reduce: 1.0 of op+res ~= 2S ring traffic;
all-gather/reduce-scatter: 1.0 ~= S; all-to-all/collective-permute: 0.5).
This is napkin-accurate ring accounting, documented in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

# TPU v5e-class hardware constants (per the assignment brief)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(pred|[suf]\d+|bf16|c64|c128)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s+[^=]*\b(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")

_FACTOR = {"all-reduce": 1.0, "all-gather": 1.0, "reduce-scatter": 1.0,
           "all-to-all": 0.5, "collective-permute": 0.5}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic bytes by op kind, from partitioned HLO."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(line))
        out[kind] = out.get(kind, 0.0) + total * _FACTOR[kind]
    return out


@dataclasses.dataclass
class Roofline:
    hlo_flops_per_device: float  # raw cost_analysis (while bodies counted ONCE)
    analytic_flops_global: float  # repro.launch.flops — the real compute term
    bytes_per_device: float
    collective_per_device: float
    coll_breakdown: Dict[str, float]
    peak_mem_per_device: float
    chips: int
    model_flops: float           # 6*N_active*tokens (train) / 2*N_active*tokens
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    useful_ratio: float = 0.0    # MODEL_FLOPS / analytic compiled FLOPs
    roofline_fraction: float = 0.0  # useful compute time / max(term)

    def finalize(self) -> "Roofline":
        self.compute_s = self.analytic_flops_global / (self.chips * PEAK_FLOPS)
        self.memory_s = self.bytes_per_device / HBM_BW
        self.collective_s = self.collective_per_device / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.dominant = max(terms, key=terms.get)
        self.useful_ratio = (self.model_flops / self.analytic_flops_global
                             if self.analytic_flops_global else 0.0)
        # fraction of roofline: time the USEFUL model flops would take at peak
        # vs. the bounding term of the compiled program
        useful_s = self.model_flops / (self.chips * PEAK_FLOPS)
        bound = max(terms.values())
        self.roofline_fraction = useful_s / bound if bound else 0.0
        return self

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def model_flops(cfg, shape) -> float:
    """Useful model FLOPs: 6*N_active*D (train), 2*N_active*D (inference)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: one token/seq


def analyze(compiled, cfg, shape, chips: int,
            hlo_text: Optional[str] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    mem = compiled.memory_analysis()
    peak = (getattr(mem, "temp_size_in_bytes", 0)
            + getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            - getattr(mem, "alias_size_in_bytes", 0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    from repro.launch.flops import step_flops
    return Roofline(
        hlo_flops_per_device=float(cost.get("flops", 0.0)),
        analytic_flops_global=step_flops(cfg, shape),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        collective_per_device=float(sum(coll.values())),
        coll_breakdown=coll,
        peak_mem_per_device=float(peak),
        chips=chips,
        model_flops=model_flops(cfg, shape),
    ).finalize()
