"""Online probe calibration: observed→predicted feedback into admission.

The scheduler is only as good as the resource vectors its probes convey,
and nothing guarantees those stay accurate: a workload whose kernels grow
(longer sequences, bigger batches) silently drifts away from the estimates
admission ranks and reserves by. This module closes the loop:

  * ``CalibrationStore`` keeps per-resource-class EWMA statistics of
    observed/predicted runtime ratio and observed memory high-water,
    keyed by the ORIGINAL probe vector — the same frozen
    ``ResourceVector`` the scheduler's waiter-class memos key by (a grow
    task's class memo adds a host-uid suffix, but that identifies
    placement, not the resource class, and is dropped here).
  * The scheduler's admission path consults the store through a
    ``_calib`` attribute with the exact ``_trace``/``_explain``
    discipline: ``None`` keeps every hook one attribute load, so the
    calibration-off hot path pays nothing (bench_profile gates the
    calibration-ON marginal cost at ≤5% over tracing-on).
  * At the first admission probe the store stamps ``task.probe_vec``
    (the uncorrected prediction — also the class key, so corrected
    vectors never mint new classes or feed their own statistics) and,
    once a class has enough completions, installs ``task.calibrated_vec``
    with the EWMA-scaled ``est_seconds`` and safety-margin memory. At
    ``task_end`` the store records the observation; the statistics fold
    runs in batches off the hot path (every ``fold_batch`` completions,
    at any read, or eagerly when observers are subscribed).

**The memory-safety invariant**: calibration may INFLATE a reservation
(observed high-water × (1 + mem_margin) above the probe's figure) but
NEVER shrinks one below the observed high-water. The default
(``allow_shrink=False``) never shrinks below the probe's own prediction
either; opting into shrinking (``allow_shrink=True``, for workloads whose
probes over-reserve) still floors every corrected footprint at the
class's observed ``hw_max`` — tested directly by
``tests/test_profile.py``.

Duck-typed on ``Task``/``ResourceVector`` (``dataclasses.replace`` on the
frozen vector) so the obs package keeps its no-core-imports rule.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import deque
from typing import Any, Callable, Dict, List, NamedTuple, Optional


class CalObservation(NamedTuple):
    """One completed task folded into the store (the drift feed for
    ``SLOMonitor.for_calibration``)."""
    t: float                      # backend-timeline completion time
    uid: int
    name: str
    predicted_s: float            # the probe's original estimate
    observed_s: Optional[float]   # None when no begin time was stamped
    used_s: float                 # the estimate admission actually used
    hw_bytes: int                 # observed memory high-water
    reserved_bytes: int           # what admission reserved
    calibrated: bool              # was a corrected vector in effect?


class _ClassCal:
    """Mutable per-class record (one resource class = one probe vector)."""

    __slots__ = ("n_run", "n_mem", "ratio_ewma", "hw_max", "hw_ewma",
                 "violations", "err_raw_sum", "err_used_sum", "n_paired",
                 "err_uncal_sum", "n_uncal", "corrected", "dirty")

    def __init__(self) -> None:
        self.n_run = 0            # runtime observations folded in
        self.n_mem = 0            # memory observations folded in
        self.ratio_ewma = 1.0     # EWMA of observed/predicted runtime
        self.hw_max = 0           # max observed memory high-water
        self.hw_ewma = 0.0
        self.violations = 0       # observations with hw > reservation
        # paired error accounting over CALIBRATED observations only: the
        # same completions scored against the raw probe estimate and the
        # corrected one — the ≥2x accuracy gate reads these
        self.err_raw_sum = 0.0
        self.err_used_sum = 0.0
        self.n_paired = 0
        # and the uncalibrated tail (warm-up below min_samples, or a store
        # attached observe-only): raw-probe error with no correction live
        self.err_uncal_sum = 0.0
        self.n_uncal = 0
        # cached corrected vector (``dataclasses.replace`` costs µs — far
        # too hot for the per-admission path): recomputed lazily after any
        # observation dirties the class. Sound because classes are keyed
        # by VALUE — every equal-valued probe vector corrects identically.
        self.corrected: Optional[Any] = None
        self.dirty = True


class CalibrationStore:
    """Per-class EWMA calibration of probe predictions, fed by the
    scheduler's admission/completion hooks (``attach_calibrator``).

    ``alpha``        — EWMA weight of the newest runtime-ratio sample.
    ``min_samples``  — runtime corrections start after this many observed
                       completions of the class (memory inflation starts
                       at the first observation — inflating is always
                       safe; shrinking waits for ``min_samples`` too).
    ``mem_margin``   — corrected memory = observed high-water × (1+margin),
                       floored as the invariant requires.
    ``allow_shrink`` — permit corrected memory below the probe's figure
                       (never below observed high-water).
    ``max_classes``  — bound on tracked classes; overflow observations are
                       counted (``class_overflow``) and dropped.
    ``fold_batch``   — completions buffered before the statistics fold
                       runs (1 = eager). Reads always flush first, and a
                       subscribed observer forces eager folding, so the
                       deferral is visible only as bounded staleness of
                       the corrections on the admission hot path.
    """

    def __init__(self, *, alpha: float = 0.25, min_samples: int = 3,
                 mem_margin: float = 0.05, allow_shrink: bool = False,
                 max_classes: int = 4096, fold_batch: int = 16):
        if not 0.0 < alpha <= 1.0:
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if min_samples < 1:
            raise ValueError("min_samples must be >= 1")
        if mem_margin < 0.0:
            raise ValueError("mem_margin must be >= 0")
        self.alpha = alpha
        self.min_samples = min_samples
        self.mem_margin = mem_margin
        self.allow_shrink = allow_shrink
        self.max_classes = max_classes
        # the completion hook runs under the scheduler lock on the drain
        # hot path, so it only APPENDS (task, t) to this ring; the actual
        # statistics fold runs in batches of ``fold_batch`` (or at any
        # read, or per-completion when observers are subscribed) — same
        # record-cheap/compute-on-read discipline as the Tracer, gated by
        # bench_profile at <=5% over tracing-on
        self.fold_batch = max(fold_batch, 1)
        self._pending: deque = deque()
        self._classes: Dict[Any, _ClassCal] = {}
        self._steps: Dict[int, List[float]] = {}  # dev -> [n, sum_s, ewma]
        self._lock = threading.Lock()
        self._observers: List[Callable[[CalObservation], None]] = []
        self.corrections = 0      # tasks given a calibrated_vec
        self._observations = 0    # completions folded in
        self._violations = 0      # hw > reservation, fleet-wide
        self._class_overflow = 0

    # -- admission-side hook (runs under the scheduler lock) -----------------
    def apply(self, task: Any) -> None:
        """Stamp the original probe vector and, when the class has enough
        history, install the corrected vector. Idempotent per task — the
        call sites guard on ``task.probe_vec is None`` so repeat admission
        probes of a parked waiter pay one attribute load."""
        if task.probe_vec is not None:
            return
        vec = task.resources          # calibrated_vec unset: the raw probe
        task.probe_vec = vec
        cal = self._classes.get(vec)
        if cal is None:
            return
        if cal.dirty:
            cal.corrected = self.corrected_for(vec, cal)
            cal.dirty = False
        if cal.corrected is not None:
            task.calibrated_vec = cal.corrected
            self.corrections += 1

    def corrected_for(self, vec: Any,
                      cal: Optional[_ClassCal] = None) -> Optional[Any]:
        """The corrected vector for ``vec`` given its class history, or
        None when no correction applies yet. Public so tests can check the
        never-below-high-water invariant directly."""
        if cal is None:
            self._flush()
            cal = self._classes.get(vec)
            if cal is None:
                return None
        est = vec.est_seconds
        if cal.n_run >= self.min_samples and est > 0:
            est = vec.est_seconds * cal.ratio_ewma
        hbm = vec.hbm_bytes
        if cal.n_mem > 0:
            need = int(cal.hw_max * (1.0 + self.mem_margin))
            if self.allow_shrink and cal.n_mem >= self.min_samples:
                # shrink permitted — but the floor is the INVARIANT:
                # never below the observed high-water
                hbm = max(need, cal.hw_max)
            else:
                hbm = max(vec.hbm_bytes, need)
        if est == vec.est_seconds and hbm == vec.hbm_bytes:
            return None
        return dataclasses.replace(vec, est_seconds=est, hbm_bytes=hbm)

    # -- completion-side hook (runs under the scheduler lock) ----------------
    def note_end(self, task: Any, now: float) -> None:
        """Record one completed task. The hot path only appends to the
        pending ring — completed tasks are immutable, so the fold can read
        their attributes later. Folding runs every ``fold_batch``
        completions, at any read, or immediately when observers are
        subscribed (the SLO drift stream wants timely delivery)."""
        self._pending.append((task, now))
        if self._observers or len(self._pending) >= self.fold_batch:
            self._flush()

    def _flush(self) -> None:
        """Drain the pending ring into the class statistics. Observers
        fire outside the store lock, in completion order."""
        dq = self._pending
        if not dq:
            return
        fired: List[CalObservation] = []
        with self._lock:
            while dq:
                task, now = dq.popleft()
                self._fold_one(task, now, fired)
        for ob in fired:
            for fn in self._observers:
                fn(ob)

    def _fold_one(self, task: Any, now: float,
                  fired: List[CalObservation]) -> None:
        """Fold one observation into its class (under the store lock):
        memory high-water always; runtime ratio only for tasks that
        actually began (``start_t`` stamped by the backend) and are not
        grow deltas (a decode slot's residency is batch membership, not
        predicted work)."""
        pv = task.probe_vec
        if pv is None:
            # completed without an admission probe (bind_resident loop
            # hosts): learn memory under the raw vector, skip runtime
            pv = task.resources
        tv = task.true_vec
        hw = tv.hbm_bytes if tv is not None else pv.hbm_bytes
        used = task.resources
        obs_s: Optional[float] = None
        start = task.start_t
        grow = getattr(task, "grow_hosts", None)
        self._observations += 1
        cal = self._classes.get(pv)
        if cal is None:
            if len(self._classes) >= self.max_classes:
                self._class_overflow += 1
                return
            cal = _ClassCal()
            self._classes[pv] = cal
        cal.n_mem += 1
        if hw > cal.hw_max:
            cal.hw_max = hw
        cal.hw_ewma = (float(hw) if cal.n_mem == 1 else
                       self.alpha * hw
                       + (1.0 - self.alpha) * cal.hw_ewma)
        if hw > used.hbm_bytes:
            cal.violations += 1
            self._violations += 1
        cal.dirty = True                # cached correction is now stale
        if start >= 0 and not grow and pv.est_seconds > 0:
            dur = now - start
            if dur >= 0:
                obs_s = dur
                ratio = dur / pv.est_seconds
                cal.ratio_ewma = (ratio if cal.n_run == 0 else
                                  self.alpha * ratio
                                  + (1.0 - self.alpha) * cal.ratio_ewma)
                cal.n_run += 1
                err_raw = abs(dur - pv.est_seconds)
                if task.calibrated_vec is not None:
                    cal.err_raw_sum += err_raw
                    cal.err_used_sum += abs(dur - used.est_seconds)
                    cal.n_paired += 1
                else:
                    cal.err_uncal_sum += err_raw
                    cal.n_uncal += 1
        if self._observers:
            fired.append(CalObservation(
                now, task.uid, task.name, pv.est_seconds, obs_s,
                used.est_seconds, hw, used.hbm_bytes,
                task.calibrated_vec is not None))

    # -- serving-side hook (per-decode-step TPOT attribution) ----------------
    def note_step(self, device: int, predicted_s: float,
                  observed_s: float) -> None:
        """One decode-loop step: observed inter-token gap vs the model's
        predicted step time, EWMA'd per device (serve.engine feeds this)."""
        with self._lock:
            st = self._steps.get(device)
            if st is None:
                st = [0.0, 0.0, 1.0]
                self._steps[device] = st
            st[0] += 1
            st[1] += observed_s
            if predicted_s > 0:
                r = observed_s / predicted_s
                st[2] = r if st[0] == 1 else \
                    self.alpha * r + (1.0 - self.alpha) * st[2]

    # -- observers ------------------------------------------------------------
    def on_observe(self, fn: Callable[[CalObservation], None]) -> None:
        """Subscribe to completion observations (``SLOMonitor.
        for_calibration`` wires its drift stream here)."""
        self._observers.append(fn)

    # -- reading ---------------------------------------------------------------
    # every read-side entry flushes the pending ring first, so deferred
    # folding is invisible to callers (bounded staleness exists only
    # between a completion and the next read/admission-batch boundary)

    @property
    def observations(self) -> int:
        """Completions folded in."""
        self._flush()
        return self._observations

    @property
    def violations(self) -> int:
        """Observed high-water above the reservation, fleet-wide."""
        self._flush()
        return self._violations

    @property
    def class_overflow(self) -> int:
        self._flush()
        return self._class_overflow

    def ratio_ewma(self, vec: Any) -> Optional[float]:
        self._flush()
        cal = self._classes.get(vec)
        return cal.ratio_ewma if cal is not None and cal.n_run else None

    def highwater(self, vec: Any) -> Optional[int]:
        self._flush()
        cal = self._classes.get(vec)
        return cal.hw_max if cal is not None and cal.n_mem else None

    def rows(self, limit: int = 8) -> List[Dict[str, Any]]:
        """Per-class accuracy rows for dashboards (launch.top), most
        observed classes first."""
        self._flush()
        with self._lock:
            items = sorted(self._classes.items(),
                           key=lambda kv: -(kv[1].n_run + kv[1].n_mem))
            out = []
            for vec, cal in items[:limit]:
                out.append({
                    "est_s": vec.est_seconds,
                    "hbm_gb": vec.hbm_bytes / 1e9,
                    "n": cal.n_run,
                    "ratio": cal.ratio_ewma if cal.n_run else float("nan"),
                    "hw_gb": cal.hw_max / 1e9,
                    "mae_raw_s": (cal.err_raw_sum + cal.err_uncal_sum)
                    / max(cal.n_paired + cal.n_uncal, 1),
                    "mae_used_s": (cal.err_used_sum + cal.err_uncal_sum)
                    / max(cal.n_paired + cal.n_uncal, 1),
                    "violations": cal.violations,
                })
            return out

    def accuracy_report(self) -> Dict[str, Any]:
        """The calibration scorecard: paired mean-absolute est_seconds
        error (raw probe vs corrected, over the SAME calibrated
        completions), the uncalibrated warm-up tail, memory violations
        (must stay 0 under the invariant), and serve-step attribution."""
        self._flush()
        with self._lock:
            n_paired = sum(c.n_paired for c in self._classes.values())
            raw = sum(c.err_raw_sum for c in self._classes.values())
            used = sum(c.err_used_sum for c in self._classes.values())
            n_uncal = sum(c.n_uncal for c in self._classes.values())
            uncal = sum(c.err_uncal_sum for c in self._classes.values())
            steps = {
                dev: {"steps": int(st[0]),
                      "observed_mean_s": st[1] / st[0] if st[0] else 0.0,
                      "err_ratio_ewma": st[2] - 1.0}
                for dev, st in self._steps.items()}
        mae_raw = raw / n_paired if n_paired else 0.0
        mae_used = used / n_paired if n_paired else 0.0
        return {
            "classes": len(self._classes),
            "observations": self._observations,
            "corrections": self.corrections,
            "violations": self._violations,
            "class_overflow": self._class_overflow,
            "paired": {
                "n": n_paired,
                "mae_raw_s": mae_raw,
                "mae_used_s": mae_used,
                # the acceptance-gate statistic: how many times smaller the
                # corrected estimates' error is than the raw probes', on
                # the same completions
                "improvement": (mae_raw / mae_used if mae_used > 0
                                else float("inf") if mae_raw > 0 else 1.0),
            },
            "uncalibrated": {"n": n_uncal,
                             "mae_s": uncal / n_uncal if n_uncal else 0.0},
            "serve_steps": steps,
        }

    def __repr__(self) -> str:
        return (f"CalibrationStore(classes={len(self._classes)}, "
                f"observations={self.observations}, "
                f"corrections={self.corrections}, "
                f"violations={self.violations})")


def attach_calibrator(sched: Any,
                      store: Optional[CalibrationStore] = None
                      ) -> CalibrationStore:
    """Point every calibration hook of ``sched`` at ``store`` (building a
    default one if None). Mirrors ``attach_tracer``: a flat/gang/preemptive
    scheduler gets ``_calib`` set directly; a ``ShardedScheduler`` fans out
    to every shard — all shards SHARE the store, so a class observed on one
    pod corrects admissions on every pod (the store's own lock covers the
    cross-shard writes)."""
    if store is None:
        store = CalibrationStore()
    shards = getattr(sched, "shards", None)
    if shards is not None:
        sched._calib = store           # wrapper-level discovery (dashboards)
        for sh in shards:
            sh._calib = store
    else:
        sched._calib = store
    return store


class CalibratedScheduler:
    """Ergonomic wrapper: ``CalibratedScheduler(sched)`` attaches a
    ``CalibrationStore`` and delegates everything else to the wrapped
    scheduler — drop-in wherever a scheduler is expected::

        sched = CalibratedScheduler(MGBAlg3Scheduler(8))
        cluster = Cluster(sched, backend="sim", trace=True)
        ...
        sched.store.accuracy_report()

    The mechanism lives in the scheduler's ``_calib`` hooks (so ``Cluster
    (calibrate=True)`` and ``attach_calibrator`` work on a bare
    scheduler); this class is the composition-style spelling. Attribute
    reads and writes forward to the inner scheduler, so backend wiring
    (``_clock`` repointing, ``shed_expired``, tracer attachment) lands on
    the real object.
    """

    _OWN = frozenset({"inner", "store"})

    def __init__(self, inner: Any,
                 store: Optional[CalibrationStore] = None, **store_kw):
        object.__setattr__(self, "inner", inner)
        object.__setattr__(
            self, "store",
            store if store is not None else CalibrationStore(**store_kw))
        attach_calibrator(inner, self.store)

    def __getattr__(self, name: str) -> Any:
        return getattr(object.__getattribute__(self, "inner"), name)

    def __setattr__(self, name: str, value: Any) -> None:
        if name in self._OWN:
            object.__setattr__(self, name, value)
        else:
            setattr(object.__getattribute__(self, "inner"), name, value)

    def __repr__(self) -> str:
        return f"CalibratedScheduler({self.inner!r}, {self.store!r})"
