"""Event-sourced observability plane for the scheduler/executor stack.

One frozen event schema (``obs.events``) covers the full task lifecycle
across every scheduler class and both backends; a bounded lock-light
ring-buffer ``Tracer`` collects it with monotonic sequence numbers on the
backend's own timeline (wall monotonic live, virtual clock simulated).

  * ``obs.events``  — the schema, the ``Tracer``, and ``attach_tracer``
  * ``obs.explain`` — per-task decision-verdict rings (why parked, who
    evicted it, at what cost) and ``attach_explainer``
  * ``obs.export``  — Chrome/Perfetto trace-event JSON (device occupancy
    tracks, queue-depth counters, cross-device flow arrows, profiling
    counter tracks)
  * ``obs.metrics`` — log-bucketed histograms + counter/gauge registry
  * ``obs.profile`` — per-task observed-vs-predicted attribution joined
    from the event stream: runtime error, memory high-water vs reserved,
    queueing-delay decomposition, per-device occupancy timelines
  * ``obs.calibrate`` — online probe calibration: per-class EWMA runtime
    correction + safety-margin memory fed back into admission
    (``attach_calibrator`` / ``CalibratedScheduler``), never shrinking a
    reservation below the observed high-water
  * ``obs.replay``  — flight recorder + sim/live parity differ +
    lifecycle state-machine validator
  * ``obs.slo``     — rolling-window SLO burn rates, degradation alerts
    (the paper's 2.5% envelope, live), probe-drift alerts, Prometheus
    text exposition
  * ``obs.whatif``  — counterfactual replay of a recorded trace under
    alternate scheduler policies, with decision-level divergence diffs

The subsystem imports nothing from ``repro.core`` at module load so the
scheduler base can import it without cycles (``obs.whatif`` imports the
simulator lazily inside ``replay``), and a ``None`` tracer/explainer
keeps every emission site a single attribute load (the PR-6 hot-path
budget survives tracing disabled).
"""
from repro.obs import (  # noqa: F401
    calibrate, events, explain, export, metrics, profile, replay, slo,
    whatif,
)
from repro.obs.calibrate import (  # noqa: F401
    CalibratedScheduler, CalibrationStore, attach_calibrator,
)
from repro.obs.events import Event, Tracer, attach_tracer  # noqa: F401
from repro.obs.explain import (  # noqa: F401
    Explainer, Verdict, attach_explainer, format_verdicts,
)
from repro.obs.profile import (  # noqa: F401
    Profiler, TaskProfile, device_occupancy, format_profile,
    profiles_from_events,
)
