"""Event-sourced observability plane for the scheduler/executor stack.

One frozen event schema (``obs.events``) covers the full task lifecycle
across every scheduler class and both backends; a bounded lock-light
ring-buffer ``Tracer`` collects it with monotonic sequence numbers on the
backend's own timeline (wall monotonic live, virtual clock simulated).

  * ``obs.events``  — the schema, the ``Tracer``, and ``attach_tracer``
  * ``obs.export``  — Chrome/Perfetto trace-event JSON (device occupancy
    tracks, queue-depth counters, cross-device flow arrows)
  * ``obs.metrics`` — log-bucketed histograms + counter/gauge registry
  * ``obs.replay``  — flight recorder + sim/live parity differ +
    lifecycle state-machine validator

The subsystem imports nothing from ``repro.core`` so the scheduler base can
import it without cycles, and a ``None`` tracer keeps every emission site a
single attribute load (the PR-6 hot-path budget survives tracing disabled).
"""
from repro.obs import events, export, metrics, replay  # noqa: F401
from repro.obs.events import Event, Tracer, attach_tracer  # noqa: F401
