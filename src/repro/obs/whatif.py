"""Counterfactual what-if replay: re-run a recorded trace under an
alternate scheduling policy and report what would have changed.

The SUBMIT events both backends emit carry the full submission context
(``obs.events.submit_data``): job identity, priority, absolute deadline,
gang label, and the complete resource vector. That makes a recorded
stream a *replayable artifact*: ``reconstruct`` rebuilds the submission
trace (arrival times, per-job task sequences, fleet faults), ``replay``
re-runs it through the discrete-event simulator under any scheduler
class / policy knobs, and ``compare`` reports the makespan /
deadline-met / p99-queueing / eviction deltas plus the FIRST divergent
decision (via ``obs.replay.diff_streams``) for each candidate policy.

Fidelity contract: a round-trip under the SAME policy (same scheduler
factory, workers, shedding and preemption settings) reproduces the
original admission/eviction sequence exactly — the property the seeded
test battery asserts on overload, gang and device-death traces. Two
scope notes:

  * fleet faults are re-injected *between* events at their recorded
    times; a task completing at exactly the fault's timestamp ordered
    after the death in the original (the scheduled-failure hook fires
    before same-instant completions) but before it in replay. Measure
    zero for real traces; avoid deadlines colliding exactly with
    injected fault times if byte-exact round-trips matter;
  * decode-slot GROW deltas (``grow_hosts``) are rebuilt as ordinary
    tasks — serving-engine traces replay with slot joins treated as
    admissions, which preserves ordering but not the grow accounting.

Everything core-side is imported lazily so the obs package stays
importable without ``repro.core``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs import events as ev
from repro.obs import metrics as mt
from repro.obs import replay as rp

# ResourceVector fields carried by every enriched SUBMIT event
VEC_FIELDS = ("hbm_bytes", "flops", "bytes_accessed", "collective_bytes",
              "est_seconds", "core_demand", "bw_demand", "chips")


@dataclasses.dataclass
class SubmittedTask:
    """One task of a recorded submission (from one SUBMIT event)."""
    name: str
    t: float                       # when ITS submit fired (tasks sequence)
    priority: int
    deadline_t: Optional[float]
    gang_id: Optional[str]
    vector: Dict[str, Any]         # VEC_FIELDS -> value


@dataclasses.dataclass
class Submission:
    """One job's recorded submission: ordered tasks, arrival = first
    task's SUBMIT time (later tasks submit as their predecessors finish;
    the simulator reproduces that sequencing by itself)."""
    job: str
    job_uid: int
    t: float
    seq: int                       # first SUBMIT's seq (same-t tiebreak)
    tasks: List[SubmittedTask] = dataclasses.field(default_factory=list)

    @property
    def priority(self) -> int:
        return self.tasks[0].priority if self.tasks else 0

    @property
    def deadline_t(self) -> Optional[float]:
        return self.tasks[0].deadline_t if self.tasks else None


@dataclasses.dataclass
class FleetOp:
    """A recorded fleet fault: device death or revival."""
    t: float
    seq: int
    kind: str                      # ev.MARK_DEAD | ev.REVIVE
    device: int                    # global flat index (mark_dead routes it)


@dataclasses.dataclass
class SubmissionTrace:
    """The replayable reconstruction of a recorded stream."""
    submissions: List[Submission]
    fleet_ops: List[FleetOp]

    def timeline(self) -> List[Tuple[float, int, object]]:
        """Submissions and fleet ops merged in recorded order (t, then
        original seq — so a death and an arrival at one instant replay
        in the order they actually happened)."""
        rows: List[Tuple[float, int, object]] = \
            [(s.t, s.seq, s) for s in self.submissions]
        rows += [(op.t, op.seq, op) for op in self.fleet_ops]
        rows.sort(key=lambda r: (r[0], r[1]))
        return rows


def reconstruct(events: Sequence[ev.Event]) -> SubmissionTrace:
    """Rebuild the submission trace from a recorded stream. Requires the
    enriched SUBMIT payload (any stream recorded since the introspection
    plane); raises on bare legacy SUBMIT events rather than replaying a
    half-reconstructed workload."""
    subs: Dict[Any, Submission] = {}
    ops: List[FleetOp] = []
    for e in events:
        if e.kind == ev.SUBMIT:
            d = e.data or {}
            if "hbm_bytes" not in d:
                raise ValueError(
                    f"SUBMIT event for {e.name!r} (seq {e.seq}) lacks the "
                    f"resource-vector payload — the stream predates the "
                    f"replayable SUBMIT enrichment and cannot be "
                    f"reconstructed")
            key = d.get("job_uid", d.get("job"))
            sub = subs.get(key)
            if sub is None:
                sub = subs[key] = Submission(
                    job=d.get("job", e.name), job_uid=d.get("job_uid", -1),
                    t=e.t, seq=e.seq)
            sub.tasks.append(SubmittedTask(
                name=e.name, t=e.t,
                priority=d.get("priority", 0),
                deadline_t=d.get("deadline_t"),
                gang_id=d.get("gang_id"),
                vector={k: d[k] for k in VEC_FIELDS}))
        elif e.kind in (ev.MARK_DEAD, ev.REVIVE) and e.device >= 0:
            ops.append(FleetOp(e.t, e.seq, e.kind, e.device))
    return SubmissionTrace(sorted(subs.values(),
                                  key=lambda s: (s.t, s.seq)), ops)


def _build_job(sub: Submission, *, use_priorities: bool,
               use_deadlines: bool):
    """Rebuild a ``repro.core.task.Job`` from a recorded submission,
    PRE-STAMPED with the recorded priority / absolute deadline (submit
    with both overrides None keeps the stamps — no clock re-derivation,
    so the round-trip replays the exact recorded deadline_t)."""
    from repro.core.task import Job, ResourceVector, Task, UnitTask
    tasks = []
    for st in sub.tasks:
        vec = ResourceVector(**st.vector)
        tasks.append(Task(
            units=[UnitTask(fn=None,
                            memobjs=frozenset({st.name or "buf"}),
                            resources=vec, name=st.name)],
            name=st.name, gang_id=st.gang_id))
    return Job(tasks=tasks, name=sub.job,
               priority=sub.priority if use_priorities else 0,
               deadline_t=sub.deadline_t if use_deadlines else None)


@dataclasses.dataclass
class ReplayResult:
    """One counterfactual leg: the replayed stream + its headline
    metrics (same definitions the compare() deltas use)."""
    policy: str
    events: List[ev.Event]
    stats: Dict[str, float]          # Cluster.stats() of the replay
    makespan_s: float
    deadline_met: float              # fraction of deadlined jobs met
    deadline_jobs: int
    p99_queueing_s: float
    evictions: int


def replay(source, scheduler_factory: Callable[[], Any], *,
           policy: str = "replay", workers: Optional[int] = None,
           shed_late: bool = False, preempt: Optional[bool] = None,
           use_priorities: bool = True, use_deadlines: bool = True,
           trace_capacity: int = 1 << 16,
           time_limit: float = 1e7) -> ReplayResult:
    """Re-run a recorded stream (or a pre-built ``SubmissionTrace``)
    through the simulator under ``scheduler_factory()``.

    ``use_priorities=False`` flattens every job to class 0 (FIFO within
    the queue); ``use_deadlines=False`` strips deadlines (disables EDF
    ordering AND shedding). The recorded fleet faults are re-injected at
    their recorded times regardless of policy."""
    from repro.core.cluster import Cluster
    trace = source if isinstance(source, SubmissionTrace) \
        else reconstruct(source)
    tracer = ev.Tracer(capacity=trace_capacity)
    cluster = Cluster(scheduler_factory(), workers=workers, backend="sim",
                      shed_late=shed_late, preempt=preempt, trace=tracer)
    for t, _seq, item in trace.timeline():
        cluster.run_until(t)
        if isinstance(item, FleetOp):
            if item.kind == ev.MARK_DEAD:
                cluster.inject_failure(item.device)
            else:
                cluster.revive(item.device)
        else:
            cluster.submit(_build_job(item, use_priorities=use_priorities,
                                      use_deadlines=use_deadlines))
    cluster._sim.drain(time_limit)
    events = tracer.events()
    met, n_dl = _deadline_met(trace, events)
    reg = mt.metrics_from_events(events)
    return ReplayResult(
        policy=policy, events=events, stats=cluster.stats(),
        makespan_s=_makespan(events),
        deadline_met=met, deadline_jobs=n_dl,
        p99_queueing_s=reg.hist("queueing_delay_s").quantile(0.99),
        evictions=reg.counter(f"events.{ev.EVICT}").snapshot())


# -- headline metrics (same definitions for recorded + replayed legs) --------

def _makespan(events: Sequence[ev.Event]) -> float:
    if not events:
        return 0.0
    ts = [e.t for e in events]
    return max(ts) - min(ts)


def _deadline_met(trace: SubmissionTrace,
                  events: Sequence[ev.Event]) -> Tuple[float, int]:
    """Fraction of deadlined jobs whose every task ENDed by the deadline.
    Matched by task NAME (uids are fresh per leg), so distinct task
    names per job make the report exact."""
    last_end: Dict[str, float] = {}
    failed: set = set()
    for e in events:
        if e.kind == ev.END:
            last_end[e.name] = e.t
        elif e.kind in (ev.SHED, ev.CRASH):
            failed.add(e.name)
    met = n = 0
    for sub in trace.submissions:
        dl = sub.deadline_t
        if dl is None:
            continue
        n += 1
        names = [st.name for st in sub.tasks]
        if any(nm in failed for nm in names):
            continue
        if all(nm in last_end and last_end[nm] <= dl + 1e-9
               for nm in names):
            met += 1
    return (met / n if n else 1.0), n


def summarize(events: Sequence[ev.Event],
              trace: Optional[SubmissionTrace] = None) -> Dict[str, float]:
    """Headline metrics of a stream (recorded or replayed): the baseline
    row of a what-if report."""
    trace = trace or reconstruct(events)
    reg = mt.metrics_from_events(events)
    met, n_dl = _deadline_met(trace, events)
    return {
        "makespan_s": _makespan(events),
        "deadline_met": met,
        "deadline_jobs": n_dl,
        "p99_queueing_s": reg.hist("queueing_delay_s").quantile(0.99),
        "evictions": reg.counter(f"events.{ev.EVICT}").snapshot(),
    }


def compare(events: Sequence[ev.Event],
            policies: Dict[str, Dict[str, Any]], *,
            scheduler_factory: Callable[[], Any],
            workers: Optional[int] = None, shed_late: bool = False,
            preempt: Optional[bool] = None,
            diff_kinds: Sequence[str] = (ev.ADMIT, ev.GROW, ev.EVICT)
            ) -> Dict[str, Any]:
    """Replay a recorded stream under each candidate policy and report,
    per policy: the headline metrics, their deltas against the recorded
    baseline, and the first decision where the counterfactual diverged
    from what actually happened (None = identical decisions).

    ``policies`` maps a display name to ``replay()`` keyword overrides,
    e.g. ``{"fifo": {"use_priorities": False, "use_deadlines": False},
    "edf": {"use_priorities": False, "use_deadlines": True}}``. The
    scheduler factory and backend knobs default to one shared setting —
    pass per-policy ``scheduler_factory``/``shed_late``/``preempt``
    overrides inside the policy dict to vary those too."""
    trace = reconstruct(events)
    base = summarize(events, trace)
    report: Dict[str, Any] = {"baseline": base, "policies": {}}
    for name, overrides in policies.items():
        kw = {"workers": workers, "shed_late": shed_late,
              "preempt": preempt, "scheduler_factory": scheduler_factory}
        kw.update(overrides)
        factory = kw.pop("scheduler_factory")
        res = replay(trace, factory, policy=name, **kw)
        div = rp.diff_streams(events, res.events, kinds=diff_kinds)
        leg = {
            "makespan_s": res.makespan_s,
            "deadline_met": res.deadline_met,
            "deadline_jobs": res.deadline_jobs,
            "p99_queueing_s": res.p99_queueing_s,
            "evictions": res.evictions,
            "delta": {
                "makespan_s": res.makespan_s - base["makespan_s"],
                "deadline_met": res.deadline_met - base["deadline_met"],
                "p99_queueing_s":
                    res.p99_queueing_s - base["p99_queueing_s"],
                "evictions": res.evictions - base["evictions"],
            },
            "first_divergence": str(div) if div is not None else None,
        }
        report["policies"][name] = leg
    return report
